"""Paper Figure 6 in miniature: sweep registered mechanisms over one trace.

    PYTHONPATH=src python examples/mechanism_sweep.py [--jobs 400]
    PYTHONPATH=src python examples/mechanism_sweep.py --mechanisms 'BASE,CUA&STEAL'
    PYTHONPATH=src python examples/mechanism_sweep.py --scenarios 'W1,W5,bursty-od'

Runs through repro.core.experiment.Experiment (process fan-out), so the
third-party STEAL/POOL policies from the Wagomu port sweep alongside the
paper's six mechanisms.  With --scenarios, the sweep spans registry-named
scenario presets (see docs/workloads.md) instead of one WorkloadConfig.
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import MECHANISMS, Experiment, WorkloadConfig, get_scenario

DEFAULT_MECHS = ("BASE",) + MECHANISMS + ("CUA&STEAL", "CUA&POOL")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=400)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--mix", default="W5")
    ap.add_argument("--mechanisms", default=",".join(DEFAULT_MECHS),
                    help="comma-separated registered mechanism strings")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario preset names to sweep "
                         "instead of a single synthetic trace")
    ap.add_argument("--trace", default=os.path.join(
                        os.path.dirname(__file__), "..", "tests", "data",
                        "sample.swf"),
                    help="SWF file for the trace-replay preset")
    ap.add_argument("--serial", action="store_true",
                    help="disable the multiprocessing fan-out")
    args = ap.parse_args()
    if args.scenarios:
        def preset(name):
            if name == "trace-replay":  # the only preset needing a file
                return get_scenario(name, trace=args.trace)
            sc = get_scenario(name)
            if sc.source != "theta":
                return sc  # non-theta preset: its factory owns the params
            return get_scenario(name, n_nodes=4392, n_jobs=args.jobs,
                                horizon_days=21.0, target_load=1.15)
        workloads = [preset(name) for name in args.scenarios.split(",")]
        label = f"scenarios={args.scenarios}"
    else:
        workloads = [WorkloadConfig(n_nodes=4392, n_jobs=args.jobs,
                                    horizon_days=21.0, target_load=1.15,
                                    notice_mix=args.mix)]
        label = f"mix={args.mix}"
    exp = Experiment(mechanisms=args.mechanisms.split(","),
                     workloads=workloads,
                     seeds=(args.seed,), processes=1 if args.serial else None)
    result = exp.run()
    hdr = (f"{'mechanism':10s} {'workload':>12s} {'turn_h':>7s} "
           f"{'rigid_h':>8s} {'mall_h':>7s} "
           f"{'util':>6s} {'instant':>8s} {'pre_r':>6s} {'pre_m':>6s}")
    print(f"trace: {args.jobs} jobs, {label}\n{hdr}")
    for run in result:
        m = run.metrics
        wl = run.spec.workload
        wname = wl.label if hasattr(wl, "label") else wl.notice_mix
        print(f"{run.spec.mechanism:10s} {wname:>12s} {m.avg_turnaround_h:7.1f} "
              f"{m.avg_turnaround_rigid_h:8.1f} "
              f"{m.avg_turnaround_malleable_h:7.1f} "
              f"{m.system_utilization:6.3f} {m.od_instant_start_rate:8.2f} "
              f"{m.preemption_ratio_rigid:6.2f} "
              f"{m.preemption_ratio_malleable:6.2f}")


if __name__ == "__main__":
    main()
