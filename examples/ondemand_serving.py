"""On-demand serving through the scheduler service's front door.

    PYTHONPATH=src python examples/ondemand_serving.py

This is the execution payload of the paper's *on-demand* job class: a
burst of requests arrives, must start instantly, runs batched greedy
decoding, reports first-token and completion latencies.

Instead of calling ServeEngine directly, the bursts are admitted as
ONDEMAND JobSpecs through an AdmissionQueue; the live scheduler service
(docs/service.md) decides when each starts against its node ledger and
a Launcher turns every start decision into a real ServeEngine batch.
The request plan comes from repro.service.plan_requests, so a shadow
(dryrun) replay of the identical trace plans the identical batch.
"""
import time

import numpy as np
import jax

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.job import JobType
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.service import (AdmissionQueue, Launcher, SchedulerService,
                           ServiceConfig, SloPolicy, plan_requests)
from repro.serving import Request, ServeEngine


def build_requests(job, vocab):
    """Materialize the deterministic request plan as engine Requests."""
    reqs = []
    for p in plan_requests(job, vocab=vocab):
        rng = np.random.default_rng(p["rid"])
        reqs.append(Request(
            rid=p["rid"],
            prompt=rng.integers(0, vocab, p["prompt_len"], dtype=np.int32),
            max_new_tokens=p["max_new_tokens"]))
    return reqs


class ServeLauncher(Launcher):
    """Execute on-demand start decisions as ServeEngine batches."""

    def __init__(self, engine: ServeEngine, vocab: int):
        self.engine = engine
        self.vocab = vocab
        self.batches = []                 # (jid, requests, wall_s)

    def start_job(self, job, size):
        if job.jtype is not JobType.ONDEMAND:
            return
        reqs = build_requests(job, self.vocab)
        t0 = time.monotonic()
        self.engine.serve_batch(reqs)
        self.batches.append((job.jid, reqs, time.monotonic() - t0))


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv=2, d_ff=1024, vocab=4096,
                      tie_embeddings=True, param_dtype="float32",
                      compute_dtype="float32", attn_block_q=64,
                      attn_block_kv=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=256)
    launcher = ServeLauncher(engine, cfg.vocab)

    # two bursts through the service's admission queue: the second is
    # announced 2 s ahead, so notice-aware mechanisms (CUA) see it coming
    queue = AdmissionQueue()
    queue.submit_inference(nodes=8, hold_s=5.0)
    queue.submit_inference(nodes=4, hold_s=3.0, submit_time=2.0,
                           notice_lead_s=2.0)
    queue.close()

    # the launcher serves inline, so each event batch's latency includes
    # real model time — the 10 ms decision bound applies to shadow mode
    # (DryrunLauncher), not to a live backend executing inference
    svc = SchedulerService(
        ServiceConfig(n_nodes=8, mechanism="CUA&SPAA",
                      slo=SloPolicy(decision_p99_ms=30_000.0)),
        launcher=launcher)
    rep = svc.run_live(queue)

    for jid, reqs, wall in launcher.batches:
        print(f"on-demand job {jid}: {len(reqs)} requests "
              f"(prompt lens {[len(r.prompt) for r in reqs]}) "
              f"served in {wall:.2f}s")
        for r in reqs:
            ttfb = (r.first_token_at - r.submitted_at) * 1e3
            total = (r.done_at - r.submitted_at) * 1e3
            print(f"  req {r.rid}: {len(r.tokens_out)} tokens, "
                  f"ttfb={ttfb:.0f}ms total={total:.0f}ms "
                  f"head={r.tokens_out[:5]}")
    n_tok = sum(len(r.tokens_out) for _, reqs, _ in launcher.batches
                for r in reqs)
    print(f"service drained: {rep.n_jobs} jobs, {rep.n_decisions} decisions, "
          f"{n_tok} tokens, decision p99="
          f"{rep.latency['p99_ms']:.2f}ms, slo_ok={rep.ok}")
    print("decision log:")
    for row in svc.log.rows:
        det = {k: v for k, v in row.items()
               if k not in ("wall", "mono", "latency_ms")}
        print("  ", det)

    # determinism check: replaying the same plan gives the same greedy
    # outputs (and a shadow replay of this trace plans the same batch)
    jid0, reqs0, _ = launcher.batches[0]
    again = [Request(rid=r.rid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs0]
    engine.serve_batch(again)
    assert all(a.tokens_out == b.tokens_out for a, b in zip(reqs0, again)), \
        "greedy decode must be deterministic"
    print("determinism check passed")


if __name__ == "__main__":
    main()
