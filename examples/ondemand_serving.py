"""On-demand serving: batched prefill+decode through the ServeEngine.

    PYTHONPATH=src python examples/ondemand_serving.py

This is the execution payload of the paper's *on-demand* job class: a
burst of requests arrives, must start instantly, runs batched greedy
decoding, reports first-token and completion latencies.
"""
import time

import numpy as np
import jax

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serving import Request, ServeEngine


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv=2, d_ff=1024, vocab=4096,
                      tie_embeddings=True, param_dtype="float32",
                      compute_dtype="float32", attn_block_q=64,
                      attn_block_kv=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=256)

    rng = np.random.default_rng(0)
    burst = [Request(rid=i,
                     prompt=rng.integers(0, cfg.vocab, rng.integers(8, 64),
                                         dtype=np.int32),
                     max_new_tokens=24)
             for i in range(8)]
    print(f"burst of {len(burst)} on-demand requests "
          f"(prompt lens {[len(r.prompt) for r in burst]})")
    t0 = time.time()
    engine.serve_batch(burst)
    for r in burst:
        ttfb = (r.first_token_at - r.submitted_at) * 1e3
        total = (r.done_at - r.submitted_at) * 1e3
        print(f"req {r.rid}: {len(r.tokens_out)} tokens, "
              f"ttfb={ttfb:.0f}ms total={total:.0f}ms "
              f"head={r.tokens_out[:5]}")
    n_tok = sum(len(r.tokens_out) for r in burst)
    print(f"batch done: {n_tok} tokens in {time.time()-t0:.2f}s")
    # determinism check: same batch, same greedy outputs
    burst2 = [Request(rid=r.rid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens) for r in burst]
    engine.serve_batch(burst2)
    assert all(a.tokens_out == b.tokens_out for a, b in zip(burst, burst2)), \
        "greedy decode must be deterministic"
    print("determinism check passed")


if __name__ == "__main__":
    main()
