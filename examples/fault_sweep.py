"""Fault injection in miniature: MTBF sweep over a hybrid workload.

    PYTHONPATH=src python examples/fault_sweep.py [--jobs 150]
    PYTHONPATH=src python examples/fault_sweep.py --mtbf 24,168,720
    PYTHONPATH=src python examples/fault_sweep.py --out results/faults/mtbf_sweep.json

Sweeps node MTBF (exp-mtbf model, fixed MTTR) over a bursty on-demand
scenario and prints, per point: failures observed, running-job
interruptions, work lost to restarts, goodput (completed useful work
over delivered up-capacity), and on-demand turnaround — the paper's
responsiveness lens applied to a flaky machine.  A perfect-machine
baseline row anchors the sweep.

Everything is deterministic: same spec -> job-for-job identical records
(the records_sha256 column), which is what lets CI gate on these cells.
See docs/faults.md for the model semantics.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import SimConfig, Simulator  # noqa: E402
from repro.core.metrics import collect, records_sha256  # noqa: E402
from repro.core.workloads import get_scenario  # noqa: E402


def run_cell(jobs, n_nodes, mechanism, faults):
    sim = Simulator(SimConfig(n_nodes=n_nodes, mechanism=mechanism,
                              faults=faults), list(jobs))
    recs = sim.run()
    m = collect(sim)
    return {
        "fault_spec": faults or "none",
        "records_sha256": records_sha256(recs),
        "n_node_failures": m.n_node_failures or 0,
        "n_interruptions": m.n_interruptions or 0,
        "lost_work_node_h": round(m.lost_work_node_h or 0.0, 2),
        "goodput": None if m.goodput is None else round(m.goodput, 4),
        "utilization": round(m.system_utilization, 4),
        "od_turnaround_h": round(m.avg_turnaround_od_h, 4),
        "avg_turnaround_h": round(m.avg_turnaround_h, 4),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=150)
    ap.add_argument("--seed", type=int, default=2)
    ap.add_argument("--scenario", default="bursty-od")
    ap.add_argument("--mechanism", default="CUA&SPAA")
    ap.add_argument("--mtbf", default="40,160,720",
                    help="comma-separated node MTBF points in hours")
    ap.add_argument("--mttr", type=float, default=2.0)
    ap.add_argument("--horizon-days", type=float, default=5.0)
    ap.add_argument("--out", default=None,
                    help="also write rows to this JSON path")
    args = ap.parse_args(argv)

    jobs, n_nodes = get_scenario(args.scenario,
                                 n_jobs=args.jobs).realize(args.seed)
    print(f"# {args.scenario}: {len(jobs)} jobs on {n_nodes} nodes, "
          f"mechanism {args.mechanism}, mttr={args.mttr}h")
    hdr = (f"{'mtbf_h':>8} {'failures':>9} {'interrupt':>9} "
           f"{'lost_node_h':>12} {'goodput':>8} {'od_turn_h':>10}")
    print(hdr)
    print("-" * len(hdr))

    rows = [dict(run_cell(jobs, n_nodes, args.mechanism, None),
                 mtbf_h=None)]
    r = rows[0]
    print(f"{'inf':>8} {r['n_node_failures']:>9} {r['n_interruptions']:>9} "
          f"{r['lost_work_node_h']:>12} {'1.0000':>8} "
          f"{r['od_turnaround_h']:>10}")
    for mtbf_h in (float(x) for x in args.mtbf.split(",")):
        spec = (f"exp-mtbf:mtbf_h={mtbf_h:g},mttr_h={args.mttr:g},"
                f"horizon_days={args.horizon_days:g}")
        r = dict(run_cell(jobs, n_nodes, args.mechanism, spec),
                 mtbf_h=mtbf_h)
        rows.append(r)
        print(f"{mtbf_h:>8g} {r['n_node_failures']:>9} "
              f"{r['n_interruptions']:>9} {r['lost_work_node_h']:>12} "
              f"{r['goodput']:>8} {r['od_turnaround_h']:>10}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump({"scenario": args.scenario, "n_jobs": len(jobs),
                       "n_nodes": n_nodes, "mechanism": args.mechanism,
                       "seed": args.seed, "mttr_h": args.mttr,
                       "horizon_days": args.horizon_days, "rows": rows},
                      fh, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
