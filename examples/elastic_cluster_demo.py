"""End-to-end driver: the paper's mechanisms scheduling REAL JAX jobs.

    PYTHONPATH=src python examples/elastic_cluster_demo.py

8 placeholder devices form the "cluster".  Two malleable training jobs and
one rigid job run; an on-demand inference burst arrives; the scheduler
shrinks the malleables (SPAA) to vacate nodes, serves the burst, then
returns the lease and expands them back (paper §III-B2/B3).  Everything is
real: training state re-shards across meshes, the rigid job checkpoints
and resumes, the on-demand job runs batched decoding on the vacated nodes.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.models import init_params  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.runtime import ElasticJob, LiveCluster  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402

SMALL = ModelConfig(name="demo-lm", family="dense", n_layers=2, d_model=128,
                    n_heads=4, n_kv=4, d_ff=256, vocab=1024,
                    tie_embeddings=True, param_dtype="float32",
                    compute_dtype="float32", attn_block_q=64,
                    attn_block_kv=64)


def main():
    devices = jax.devices()
    print(f"cluster: {len(devices)} nodes ({devices[0].platform})")
    cluster = LiveCluster(devices, arrival_policy="SPAA")
    tmp = tempfile.mkdtemp(prefix="hybrid_demo_")

    m1 = ElasticJob(1, SMALL, kind="malleable", batch=8, seq=64,
                    ckpt_dir=f"{tmp}/j1", seed=1)
    m2 = ElasticJob(2, SMALL, kind="malleable", batch=8, seq=64,
                    ckpt_dir=f"{tmp}/j2", seed=2)
    r3 = ElasticJob(3, SMALL, kind="rigid", batch=8, seq=64,
                    ckpt_dir=f"{tmp}/j3", ckpt_every=10, seed=3)
    i1 = cluster.submit(m1, min_nodes=1, max_nodes=3, target_steps=60)
    i2 = cluster.submit(m2, min_nodes=1, max_nodes=3, target_steps=60)
    i3 = cluster.submit(r3, min_nodes=2, max_nodes=2, target_steps=60)
    print(f"allocation: j1={len(i1.node_ids)} j2={len(i2.node_ids)} "
          f"j3={len(i3.node_ids)} free={len(cluster.free)} "
          f"util={cluster.utilization():.2f}")

    cluster.step_all(10)
    print(f"after 10 rounds: steps=({i1.steps_done},{i2.steps_done},"
          f"{i3.steps_done})")

    # ---- on-demand burst arrives: needs 4 nodes ---------------------------
    print("\n== on-demand burst arrives (needs 4 nodes) ==")
    t0 = time.time()
    nodes = cluster.acquire_for_ondemand(4)
    print(f"vacated {len(nodes)} nodes in {time.time()-t0:.2f}s "
          f"(j1={len(i1.node_ids)} j2={len(i2.node_ids)} "
          f"j3={len(i3.node_ids)})")
    params = init_params(jax.random.PRNGKey(9), SMALL)
    engine = ServeEngine(SMALL, params, max_seq=128)
    rng = np.random.default_rng(0)
    burst = [Request(rid=i, prompt=rng.integers(0, 1024, 16, dtype=np.int32),
                     max_new_tokens=16) for i in range(4)]
    engine.serve_batch(burst)
    print(f"served {sum(len(r.tokens_out) for r in burst)} tokens for "
          f"{len(burst)} requests")

    # training continues at reduced size during the on-demand job
    cluster.step_all(10)

    # ---- on-demand completes: lease returned, jobs expand ------------------
    print("\n== on-demand completes: returning lease ==")
    cluster.release_ondemand(nodes)
    print(f"allocation: j1={len(i1.node_ids)} j2={len(i2.node_ids)} "
          f"j3={len(i3.node_ids)} free={len(cluster.free)}")
    while any(i.status == "running" for i in (i1, i2, i3)):
        cluster.step_all(5)
    print(f"\nall jobs done: steps=({i1.steps_done},{i2.steps_done},"
          f"{i3.steps_done}) shrinks={i1.shrink_count + i2.shrink_count} "
          f"preempts={i1.preempt_count + i2.preempt_count + i3.preempt_count}")
    resharding = [f"{c:.2f}s" for c in m1.resize_costs + m2.resize_costs]
    print(f"measured re-shard costs: {resharding}")
    print("\nevent log:")
    for e in cluster.log:
        print("  ", {k: v for k, v in e.items() if k != "t"})


if __name__ == "__main__":
    main()
