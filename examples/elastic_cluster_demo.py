"""End-to-end driver: the scheduler service running REAL JAX jobs.

    PYTHONPATH=src python examples/elastic_cluster_demo.py

8 placeholder devices form the "cluster".  Two malleable training jobs
and one rigid job are admitted through the service's front door
(AdmissionQueue); a paced on-demand inference burst arrives mid-run with
advance notice.  The service's policy core (CUA&SPAA) decides WHAT
starts/shrinks WHEN; the LiveClusterLauncher executes each decision on a
LiveCluster, whose registry-resolved arrival policy picks WHICH physical
nodes move (paper §III-B2/B3).  Everything is real: training state
re-shards across meshes, the rigid job checkpoints, the on-demand job
runs batched decoding on the vacated nodes, and the lease is repaid when
the burst finishes.  See docs/service.md for the architecture.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.job import JobType  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.runtime import ElasticJob, LiveCluster  # noqa: E402
from repro.service import (AdmissionQueue, LiveClusterLauncher,  # noqa: E402
                           SchedulerService, ServiceConfig, plan_requests)
from repro.serving import Request, ServeEngine  # noqa: E402

SMALL = ModelConfig(name="demo-lm", family="dense", n_layers=2, d_model=128,
                    n_heads=4, n_kv=4, d_ff=256, vocab=1024,
                    tie_embeddings=True, param_dtype="float32",
                    compute_dtype="float32", attn_block_q=64,
                    attn_block_kv=64)


def main():
    devices = jax.devices()
    print(f"cluster: {len(devices)} nodes ({devices[0].platform})")
    cluster = LiveCluster(devices, arrival_policy="SPAA")
    tmp = tempfile.mkdtemp(prefix="hybrid_demo_")

    def job_factory(spec):
        kind = "malleable" if spec.jtype is JobType.MALLEABLE else "rigid"
        return ElasticJob(spec.jid, SMALL, kind=kind, batch=8, seq=64,
                          ckpt_dir=f"{tmp}/j{spec.jid}", ckpt_every=10,
                          seed=spec.jid % 97)

    serve_state = {}

    def serve_fn(job, node_ids):
        """Run the on-demand payload on the nodes the cluster vacated."""
        if "engine" not in serve_state:
            params = init_params(jax.random.PRNGKey(9), SMALL)
            serve_state["engine"] = ServeEngine(SMALL, params, max_seq=128)
        reqs = []
        for p in plan_requests(job, vocab=SMALL.vocab):
            rng = np.random.default_rng(p["rid"])
            reqs.append(Request(
                rid=p["rid"],
                prompt=rng.integers(0, SMALL.vocab, p["prompt_len"],
                                    dtype=np.int32),
                max_new_tokens=p["max_new_tokens"]))
        serve_state["engine"].serve_batch(reqs)
        print(f"  served {sum(len(r.tokens_out) for r in reqs)} tokens for "
              f"{len(reqs)} requests on {len(node_ids)} vacated nodes")
        return reqs

    launcher = LiveClusterLauncher(cluster, job_factory, serve_fn=serve_fn,
                                   steps_per_tick=2, target_steps=40)

    # ---- admit the hybrid workload through the service's front door -------
    queue = AdmissionQueue()
    m1 = queue.submit_training(n_max=3, runtime_s=40.0, n_min=1)
    m2 = queue.submit_training(n_max=3, runtime_s=40.0, n_min=1)
    r3 = queue.submit_rigid(nodes=2, runtime_s=40.0)
    od = queue.submit_inference(nodes=4, hold_s=8.0, submit_time=15.0,
                                notice_lead_s=5.0)
    queue.close()
    print(f"admitted: malleable {m1.jid},{m2.jid} rigid {r3.jid} "
          f"on-demand {od.jid} (4 nodes at t=15s, 5s notice)")

    # ---- the service paces the trace at 40 sim-s/wall-s -------------------
    svc = SchedulerService(
        ServiceConfig(n_nodes=len(devices), mechanism="CUA&SPAA", speed=40.0),
        launcher=launcher)
    rep = svc.run_live(queue)

    infos = launcher.infos
    print(f"\nservice drained in {rep.wall_s:.2f}s wall "
          f"({rep.n_decisions} decisions, p99={rep.latency['p99_ms']:.2f}ms)")
    print("decision log (deterministic fields):")
    for row in svc.log.rows:
        det = {k: v for k, v in row.items()
               if k not in ("wall", "mono", "latency_ms")}
        print("  ", det)

    # ---- drain the training tail on the live cluster ----------------------
    while any(i.status in ("running", "waiting") for i in infos.values()):
        cluster.step_all(5)
    steps = {jid: i.steps_done for jid, i in sorted(infos.items())}
    shrinks = sum(i.shrink_count for i in infos.values())
    preempts = sum(i.preempt_count for i in infos.values())
    print(f"\nall training done: steps={steps} "
          f"shrinks={shrinks} preempts={preempts}")
    print("\ncluster event log:")
    for e in cluster.log:
        print("  ", {k: v for k, v in e.items() if k != "t"})


if __name__ == "__main__":
    main()
