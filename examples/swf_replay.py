"""Replay a Standard Workload Format trace through scheduling mechanisms.

    PYTHONPATH=src python examples/swf_replay.py                    # sample trace
    PYTHONPATH=src python examples/swf_replay.py --trace theta.swf --mix W2
    PYTHONPATH=src python examples/swf_replay.py --load-scale 1.3
    PYTHONPATH=src python examples/swf_replay.py --stream --max-rss  # year-scale

Real traces (e.g. from the Parallel Workloads Archive) carry no
job-type/notice labels, so the "swf" workload source annotates them with
the paper's §IV-A rules (per-project types, Table III notice mixes) —
see docs/workloads.md.  Scenario transforms stack on the replay:
``--load-scale 1.3`` compresses arrivals to 1.3x offered load.

``--stream`` runs every cell in bounded memory (chunked SWF scan, lazy
JobSpec construction, incremental arrival feed, streaming metrics) with
a per-run progress line — the mode for year-scale archive traces, where
materializing the trace per (mechanism x seed) cell would dominate RAM.
``--max-rss`` prints the process peak RSS at exit, so the example
doubles as a memory smoke check (docs/performance.md).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Experiment, Scenario

SAMPLE = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                      "sample.swf")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=SAMPLE, help="SWF file to replay")
    ap.add_argument("--mechanisms", default="BASE,CUA&SPAA,CUA&STEAL",
                    help="comma-separated registered mechanism strings")
    ap.add_argument("--mix", default="W5", help="Table III notice mix")
    ap.add_argument("--frac-od", type=float, default=0.25,
                    help="fraction of trace projects marked on-demand")
    ap.add_argument("--load-scale", type=float, default=None,
                    help="compress arrivals to this multiple of the "
                         "trace's offered load")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of annotation seeds to average")
    ap.add_argument("--serial", action="store_true",
                    help="disable the multiprocessing fan-out")
    ap.add_argument("--stream", action="store_true",
                    help="bounded-memory replay (lazy trace + record "
                         "sink) with a per-run progress line")
    ap.add_argument("--max-rss", action="store_true",
                    help="print the peak RSS at exit (memory smoke check)")
    args = ap.parse_args()

    transforms = []
    if args.load_scale:
        transforms.append(("load_scale", {"factor": args.load_scale}))
    params = {"path": args.trace, "notice_mix": args.mix,
              "frac_od_projects": args.frac_od}
    if args.stream:
        params["stream"] = True  # chunked scan, no record-dict parse
    scenario = Scenario("swf", params=params,
                        transforms=tuple(transforms), name="trace-replay")
    exp = Experiment(mechanisms=args.mechanisms.split(","),
                     workloads=(scenario,), seeds=range(args.seeds),
                     processes=1 if args.serial else None,
                     stream=args.stream)
    if args.stream:
        results, n_runs = [], len(args.mechanisms.split(",")) * args.seeds
        t0 = time.perf_counter()
        for r in exp.run_stream():
            results.append(r)
            print(f"[{len(results)}/{n_runs}] {r.spec.mechanism} "
                  f"seed={r.spec.seed}: {r.metrics.n_completed}/"
                  f"{r.metrics.n_jobs} jobs in {r.elapsed_s:.1f}s "
                  f"({time.perf_counter() - t0:.1f}s total)", flush=True)
        from repro.core.experiment import ExperimentResult
        result = ExperimentResult(results)
    else:
        result = exp.run()
    rows = result.mean(("mechanism",))
    print(f"trace: {args.trace} (mix={args.mix}, frac_od={args.frac_od}"
          + (f", load x{args.load_scale}" if args.load_scale else "") + ")")
    hdr = (f"{'mechanism':10s} {'turn_h':>7s} {'od_h':>7s} {'util':>6s} "
           f"{'instant':>8s} {'done':>5s}")
    print(hdr)
    for row in rows:
        print(f"{row['mechanism']:10s} {row['avg_turnaround_h']:7.1f} "
              f"{row['avg_turnaround_od_h']:7.2f} "
              f"{row['system_utilization']:6.3f} "
              f"{row['od_instant_start_rate']:8.2f} "
              f"{row['n_completed']:5.0f}")
    if args.max_rss:
        import resource
        rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
        print(f"peak RSS: {rss_mb:.0f} MB (self"
              + ("" if args.serial else "; worker processes excluded") + ")")


if __name__ == "__main__":
    main()
