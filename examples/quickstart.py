"""Quickstart: train a small LM end-to-end on local devices.

    PYTHONPATH=src python examples/quickstart.py --steps 300 --size 100m

Uses the same train_step / optimizer / checkpoint stack as the production
launcher; --size 100m trains a ~100M-param llama-style model (CPU: expect
minutes/step at full size — use --size 20m for a fast demo).
"""
import argparse
import time

import jax

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.training import (AdamW, checkpoint, make_train_state,
                            make_train_step, synthetic_batch)

SIZES = {
    "20m": ModelConfig(name="quick-20m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv=6, d_ff=1536,
                       vocab=8192, tie_embeddings=True),
    "100m": ModelConfig(name="quick-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv=12, d_ff=3072,
                        vocab=16384, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="20m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = SIZES[args.size].with_(param_dtype="float32",
                                 compute_dtype="float32")
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=args.lr, warmup=20, total_steps=args.steps)
    state = make_train_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    t0 = time.time()
    for i in range(args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=0, step=i)
        state, m = step_fn(state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tput = args.batch * args.seq * (i + 1) / dt
            print(f"step {i:4d} nll={float(m['nll']):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"lr={float(m['lr']):.2e} tok/s={tput:,.0f}")
        if args.ckpt_dir and (i + 1) % 100 == 0:
            checkpoint.save(args.ckpt_dir, i + 1, state)
            print(f"  checkpointed step {i+1}")
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
