"""Pluggable-policy API: registry, golden seed equivalence, Experiment."""
import json
import os

import pytest

from repro.core import (MECHANISMS, Experiment, JobSpec, JobType, NoticeKind,
                        SimConfig, Simulator, WorkloadConfig, collect,
                        generate, get_policy, register_policy,
                        registered_mechanisms, registered_policies,
                        resolve_mechanism)
from repro.core.policy import ArrivalPolicy, PolicyBundle

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_seed_metrics.json")


# ----------------------------------------------------------------- registry
def test_legacy_mechanisms_all_registered():
    regs = registered_mechanisms()
    assert "BASE" in regs
    for mech in MECHANISMS:
        assert mech in regs


def test_resolve_legacy_mechanism_round_trip():
    for mech in MECHANISMS:
        bundle = resolve_mechanism(mech)
        assert isinstance(bundle, PolicyBundle)
        n, a = mech.split("&")
        assert bundle.notice.name == n
        assert bundle.arrival.name == a
        assert bundle.od_aware


def test_resolve_base_is_od_unaware():
    assert not resolve_mechanism("BASE").od_aware


def test_unknown_mechanism_raises_value_error_listing_registry():
    with pytest.raises(ValueError) as ei:
        Simulator(SimConfig(n_nodes=8, mechanism="NOPE&NADA"), [])
    msg = str(ei.value)
    assert "NOPE&NADA" in msg
    for mech in ("BASE",) + MECHANISMS:
        assert mech in msg


def test_unknown_policy_kind_rejected():
    with pytest.raises(ValueError):
        register_policy("flavor", "VANILLA")
    with pytest.raises(ValueError):
        get_policy("arrival", "DOES_NOT_EXIST")


def test_register_custom_arrival_policy_end_to_end():
    name = "_TEST_GREEDY"
    if name not in registered_policies("arrival"):
        @register_policy("arrival", name)
        class GreedyArrival(ArrivalPolicy):
            """Preempt every running job until demand is met."""

            def acquire(self, ops, jid, need):
                for rid in list(ops.running):
                    if need <= 0:
                        break
                    freed = ops.running[rid].cur_size
                    ops.preempt(rid, beneficiary=jid)
                    need -= freed
                if ops.reserved_of(jid) + ops.free < ops.jobs[jid].size:
                    return False
                ops.start_od(jid)
                return True

    jobs = [JobSpec(0, JobType.RIGID, "p", 0.0, 80, 2000.0, 1000.0),
            JobSpec(1, JobType.ONDEMAND, "p", 100.0, 50, 200.0, 100.0)]
    sim = Simulator(SimConfig(n_nodes=100, mechanism=f"N&{name}"), jobs)
    sim.run()
    assert sim.records[1].instant
    assert sim.records[0].n_preempted == 1
    assert all(r.completion is not None for r in sim.records.values())


# ------------------------------------------------------------------- golden
def test_golden_seed_metrics():
    """Every legacy mechanism string reproduces the pre-refactor seed
    metrics bit-for-bit on the fixed WorkloadConfig(seed=0) trace."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    cfg = WorkloadConfig(n_jobs=120, n_nodes=512, n_projects=12,
                         horizon_days=4.0, seed=0)
    jobs = generate(cfg)
    for mech in ("BASE",) + MECHANISMS:
        sim = Simulator(SimConfig(n_nodes=cfg.n_nodes, mechanism=mech),
                        [j for j in jobs])
        sim.run()
        got = collect(sim).as_dict()
        for key, want in golden[mech].items():
            assert got[key] == want, f"{mech}.{key}: {got[key]!r} != {want!r}"


# --------------------------------------------------- third-party policies
def test_wagomu_policies_run_through_experiment_sweep():
    wl = WorkloadConfig(n_jobs=80, n_nodes=512, n_projects=12,
                        horizon_days=4.0)
    exp = Experiment(mechanisms=("CUA&STEAL", "CUA&POOL"), workloads=(wl,),
                     seeds=(0, 1), processes=1)
    result = exp.run()
    assert len(result) == 4
    for run in result:
        assert run.metrics.n_completed == run.metrics.n_jobs
    rows = result.mean(("mechanism",))
    assert {r["mechanism"] for r in rows} == {"CUA&STEAL", "CUA&POOL"}
    for r in rows:
        assert r["od_instant_start_rate"] >= 0.9


def test_steal_policy_sheds_from_fullest_malleable():
    # two malleables: j0 has slack (80/min 20), j1 has none (30/min 30);
    # STEAL must take the od's 30 nodes from j0 without preempting anyone.
    jobs = [JobSpec(0, JobType.MALLEABLE, "p", 0.0, 80, 4000.0, 2000.0, n_min=20),
            JobSpec(1, JobType.MALLEABLE, "p", 0.0, 30, 4000.0, 2000.0, n_min=30),
            JobSpec(2, JobType.ONDEMAND, "p", 100.0, 30, 200.0, 100.0)]
    sim = Simulator(SimConfig(n_nodes=110, mechanism="N&STEAL"), jobs)
    sim.run()
    assert sim.records[2].instant
    assert sim.records[0].n_shrunk == 1
    assert sim.records[1].n_shrunk == 0
    assert sim.records[0].n_preempted == 0
    assert sim.records[1].n_preempted == 0


def test_balance_elasticity_expands_shrunk_malleable_into_idle_nodes():
    # the od leases 30 of the malleable's nodes; under BALANCE the
    # malleable reclaims idle nodes instead of waiting for lease repayment
    # alone, so it must be back at full size after the od completes.
    jobs = [JobSpec(0, JobType.MALLEABLE, "p", 0.0, 100, 40000.0, 20000.0,
                    n_min=20),
            JobSpec(1, JobType.ONDEMAND, "p", 100.0, 30, 400.0, 200.0)]
    sim = Simulator(SimConfig(n_nodes=100, mechanism="N&STEAL"), jobs)
    sim.run()
    assert sim.records[1].instant
    assert sim.records[0].n_shrunk == 1
    assert sim.records[0].completion is not None
    # linear-speedup accounting: a job that got its nodes back finishes
    # well before one stuck at 70 nodes for the rest of its run.
    stuck_end = 100.0 + (20000.0 * 100 / 70)
    assert sim.records[0].completion < stuck_end


def test_ops_guard_rejects_preempting_or_shrinking_wrong_job_types():
    # the ops layer enforces the paper invariants a policy must respect:
    # on-demand jobs are never preempted, only malleables shrink.
    name = "_TEST_OD_KILLER"
    if name not in registered_policies("arrival"):
        @register_policy("arrival", name)
        class OdKiller(ArrivalPolicy):
            def acquire(self, ops, jid, need):
                for rid, rs in list(ops.running.items()):
                    ops.preempt(rid, beneficiary=jid)  # no jtype filter: bug
                ops.start_od(jid)
                return True

    jobs = [JobSpec(0, JobType.ONDEMAND, "p", 0.0, 60, 400.0, 200.0),
            JobSpec(1, JobType.ONDEMAND, "p", 10.0, 80, 400.0, 200.0)]
    sim = Simulator(SimConfig(n_nodes=100, mechanism=f"N&{name}"), jobs)
    with pytest.raises(ValueError, match="never preempted"):
        sim.run()

    name2 = "_TEST_RIGID_SHRINKER"
    if name2 not in registered_policies("arrival"):
        @register_policy("arrival", name2)
        class RigidShrinker(ArrivalPolicy):
            def acquire(self, ops, jid, need):
                rid = next(iter(ops.running))
                ops.shrink(rid, 1, jid)
                return False

    jobs = [JobSpec(0, JobType.RIGID, "p", 0.0, 90, 400.0, 200.0),
            JobSpec(1, JobType.ONDEMAND, "p", 10.0, 80, 400.0, 200.0)]
    sim = Simulator(SimConfig(n_nodes=100, mechanism=f"N&{name2}"), jobs)
    with pytest.raises(ValueError, match="non-malleable"):
        sim.run()


def test_queue_policy_order_key_override_takes_effect():
    # a subclass overriding only order_key must change the sort order even
    # though the base installs a specialized closure for the default key
    from repro.core.policies.builtin import FcfsEasyBackfill

    name = "_TEST_LIFO"
    if name not in registered_policies("queue"):
        @register_policy("queue", name)
        class LifoEasy(FcfsEasyBackfill):
            def order_key(self, view, jid):
                return (0 if view.od_front(jid) else 1,
                        -view.jobs[jid].submit_time, jid)

    # two equal-size jobs only one can run at a time: LIFO starts the
    # younger one first once the head blocks... simplest observable: the
    # closure must consult the override.
    jobs = [JobSpec(0, JobType.RIGID, "p", 0.0, 60, 400.0, 200.0),
            JobSpec(1, JobType.RIGID, "p", 10.0, 60, 400.0, 200.0),
            JobSpec(2, JobType.RIGID, "p", 20.0, 60, 400.0, 200.0)]
    sim = Simulator(SimConfig(n_nodes=60, mechanism="BASE",
                              queue_policy=name), [j for j in jobs])
    sim.run()
    # under LIFO, job 2 (youngest waiter) runs before job 1
    assert sim.records[2].first_start < sim.records[1].first_start


# ---------------------------------------------------------------- experiment
def test_experiment_grid_and_grouping():
    wls = [WorkloadConfig(n_jobs=40, n_nodes=256, n_projects=8,
                          horizon_days=2.0, notice_mix=m) for m in ("W1", "W5")]
    exp = Experiment(mechanisms=("BASE", "CUA&SPAA"), workloads=wls,
                     seeds=(0, 1), processes=1)
    specs = list(exp.specs())
    assert len(specs) == 8
    result = exp.run()
    assert len(result) == 8
    by_mix = result.mean(("mechanism", "notice_mix"))
    assert len(by_mix) == 4
    for row in by_mix:
        assert row["n_jobs"] == 40.0
    # rows() must expose any workload field that varies across the sweep
    for row in result.rows():
        assert row["notice_mix"] in ("W1", "W5")


def test_experiment_rows_include_varying_workload_fields():
    wls = [WorkloadConfig(n_jobs=30, n_nodes=256, n_projects=8,
                          horizon_days=2.0, ckpt_freq_factor=f)
           for f in (0.5, 2.0)]
    result = Experiment(mechanisms=("CUA&PAA",), workloads=wls,
                        seeds=(0,), processes=1).run()
    factors = {row["ckpt_freq_factor"] for row in result.rows()}
    assert factors == {0.5, 2.0}


def test_experiment_parallel_matches_serial():
    wl = WorkloadConfig(n_jobs=40, n_nodes=256, n_projects=8, horizon_days=2.0)
    kw = dict(mechanisms=("CUA&SPAA",), workloads=(wl,), seeds=(0, 1))
    serial = Experiment(processes=1, **kw).run()
    parallel = Experiment(processes=2, **kw).run()
    for a, b in zip(serial, parallel):
        assert a.spec == b.spec
        am, bm = a.metrics.as_dict(), b.metrics.as_dict()
        assert am.keys() == bm.keys()
        for k in am:
            assert am[k] == bm[k] or (am[k] != am[k] and bm[k] != bm[k]), k


def test_experiment_run_stream_yields_compact_rows_with_summaries():
    wl = WorkloadConfig(n_jobs=40, n_nodes=256, n_projects=8, horizon_days=2.0)
    exp = Experiment(mechanisms=("BASE", "CUA&SPAA"), workloads=(wl,),
                     seeds=(0,), processes=1, record_summary=16)
    seen = []
    for r in exp.run_stream():           # streaming: consumed one by one
        assert r.elapsed_s > 0.0
        assert r.summary is not None
        assert r.summary["n_records"] == 40
        assert len(r.summary["sample"]) <= 16
        assert r.summary["turnaround_s"]["p50"] <= \
            r.summary["turnaround_s"]["p99"]
        seen.append(r.spec.mechanism)
    assert sorted(seen) == ["BASE", "CUA&SPAA"]
    # without the knob, no summary rides along (compact rows only)
    r = next(iter(Experiment(mechanisms=("BASE",), workloads=(wl,),
                             seeds=(0,), processes=1).run()))
    assert r.summary is None
    assert "elapsed_s" in Experiment(
        mechanisms=("BASE",), workloads=(wl,), seeds=(0,),
        processes=1).run().rows()[0]


def test_experiment_scale_knob_scales_jobs_and_horizon():
    wl = WorkloadConfig(n_jobs=40, n_nodes=256, n_projects=8, horizon_days=2.0)
    exp = Experiment(mechanisms=("BASE",), workloads=(wl,), seeds=(0,),
                     processes=1, scale=0.5)
    spec = next(exp.specs())
    assert spec.workload.n_jobs == 20
    assert spec.workload.horizon_days == 1.0
    result = exp.run()
    assert result.runs[0].metrics.n_jobs == 20
    # scenarios scale through their source params when present
    from repro.core import Scenario
    sc = Scenario("theta", params={"n_jobs": 40, "horizon_days": 2.0,
                                   "n_nodes": 256, "n_projects": 8})
    spec = next(Experiment(mechanisms=("BASE",), workloads=(sc,),
                           seeds=(0,), scale=2.0).specs())
    assert spec.workload.params["n_jobs"] == 80
    assert spec.workload.params["horizon_days"] == 4.0


def test_experiment_serial_fallback_logs_warning(monkeypatch, caplog):
    import concurrent.futures

    class NoPool:
        def __init__(self, *a, **kw):
            raise OSError("subprocesses forbidden here")

    monkeypatch.setattr(concurrent.futures, "ProcessPoolExecutor", NoPool)
    wl = WorkloadConfig(n_jobs=20, n_nodes=256, n_projects=8, horizon_days=2.0)
    exp = Experiment(mechanisms=("BASE",), workloads=(wl,), seeds=(0, 1),
                     processes=2)
    import logging
    with caplog.at_level(logging.WARNING, logger="repro.core.experiment"):
        result = exp.run()
    assert len(result) == 2  # degraded but complete
    assert any("process fan-out unavailable" in r.message
               and "subprocesses forbidden here" in r.message
               for r in caplog.records)
