"""Streaming replay engine tests: lazy sources == materialized sources
job-for-job (sha256), bounded simulator memory under a record sink, and
streaming sweep/metrics equivalence.

The bit-identity pins here are what let the engine swap freely between
the two data-flow modes: every assertion compares the streaming path
against the golden-tested materialized path, never against re-derived
expectations.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core import (Experiment, SimConfig, Simulator, WorkloadConfig,
                        collect, generate)
from repro.core.metrics import P2Quantile, StreamingMetrics, Welford
from repro.core.workloads import (Scenario, SwfTrace, ThetaGenerator,
                                  trace_sha256)

SAMPLE_SWF = os.path.join(os.path.dirname(__file__), "data", "sample.swf")

MECHS = ("BASE", "CUA&SPAA")
SEEDS = (0, 1)


def _close(a, b, tol=1e-9):
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) and math.isnan(b):
            return True
        return abs(a - b) <= tol * max(1.0, abs(a), abs(b))
    return a == b


# ------------------------------------------------- source-level sha identity
@pytest.mark.parametrize("seed", SEEDS)
def test_theta_iter_jobs_identical_to_jobs(seed):
    cfg = WorkloadConfig(n_jobs=500, seed=seed)
    mat = ThetaGenerator(cfg).jobs()
    lazy = list(ThetaGenerator(cfg).iter_jobs())
    assert len(mat) == len(lazy)
    assert all(a == b for a, b in zip(mat, lazy))
    assert trace_sha256(mat) == trace_sha256(lazy)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("stream", [False, True])
def test_swf_iter_jobs_identical_to_jobs(seed, stream):
    kw = dict(seed=seed, frac_od_projects=0.3)
    mat = SwfTrace(SAMPLE_SWF, **kw).jobs()
    lazy = list(SwfTrace(SAMPLE_SWF, stream=stream, **kw).iter_jobs())
    assert all(a == b for a, b in zip(mat, lazy))
    assert trace_sha256(mat) == trace_sha256(lazy)


def test_swf_stream_mode_never_materializes_record_dicts():
    src = SwfTrace(SAMPLE_SWF, stream=True)
    assert src._records is None
    assert src.n_nodes == 512          # MaxNodes directive, from the scan
    assert len(list(src.iter_jobs())) == 80
    assert src._records is None        # still no dict materialization


# --------------------------------------------- scenario stacks, both regimes
STACKS = [
    (),                                                      # bare source
    (("load_scale", {"factor": 1.3}),
     ("diurnal", {"amplitude": 0.5}),
     ("notice_mix", {"mix": "W2"})),                         # fully streaming
    (("burst_inject", {"n_bursts": 2, "mix": "W1"}),
     ("notice_mix", {"mix": "W5"})),                         # tagged merge
    (("load_scale", {"factor": 0.8}),
     ("burst_inject", {"n_bursts": 3}),
     ("diurnal", {"amplitude": 0.4}),
     ("notice_mix", {"mix": "W3"})),       # merge sandwiched by warps
    (("burst_inject", {"n_bursts": 2, "mix": "W2"}),
     ("burst_inject", {"n_bursts": 1, "burst_size": (3, 5)}),
     ("notice_mix", {"mix": "W4"})),       # stacked merges (multi-rank)
    (("type_mix", {"frac_od": 0.3, "frac_rigid": 0.3}),
     ("burst_inject", {"n_bursts": 2})),                     # fallback path
]


@pytest.mark.parametrize("transforms", STACKS)
@pytest.mark.parametrize("seed", SEEDS)
def test_scenario_iter_realize_identity_theta(transforms, seed):
    sc = Scenario("theta", params={"n_jobs": 300}, transforms=transforms)
    jobs, n = sc.realize(seed)
    it, n2 = sc.iter_realize(seed)
    lazy = list(it)
    assert n == n2
    assert all(a == b for a, b in zip(jobs, lazy)) and len(jobs) == len(lazy)
    assert trace_sha256(jobs) == trace_sha256(lazy)


@pytest.mark.parametrize("transforms", STACKS[:2])
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("stream", [False, True])
def test_scenario_iter_realize_identity_swf(transforms, seed, stream):
    sc = Scenario("swf", params={"path": SAMPLE_SWF, "stream": stream,
                                 "frac_od_projects": 0.3},
                  transforms=transforms)
    jobs, n = sc.realize(seed)
    it, n2 = sc.iter_realize(seed)
    lazy = list(it)
    assert n == n2
    assert all(a == b for a, b in zip(jobs, lazy)) and len(jobs) == len(lazy)
    assert trace_sha256(jobs) == trace_sha256(lazy)


def test_streamable_classification():
    assert Scenario("theta").streamable
    assert Scenario("theta", transforms=(("load_scale", {"factor": 2.0}),
                                         ("diurnal", {}),
                                         ("notice_mix", {}))).streamable
    assert Scenario("theta",
                    transforms=(("burst_inject", {}),)).streamable
    assert not Scenario("theta", transforms=(("type_mix", {}),)).streamable


def test_materialized_fallback_warns_once_naming_transform(caplog):
    import logging

    from repro.core.workloads import base as wl_base

    sc = Scenario("theta", params={"n_jobs": 50},
                  transforms=(("type_mix", {"frac_od": 0.3}),))
    wl_base._WARNED_MATERIALIZED.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.workloads.base"):
        list(sc.iter_realize(0)[0])
        list(sc.iter_realize(1)[0])  # second run: already warned
    warned = [r for r in caplog.records if "not streamable" in r.message]
    assert len(warned) == 1
    assert "type_mix" in warned[0].getMessage()
    assert "bounded-memory" in warned[0].getMessage()
    # streamable stacks never warn
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.workloads.base"):
        list(Scenario("theta", params={"n_jobs": 50}).iter_realize(0)[0])
    assert not [r for r in caplog.records if "not streamable" in r.message]


# --------------------------------------------------- simulator: iterator feed
def _record_tuples(records):
    return sorted((r.job.jid, r.first_start, r.completion, r.killed,
                   r.n_preempted, r.n_shrunk, r.instant) for r in records)


@pytest.mark.parametrize("mech", MECHS)
def test_simulator_iterator_feed_matches_list(mech):
    wl = WorkloadConfig(n_nodes=4392, n_jobs=500, horizon_days=21.0,
                        target_load=1.15, seed=0)
    jobs = generate(wl)
    cfg = SimConfig(n_nodes=4392, mechanism=mech)
    a = Simulator(cfg, list(jobs))
    a.run()
    b = Simulator(cfg, iter(list(jobs)))
    b.run()
    assert _record_tuples(a.records.values()) \
        == _record_tuples(b.records.values())


@pytest.mark.parametrize("mech", MECHS)
def test_iterator_feed_identical_on_integer_timestamp_ties(mech):
    """SWF traces carry integer seconds, so job ends collide with later
    submits constantly.  Lazy ingestion must not reorder those ties:
    trace events take jid-derived heap seqs below every dynamic event,
    exactly the order the legacy constructor produced — this trace is
    built so ends and submits land on the same second hundreds of
    times."""
    from repro.core import JobSpec, JobType
    from repro.core.workloads import canonicalize
    jobs = canonicalize([
        JobSpec(-1, JobType.RIGID, f"p{i % 5}", float((i // 4) * 600),
                64 + 64 * (i % 4), float(600 * (1 + i % 5)),
                float(600 * (1 + i % 5)))
        for i in range(400)])
    cfg = SimConfig(n_nodes=512, mechanism=mech)
    a = Simulator(cfg, list(jobs))
    a.run()
    retired = []
    b = Simulator(cfg, iter(list(jobs)), record_sink=retired.append)
    b.run()
    assert _record_tuples(a.records.values()) == _record_tuples(retired)


def test_unsorted_arrival_iterator_is_rejected():
    """An arrival the clock has already passed must fail loudly.
    (Inversions that stay inside the lookahead window are harmlessly
    re-ordered by the event heap; this one cannot be.)"""
    from repro.core import JobSpec, JobType
    out_of_order = [
        JobSpec(0, JobType.RIGID, "p0", 0.0, 8, 600.0, 600.0),
        JobSpec(1, JobType.RIGID, "p0", 100000.0, 8, 600.0, 600.0),
        JobSpec(2, JobType.RIGID, "p0", 10.0, 8, 600.0, 600.0),
    ]
    sim = Simulator(SimConfig(n_nodes=64, mechanism="BASE"),
                    iter(out_of_order))
    with pytest.raises(ValueError, match="out of order"):
        sim.run()


def test_lookahead_shorter_than_notice_lead_raises_clearly():
    """Notice leads beyond arrival_lookahead must fail loudly (the event
    would land in the past), and raising the lookahead must fix it."""
    wl = WorkloadConfig(n_nodes=2048, n_jobs=150, seed=0,
                        notice_lead=(21600.0, 43200.0))
    jobs = generate(wl)
    cfg = SimConfig(n_nodes=2048, mechanism="CUA&SPAA")
    with pytest.raises(ValueError, match="arrival_lookahead"):
        Simulator(cfg, iter(list(jobs))).run()
    ok = Simulator(SimConfig(n_nodes=2048, mechanism="CUA&SPAA",
                             arrival_lookahead=90000.0), iter(list(jobs)))
    ok.run()
    assert len(ok.records) == len(jobs)


# ------------------------------------------------ record sink: O(active) RAM
@pytest.mark.parametrize("mech", MECHS)
def test_record_sink_bounds_live_job_state(mech):
    """With a sink installed the simulator must hold O(active) job
    records — observed live-set high-water far below the trace length —
    and still produce the exact record stream of the legacy run."""
    wl = WorkloadConfig(n_nodes=4392, n_jobs=600, horizon_days=21.0,
                        target_load=1.15, seed=0)
    jobs = generate(wl)
    cfg = SimConfig(n_nodes=4392, mechanism=mech)
    ref = Simulator(cfg, list(jobs))
    ref.run()

    retired = []
    peaks = {"records": 0, "jobs": 0}
    sim = Simulator(cfg, iter(list(jobs)), record_sink=retired.append)

    orig_retire = sim._retire

    def watching_retire(jid, rec):
        peaks["records"] = max(peaks["records"], len(sim.records))
        peaks["jobs"] = max(peaks["jobs"], len(sim.jobs))
        orig_retire(jid, rec)

    sim._retire = watching_retire
    sim.run()

    assert len(retired) == len(jobs)
    assert sim.records == {} and sim.jobs == {} and sim.est_remaining == {}
    assert sim.od_status == {}
    # live set stays a small fraction of the trace: O(active), not O(total)
    assert peaks["records"] < len(jobs) // 2, peaks
    assert _record_tuples(retired) == _record_tuples(ref.records.values())


def test_sink_without_iterator_also_retires():
    wl = WorkloadConfig(n_nodes=2048, n_jobs=200, seed=3)
    jobs = generate(wl)
    retired = []
    sim = Simulator(SimConfig(n_nodes=2048, mechanism="CUA&SPAA"),
                    list(jobs), record_sink=retired.append)
    sim.run()
    assert len(retired) == len(jobs) and sim.records == {}


# ------------------------------------------------------- incremental metrics
def test_streaming_metrics_match_collect():
    wl = WorkloadConfig(n_nodes=4392, n_jobs=500, horizon_days=21.0,
                        target_load=1.15, seed=1)
    jobs = generate(wl)
    cfg = SimConfig(n_nodes=4392, mechanism="CUA&SPAA")
    a = Simulator(cfg, list(jobs))
    a.run()
    want = collect(a).as_dict()
    sink = StreamingMetrics(instant_eps=cfg.instant_eps)
    b = Simulator(cfg, iter(list(jobs)), record_sink=sink)
    b.run()
    got = sink.result(b).as_dict()
    assert set(want) == set(got)
    for k, v in want.items():
        assert _close(v, got[k]), (k, v, got[k])


def test_welford_and_p2_primitives():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(6, 1.0, 5000)
    w = Welford()
    for x in xs:
        w.add(float(x))
    assert abs(w.mean - xs.mean()) < 1e-8 * xs.mean()
    assert abs(w.variance - xs.var()) < 1e-6 * xs.var()
    for p in (0.5, 0.9, 0.99):
        q = P2Quantile(p)
        for x in xs:
            q.add(float(x))
        exact = float(np.percentile(xs, p * 100))
        assert abs(q.result() - exact) / exact < 0.05, (p, q.result(), exact)
    # exact below five observations
    q = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        q.add(x)
    assert q.result() == 2.0


def test_sink_sees_jobs_that_never_complete():
    """A job that can never start (size > machine) must still reach the
    sink when the heap drains, so n_jobs and every ratio denominator
    match collect()'s over the same trace."""
    from repro.core import JobSpec, JobType
    from repro.core.workloads import canonicalize
    jobs = canonicalize(
        [JobSpec(-1, JobType.RIGID, "p0", 60.0 * i, 16, 600.0, 600.0)
         for i in range(10)]
        + [JobSpec(-1, JobType.RIGID, "p1", 120.0, 9999, 600.0, 600.0)])
    cfg = SimConfig(n_nodes=64, mechanism="BASE")
    ref = Simulator(cfg, list(jobs))
    ref.run()
    want = collect(ref)
    sink = StreamingMetrics(instant_eps=cfg.instant_eps)
    sim = Simulator(cfg, iter(list(jobs)), record_sink=sink)
    sim.run()
    got = sink.result(sim)
    assert got.n_jobs == want.n_jobs == 11
    assert got.n_completed == want.n_completed == 10
    assert _close(got.preemption_ratio_rigid, want.preemption_ratio_rigid)
    assert sim.records == {}


def test_streaming_metrics_empty_trace_is_nan_not_crash():
    sink = StreamingMetrics()
    sim = Simulator(SimConfig(n_nodes=64, mechanism="BASE"), iter(()),
                    record_sink=sink)
    sim.run()
    m = sink.result(sim)
    assert m.n_jobs == 0 and math.isnan(m.avg_turnaround_h)


# ------------------------------------------------------- experiment streaming
def test_experiment_stream_mode_matches_materialized():
    sc = Scenario("theta", params={"n_jobs": 250}, name="W5")
    kw = dict(mechanisms=MECHS, workloads=(sc,), seeds=(0,), processes=1)
    rows_m = Experiment(stream=False, **kw).run().rows()
    rows_s = Experiment(stream=True, **kw).run().rows()
    for a, b in zip(rows_m, rows_s):
        for k in a:
            if k == "elapsed_s":
                continue
            assert _close(a[k], b[k]), (k, a[k], b[k])


def test_run_stream_checkpoint_resume(tmp_path):
    sc = Scenario("theta", params={"n_jobs": 150}, name="W5")
    exp = Experiment(mechanisms=MECHS, workloads=(sc,), seeds=(0, 1),
                     stream=True, processes=1)
    ck = str(tmp_path / "progress.json")
    first = {}
    for i, r in enumerate(exp.run_stream(checkpoint=ck)):
        first[(r.spec.mechanism, r.spec.seed)] = r.metrics.avg_turnaround_h
        if i == 1:
            break  # abandon mid-sweep; checkpoint holds the finished runs
    saved = json.load(open(ck))
    assert len(saved["runs"]) == 2 and saved["n_specs"] == 4
    resumed = {(r.spec.mechanism, r.spec.seed): r.metrics.avg_turnaround_h
               for r in exp.run_stream(checkpoint=ck)}
    assert len(resumed) == 4
    for k, v in first.items():
        assert _close(v, resumed[k])
    # a different grid must refuse the file, not silently misapply it
    other = Experiment(mechanisms=("BASE",), workloads=(sc,), seeds=(0,),
                       stream=True, processes=1)
    with pytest.raises(ValueError, match="different"):
        list(other.run_stream(checkpoint=ck))
