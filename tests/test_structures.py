"""Deterministic units for the incremental engine containers
(repro.core.structures) and the simulator behaviors layered on them
(hold stealing, order-key invalidation, legacy-mode opt-out).

The randomized equivalence property (incremental queue == full sort
under arbitrary interleavings) lives in tests/test_properties.py behind
the hypothesis importorskip.
"""
import pytest

from repro.core import (JobSpec, JobType, OrderedSet, SimConfig, Simulator,
                        WaitQueue, collect, register_policy,
                        registered_policies)


# ---------------------------------------------------------------- WaitQueue
def _fifo_queue(**kw):
    q = WaitQueue()
    q.configure(lambda jid: (jid,), **kw)
    return q


def test_waitqueue_keeps_key_order_with_list_surface():
    q = WaitQueue()
    q.configure(lambda jid: (-jid,))  # descending jid order
    for jid in (3, 1, 4, 2):
        q.append(jid)
    assert list(q) == [4, 3, 2, 1]
    assert q[0] == 4 and q[1:3] == [3, 2]
    assert len(q) == 4 and bool(q)
    assert 3 in q and 9 not in q
    assert q.position(2) == 2
    q.remove(3)
    assert list(q) == [4, 2, 1]
    assert 3 not in q
    assert list(reversed(q)) == [1, 2, 4]


def test_waitqueue_rejects_duplicate_and_missing_members():
    q = _fifo_queue()
    q.append(1)
    with pytest.raises(ValueError):
        q.append(1)
    with pytest.raises(KeyError):
        q.remove(2)


def test_waitqueue_invalidate_recomputes_key_and_is_noop_for_nonmembers():
    prio = {1: 5, 2: 1, 3: 3}
    q = WaitQueue()
    q.configure(lambda jid: (prio[jid], jid))
    for jid in (1, 2, 3):
        q.append(jid)
    assert list(q) == [2, 3, 1]
    prio[1] = 0
    q.invalidate(1)
    assert list(q) == [1, 2, 3]
    q.invalidate(99)  # non-member: no-op, no raise
    assert list(q) == [1, 2, 3]


def test_waitqueue_legacy_mode_sorts_stably_on_refresh():
    # order_keys_stable=False policies get the legacy list semantics:
    # appends stay unsorted until refresh(), which stable-sorts with
    # freshly computed keys (ties keep their pre-sort order)
    prio = {1: 1, 2: 0, 3: 1}
    q = WaitQueue()
    q.configure(lambda jid: (prio[jid],), incremental=False,
                meta_fn=lambda jid: (float(jid), 0.0))
    for jid in (1, 2, 3):
        q.append(jid)
    assert list(q) == [1, 2, 3]  # unsorted until a pass refreshes
    q.refresh()
    assert list(q) == [2, 1, 3]  # stable: 1 before 3 (tied keys)
    prio[2] = 9
    q.refresh()                  # keys recomputed every refresh
    assert list(q) == [1, 3, 2]
    assert q.meta_window(0, 3)[0] == [1.0, 3.0, 2.0]
    q.remove(3)
    assert list(q) == [1, 2]


def test_waitqueue_meta_window_aligns_with_slices():
    q = _fifo_queue(meta_fn=lambda jid: (jid * 10.0, jid * 100.0))
    for jid in (2, 0, 1):
        q.append(jid)
    needs, ests = q.meta_window(0, 3)
    assert needs == [0.0, 10.0, 20.0]
    assert ests == [0.0, 100.0, 200.0]
    assert q.meta_window(1, 3)[0] == [10.0, 20.0]


# --------------------------------------------------------------- OrderedSet
def test_ordered_set_is_ordered_with_o1_membership():
    s = OrderedSet()
    for x in (3, 1, 2, 1):
        s.append(x)
    assert list(s) == [3, 1, 2]  # first insertion wins, like guarded append
    assert 1 in s and 9 not in s
    assert len(s) == 3 and bool(s)
    s.remove(1)
    assert list(s) == [3, 2]
    with pytest.raises(ValueError):
        s.remove(1)
    s.discard(1)  # missing member: no-op
    s.discard(3)
    assert list(s) == [2]
    assert not OrderedSet()


# --------------------------------------------- simulator: hold steal return
def _batch(jid, submit, size, est=4000.0, act=2000.0):
    return JobSpec(jid, JobType.RIGID, "p", submit, size, est, act)


def test_steal_holds_insufficient_returns_zero_but_transfers_stand():
    """Satellite: an insufficient steal returns 0 (the legacy identical-
    arms conditional returned the shortfall anyway) so _schedule skips
    the doomed _try_start retry; the transferred nodes stay free."""
    sim = Simulator(SimConfig(n_nodes=100, mechanism="BASE"),
                    [_batch(0, 0.0, 90), _batch(1, 10.0, 5)])
    sim.queue.append(0)
    sim.queue.append(1)
    sim.ledger.occupied = 97          # synthetic: most of the machine busy
    sim.ledger.free = 0
    sim.ledger.add_hold(1, 3)         # job 1 holds 3 returned-lease nodes
    sim.ledger.check()
    moved = sim._steal_holds(0)       # head 0 needs 90, can reach only 3
    assert moved == 0
    assert sim.ledger.free == 3       # the transfer itself stands
    assert sim.ledger.hold_of(1) == 0


def test_steal_holds_sufficient_returns_moved_youngest_first():
    sim = Simulator(SimConfig(n_nodes=100, mechanism="BASE"),
                    [_batch(0, 0.0, 10), _batch(1, 10.0, 5),
                     _batch(2, 20.0, 5)])
    for jid in (0, 1, 2):
        sim.queue.append(jid)
    sim.ledger.occupied = 88
    sim.ledger.free = 2
    sim.ledger.add_hold(1, 5)
    sim.ledger.add_hold(2, 5)
    sim.ledger.check()
    moved = sim._steal_holds(0)       # short 8: all of 2's, 3 of 1's
    assert moved == 8
    assert sim.ledger.free == 10
    assert sim.ledger.hold_of(2) == 0
    assert sim.ledger.hold_of(1) == 2


def test_golden_behavior_unchanged_by_steal_fix():
    """The steal-fix must not change outcomes: an insufficient steal's
    _try_start would have failed anyway.  End-to-end: a hold-heavy
    scenario completes with finite metrics."""
    jobs = [JobSpec(0, JobType.MALLEABLE, "p", 0.0, 80, 8000.0, 4000.0,
                    n_min=20),
            JobSpec(1, JobType.ONDEMAND, "p", 100.0, 40, 400.0, 200.0),
            _batch(2, 150.0, 90)]
    sim = Simulator(SimConfig(n_nodes=100, mechanism="CUA&SPAA"), jobs)
    sim.run()
    m = collect(sim)
    assert m.n_completed == m.n_jobs == 3


# ------------------------------------------------- order_keys_stable opt-out
def test_order_keys_stable_false_policy_gets_legacy_resort():
    """A queue policy whose keys read the clock opts out of incremental
    caching and still orders correctly (re-sorted every pass)."""
    from repro.core.policies.builtin import FcfsEasyBackfill

    name = "_TEST_UNSTABLE_LIFO"
    if name not in registered_policies("queue"):
        @register_policy("queue", name)
        class UnstableLifo(FcfsEasyBackfill):
            order_keys_stable = False

            def order_key(self, view, jid):
                # clock-dependent: age since submit, newest first
                return (0 if view.od_front(jid) else 1,
                        view.now - view.jobs[jid].submit_time, jid)

    jobs = [_batch(0, 0.0, 60, est=400.0, act=200.0),
            _batch(1, 10.0, 60, est=400.0, act=200.0),
            _batch(2, 20.0, 60, est=400.0, act=200.0)]
    sim = Simulator(SimConfig(n_nodes=60, mechanism="BASE",
                              queue_policy=name), jobs)
    assert not sim.queue.incremental
    sim.run()
    # newest-first: job 2 (smallest age) starts before job 1
    assert sim.records[2].first_start < sim.records[1].first_start
    assert all(r.completion is not None for r in sim.records.values())
