"""Skip jax-dependent test modules when jax is unavailable (e.g. the
lightweight CI container, which installs requirements-dev.txt only)."""

collect_ignore = []
try:
    import jax  # noqa: F401
except Exception:
    collect_ignore = ["test_archs.py", "test_decision_jax.py",
                      "test_kernels.py", "test_runtime.py"]
