"""Integration tests: elastic runtime, serving, checkpoint/restart,
straggler detection, and a miniature multi-device dry-run.

Multi-device cases run in a subprocess so the 8-device XLA flag does not
leak into the rest of the suite (the main process stays single-device).
"""
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


SMALL_CFG = """
from repro.models.config import ModelConfig
CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=256,
                  tie_embeddings=True, param_dtype="float32",
                  compute_dtype="float32", attn_block_q=32, attn_block_kv=32)
"""


def test_elastic_shrink_expand_preserves_training():
    """Resize must not corrupt the train state: loss keeps decreasing and
    params stay identical through a round-trip re-shard."""
    out = run_py(SMALL_CFG + """
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import ElasticJob
devs = jax.devices()
job = ElasticJob(1, CFG, kind="malleable", batch=8, seq=32, seed=0)
job.start(devs[:4])
for _ in range(3): m = job.step()
before = jax.tree.map(lambda x: np.asarray(x), job.state.params)
job.resize(devs[:2])     # shrink
after = jax.tree.map(lambda x: np.asarray(x), job.state.params)
errs = [np.abs(a-b).max() for a,b in zip(jax.tree.leaves(before), jax.tree.leaves(after))]
print("reshard_err", max(errs))
m1 = job.step()
job.resize(devs[:6])     # expand
m2 = job.step()
print("loss_seq", m["loss"], m1["loss"], m2["loss"])
assert all(np.isfinite([m["loss"], m1["loss"], m2["loss"]]))
""")
    reshard_err = float(out.split("reshard_err")[1].split()[0])
    assert reshard_err == 0.0


def test_preempt_resume_from_checkpoint():
    out = run_py(SMALL_CFG + """
import jax, numpy as np, tempfile
from repro.runtime import ElasticJob
devs = jax.devices()
d = tempfile.mkdtemp()
job = ElasticJob(1, CFG, kind="malleable", batch=8, seq=32,
                 ckpt_dir=d, ckpt_every=100, seed=0)
job.start(devs[:4])
for _ in range(4): job.step()
params_at_preempt = [np.asarray(x) for x in jax.tree.leaves(job.state.params)]
job.preempt(warning=True)       # 2-minute-warning checkpoint
assert job.mesh is None
job2 = ElasticJob(1, CFG, kind="malleable", batch=8, seq=32,
                  ckpt_dir=d, seed=0)
job2.resume(devs[4:8])          # different nodes entirely
assert job2.step_idx == 4
restored = [np.asarray(x) for x in jax.tree.leaves(job2.state.params)]
err = max(np.abs(a-b).max() for a,b in zip(params_at_preempt, restored))
print("resume_err", err)
job2.step()
""")
    assert float(out.split("resume_err")[1].split()[0]) == 0.0


def test_deterministic_restart_same_stream():
    """Restart-from-checkpoint must replay the same data stream: training
    A->(10 steps) equals A->(5 steps)->ckpt->restore->(5 steps)."""
    out = run_py(SMALL_CFG + """
import jax, numpy as np, tempfile
from repro.models import init_params
from repro.training import AdamW, make_train_state, make_train_step, \
    synthetic_batch, checkpoint
opt = AdamW(lr=1e-3, warmup=2, total_steps=20)
step = jax.jit(make_train_step(CFG, opt))
def train(state, a, b):
    for i in range(a, b):
        state, _ = step(state, synthetic_batch(CFG, 4, 32, seed=7, step=i))
    return state
s0 = make_train_state(init_params(jax.random.PRNGKey(0), CFG), opt)
sA = train(s0, 0, 10)
s0 = make_train_state(init_params(jax.random.PRNGKey(0), CFG), opt)
sB = train(s0, 0, 5)
d = tempfile.mkdtemp()
checkpoint.save(d, 5, sB)
sB = checkpoint.restore(d, sB)
sB = train(sB, 5, 10)
err = max(np.abs(np.asarray(a, np.float64)-np.asarray(b, np.float64)).max()
          for a,b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)))
print("restart_err", err)
""", devices=1)
    assert float(out.split("restart_err")[1].split()[0]) < 1e-6


def test_mini_dryrun_with_moe_shard_map():
    """Lower+compile a train step for a reduced MoE arch on a 4x2 mesh —
    the same code path as the production dry-run, incl. expert-parallel
    shard_map."""
    run_py("""
import jax
from repro.configs.reduced import reduced
from repro.launch.dryrun import build_lowerable, cost_analysis_dict
from repro.launch.mesh import make_mesh
from repro.models import SHAPES_BY_NAME, set_mesh
from repro.models.config import ShapeSpec
from repro.sharding import batch_axes
cfg = reduced("olmoe_1b_7b").with_(train_microbatches=2)
shape = ShapeSpec("t", 64, 16, "train")
mesh = make_mesh((4, 2), ("data", "model"))
set_mesh(mesh, batch_axes(mesh))
fn, args, in_sh, out_sh, donate = build_lowerable(cfg, shape, mesh)
with mesh:
    c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate).lower(*args).compile()
print("compiled_ok", cost_analysis_dict(c).get("flops", 0) > 0)
""")


def test_mini_dryrun_decode_cache_sharding():
    run_py("""
import jax
from repro.configs.reduced import reduced
from repro.launch.dryrun import build_lowerable
from repro.launch.mesh import make_mesh
from repro.models import set_mesh
from repro.models.config import ShapeSpec
from repro.sharding import batch_axes
cfg = reduced("llama3_8b")
shape = ShapeSpec("d", 64, 8, "decode")
mesh = make_mesh((4, 2), ("data", "model"))
set_mesh(mesh, batch_axes(mesh))
fn, args, in_sh, out_sh, donate = build_lowerable(cfg, shape, mesh)
with mesh:
    c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate).lower(*args).compile()
print("compiled_ok")
""")


def test_serving_engine_batches_and_latency():
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving import Request, ServeEngine
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=128,
                      tie_embeddings=True, param_dtype="float32",
                      compute_dtype="float32", attn_block_q=32,
                      attn_block_kv=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 128, 8 + i,
                                               dtype=np.int32),
                    max_new_tokens=8) for i in range(3)]
    eng.serve_batch(reqs)
    for r in reqs:
        assert len(r.tokens_out) == 8
        assert r.first_token_at is not None and r.done_at >= r.first_token_at
    # determinism
    reqs2 = [Request(rid=r.rid, prompt=r.prompt, max_new_tokens=8)
             for r in reqs]
    eng.serve_batch(reqs2)
    assert all(a.tokens_out == b.tokens_out for a, b in zip(reqs, reqs2))


def test_straggler_monitor():
    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)          # 5x the EMA
    assert len(mon.events) == 1
    assert not mon.observe(1.0)      # EMA not poisoned by the spike
