"""Property-based fault-model invariants (hypothesis).

Guarded by importorskip like tests/test_properties.py: hypothesis ships
via requirements-dev.txt and may be absent from minimal environments —
the deterministic fault tests in tests/test_faults.py still run there.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SimConfig, Simulator
from repro.core.metrics import records_sha256
from repro.core.workloads import get_scenario
from repro.faults import ExpMtbfFaults, WeibullFaults


@settings(max_examples=25, deadline=None)
@given(mtbf_h=st.floats(5.0, 500.0), mttr_h=st.floats(0.1, 24.0),
       seed=st.integers(0, 2**31 - 1), n_nodes=st.integers(1, 32))
def test_stream_deterministic_and_sorted(mtbf_h, mttr_h, seed, n_nodes):
    m = ExpMtbfFaults(mtbf_h=mtbf_h, mttr_h=mttr_h, horizon_days=3.0,
                      seed=seed)
    evs = m.events(n_nodes)
    assert evs == m.events(n_nodes)       # pure function of the params
    assert evs == sorted(evs)
    horizon = 3.0 * 86400.0
    assert all(e.t < horizon for e in evs if e.kind == "down")


@settings(max_examples=15, deadline=None)
@given(shape=st.floats(0.3, 3.0), seed=st.integers(0, 2**31 - 1))
def test_weibull_alternates_per_node(shape, seed):
    evs = WeibullFaults(shape=shape, scale_h=30.0, mttr_h=2.0,
                        horizon_days=3.0, seed=seed).events(8)
    per_node = {}
    for e in evs:
        per_node.setdefault(e.node, []).append(e.kind)
    for kinds in per_node.values():
        assert kinds == ["down", "up"] * (len(kinds) // 2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_none_digest_invariant(seed):
    jobs, n_nodes = get_scenario("bursty-od", n_jobs=12).realize(seed % 5)
    base = dict(n_nodes=n_nodes, mechanism="CUA&SPAA")
    ref = records_sha256(Simulator(SimConfig(**base), list(jobs)).run())
    got = records_sha256(Simulator(
        SimConfig(**base, faults="none"), list(jobs)).run())
    assert got == ref
