"""Edge cases of the vectorized decision kernels (repro.core.decision).

Randomized properties live in tests/test_properties.py (hypothesis,
importorskip'd); these deterministic cases pin the boundary semantics the
simulator's bit-for-bit golden guarantee leans on: need <= 0, zero
slack, exact-cover cumsum boundaries, largest-remainder ties, and the
shadow/prefilter kernels against their Python reference loops.
"""
import math

import numpy as np
import pytest

from repro.core import (apportion_shrink, backfill_prefilter,
                        backfill_shadow_filter, easy_shadow,
                        select_preemption_victims)


# ------------------------------------------------- select_preemption_victims
def test_paa_need_nonpositive_returns_empty():
    assert select_preemption_victims([100, 50], [1.0, 2.0], 0) == ([], 0)
    assert select_preemption_victims([100, 50], [1.0, 2.0], -5) == ([], 0)
    assert select_preemption_victims([], [], 0) == ([], 0)


def test_paa_insufficient_supply_returns_empty():
    assert select_preemption_victims([10, 20], [1.0, 2.0], 31) == ([], 0)


def test_paa_exact_cover_cumsum_boundary():
    # need lands exactly on a cumsum entry: that prefix, surplus 0 —
    # searchsorted must not include one victim too many
    victims, surplus = select_preemption_victims(
        [100, 100], [1.0, 2.0], 100)
    assert victims == [0] and surplus == 0
    victims, surplus = select_preemption_victims(
        [100, 100], [1.0, 2.0], 200)
    assert victims == [0, 1] and surplus == 0
    # one past the boundary pulls in the next victim
    victims, surplus = select_preemption_victims(
        [100, 100], [1.0, 2.0], 101)
    assert victims == [0, 1] and surplus == 99


def test_paa_equal_overheads_stable_order():
    victims, _ = select_preemption_victims([50, 50, 50], [7.0, 7.0, 7.0], 120)
    assert victims == [0, 1, 2]


# --------------------------------------------------------- apportion_shrink
def test_apportion_need_nonpositive_returns_zeros():
    assert apportion_shrink([10, 10], [2, 2], 0) == [0, 0]
    assert apportion_shrink([10, 10], [2, 2], -1) == [0, 0]


def test_apportion_zero_slack_cannot_cover():
    # cur == min everywhere: no slack, any positive need fails to []
    assert apportion_shrink([10, 10], [10, 10], 1) == []


def test_apportion_exact_slack_cover():
    # need equals the total slack: every job sheds down to its minimum
    assert apportion_shrink([10, 8], [4, 6], 8) == [6, 2]


def test_apportion_largest_remainder_ties_go_to_first():
    # equal slack, odd need: quotas are 1.5/1.5 — the stable argsort
    # hands the leftover node to the earlier job
    assert apportion_shrink([3, 3], [1, 1], 3) == [2, 1]
    # and with four tied jobs, the first `short` jobs get the extra node
    assert apportion_shrink([3, 3, 3, 3], [1, 1, 1, 1], 6) == [2, 2, 1, 1]


def test_apportion_respects_per_job_slack_cap():
    sheds = apportion_shrink([20, 4], [2, 3], 17)
    assert sheds == [16, 1]
    assert all(s <= c - m for s, c, m in zip(sheds, [20, 4], [2, 3]))


@pytest.mark.parametrize("cur, need", [
    # regression: need * slack overflows int64, wrapping into garbage
    # quotas whose clamped floors left a shortfall larger than the
    # number of jobs with fractional slack — the single-pass largest
    # remainder then promoted -inf entries and tripped the sum assert
    ([65045927626, 68844673057], 52072923076),
    ([26978671376, 4097352393, 1652763552, 81327023920, 91275557727],
     124561354304),
    ([32186939107, 59430003019], 30958393192),
])
def test_apportion_huge_slack_overflow_regression(cur, need):
    sheds = apportion_shrink(cur, [0] * len(cur), need)
    assert sum(sheds) == need
    assert all(0 <= s <= c for s, c in zip(sheds, cur))


# -------------------------------------------------------------- easy_shadow
def _shadow_reference(avail, need, bases, sizes, now):
    """The legacy Python loop easy_shadow replaced (plus the hardened
    avail-already-covers fast path: the head starts now, no release)."""
    if avail >= need:
        return now, avail - need
    rel = sorted((max(b, now), s) for b, s in zip(bases, sizes))
    for t, k in rel:
        avail += k
        if avail >= need:
            return t, avail - need
    return math.inf, 0


@pytest.mark.parametrize("seed", range(5))
def test_easy_shadow_matches_reference_loop(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 40))
    bases = rng.uniform(0.0, 1e5, n)
    sizes = rng.integers(1, 512, n)
    now = float(rng.uniform(0.0, 1e5))
    avail = int(rng.integers(0, 256))
    need = int(rng.integers(1, 4096))
    assert easy_shadow(avail, need, bases, sizes, now) == \
        _shadow_reference(avail, need, bases, sizes, now)


def test_easy_shadow_exact_cover_and_tie_order():
    # exact boundary: the crossing release's time, zero extra
    assert easy_shadow(0, 30, [5.0, 9.0], [10, 20], 0.0) == (9.0, 0)
    # tied est-ends accumulate in ascending-size order (the legacy
    # tuple-sort), which decides the surplus at the crossing
    assert easy_shadow(0, 5, [7.0, 7.0], [20, 10], 0.0) == (7.0, 5)
    # past-due estimates clamp to now
    t, extra = easy_shadow(0, 10, [3.0], [10], 50.0)
    assert (t, extra) == (50.0, 0)


def test_easy_shadow_insufficient_supply_is_infinite():
    assert easy_shadow(0, 100, [1.0], [10], 0.0) == (math.inf, 0)
    assert easy_shadow(0, 1, [], [], 0.0) == (math.inf, 0)


def test_easy_shadow_avail_covers_need_regression():
    # regression: empty running set with avail >= need used to walk
    # searchsorted off the empty cumsum and misreport an immediately
    # startable head as (inf, 0)
    assert easy_shadow(5, 3, [], [], 7.0) == (7.0, 2)
    assert easy_shadow(3, 3, [], [], 0.0) == (0.0, 0)
    # same fast path with running jobs present: the head starts now,
    # no release needs to be awaited
    assert easy_shadow(10, 4, [99.0, 50.0], [8, 8], 2.5) == (2.5, 6)


# ------------------------------------------------------- backfill prefilter
def test_backfill_prefilter_supply_bound_and_od_inf():
    needs = [64.0, math.inf, 128.0, 4096.0]
    idx = backfill_prefilter(needs, 128.0)
    assert idx.tolist() == [0, 2]         # inf (on-demand) never passes
    assert backfill_prefilter(needs, 0.0).tolist() == []


def test_backfill_shadow_filter_budget_or_hole():
    needs = np.array([10.0, 50.0, 50.0, 50.0])
    ests = np.array([100.0, 100.0, 1e6, 100.0])
    cand = np.arange(4)
    # budget 20, shadow at now+200: idx0 fits the budget, idx1/idx3 fit
    # the hole, idx2 fits neither
    keep = backfill_shadow_filter(needs, ests, cand, 20, 0.0, 200.0)
    assert keep.tolist() == [0, 1, 3]
    # only a subset of candidates is ever considered
    keep = backfill_shadow_filter(needs, ests, np.array([2, 3]), 20, 0.0, 200.0)
    assert keep.tolist() == [3]
