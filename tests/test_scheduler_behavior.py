"""Behavioral micro-scenarios for the six mechanisms (paper §III-B).

Randomized (hypothesis) drain/invariant properties live in
tests/test_properties.py, which importorskips hypothesis so a checkout
without the dev extras still collects and runs these deterministic tests.
"""
import pytest

from repro.core import (JobSpec, JobType, NoticeKind, SimConfig, Simulator)

N = 100  # cluster size for micro-scenarios


def rigid(jid, t, size, rt, est=None, setup=0.0, **kw):
    return JobSpec(jid, JobType.RIGID, "p", t, size, est or rt * 2, rt,
                   t_setup=setup, **kw)


def mall(jid, t, size, rt, est=None, setup=0.0, n_min=0):
    return JobSpec(jid, JobType.MALLEABLE, "p", t, size, est or rt * 2, rt,
                   t_setup=setup, n_min=n_min)


def od(jid, t, size, rt, kind=NoticeKind.NONE, notice=None, est_arr=None):
    return JobSpec(jid, JobType.ONDEMAND, "p", t, size, rt * 2, rt,
                   notice_kind=kind, notice_time=notice, est_arrival=est_arr)


def run(jobs, mech="N&PAA", n=N, **kw):
    sim = Simulator(SimConfig(n_nodes=n, mechanism=mech, **kw), jobs)
    sim.run()
    return sim


def test_od_instant_on_free_nodes():
    sim = run([od(0, 10.0, 50, 100.0)])
    r = sim.records[0]
    assert r.instant and r.first_start == 10.0 and r.completion == 110.0


def test_paa_preempts_cheapest_running_job():
    # two rigid jobs; the smaller/cheaper one (no progress to lose w/o ckpt,
    # equal setup rate) is preempted when the od job needs 30 nodes.
    jobs = [rigid(0, 0.0, 60, 1000.0, setup=10.0),
            rigid(1, 0.0, 40, 1000.0, setup=5.0),
            od(2, 100.0, 30, 50.0)]
    sim = run(jobs, "N&PAA")
    # free = 0 at t=100; od needs 30: preempt j1 (waste 40*(5+95) < 60*(10+90))
    assert sim.records[2].instant
    assert sim.records[1].n_preempted == 1
    assert sim.records[0].n_preempted == 0
    # preempted job resumes and completes; everything drains
    assert all(r.completion is not None for r in sim.records.values())


def test_spaa_shrinks_instead_of_preempting():
    jobs = [mall(0, 0.0, 80, 1000.0, n_min=20),
            od(1, 100.0, 50, 60.0)]
    sim = run(jobs, "N&SPAA")
    assert sim.records[1].instant
    assert sim.records[0].n_preempted == 0
    assert sim.records[0].n_shrunk == 1
    # malleable expands back after od completes and still finishes
    assert sim.records[0].completion is not None


def test_spaa_falls_back_to_paa_when_slack_insufficient():
    jobs = [mall(0, 0.0, 30, 500.0, n_min=25),     # slack 5 only
            rigid(1, 0.0, 70, 500.0, setup=1.0),
            od(2, 50.0, 60, 60.0)]
    sim = run(jobs, "N&SPAA")
    assert sim.records[2].instant
    assert sim.records[1].n_preempted + sim.records[0].n_preempted >= 1


def test_paa_insufficient_supply_queues_od_at_front():
    # a running od occupies most of the system; ods are not preemptable
    jobs = [od(0, 0.0, 90, 500.0),
            od(1, 10.0, 50, 100.0)]
    sim = run(jobs, "N&PAA")
    assert sim.records[0].instant
    assert not sim.records[1].instant
    # od1 starts right when od0 completes
    assert sim.records[1].first_start == pytest.approx(500.0)


def test_cua_collects_released_nodes_before_arrival():
    # j0 releases 60 nodes at t=100, within [notice=50, arrival=200]
    jobs = [rigid(0, 0.0, 60, 100.0),
            rigid(1, 0.0, 40, 1000.0),
            od(2, 200.0, 60, 50.0, NoticeKind.ACCURATE, notice=50.0,
               est_arr=200.0)]
    sim = run(jobs, "CUA&PAA")
    assert sim.records[2].instant
    assert sim.records[1].n_preempted == 0  # reservation avoided preemption


def test_reservation_released_after_timeout():
    # od notices at 50, est arrival 100, but actually arrives at 5000
    # (far beyond the 600 s threshold): reserved nodes must return so the
    # queued rigid job can start before the od arrives.
    jobs = [rigid(0, 0.0, 60, 100.0),
            od(1, 5000.0, 60, 50.0, NoticeKind.LATE, notice=50.0,
               est_arr=100.0),
            rigid(2, 120.0, 80, 100.0)]
    sim = run(jobs, "CUA&PAA")
    r2 = sim.records[2]
    assert r2.first_start is not None and r2.first_start < 1000.0
    assert sim.records[1].completion is not None


def test_cup_preempts_rigid_after_checkpoint():
    # one big rigid job with checkpoints; CUP should vacate it right after a
    # checkpoint completes, before the od's estimated arrival.
    jobs = [rigid(0, 0.0, 90, 5000.0, setup=10.0,
                  ckpt_overhead=50.0, ckpt_interval=500.0),
            od(1, 2000.0, 80, 100.0, NoticeKind.ACCURATE, notice=1000.0,
               est_arr=2000.0)]
    sim = run(jobs, "CUP&PAA")
    assert sim.records[1].instant
    assert sim.records[0].n_preempted == 1
    assert sim.records[0].completion is not None


def test_lease_returned_to_preempted_lender():
    # od preempts j0 entirely; when od finishes, j0 reclaims nodes + resumes.
    jobs = [rigid(0, 0.0, 100, 1000.0, setup=10.0),
            od(1, 100.0, 100, 50.0)]
    sim = run(jobs, "N&PAA")
    assert sim.records[1].instant
    r0 = sim.records[0]
    assert r0.n_preempted == 1
    # resumes immediately at od completion (150) and reruns from scratch
    assert r0.completion == pytest.approx(150.0 + 10.0 + 1000.0 - 10.0, abs=2.0)


def test_killed_at_estimate():
    j = JobSpec(0, JobType.RIGID, "p", 0.0, 10, t_estimate=100.0,
                t_actual=100.0, t_setup=0.0)
    j.t_actual = 100.0
    sim = run([j])
    assert sim.records[0].completion == pytest.approx(100.0)
    assert not sim.records[0].killed  # exactly finished


def test_easy_backfill_small_job_jumps_queue():
    # head job needs 100 nodes (blocked until t=1000); a 20-node short job
    # submitted later must backfill into the hole.
    jobs = [rigid(0, 0.0, 90, 1000.0),
            rigid(1, 10.0, 100, 500.0),        # blocked head
            rigid(2, 20.0, 10, 100.0, est=100.0)]  # fits the hole: est end 120 < 1000
    sim = run(jobs, "BASE")
    assert sim.records[2].first_start == pytest.approx(20.0)
    assert sim.records[1].first_start == pytest.approx(1000.0)


def test_backfill_on_reserved_nodes_preempted_at_arrival():
    # CUA reserves 50 nodes at notice; a malleable job backfills onto them
    # (cheap preemption) and is preempted the moment the od arrives.
    jobs = [rigid(0, 0.0, 50, 2000.0),
            od(1, 1000.0, 50, 100.0, NoticeKind.ACCURATE, notice=100.0,
               est_arr=1000.0),
            mall(2, 150.0, 50, 5000.0, est=6000.0, n_min=40)]
    sim = run(jobs, "CUA&PAA")
    assert sim.records[1].instant
    assert sim.records[2].n_preempted == 1
    assert sim.records[2].first_start == pytest.approx(150.0)


def test_rigid_wont_borrow_reserved_past_est_arrival():
    # same shape but a rigid borrower whose estimate runs past the od's
    # estimated arrival: it must NOT start on the reserved nodes.
    jobs = [rigid(0, 0.0, 50, 2000.0),
            od(1, 1000.0, 50, 100.0, NoticeKind.ACCURATE, notice=100.0,
               est_arr=1000.0),
            rigid(2, 150.0, 50, 5000.0, est=6000.0)]
    sim = run(jobs, "CUA&PAA")
    assert sim.records[1].instant
    assert sim.records[2].n_preempted == 0
    assert sim.records[2].first_start > 1000.0


def test_xfactor_ages_short_jobs_ahead_of_long():
    """queue_policy="XFACTOR": expansion-factor priority ranks the short
    waiter above the long one (its xfactor grows ~200x faster), while
    plain EASY keeps FCFS order and strands it behind the wide head."""
    def jobs():
        return [rigid(0, 0.0, N, 500.0),                  # fills the machine
                rigid(1, 1.0, N, 10000.0, est=20000.0),   # long, wide, first
                rigid(2, 2.0, 10, 50.0, est=100.0)]       # short, later
    easy = run(jobs())
    assert easy.records[1].first_start == pytest.approx(500.0)
    assert easy.records[2].first_start == pytest.approx(10500.0)
    xf = run(jobs(), queue_policy="XFACTOR")
    assert xf.records[2].first_start == pytest.approx(500.0)
    assert xf.records[1].first_start == pytest.approx(550.0)
