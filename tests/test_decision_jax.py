"""Parity suite: jitted padded decision kernels vs numpy references.

The numpy kernels in repro.core.decision are the bit-for-bit references;
under x64 every JAX port must match them *exactly* (same IEEE
expressions, same stable sort order), including the hardened boundary
semantics (empty running set, avail-covers-need, exact cumsum cover,
int64-overflow apportionment).  Under float32 the documented contract is
weaker: continuous outputs within FLOAT32_RTOL, discrete outputs checked
by structural invariants (exact sums, per-job caps).

Randomized cases draw padded lengths from a small fixed set so each
jitted wrapper compiles a handful of shapes, not one per example.
"""
import math

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import decision as D
from repro.core import decision_jax as J
from repro.core.experiment import Experiment
from repro.core.policy import registered_mechanisms
from repro.core.workloads import WorkloadConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: requirements-dev only
    HAVE_HYPOTHESIS = False

# bounded pad shapes: the single-call wrappers trace once per (shape,
# dtype), so random examples reuse a handful of compiled programs
SIZES = (0, 1, 2, 3, 7, 16)


def _same_shadow(a, b):
    return (a == b) or (math.isinf(a[0]) and math.isinf(b[0])
                        and a[1] == b[1])


# ------------------------------------------------------------ exact parity
@pytest.mark.parametrize("seed", range(4))
def test_easy_shadow_parity_x64(seed):
    rng = np.random.default_rng(seed)
    for n in SIZES:
        for _ in range(8):
            avail = int(rng.integers(0, 50))
            need = int(rng.integers(1, 60))
            bases = rng.uniform(0.0, 100.0, n)
            sizes = rng.integers(1, 20, n)
            now = float(rng.uniform(0.0, 50.0))
            ref = D.easy_shadow(avail, need, bases, sizes, now)
            got = J.easy_shadow_jax(avail, need, bases, sizes, now)
            assert _same_shadow(ref, got), (avail, need, bases, sizes, now)


@pytest.mark.parametrize("seed", range(4))
def test_victims_parity_x64(seed):
    rng = np.random.default_rng(seed)
    for n in SIZES:
        for _ in range(8):
            sizes = rng.integers(1, 20, n)
            over = rng.uniform(0.0, 100.0, n)
            need = int(rng.integers(0, 80))
            assert D.select_preemption_victims(sizes, over, need) == \
                J.select_preemption_victims_jax(sizes, over, need)


@pytest.mark.parametrize("seed", range(4))
def test_apportion_parity_x64(seed):
    rng = np.random.default_rng(seed)
    for n in SIZES:
        for _ in range(8):
            mn = rng.integers(0, 10, n)
            cur = mn + rng.integers(0, 20, n)
            need = int(rng.integers(0, 60))
            assert D.apportion_shrink(cur, mn, need) == \
                J.apportion_shrink_jax(cur, mn, need)


@pytest.mark.parametrize("seed", range(4))
def test_backfill_filters_parity_x64(seed):
    rng = np.random.default_rng(seed)
    for n in SIZES:
        for _ in range(6):
            needs = np.where(rng.random(n) < 0.2, np.inf,
                             rng.integers(1, 30, n).astype(float))
            bound = float(rng.integers(0, 40))
            assert np.array_equal(D.backfill_prefilter(needs, bound),
                                  J.backfill_prefilter_jax(needs, bound))
    for k in SIZES:
        N = max(k, 1) + 3
        needs = rng.integers(1, 30, N).astype(float)
        ests = rng.uniform(0.0, 100.0, N)
        cand = np.sort(rng.choice(N, size=k, replace=False))
        budget = int(rng.integers(0, 40))
        now = float(rng.uniform(0.0, 50.0))
        ts = float(rng.uniform(0.0, 150.0))
        assert np.array_equal(
            D.backfill_shadow_filter(needs, ests, cand, budget, now, ts),
            J.backfill_shadow_filter_jax(needs, ests, cand, budget, now, ts))


# -------------------------------------------------------------- boundaries
def test_easy_shadow_boundaries():
    # empty running set, avail covers: the hardened (now, extra) path
    assert J.easy_shadow_jax(5, 3, [], [], 7.0) == (7.0, 2)
    assert J.easy_shadow_jax(3, 3, [], [], 0.0) == (0.0, 0)
    # empty running set, cannot cover
    t, extra = J.easy_shadow_jax(0, 1, [], [], 0.0)
    assert math.isinf(t) and extra == 0
    # exact cumsum cover at a release
    assert J.easy_shadow_jax(0, 30, [5.0, 9.0], [10, 20], 0.0) == (9.0, 0)
    # tied est-ends accumulate in ascending-size order
    assert J.easy_shadow_jax(0, 5, [7.0, 7.0], [20, 10], 0.0) == (7.0, 5)


def test_victims_and_apportion_boundaries():
    assert J.select_preemption_victims_jax([], [], 0) == ([], 0)
    assert J.select_preemption_victims_jax([100, 100], [1.0, 2.0], 100) \
        == ([0], 0)
    assert J.select_preemption_victims_jax([10, 20], [1.0, 2.0], 31) \
        == ([], 0)
    assert J.apportion_shrink_jax([10, 8], [4, 6], 8) == [6, 2]
    assert J.apportion_shrink_jax([10, 10], [10, 10], 1) == []
    assert J.apportion_shrink_jax([10, 10], [2, 2], 0) == [0, 0]


@pytest.mark.parametrize("cur, need", [
    ([65045927626, 68844673057], 52072923076),
    ([26978671376, 4097352393, 1652763552, 81327023920, 91275557727],
     124561354304),
])
def test_apportion_overflow_regression_parity(cur, need):
    # the int64-overflow regime exercises the guarded quota branch on
    # both sides; parity must survive it
    ref = D.apportion_shrink(cur, [0] * len(cur), need)
    got = J.apportion_shrink_jax(cur, [0] * len(cur), need)
    assert ref == got and sum(got) == need


# ------------------------------------------------------- float32 fallback
def test_float32_shadow_within_documented_tolerance():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.choice([c for c in SIZES if c]))
        avail = int(rng.integers(0, 30))
        need = int(rng.integers(1, 50))
        bases = rng.uniform(0.0, 100.0, n)
        sizes = rng.integers(1, 20, n)
        now = float(rng.uniform(0.0, 50.0))
        ref_t, _ = D.easy_shadow(avail, need, bases, sizes, now)
        got_t, _ = J.easy_shadow_jax(avail, need, bases, sizes, now,
                                     dtype="float32")
        if math.isinf(ref_t):
            assert math.isinf(got_t)
        else:
            assert abs(got_t - ref_t) <= \
                J.FLOAT32_RTOL * max(abs(ref_t), 1.0)


def test_float32_apportion_invariants_hold():
    rng = np.random.default_rng(11)
    for _ in range(20):
        n = int(rng.choice([c for c in SIZES if c]))
        mn = rng.integers(0, 10, n)
        cur = mn + rng.integers(0, 20, n)
        slack = np.maximum(cur - mn, 0)
        supply = int(slack.sum())
        if supply == 0:
            continue
        need = int(rng.integers(1, supply + 1))
        got = J.apportion_shrink_jax(cur, mn, need, dtype="float32")
        assert sum(got) == need
        assert all(0 <= g <= s for g, s in zip(got, slack))


def test_bad_dtype_rejected():
    with pytest.raises(ValueError, match="dtype"):
        J.easy_shadow_jax(1, 1, [], [], 0.0, dtype="bfloat16")


# ----------------------------------------------------- hypothesis parity
if HAVE_HYPOTHESIS:
    @given(st.integers(0, 64), st.integers(1, 128),
           st.lists(st.tuples(st.floats(0, 1e4), st.integers(1, 32)),
                    min_size=0, max_size=16),
           st.floats(0, 1e4))
    @settings(max_examples=60, deadline=None)
    def test_hyp_easy_shadow_parity(avail, need, jobs, now):
        # pad every draw to one shape so hypothesis explores values, not
        # compile cache entries
        jobs = jobs + [(math.inf, 0)] * (16 - len(jobs))
        bases = [j[0] for j in jobs]
        sizes = [j[1] for j in jobs]
        ref = D.easy_shadow(avail, need, bases, sizes, now)
        got = J.easy_shadow_jax(avail, need, bases, sizes, now)
        assert _same_shadow(ref, got)

    @given(st.lists(st.integers(0, 10**11), min_size=8, max_size=8),
           st.data())
    @settings(max_examples=60, deadline=None)
    def test_hyp_apportion_parity_any_scale(slacks, data):
        need = data.draw(st.integers(0, sum(slacks)))
        assert D.apportion_shrink(slacks, [0] * 8, need) == \
            J.apportion_shrink_jax(slacks, [0] * 8, need)


# ----------------------------------------- batched grid: all mechanisms
def test_device_sweep_parity_across_all_registered_mechanisms():
    mechs = registered_mechanisms()
    exp = Experiment(mechanisms=mechs,
                     workloads=[WorkloadConfig(n_jobs=50, notice_mix="W3")],
                     seeds=(0,), processes=0,
                     device="jax", device_capture=64)
    res = exp.run()
    rep = res.device_report
    assert rep.n_cells == len(mechs)
    assert rep.n_programs == 1
    assert rep.n_calls > 0
    assert rep.parity_ok, rep.mismatches[:5]
    # the device replay is an overlay: metrics equal the plain fan-out
    base = Experiment(mechanisms=mechs, workloads=exp.workloads,
                      seeds=(0,), processes=0).run()
    assert [r.metrics.as_dict() for r in res] == \
        [r.metrics.as_dict() for r in base]


def test_capture_trace_survives_pickle_and_fanout_shape():
    import pickle

    with D.capture(limit=4) as tr:
        D.easy_shadow(5, 3, [], [], 7.0)
        D.apportion_shrink([4, 4], [1, 1], 3)
    tr2 = pickle.loads(pickle.dumps(tr))
    assert tr2.n_calls() == tr.n_calls() == 2
    cells = [("cell0", tr2)]
    rep = J.run_device_sweep(cells)
    assert rep.parity_ok and rep.n_calls == 2
