"""Unit tests for the scheduling core (decision kernels, job math).

Randomized (hypothesis) properties of the decision kernels live in
tests/test_properties.py, which importorskips hypothesis so a checkout
without the dev extras still collects and runs these deterministic tests.
"""
import math

import pytest

from repro.core import JobSpec, JobType, daly_interval, select_preemption_victims
from repro.core.job import RunState


# --------------------------------------------------------------- decision
def test_paa_prefers_cheap_victims():
    victims, surplus = select_preemption_victims(
        sizes=[100, 100, 100], overheads=[50.0, 5.0, 500.0], need=150)
    assert victims == [1, 0] and surplus == 50


# --------------------------------------------------------------- Daly model
def test_daly_interval_formula():
    tau = daly_interval(600.0, 100 * 3600.0)
    assert tau == pytest.approx(math.sqrt(2 * 600 * 360000) - 600)
    assert daly_interval(600.0, math.inf) == math.inf


# --------------------------------------------------------------- rigid math
def _rigid(tau=1000.0, delta=100.0, setup=50.0, t_actual=3500.0, n=10):
    return JobSpec(0, JobType.RIGID, "p", 0.0, n, t_estimate=5000.0,
                   t_actual=t_actual, t_setup=setup,
                   ckpt_overhead=delta, ckpt_interval=tau)


def test_rigid_compute_structure():
    # 3500 = 50 setup + [1000 work + 100 ckpt] x k + tail
    j = _rigid()
    # elapsed after setup: 3450 -> 3 full segments (3300) + 150 tail work
    assert j.compute_time == pytest.approx(3 * 1000 + 150)
    assert j.work == pytest.approx(3150 * 10)


def test_rigid_progress_and_checkpoint_accounting():
    j = _rigid()
    rs = RunState(job=j, start_time=0.0, cur_size=j.size)
    # during setup: no progress
    assert rs.work_done(25.0) == 0.0
    # mid first work segment
    assert rs.work_done(50.0 + 500.0) == pytest.approx(500 * 10)
    assert rs.checkpointed_work(550.0) == 0.0
    # right after first checkpoint completes (t = 50 + 1000 + 100)
    assert rs.checkpointed_work(1151.0) == pytest.approx(1000 * 10)
    # during a checkpoint, work does not advance
    assert rs.work_done(50 + 1000 + 50) == pytest.approx(1000 * 10)
    # natural end = uninterrupted trace runtime
    assert rs.natural_end(0.0) == pytest.approx(j.t_actual)
    assert rs.natural_end(2000.0) == pytest.approx(j.t_actual)


def test_rigid_preemption_overhead_grows_since_checkpoint():
    j = _rigid()
    rs = RunState(job=j, start_time=0.0, cur_size=j.size)
    o1 = rs.preemption_overhead(1150.0)   # right after ckpt: setup only
    o2 = rs.preemption_overhead(1150.0 + 500.0)
    assert o1 == pytest.approx(j.t_setup * j.size)
    assert o2 == pytest.approx(j.t_setup * j.size + 500 * 10)


def test_next_ckpt_completion():
    j = _rigid()
    rs = RunState(job=j, start_time=0.0, cur_size=j.size)
    assert rs.next_ckpt_completion(0.0) == pytest.approx(50 + 1000 + 100)
    assert rs.next_ckpt_completion(1200.0) == pytest.approx(50 + 2 * 1100)
    # near the end: no checkpoint after the last segment
    assert rs.next_ckpt_completion(3400.0) is None


def test_malleable_linear_speedup():
    j = JobSpec(1, JobType.MALLEABLE, "p", 0.0, 100, t_estimate=4000.0,
                t_actual=2100.0, t_setup=100.0)
    assert j.n_min == 20
    assert j.work == pytest.approx(2000 * 100)
    rs = RunState(job=j, start_time=0.0, cur_size=50)
    # at half size, compute takes twice as long
    assert rs.natural_end(0.0) == pytest.approx(100 + 2000 * 100 / 50)


def test_malleable_resize_preserves_work():
    j = JobSpec(1, JobType.MALLEABLE, "p", 0.0, 100, t_estimate=4000.0,
                t_actual=2100.0, t_setup=100.0)
    rs = RunState(job=j, start_time=0.0, cur_size=100)
    t = 600.0  # 500 s of compute done
    rs.work_at_resize = rs.work_done(t)
    rs.last_resize = t
    rs.cur_size = 40
    assert rs.work_done(t) == pytest.approx(500 * 100)
    rem = j.work - 500 * 100
    assert rs.natural_end(t) == pytest.approx(t + rem / 40)
