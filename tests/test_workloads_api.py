"""Composable workload API: registries, invariants, transforms, sweeps.

The shared trace invariants (sorted arrivals, contiguous jids, on-demand
size cap, notice geometry) run against BOTH the synthetic generator and
the SWF trace reader; source-specific checks (offered load vs
target_load, Table III proportions) follow.
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from repro.core import (Experiment, JobType, NoticeKind, Scenario,
                        UnknownWorkloadError, WorkloadConfig,
                        WorkloadDataError, collect, generate, get_scenario,
                        get_source, get_transform, notice_mix,
                        registered_scenarios, registered_sources,
                        registered_transforms, register_source, SimConfig,
                        Simulator, SwfTrace, WorkloadSource)
from repro.core.workloads import canonicalize
from repro.core.workloads.swf import parse_swf

SAMPLE_SWF = os.path.join(os.path.dirname(__file__), "data", "sample.swf")
SMALL = dict(n_jobs=120, n_nodes=512, n_projects=12, horizon_days=4.0)


def assert_trace_invariants(jobs, n_nodes):
    """The invariants every source and scenario must satisfy."""
    assert jobs, "empty trace"
    assert [j.jid for j in jobs] == list(range(len(jobs)))  # contiguous jids
    assert all(a.submit_time <= b.submit_time
               for a, b in zip(jobs, jobs[1:]))             # sorted arrivals
    for j in jobs:
        assert 1 <= j.size <= n_nodes
        assert j.t_actual > 0
        assert j.t_actual <= j.t_estimate + 1e-6
        if j.jtype is JobType.MALLEABLE:
            assert 1 <= j.n_min <= j.size
        if j.jtype is JobType.ONDEMAND:
            assert j.size <= n_nodes // 2                   # od size cap
            if j.notice_kind is not NoticeKind.NONE:
                assert j.notice_time is not None
                assert j.est_arrival is not None
                assert j.notice_time <= j.submit_time
                if j.notice_kind is NoticeKind.LATE:
                    assert j.submit_time >= j.est_arrival - 1e-6
                if j.notice_kind is NoticeKind.EARLY:
                    assert j.submit_time <= j.est_arrival + 1e-6


def _generator_jobs():
    cfg = WorkloadConfig(seed=3, **SMALL)
    return generate(cfg), cfg.n_nodes


def _swf_jobs():
    src = SwfTrace(SAMPLE_SWF, seed=3, frac_od_projects=0.3)
    return src.jobs(), src.n_nodes


@pytest.mark.parametrize("build", [_generator_jobs, _swf_jobs],
                         ids=["theta", "swf"])
def test_trace_invariants_both_sources(build):
    jobs, n_nodes = build()
    assert_trace_invariants(jobs, n_nodes)


@pytest.mark.parametrize("build", [_generator_jobs, _swf_jobs],
                         ids=["theta", "swf"])
def test_sources_are_deterministic_per_seed(build):
    a, _ = build()
    b, _ = build()
    assert [dataclasses.asdict(x) for x in a] == \
           [dataclasses.asdict(x) for x in b]


def test_offered_load_within_tolerance_of_target():
    cfg = WorkloadConfig(n_jobs=1500, n_nodes=4392, seed=0, target_load=1.15,
                         horizon_days=60.0)  # horizon must not clip the span
    jobs = generate(cfg)
    span = max(j.submit_time for j in jobs) - min(j.submit_time for j in jobs)
    work = sum(j.t_actual * j.size for j in jobs)
    load = work / (span * cfg.n_nodes)
    assert abs(load - cfg.target_load) / cfg.target_load < 0.35


@pytest.mark.parametrize("mix", ["W1", "W2", "W5"])
def test_table3_notice_mix_proportions_generator(mix):
    cfg = WorkloadConfig(n_jobs=3000, n_nodes=2048, seed=3, notice_mix=mix,
                         frac_od_projects=0.5, frac_rigid_projects=0.3)
    jobs = generate(cfg)
    od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
    assert len(od) > 300
    target = dict(zip([NoticeKind.NONE, NoticeKind.ACCURATE,
                       NoticeKind.EARLY, NoticeKind.LATE], notice_mix(mix)))
    for kind, frac in target.items():
        got = np.mean([j.notice_kind is kind for j in od])
        assert abs(got - frac) < 0.10, (mix, kind, got)


def test_table3_notice_mix_proportions_swf():
    src = SwfTrace(SAMPLE_SWF, seed=1, frac_od_projects=1.0,
                   frac_rigid_projects=0.0, notice_mix="W2")
    od = [j for j in src.jobs() if j.jtype is JobType.ONDEMAND]
    assert len(od) > 40
    frac_acc = np.mean([j.notice_kind is NoticeKind.ACCURATE for j in od])
    assert 0.5 < frac_acc < 0.9  # W2: 70% accurate notice


# ------------------------------------------------------------------ legacy
def test_scenario_theta_matches_legacy_generate_bit_for_bit():
    cfg_kw = dict(seed=11, notice_mix="W3", **SMALL)
    legacy = generate(WorkloadConfig(**cfg_kw))
    via_api, n_nodes = Scenario("theta", params=dict(cfg_kw)).realize(seed=11)
    assert n_nodes == SMALL["n_nodes"]
    assert [dataclasses.asdict(j) for j in legacy] == \
           [dataclasses.asdict(j) for j in via_api]


def test_legacy_workload_module_still_imports():
    from repro.core import workload as legacy
    assert legacy.WorkloadConfig is WorkloadConfig
    assert legacy.generate is generate
    assert legacy.notice_mix is notice_mix


# ---------------------------------------------------------------- registries
def test_builtin_sources_transforms_scenarios_registered():
    assert {"theta", "swf"} <= set(registered_sources())
    assert {"load_scale", "burst_inject", "diurnal", "notice_mix",
            "type_mix"} <= set(registered_transforms())
    assert {"W1", "W2", "W3", "W4", "W5", "bursty-od", "diurnal",
            "trace-replay"} <= set(registered_scenarios())


def test_unknown_names_raise_listing_registry():
    with pytest.raises(UnknownWorkloadError) as ei:
        get_source("NOPE")
    assert "theta" in str(ei.value) and "swf" in str(ei.value)
    with pytest.raises(UnknownWorkloadError) as ei:
        get_transform("NOPE")
    assert "load_scale" in str(ei.value)
    with pytest.raises(UnknownWorkloadError) as ei:
        get_scenario("NOPE")
    assert "bursty-od" in str(ei.value)
    with pytest.raises(UnknownWorkloadError) as ei:
        generate(WorkloadConfig(notice_mix="W9", n_jobs=10))
    msg = str(ei.value)
    assert "W9" in msg
    for valid in ("W1", "W2", "W3", "W4", "W5"):
        assert valid in msg
    assert isinstance(ei.value, ValueError)  # backward compatible


def test_scenario_validate_fails_fast_without_building():
    with pytest.raises(UnknownWorkloadError):
        Scenario("no_such_source").validate()
    with pytest.raises(UnknownWorkloadError):
        Scenario("theta", transforms=(("no_such_transform", {}),)).validate()
    # worker-deterministic errors must be caught before process fan-out:
    # a bad mix or a missing trace would otherwise cost a serial re-run
    with pytest.raises(UnknownWorkloadError):
        Scenario("theta", params={"notice_mix": "W9"}).validate()
    with pytest.raises(UnknownWorkloadError):
        Scenario("theta",
                 transforms=(("notice_mix", {"mix": "W9"}),)).validate()
    with pytest.raises(WorkloadDataError, match="not found"):
        Scenario("swf", params={"path": "/no/such/file.swf"}).validate()
    Scenario("theta", transforms=(("load_scale", {"factor": 2.0}),)).validate()
    Scenario("swf", params={"path": SAMPLE_SWF}).validate()


def test_register_custom_source_end_to_end():
    name = "_TEST_TWO_JOBS"
    if name not in registered_sources():
        @register_source(name)
        class TwoJobs(WorkloadSource):
            def __init__(self, n_nodes=64, seed=0):
                self.n_nodes, self.seed = n_nodes, seed

            def jobs(self):
                from repro.core import JobSpec
                return canonicalize([
                    JobSpec(-1, JobType.RIGID, "p", 50.0, 32, 2000.0, 1000.0),
                    JobSpec(-1, JobType.RIGID, "p", 0.0, 32, 2000.0, 1000.0)])

    res = Experiment(mechanisms=("BASE",),
                     workloads=(Scenario(name, name="twojobs"),),
                     seeds=(0,), processes=1).run()
    assert res.runs[0].metrics.n_jobs == 2
    assert res.runs[0].metrics.n_completed == 2


# ---------------------------------------------------------------- transforms
def _theta_small(seed=0, **kw):
    return generate(WorkloadConfig(seed=seed, **{**SMALL, **kw}))


def test_load_scale_compresses_span():
    rng = np.random.default_rng(0)
    base = _theta_small()
    span0 = max(j.submit_time for j in base) - min(j.submit_time for j in base)
    scaled = get_transform("load_scale", factor=2.0).apply(
        _theta_small(), rng, SMALL["n_nodes"])
    span1 = max(j.submit_time for j in scaled) - min(j.submit_time
                                                     for j in scaled)
    assert span1 == pytest.approx(span0 / 2.0)
    assert_trace_invariants(canonicalize(scaled), SMALL["n_nodes"])


def test_burst_inject_adds_od_jobs_and_keeps_invariants():
    sc = Scenario("theta", params=dict(seed=0, **SMALL),
                  transforms=(("burst_inject",
                               {"n_bursts": 3, "burst_size": (4, 6),
                                "size": (32, 128), "mix": "W5"}),))
    jobs, n_nodes = sc.realize(seed=0)
    base = _theta_small()
    extra = [j for j in jobs if j.project.startswith("odburst")]
    assert len(jobs) == len(base) + len(extra)
    assert 12 <= len(extra) <= 18
    assert all(j.jtype is JobType.ONDEMAND for j in extra)
    assert_trace_invariants(jobs, n_nodes)


def test_burst_inject_respects_od_cap_on_small_systems():
    # the preset draws sizes up to 256; on a 200-node machine the
    # injected on-demand jobs must still respect the half-system cap
    sc = Scenario("theta", params=dict(seed=0, n_jobs=60, n_nodes=200,
                                       n_projects=8, horizon_days=4.0),
                  transforms=(("burst_inject",
                               {"n_bursts": 3, "burst_size": (4, 6),
                                "size": (64, 256)}),))
    jobs, n_nodes = sc.realize(seed=0)
    assert n_nodes == 200
    assert_trace_invariants(jobs, n_nodes)


def test_diurnal_modulation_concentrates_arrivals():
    sc = Scenario("theta", params=dict(seed=0, **SMALL),
                  transforms=(("diurnal", {"amplitude": 0.9}),))
    jobs, n_nodes = sc.realize(seed=0)
    base = _theta_small()
    assert len(jobs) == len(base)
    assert_trace_invariants(jobs, n_nodes)
    # same span endpoints, but arrivals pile up around the daily peak:
    # the dispersion of time-of-day phases must shrink vs the flat trace
    def phase_concentration(js):
        ph = np.array([j.submit_time for j in js]) * (2 * np.pi / 86400.0)
        return np.hypot(np.mean(np.cos(ph)), np.mean(np.sin(ph)))
    assert phase_concentration(jobs) > phase_concentration(base) + 0.1


def test_notice_mix_override_rewrites_proportions():
    base = _theta_small(frac_od_projects=0.5, frac_rigid_projects=0.3,
                        n_jobs=2000, notice_mix="W1")
    rng = np.random.default_rng(0)
    jobs = get_transform("notice_mix", mix="W2").apply(base, rng,
                                                       SMALL["n_nodes"])
    od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
    frac_acc = np.mean([j.notice_kind is NoticeKind.ACCURATE for j in od])
    assert 0.6 < frac_acc < 0.8  # was 10% under W1, now 70%
    assert_trace_invariants(canonicalize(jobs), SMALL["n_nodes"])


def test_type_mix_reassigns_types_per_project():
    n_nodes = SMALL["n_nodes"]
    base = _theta_small(n_jobs=2000)
    rng = np.random.default_rng(0)
    jobs = get_transform("type_mix", frac_od=0.0, frac_rigid=1.0).apply(
        base, rng, n_nodes)
    assert all(j.jtype is JobType.RIGID for j in jobs)
    # promoted rigids get the generator's Daly checkpoint model, not an
    # infinite interval that would forfeit all work on preemption
    assert all(math.isfinite(j.ckpt_interval) and j.ckpt_overhead > 0
               for j in jobs)
    # per-project assignment: with a cap no job exceeds, every project is
    # single-typed (the paper's per-project rule)
    jobs = get_transform("type_mix", frac_od=0.3, frac_rigid=0.3,
                         od_max_size=n_nodes).apply(jobs, rng, n_nodes)
    types = {t: sum(j.jtype is t for j in jobs) for t in JobType}
    assert all(v > 0 for v in types.values())
    for p in {j.project for j in jobs}:
        assert len({j.jtype for j in jobs if j.project == p}) == 1
    # default cap = half the system: oversized ods bounce to rigid/malleable
    jobs = get_transform("type_mix", frac_od=1.0, frac_rigid=0.0).apply(
        jobs, rng, n_nodes)
    assert all(j.size <= n_nodes // 2
               for j in jobs if j.jtype is JobType.ONDEMAND)
    assert any(j.jtype is not JobType.ONDEMAND for j in jobs)  # bounced
    assert_trace_invariants(canonicalize(jobs), n_nodes)


def test_transform_param_validation():
    with pytest.raises(ValueError):
        get_transform("load_scale", factor=0.0)
    with pytest.raises(ValueError):
        get_transform("diurnal", amplitude=1.5)
    with pytest.raises(ValueError):
        get_transform("type_mix", frac_od=0.8, frac_rigid=0.8)


# ----------------------------------------------------------------------- swf
def test_parse_swf_header_and_filtering():
    records, header = parse_swf(SAMPLE_SWF)
    assert header["MaxNodes"] == "512"
    assert len(records) == 82  # raw lines, incl. cancelled + unsized
    src = SwfTrace(SAMPLE_SWF, seed=0)
    jobs = src.jobs()
    assert src.n_nodes == 512  # from the MaxNodes directive
    assert len(jobs) == 80     # cancelled (status 5) + unsized dropped
    assert min(j.submit_time for j in jobs) == 0.0  # normalized to t=0
    for j in jobs:
        assert j.t_estimate >= j.t_actual  # kill limit never truncates
        if j.jtype is JobType.RIGID:
            # generator-consistent Daly model: preemption must not
            # forfeit all completed work
            assert math.isfinite(j.ckpt_interval) and j.ckpt_overhead > 0


def test_swf_n_nodes_override_and_unknown_mix():
    src = SwfTrace(SAMPLE_SWF, n_nodes=256, seed=0)
    assert src.n_nodes == 256
    assert all(j.size <= 256 for j in src.jobs())
    with pytest.raises(UnknownWorkloadError):
        SwfTrace(SAMPLE_SWF, notice_mix="W0").jobs()


def test_corrupt_swf_raises_data_error_not_registry_error(tmp_path):
    bad = tmp_path / "bad.swf"
    bad.write_text("; MaxNodes: 64\n1 0 0 100 8 x y z\n")
    with pytest.raises(WorkloadDataError, match="unparseable"):
        SwfTrace(str(bad))
    # data errors must NOT look like registry misses: Experiment retries
    # those serially, which would re-run entire sweeps for a bad trace
    assert not isinstance(WorkloadDataError("x"), UnknownWorkloadError)
    empty = tmp_path / "empty.swf"
    empty.write_text("; MaxNodes: 64\n1 0 0 -1 0 -1 -1 0 -1 -1 0 1 1\n")
    with pytest.raises(WorkloadDataError, match="no usable jobs"):
        SwfTrace(str(empty)).jobs()


def test_scenario_n_nodes_override_reaches_the_source():
    # the override must reshape the trace (size clip + od cap), not just
    # the SimConfig: jobs larger than the simulated machine can never run
    jobs, n_nodes = Scenario("theta", params=dict(seed=0, **SMALL),
                             n_nodes=200).realize(seed=0)
    assert n_nodes == 200
    assert_trace_invariants(jobs, 200)
    jobs, n_nodes = Scenario("swf", params={"path": SAMPLE_SWF},
                             n_nodes=128).realize(seed=0)
    assert n_nodes == 128
    assert all(j.size <= 128 for j in jobs)


# ----------------------------------------------------------------- experiment
def test_experiment_sweeps_named_scenarios_and_trace_replay():
    """Acceptance: >= 3 registry-named scenarios (one SWF trace replay)
    through >= 2 mechanisms end-to-end."""
    small = dict(n_jobs=60, n_nodes=512, n_projects=12, horizon_days=4.0)
    wls = [get_scenario("W2", **small),
           get_scenario("bursty-od", **small),
           get_scenario("trace-replay", trace=SAMPLE_SWF)]
    res = Experiment(mechanisms=("BASE", "CUA&SPAA"), workloads=wls,
                     seeds=(0,), processes=1).run()
    assert len(res) == 6
    for run in res:
        assert run.metrics.n_completed == run.metrics.n_jobs > 0
    rows = res.mean(("mechanism", "scenario"))
    assert {r["scenario"] for r in rows} == {"W2", "bursty-od",
                                             "trace-replay"}
    for row in res.rows():
        assert row["scenario"] in {"W2", "bursty-od", "trace-replay"}


def test_experiment_accepts_preset_name_strings():
    exp = Experiment(mechanisms=("BASE",), workloads=("W1", "diurnal"),
                     seeds=(0,))
    specs = list(exp.specs())
    assert [s.workload.label for s in specs] == ["W1", "diurnal"]
    assert all(isinstance(s.workload, Scenario) for s in specs)
    with pytest.raises(UnknownWorkloadError):
        list(Experiment(mechanisms=("BASE",), workloads=("NOPE",),
                        seeds=(0,)).specs())


def test_experiment_seed_replaces_scenario_template_seed():
    sc = get_scenario("trace-replay", trace=SAMPLE_SWF)
    res = Experiment(mechanisms=("BASE",), workloads=(sc,), seeds=(0, 1),
                     processes=1).run()
    a, b = res.runs
    assert a.spec.seed == 0 and b.spec.seed == 1
    # same trace, different annotation draws -> od sets differ
    ja, _ = sc.realize(seed=0)
    jb, _ = sc.realize(seed=1)
    kinds_a = [j.jtype for j in ja]
    kinds_b = [j.jtype for j in jb]
    assert kinds_a != kinds_b


# -------------------------------------------------------------------- metrics
def test_collect_handles_empty_record_set():
    sim = Simulator(SimConfig(n_nodes=8, mechanism="BASE"), [])
    sim.run()
    m = collect(sim)
    assert m.n_jobs == 0 and m.n_completed == 0
    assert math.isnan(m.avg_turnaround_h)
    assert math.isnan(m.system_utilization)
    assert math.isnan(m.od_instant_start_rate)
