"""Crash-recoverable service: rotation, torn tails, SIGKILL recovery,
retrying launchers, quarantine, and admission backpressure.

The headline gate: SIGKILL a live daemon mid-replay, recover from its
(rotated, possibly torn) on-disk decision log, finish the run, and the
concatenated decision stream must be sha256-identical to an
uninterrupted run — across mechanisms.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

import repro.core
from repro.core import SimConfig
from repro.core.workloads import get_scenario
from repro.service import (AdmissionQueue, AdmissionRejected, DecisionLog,
                           DryrunLauncher, RetryPolicy, RetryingLauncher,
                           SchedulerService, ServiceConfig, ServiceCore,
                           ShadowLaunchError, TornLogError,
                           TransientLaunchError, decision_digest,
                           log_segments, read_decision_log)

SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(repro.core.__file__))))


def _jobs(n_jobs=40, seed=3):
    return get_scenario("bursty-od", n_jobs=n_jobs).realize(seed)


def _reference_digest(jobs, n_nodes, mechanism):
    """One uninterrupted in-memory run — the digest every crashed-and-
    recovered variant must reproduce."""
    svc = SchedulerService(
        ServiceConfig(n_nodes=n_nodes, mechanism=mechanism), list(jobs))
    return svc.run_replay().digest


# --------------------------------------------------------------- rotation
def test_rotation_produces_segments_and_roundtrips(tmp_path):
    jobs, n_nodes = _jobs()
    path = str(tmp_path / "log.jsonl")
    cfg = ServiceConfig(n_nodes=n_nodes, decision_log_path=path,
                        log_rotate_bytes=2048)
    svc = SchedulerService(cfg, list(jobs))
    rep = svc.run_replay()
    segs = log_segments(path)
    assert len(segs) > 2                     # actually rotated
    assert segs[-1] == path                  # active file is last
    for seg in segs[:-1]:
        assert os.path.getsize(seg) >= 2048  # rotated past the threshold
    rows = read_decision_log(path)
    assert len(rows) == rep.n_decisions
    assert decision_digest(rows) == rep.digest


# -------------------------------------------------------------- torn tails
def test_torn_final_line_skipped_with_warning(tmp_path):
    path = str(tmp_path / "log.jsonl")
    with DecisionLog(path) as log:
        log.append({"seq": 0, "event": "start", "jid": 1, "t_sim": 0.0})
        log.append({"seq": 1, "event": "end", "jid": 1, "t_sim": 5.0})
    with open(path, "a") as fh:
        fh.write('{"seq": 2, "event": "sta')     # crash mid-write
    with pytest.warns(RuntimeWarning, match="torn final line"):
        rows = read_decision_log(path)
    assert [r["seq"] for r in rows] == [0, 1]


def test_midfile_corruption_raises(tmp_path):
    path = str(tmp_path / "log.jsonl")
    good = json.dumps({"seq": 0, "event": "start", "jid": 1})
    with open(path, "w") as fh:
        fh.write(good + "\n")
        fh.write("NOT JSON AT ALL\n")            # corruption *with* newline
        fh.write(good + "\n")
    with pytest.raises(TornLogError, match="corrupt row"):
        read_decision_log(path)


def test_recover_truncates_torn_tail_and_appends(tmp_path):
    path = str(tmp_path / "log.jsonl")
    with DecisionLog(path) as log:
        log.append({"seq": 0, "event": "start", "jid": 1, "t_sim": 0.0})
    size_clean = os.path.getsize(path)
    with open(path, "a") as fh:
        fh.write('{"torn')
    with pytest.warns(RuntimeWarning):
        log2, rows = DecisionLog.recover(path)
    assert os.path.getsize(path) == size_clean   # tail physically removed
    assert len(rows) == 1 and log2.n_rows == 1
    log2.append({"seq": 1, "event": "end", "jid": 1, "t_sim": 5.0})
    log2.close()
    rows = read_decision_log(path)
    assert [r["seq"] for r in rows] == [0, 1]
    # digest continuity: recovered-prefix + appended == fresh full log
    ref = DecisionLog()
    ref.append({"seq": 0, "event": "start", "jid": 1, "t_sim": 0.0})
    ref.append({"seq": 1, "event": "end", "jid": 1, "t_sim": 5.0})
    assert log2.digest == ref.digest


def test_recover_reads_rotated_segments_in_order(tmp_path):
    path = str(tmp_path / "log.jsonl")
    with DecisionLog(path, rotate_bytes=200) as log:
        for i in range(20):
            log.append({"seq": i, "event": "start", "jid": i, "t_sim": 0.0})
    assert len(log_segments(path)) > 1
    _, rows = DecisionLog.recover(path)
    assert [r["seq"] for r in rows] == list(range(20))


# ----------------------------------------------------- SIGKILL + recovery
_CHILD = """
import os, signal, sys
from repro.core.workloads import get_scenario
from repro.service import SchedulerService, ServiceConfig

path, mech, k = sys.argv[1], sys.argv[2], int(sys.argv[3])
jobs, n_nodes = get_scenario("bursty-od", n_jobs=40).realize(3)
cfg = ServiceConfig(n_nodes=n_nodes, mechanism=mech,
                    decision_log_path=path, log_rotate_bytes=2048)
svc = SchedulerService(cfg, list(jobs))
orig = svc.log.append
state = {"n": 0}
def killing_append(row, **kw):
    out = orig(row, **kw)
    state["n"] += 1
    if state["n"] >= k:
        os.kill(os.getpid(), signal.SIGKILL)   # no atexit, no flush, no mercy
    return out
svc.log.append = killing_append
svc.run_replay()
raise SystemExit("unreachable: child should have been SIGKILLed")
"""


@pytest.mark.parametrize("mechanism", ["CUA&SPAA", "CUP&STEAL"])
def test_sigkill_then_recover_digest_identical(tmp_path, mechanism):
    """Kill a real daemon process after K logged decisions; recover in
    this process; the finished stream must be sha256-identical to an
    uninterrupted run."""
    jobs, n_nodes = _jobs()
    path = str(tmp_path / "log.jsonl")
    child = tmp_path / "child.py"
    child.write_text(_CHILD)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(child), path, mechanism, "25"],
                          env=env, capture_output=True, timeout=120)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()

    cfg = ServiceConfig(n_nodes=n_nodes, mechanism=mechanism,
                        decision_log_path=path, log_rotate_bytes=2048)
    svc, rr = SchedulerService.recover(cfg, list(jobs))
    assert rr.ok and rr.digests_match
    assert rr.n_decisions_recovered >= 25
    rep = svc.run_replay()

    ref = _reference_digest(jobs, n_nodes, mechanism)
    assert rep.digest == ref
    # and the on-disk stream (rotated segments concatenated) agrees
    assert decision_digest(read_decision_log(path)) == ref


def test_recover_in_process_after_abandoned_partial_run(tmp_path):
    """The same contract without a subprocess: abandon a half-replayed
    service (simulated crash), recover, finish, compare digests."""
    jobs, n_nodes = _jobs()
    path = str(tmp_path / "log.jsonl")
    cfg = ServiceConfig(n_nodes=n_nodes, decision_log_path=path,
                        log_rotate_bytes=1024)
    crashed = SchedulerService(cfg, list(jobs))
    while crashed.core.n_decisions < 40:
        t = crashed.core.next_event_time()
        if t is None:
            break
        crashed._step_batch(t)
    # walk away: no close(), no finalize — the open handle just drops

    svc, rr = SchedulerService.recover(cfg, list(jobs))
    assert rr.ok
    assert rr.resumed_at > 0.0
    rep = svc.run_replay()
    assert rep.digest == _reference_digest(jobs, n_nodes, cfg.mechanism)


def test_recover_requires_log_path():
    with pytest.raises(ValueError, match="decision_log_path"):
        SchedulerService.recover(ServiceConfig(n_nodes=8), [])


# -------------------------------------------------------- retrying launcher
class _FlakyLauncher(DryrunLauncher):
    """Fails the first `fail_first` start attempts transiently."""

    def __init__(self, n_nodes, fail_first):
        super().__init__(n_nodes)
        self.fails = fail_first

    def start_job(self, job, size):
        if self.fails > 0:
            self.fails -= 1
            raise TransientLaunchError("network blip")
        super().start_job(job, size)


def test_retry_recovers_transient_failures_and_digest_unchanged():
    jobs, n_nodes = _jobs()
    naps = []
    rl = RetryingLauncher(_FlakyLauncher(n_nodes, fail_first=3),
                          RetryPolicy(retries=3, seed=1), sleep=naps.append)
    svc = SchedulerService(ServiceConfig(n_nodes=n_nodes), list(jobs),
                           launcher=rl)
    rep = svc.run_replay()
    assert rep.digest == _reference_digest(jobs, n_nodes, "CUA&SPAA")
    assert rl.counts["launch_retries"] == 3
    assert rl.counts["launch_failures"] == 0
    assert len(naps) == 3 and all(d >= 0.0 for d in naps)
    assert rep.launcher_counts["launch_retries"] == 3


def test_retry_backoff_grows_and_is_seeded():
    def delays(seed):
        out = []
        rl = RetryingLauncher(DryrunLauncher(4),
                              RetryPolicy(retries=5, base_delay_s=0.1,
                                          max_delay_s=100.0, seed=seed),
                              sleep=out.append)
        for attempt in range(5):
            out.append(rl._delay(attempt))
        return out
    assert delays(7) == delays(7)            # deterministic per seed
    assert delays(7) != delays(8)
    caps = [0.1 * 2 ** i for i in range(5)]
    for d, cap in zip(delays(7), caps):
        assert 0.0 <= d <= cap               # full jitter stays under cap


def test_persistent_failure_goes_to_give_up_callback():
    class Broken(DryrunLauncher):
        def start_job(self, job, size):
            raise RuntimeError("bad node")
    seen = []
    rl = RetryingLauncher(Broken(8), RetryPolicy(retries=2),
                          on_give_up=lambda a, s, e: seen.append((a, str(e))),
                          sleep=lambda s: None)
    jobs, _ = _jobs(n_jobs=10)
    rl.start_job(jobs[0], 2)
    assert seen == [("start", "bad node")]   # persistent => no retries spent
    assert rl.launch_retries == 0
    assert rl.launch_failures == 1


def test_shadow_launch_error_stays_fatal():
    rl = RetryingLauncher(DryrunLauncher(4), RetryPolicy(retries=5),
                          sleep=lambda s: None)
    jobs, _ = _jobs(n_jobs=10)
    rl.start_job(jobs[0], 2)
    with pytest.raises(ShadowLaunchError):
        rl.start_job(jobs[0], 2)             # double start = invariant broken


def test_give_up_without_callback_warns_not_raises():
    class Broken(DryrunLauncher):
        def start_job(self, job, size):
            raise RuntimeError("bad node")
    rl = RetryingLauncher(Broken(8), RetryPolicy(retries=0),
                          sleep=lambda s: None)
    jobs, _ = _jobs(n_jobs=10)
    with pytest.warns(RuntimeWarning, match="gave up"):
        rl.start_job(jobs[0], 2)


# ---------------------------------------------------- quarantine wiring
def test_launch_failures_quarantine_nodes_and_are_digest_exempt():
    """A permanently failing backend: the replay still completes, every
    give-up is logged as a seq=-1 launch_failed row, and nodes drain."""
    class Broken(DryrunLauncher):
        def start_job(self, job, size):
            raise RuntimeError("bad node")

        def preempt(self, job):
            pass

        def resize(self, job, new_size):
            pass

        def finish(self, rec):
            pass

        def close(self):
            pass

    jobs, n_nodes = _jobs()
    rl = RetryingLauncher(Broken(n_nodes), RetryPolicy(retries=1),
                          sleep=lambda s: None)
    svc = SchedulerService(ServiceConfig(n_nodes=n_nodes), list(jobs),
                           launcher=rl)
    rep = svc.run_replay()
    lf = [r for r in svc.log.rows if r["event"] == "launch_failed"]
    q = [r for r in svc.log.rows if r["event"] == "quarantine"]
    assert lf and q
    assert all(r["seq"] == -1 for r in lf + q)
    assert svc.core.ledger.draining > 0
    assert svc.core.n_quarantined == len(q)
    # runtime rows never enter the digest: recompute over decision rows
    assert decision_digest(svc.log.rows) == rep.digest


def test_quarantine_waits_for_free_nodes():
    jobs, _ = _jobs(n_jobs=6)
    core = ServiceCore(SimConfig(n_nodes=4), jobs)
    core.quarantine(2)
    core.run()
    assert core.ledger.draining == 2
    assert core.n_quarantined == 2
    core.ledger.check()


# ------------------------------------------------- admission backpressure
def test_admission_rejects_bad_config():
    with pytest.raises(ValueError, match="backpressure"):
        AdmissionQueue(backpressure="drop-everything")
    with pytest.raises(ValueError, match="maxsize"):
        AdmissionQueue(maxsize=0)


def test_shed_oldest_inference_spares_training():
    aq = AdmissionQueue(maxsize=3, backpressure="shed-oldest-inference")
    first = aq.submit_inference(2, 60.0)
    aq.submit_training(4, 100.0)
    aq.submit_inference(2, 60.0, submit_time=1.0)
    aq.submit_inference(2, 60.0, submit_time=2.0)   # full: sheds `first`
    assert aq.counts == {"submitted": 4, "shed": 1, "rejected": 0,
                         "blocked": 0}
    drained = aq.drain()
    assert first not in drained
    assert len(drained) == 3


def test_shed_policy_rejects_when_nothing_sheddable():
    aq = AdmissionQueue(maxsize=1, backpressure="shed-oldest-inference")
    aq.submit_training(4, 100.0)
    with pytest.raises(AdmissionRejected):
        aq.submit_training(4, 100.0)        # training is never shed
    assert aq.counts["rejected"] == 1


def test_reject_policy_raises_at_capacity():
    aq = AdmissionQueue(maxsize=2, backpressure="reject")
    aq.submit_rigid(2, 50.0)
    aq.submit_rigid(2, 50.0)
    with pytest.raises(AdmissionRejected):
        aq.submit_rigid(2, 50.0)
    assert aq.counts == {"submitted": 2, "shed": 0, "rejected": 1,
                         "blocked": 0}


def test_block_policy_waits_for_drain():
    aq = AdmissionQueue(maxsize=1, backpressure="block")
    aq.submit_rigid(2, 50.0)
    unblocked = threading.Event()

    def producer():
        aq.submit_rigid(2, 60.0)            # blocks until the drain below
        unblocked.set()

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    time.sleep(0.05)
    assert not unblocked.is_set()           # genuinely waiting
    assert len(aq.drain()) == 1
    assert unblocked.wait(2.0)
    th.join(2.0)
    assert aq.counts["blocked"] == 1 and aq.counts["submitted"] == 2
    assert len(aq) == 1


def test_block_policy_timeout_rejects():
    aq = AdmissionQueue(maxsize=1, backpressure="block")
    spec = aq.submit_rigid(2, 50.0)
    with pytest.raises(AdmissionRejected):
        aq.put(spec, timeout=0.05)
    assert aq.counts["rejected"] == 1


def test_live_report_carries_admission_counts():
    jobs, n_nodes = _jobs(n_jobs=0)
    aq = AdmissionQueue(maxsize=64)
    svc = SchedulerService(ServiceConfig(n_nodes=16, speed=1e6), [])
    aq.submit_rigid(2, 50.0)
    aq.submit_training(4, 100.0, submit_time=10.0)
    aq.close()
    rep = svc.run_live(aq)
    assert rep.admission_counts is not None
    assert rep.admission_counts["submitted"] == 2
    assert rep.n_jobs == 2
