"""Randomized property tests (drain, conservation, kernel invariants).

Guarded by importorskip: hypothesis ships via requirements-dev.txt and is
optional — without it this module skips instead of failing collection.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

import numpy as np

from repro.core import (MECHANISMS, JobType, NoticeKind, SimConfig, Simulator,
                        WaitQueue, WorkloadConfig, apportion_shrink, collect,
                        generate, select_preemption_victims)
from repro.core.metrics import P2Quantile

# new-policy composites ride the same drain/conservation properties
EXTRA_MECHANISMS = ("CUA&STEAL", "CUA&POOL")


# ------------------------------------------------------------------ workload
@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_workload_invariants(seed):
    cfg = WorkloadConfig(n_jobs=200, n_nodes=2048, seed=seed)
    jobs = generate(cfg)
    assert len(jobs) == 200
    for j in jobs:
        assert 1 <= j.size <= cfg.n_nodes
        assert j.t_actual <= j.t_estimate + 1e-6
        assert j.t_setup < j.t_actual
        if j.jtype is JobType.MALLEABLE:
            assert 1 <= j.n_min <= j.size
        if j.jtype is JobType.ONDEMAND:
            # paper: large on-demand jobs reassigned
            assert j.size <= cfg.n_nodes // 2
            if j.notice_kind is not NoticeKind.NONE:
                assert j.notice_time <= j.submit_time
                assert j.est_arrival is not None
                if j.notice_kind is NoticeKind.LATE:
                    assert j.submit_time >= j.est_arrival - 1e-6
                if j.notice_kind is NoticeKind.EARLY:
                    assert j.submit_time <= j.est_arrival + 1e-6
    # submit times sorted, ids consecutive
    assert all(a.submit_time <= b.submit_time
               for a, b in zip(jobs, jobs[1:]))
    assert [j.jid for j in jobs] == list(range(200))


# --------------------------------------------------------- decision kernels
@given(st.lists(st.tuples(st.integers(1, 512), st.floats(0, 1e6)),
                min_size=0, max_size=64),
       st.integers(0, 4096))
@settings(max_examples=200, deadline=None)
def test_paa_selection_properties(cand, need):
    sizes = [c[0] for c in cand]
    overheads = [c[1] for c in cand]
    victims, surplus = select_preemption_victims(sizes, overheads, need)
    if need <= 0:
        assert victims == []
        return
    if sum(sizes) < need:
        assert victims == [] and surplus == 0
        return
    got = sum(sizes[i] for i in victims)
    assert got >= need and surplus == got - need
    # minimality: dropping the last victim breaks coverage
    assert got - sizes[victims[-1]] < need
    # ascending overhead order
    ov = [overheads[i] for i in victims]
    assert ov == sorted(ov)


@given(st.lists(st.tuples(st.integers(1, 256), st.integers(0, 255)),
                min_size=1, max_size=64),
       st.integers(1, 2048))
@settings(max_examples=200, deadline=None)
def test_spaa_apportion_properties(jobs, need):
    cur = [max(c, m + 1) if c > m else c for c, m in jobs]
    mn = [min(c, m) for c, m in jobs]
    sheds = apportion_shrink(cur, mn, need)
    slack = sum(c - m for c, m in zip(cur, mn))
    if slack < need:
        assert sheds == []
        return
    assert sum(sheds) == need
    for s, c, m in zip(sheds, cur, mn):
        assert 0 <= s <= c - m  # never below n_min
    # proportionality: jobs with zero slack shed nothing
    for s, c, m in zip(sheds, cur, mn):
        if c == m:
            assert s == 0


@given(st.lists(st.integers(0, 10**11), min_size=1, max_size=16),
       st.data())
@settings(max_examples=200, deadline=None)
def test_spaa_apportion_never_asserts_at_any_scale(slacks, data):
    # regression scale: need * slack overflows int64 here, which used to
    # wrap into garbage quotas and trip the sum assert; with supply >=
    # need the kernel must always terminate with an exact sum
    supply = sum(slacks)
    need = data.draw(st.integers(0, supply))
    sheds = apportion_shrink(slacks, [0] * len(slacks), need)
    assert sum(sheds) == (need if need > 0 else 0)
    for s, c in zip(sheds, slacks):
        assert 0 <= s <= c


# ------------------------------------------------------------ property: drain
@given(seed=st.integers(0, 10_000),
       mech=st.sampled_from(("BASE",) + MECHANISMS + EXTRA_MECHANISMS))
@settings(max_examples=25, deadline=None)
def test_random_workload_drains_and_conserves_nodes(seed, mech):
    """Every random workload completes under every mechanism; the node
    ledger invariant (checked at every event) never trips; metrics finite."""
    cfg = WorkloadConfig(n_jobs=60, n_nodes=512, n_projects=12,
                         horizon_days=4.0, seed=seed)
    jobs = generate(cfg)
    sim = Simulator(SimConfig(n_nodes=cfg.n_nodes, mechanism=mech,
                              check_invariants=True), jobs)
    sim.run()
    m = collect(sim)
    assert m.n_completed == m.n_jobs
    assert 0.0 <= m.system_utilization <= 1.0
    for r in sim.records.values():
        assert r.completion is not None
        assert r.first_start is not None
        assert r.first_start >= r.job.submit_time - 1e-9
        assert r.completion >= r.first_start


# --------------------------------------------- property: incremental queue
@given(st.lists(st.tuples(st.sampled_from(("submit", "start", "preempt",
                                           "requeue")),
                          st.integers(0, 61), st.integers(0, 7)),
                min_size=1, max_size=120))
@settings(max_examples=200, deadline=None)
def test_incremental_queue_matches_full_sort_under_interleavings(ops):
    """The incremental WaitQueue yields exactly sorted(queue, key=order_key)
    after every submit/start/preempt/requeue interleaving.  Priorities
    change across requeues (a preempted job's order inputs may change,
    e.g. est_remaining) — the structure recomputes keys at re-append, so
    the full-sort oracle must agree at every step."""
    prio = {}

    def order_key(jid):
        return (prio[jid], jid)  # builtin-style: jid-tiebroken total order

    q = WaitQueue()
    q.configure(order_key, incremental=True,
                meta_fn=lambda jid: (float(jid), 0.0))
    members = {}
    next_jid = 0
    for action, pick, p in ops:
        if action == "submit":
            jid = next_jid
            next_jid += 1
            prio[jid] = p
            members[jid] = None
            q.append(jid)
        elif members:
            jid = list(members)[pick % len(members)]
            if action == "start":           # leaves the queue for good
                del members[jid]
                q.remove(jid)
            elif action == "preempt":       # out, new priority, back in
                q.remove(jid)
                prio[jid] = (prio[jid] + 1 + p) % 11
                q.append(jid)
            else:                            # requeue: key change in place
                prio[jid] = p
                q.invalidate(jid)
        assert list(q) == sorted(members, key=order_key)
        assert len(q) == len(members)
        for jid in members:
            assert jid in q
        # the cached backfill metas track the sorted order
        assert q.meta_window(0, len(q))[0] == [float(j) for j in q]


@given(seed=st.integers(0, 10_000),
       mech=st.sampled_from(("CUA&SPAA",) + EXTRA_MECHANISMS))
@settings(max_examples=10, deadline=None)
def test_od_jobs_never_preempted(seed, mech):
    cfg = WorkloadConfig(n_jobs=80, n_nodes=512, n_projects=12,
                         horizon_days=4.0, seed=seed, frac_od_projects=0.3,
                         frac_rigid_projects=0.4)
    jobs = generate(cfg)
    sim = Simulator(SimConfig(n_nodes=cfg.n_nodes, mechanism=mech,
                              check_invariants=True), jobs)
    sim.run()
    for r in sim.records.values():
        if r.job.jtype is JobType.ONDEMAND:
            assert r.n_preempted == 0 and r.n_shrunk == 0


# --------------------------------------------------- P² quantile sketch
def _p2_markers_valid(sk):
    """The estimator's structural invariants after any stream: marker
    heights non-decreasing, marker positions strictly increasing (the
    property that makes every adjustment denominator >= 1 — the classic
    P² divide-by-zero on duplicate-heavy streams cannot occur)."""
    assert all(a <= b + 1e-12 for a, b in zip(sk._q, sk._q[1:]))
    assert all(b - a >= 1 for a, b in zip(sk._n, sk._n[1:]))


@given(values=st.lists(st.floats(0.0, 1e9), min_size=1, max_size=5),
       p=st.floats(0.01, 0.99))
@settings(max_examples=100, deadline=None)
def test_p2_exact_below_five_observations(values, p):
    sk = P2Quantile(p)
    for v in values:
        sk.add(v)
    assert sk.result() == pytest.approx(
        float(np.percentile(np.asarray(values), p * 100)))


@given(value=st.floats(-1e9, 1e9), n=st.integers(6, 400),
       p=st.sampled_from((0.5, 0.9, 0.99)))
@settings(max_examples=60, deadline=None)
def test_p2_constant_stream_is_exact(value, n, p):
    """All five markers collapse to one height; the estimate is exactly
    the constant and no marker adjustment ever divides by zero."""
    sk = P2Quantile(p)
    for _ in range(n):
        sk.add(value)
    assert sk.result() == value
    _p2_markers_valid(sk)


@given(data=st.data(), p=st.sampled_from((0.5, 0.9, 0.99)))
@settings(max_examples=150, deadline=None)
def test_p2_duplicate_heavy_streams(data, p):
    """Streams drawn from <= 3 distinct values are the historical P²
    crash case: textbook transcriptions let adjacent markers collide on
    ties and divide by zero in the parabolic adjustment.  The invariants
    under test are exactly the ones that preclude that — strictly
    increasing marker positions, sorted marker heights — plus the
    estimate staying inside the sample range.  No rank-accuracy claim
    here: on massive-tie streams P²'s value interpolates between the
    distinct levels and its rank error is genuinely unbounded (the
    documented tail caveat); np.percentile comparisons live in the
    exact small-n and sorted-stream tests."""
    support = data.draw(st.lists(st.floats(0.0, 1e6), min_size=1,
                                 max_size=3, unique=True))
    values = data.draw(st.lists(st.sampled_from(support), min_size=6,
                                max_size=300))
    sk = P2Quantile(p)
    for v in values:
        sk.add(v)
    est = sk.result()
    assert min(values) <= est <= max(values)
    _p2_markers_valid(sk)


@given(n=st.integers(50, 400), scale=st.floats(1e-3, 1e6),
       p=st.sampled_from((0.5, 0.9)))
@settings(max_examples=60, deadline=None)
def test_p2_sorted_stream_tracks_percentile(n, scale, p):
    """A sorted (monotone) stream — the arrival pattern of cumulative
    latencies — must track np.percentile to a small rank error."""
    values = [i * scale / n for i in range(n)]
    sk = P2Quantile(p)
    for v in values:
        sk.add(v)
    est = sk.result()
    assert values[0] <= est <= values[-1]
    _p2_markers_valid(sk)
    rank = sum(1 for v in values if v <= est) / n
    assert abs(rank - p) <= 0.15


# --------------------------------------------------- chunked SWF parsing
@given(chunk_lines=st.integers(1, 64),
       max_jobs=st.one_of(st.none(), st.integers(1, 100)),
       data=st.data())
@settings(max_examples=40, deadline=None)
def test_chunked_swf_parse_equals_whole_file(tmp_path_factory, chunk_lines,
                                             max_jobs, data):
    """iter_swf must yield the same records and header for ANY chunk
    size — comments, blank lines, and short/long job lines landing on
    chunk boundaries included."""
    from repro.core.workloads.swf import SWF_FIELDS, iter_swf

    lines = ["; MaxNodes: 512", "; Note: chunk boundary torture"]
    n_lines = data.draw(st.integers(0, 40))
    for i in range(n_lines):
        kind = data.draw(st.sampled_from(("job", "comment", "blank",
                                          "short", "padded")))
        if kind == "comment":
            lines.append(f"; c{i}: v{i}")
        elif kind == "blank":
            lines.append("")
        elif kind == "short":   # fewer fields than SWF defines: -1 padded
            lines.append(f"{i} {i * 10} 0 {60 + i} {1 + i % 8}")
        elif kind == "padded":  # whitespace noise
            lines.append(f"  {i}\t{i * 10} 0 {60 + i} {1 + i % 8} "
                         + " ".join(["-1"] * 13) + "  ")
        else:
            lines.append(f"{i} {i * 10} 0 {60 + i} {1 + i % 8} "
                         + " ".join(str(f) for f in range(13)))
    path = tmp_path_factory.mktemp("swf") / "t.swf"
    path.write_text("\n".join(lines) + "\n")

    whole_header, chunk_header = {}, {}
    whole = list(iter_swf(str(path), max_jobs, header=whole_header,
                          chunk_lines=10_000))
    chunked = list(iter_swf(str(path), max_jobs, header=chunk_header,
                            chunk_lines=chunk_lines))
    assert whole == chunked
    assert whole_header == chunk_header
    for rec in chunked:
        assert set(rec) == set(SWF_FIELDS)
