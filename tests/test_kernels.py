"""Kernel validation: Pallas (interpret=True) and chunked-jnp ops vs the
pure-jnp oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

rng = np.random.default_rng(42)


def rnd(*shape, dt=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dt)


def tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 256, 4, 1, 64),     # MQA
    (1, 512, 2, 2, 128),    # MXU-aligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, K, D, dtype, causal):
    q, k, v = rnd(B, S, H, D, dt=dtype), rnd(B, S, K, D, dt=dtype), \
        rnd(B, S, K, D, dt=dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=128,
                        interpret=True)
    o2 = ref.naive_attention(q, k, v, causal=causal)
    err = jnp.abs(o.astype(jnp.float32) - o2.astype(jnp.float32)).max()
    assert float(err) < tol(dtype) * 10, float(err)
    assert o.dtype == q.dtype


def test_flash_attention_uneven_blocks():
    q, k, v = rnd(1, 192, 2, 32), rnd(1, 192, 1, 32), rnd(1, 192, 1, 32)
    o = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                        interpret=True)
    o2 = ref.naive_attention(q, k, v, causal=True)
    assert float(jnp.abs(o - o2).max()) < 1e-4


# ------------------------------------------------- chunked-jnp attention path
@pytest.mark.parametrize("S,block_q", [(512, 128), (1024, 128), (2048, 256)])
def test_binary_causal_attention(S, block_q):
    q, k, v = rnd(2, S, 4, 32), rnd(2, S, 2, 32), rnd(2, S, 2, 32)
    o = ops.attention(q, k, v, causal=True, block_q=block_q, block_kv=256)
    o2 = ref.naive_attention(q, k, v, causal=True)
    assert float(jnp.abs(o - o2).max()) < 1e-4


@pytest.mark.parametrize("valid", [1, 37, 100])
def test_decode_attention_valid_len(valid):
    q = rnd(2, 1, 8, 32)
    k, v = rnd(2, 128, 4, 32), rnd(2, 128, 4, 32)
    o = ops.attention(q, k, v, causal=False, kv_valid_len=jnp.asarray(valid))
    o2 = ref.naive_attention(q, k, v, kv_valid_len=jnp.asarray(valid))
    assert float(jnp.abs(o - o2).max()) < 1e-5


def test_cross_attention_matches():
    q = rnd(2, 64, 4, 32)
    k, v = rnd(2, 96, 4, 32), rnd(2, 96, 4, 32)
    o = ops.attention(q, k, v, causal=False, block_kv=32)
    o2 = ref.naive_attention(q, k, v, causal=False)
    assert float(jnp.abs(o - o2).max()) < 1e-5


# -------------------------------------------------------------------- SSD
@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64), (512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(s, chunk, dtype):
    b, h, p, n = 2, 2, 16, 8
    x = rnd(b, s, h, p, dt=dtype)
    dt = jnp.abs(rnd(b, s, h, scale=0.1)).astype(jnp.float32)
    A = -jnp.abs(rnd(h))
    Bm, Cm = rnd(b, s, n, dt=dtype), rnd(b, s, n, dt=dtype)
    Dp = rnd(h)
    y = ssd_scan(x, dt, A, Bm, Cm, Dp, chunk=chunk, interpret=True)
    y2 = ref.naive_ssd(x, dt, A, Bm, Cm, Dp)
    scale = float(jnp.abs(y2.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(y.astype(jnp.float32) - y2.astype(jnp.float32)).max())
    assert err / scale < tol(dtype), (err, scale)


def test_ssd_jnp_matches_kernel_semantics():
    b, s, h, p, n = 1, 256, 2, 8, 4
    x = rnd(b, s, h, p)
    dt = jnp.abs(rnd(b, s, h, scale=0.1))
    A = -jnp.abs(rnd(h))
    Bm, Cm, Dp = rnd(b, s, n), rnd(b, s, n), rnd(h)
    y1 = ops.ssd_scan(x, dt, A, Bm, Cm, Dp, chunk=64)
    y2 = ssd_scan(x, dt, A, Bm, Cm, Dp, chunk=64, interpret=True)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4


def test_ssd_decode_step_consistent():
    b, s, h, p, n = 1, 16, 2, 8, 4
    x = rnd(b, s, h, p)
    dt = jnp.abs(rnd(b, s, h, scale=0.1))
    A = -jnp.abs(rnd(h))
    Bm, Cm, Dp = rnd(b, s, n), rnd(b, s, n), rnd(h)
    y_ref = ref.naive_ssd(x, dt, A, Bm, Cm, Dp)
    st = jnp.zeros((b, h, p, n))
    for t in range(s):
        st, yt = ops.ssd_step(st, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], Dp)
        assert float(jnp.abs(yt - y_ref[:, t]).max()) < 1e-4


# -------------------------------------------------------------------- mLSTM
@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64)])
def test_mlstm_chunked(s, chunk):
    b, h, d = 2, 2, 16
    q, k, v = rnd(b, s, h, d), rnd(b, s, h, d, scale=0.5), rnd(b, s, h, d)
    ig, fg = rnd(b, s, h), rnd(b, s, h) + 2.0
    y = ops.mlstm_scan(q, k, v, ig, fg, chunk=chunk)
    y2 = ref.naive_mlstm(q, k, v, ig, fg)
    scale = float(jnp.abs(y2).max()) + 1e-6
    assert float(jnp.abs(y - y2).max()) / scale < 1e-4


# ------------------------------------------------------------- flash decode
@pytest.mark.parametrize("B,S,H,K,D,vl", [
    (2, 256, 8, 2, 64, 100),   # GQA, partial cache
    (1, 512, 4, 4, 32, 512),   # MHA, full cache
    (2, 128, 4, 1, 32, 1),     # MQA, single valid token
    (1, 256, 8, 8, 128, 37),   # MXU-aligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, K, D, vl, dtype):
    from repro.kernels.flash_decode import flash_decode
    q = rnd(B, 1, H, D, dt=dtype)
    k, v = rnd(B, S, K, D, dt=dtype), rnd(B, S, K, D, dt=dtype)
    o = flash_decode(q, k, v, jnp.asarray(vl), block_kv=64, interpret=True)
    o2 = ref.naive_attention(q, k, v, kv_valid_len=jnp.asarray(vl))
    err = jnp.abs(o.astype(jnp.float32) - o2.astype(jnp.float32)).max()
    assert float(err) < tol(dtype) * 10
    assert o.dtype == q.dtype
