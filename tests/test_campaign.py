"""repro.campaign: trace zoo, calibration, specs, runner, reports.

Everything here is offline: the zoo's checked-in gzipped fixtures are
the only traces touched, and the one "remote" test asserts that
offline mode refuses to download rather than trying to.
"""
import gzip
import json
import os

import numpy as np
import pytest

from repro.campaign import (CampaignSpec, CampaignSpecError, TraceSpec,
                            calibrated_scenario, fetch, file_sha256,
                            get_trace, profile_trace, register_trace,
                            run_campaign)
from repro.campaign import _toml, zoo
from repro.campaign.report import aggregate, bootstrap_ci, winners
from repro.campaign.spec import default_output_dir
from repro.core.workloads.base import WorkloadDataError
from repro.core.workloads.swf import iter_swf

FIXTURES = ("mini-steady", "mini-bursty", "mini-heavy")

#: the in-test campaign: 2 traces x 2 mechanisms x 2 seeds x 1 grid point
SPEC_DICT = {
    "campaign": {"name": "t", "mechanisms": ["BASE", "CUA&SPAA"],
                 "seeds": [0, 1], "max_jobs": 120},
    "grid": {"target_load": [0.8], "notice": ["W2"]},
    "trace": [{"name": "mini-steady"}, {"name": "mini-bursty"}],
}


# ------------------------------------------------------------------ trace zoo
def test_zoo_fixtures_resolve_and_verify():
    for name in FIXTURES:
        path = fetch(name)
        assert os.path.exists(path)
        assert path.endswith(".swf.gz")
        assert file_sha256(path) == get_trace(name).sha256


def test_zoo_unknown_trace_lists_registry():
    with pytest.raises(WorkloadDataError, match="mini-steady"):
        get_trace("no-such-trace")


def test_zoo_sha_mismatch_refused():
    register_trace(TraceSpec(
        name="tampered-test", description="x", license="x",
        sha256="0" * 64, fixture="mini-steady.swf.gz"))
    try:
        with pytest.raises(WorkloadDataError, match="sha256 mismatch"):
            fetch("tampered-test")
    finally:
        del zoo._ZOO["tampered-test"]


def test_zoo_offline_refuses_download(tmp_path, monkeypatch):
    monkeypatch.setenv(zoo.CACHE_ENV, str(tmp_path / "cache"))
    assert get_trace("kth-sp2").remote
    with pytest.raises(WorkloadDataError, match="offline"):
        fetch("kth-sp2", offline=True)


def test_zoo_reregistration_conflict():
    spec = get_trace("mini-steady")
    register_trace(spec)  # identical: idempotent
    with pytest.raises(ValueError, match="already registered"):
        register_trace(TraceSpec(
            name="mini-steady", description="different", license="x"))


# ----------------------------------------------------- gzip SWF reader (swf.py)
def test_gzip_reads_identical_to_plain(tmp_path):
    gz_path = fetch("mini-steady")
    plain = tmp_path / "plain.swf"
    with gzip.open(gz_path, "rb") as f:
        plain.write_bytes(f.read())
    hdr_gz, hdr_plain = {}, {}
    recs_gz = list(iter_swf(gz_path, header=hdr_gz))
    recs_plain = list(iter_swf(str(plain), header=hdr_plain))
    assert recs_gz == recs_plain
    assert hdr_gz == hdr_plain
    assert hdr_gz["MaxNodes"] == "64"


def test_truncated_gzip_is_data_error(tmp_path):
    blob = open(fetch("mini-steady"), "rb").read()
    bad = tmp_path / "trunc.swf.gz"
    bad.write_bytes(blob[:len(blob) // 2])
    with pytest.raises(WorkloadDataError, match="corrupt gzip"):
        list(iter_swf(str(bad)))


def test_binary_junk_is_data_error(tmp_path):
    bad = tmp_path / "junk.swf"
    bad.write_bytes(b"\xfe\xfe\xff\x00" * 64)
    with pytest.raises(WorkloadDataError, match="not a text SWF"):
        list(iter_swf(str(bad)))


def test_missing_fields_padded_with_unknown_marker(tmp_path):
    short = tmp_path / "short.swf"
    short.write_text("; MaxNodes: 8\n1 0 -1 60 4\n")
    (rec,) = list(iter_swf(str(short)))
    assert rec["allocated_procs"] == 4
    assert rec["think_time"] == -1.0  # padded


# ---------------------------------------------------------------- TOML subset
def test_toml_subset_roundtrip():
    data = _toml.loads("""
# comment
[campaign]
name = "x"           # trailing comment
seeds = [0, 1,
         2]
scale = 1.5
flag = true
[campaign.sim]
queue_policy = "EASY"
[[trace]]
name = "a"
[[trace]]
name = "b"
target_load = [0.7]
""")
    assert data["campaign"]["name"] == "x"
    assert data["campaign"]["seeds"] == [0, 1, 2]
    assert data["campaign"]["scale"] == 1.5
    assert data["campaign"]["flag"] is True
    assert data["campaign"]["sim"]["queue_policy"] == "EASY"
    assert [t["name"] for t in data["trace"]] == ["a", "b"]
    assert data["trace"][1]["target_load"] == [0.7]


@pytest.mark.parametrize("bad, err", [
    ('x = "unterminated', "unterminated string"),
    ("just a line", "expected 'key = value'"),
    ("x = 2026-01-01", "unsupported value"),
    ("[t]\nx = 1\nx = 2", "duplicate key"),
    ('x = "a" "b"', "trailing garbage"),
])
def test_toml_subset_errors(bad, err):
    with pytest.raises(_toml.TomlError, match=err):
        _toml.loads(bad)


# ---------------------------------------------------------------- calibration
def test_profile_matches_fixture_generation():
    p = profile_trace("mini-steady")
    assert p.n_jobs == 340
    assert p.n_nodes == 64
    assert 0.7 < p.offered_load < 0.85
    heavy = profile_trace("mini-heavy")
    assert heavy.offered_load > 1.0


def test_load_factor_math():
    p = profile_trace("mini-steady")
    assert p.load_factor(p.offered_load) == pytest.approx(1.0)
    assert p.load_factor(2 * p.offered_load) == pytest.approx(2.0)
    with pytest.raises(ValueError):
        p.load_factor(0.0)


def test_calibrated_scenario_hits_target_load():
    target = 1.1
    sc = calibrated_scenario("mini-steady", target_load=target,
                             notice="W2")
    assert sc.streamable  # the whole point: streaming path, no fallback
    jobs, n_nodes = sc.realize(seed=0)
    span = jobs[-1].submit_time - jobs[0].submit_time
    load = sum(j.size * j.t_actual for j in jobs) / (n_nodes * span)
    assert load == pytest.approx(target, rel=0.01)


def test_calibrated_scenario_type_fractions_streamable():
    sc = calibrated_scenario("mini-bursty", malleable_frac=0.5,
                             od_frac=0.2)
    assert sc.streamable
    assert sc.params["frac_od_projects"] == pytest.approx(0.2)
    assert sc.params["frac_rigid_projects"] == pytest.approx(0.3)


def test_calibrated_scenario_invalid_fractions():
    with pytest.raises(ValueError, match="sum <= 1"):
        calibrated_scenario("mini-steady", malleable_frac=0.9,
                            od_frac=0.3)


# ------------------------------------------------------------ spec validation
def _spec(**over):
    import copy
    d = copy.deepcopy(SPEC_DICT)
    for dotted, v in over.items():
        cur = d
        *parents, leaf = dotted.split(".")
        for p in parents:
            cur = cur[p]
        if v is None:
            cur.pop(leaf, None)
        else:
            cur[leaf] = v
    return d


def test_spec_loads_and_counts_cells():
    spec = CampaignSpec.from_dict(SPEC_DICT)
    assert spec.n_cells == 2 * 2 * 2  # traces x mechanisms x seeds
    assert default_output_dir(spec).endswith(os.path.join("campaigns", "t"))


@pytest.mark.parametrize("over, err", [
    ({"campaign": None}, "missing .campaign."),
    ({"campaign.mechanisms": ["NOPE&X"]}, "mechanism"),
    ({"campaign.mechanisms": []}, "non-empty"),
    ({"campaign.seeds": [0, 0]}, "duplicate seeds"),
    ({"campaign.name": "a b"}, "without spaces"),
    ({"campaign.typo_key": 1}, "unknown key"),
    ({"grid.notice": ["W9"]}, "unknown notice mix"),
    ({"grid.target_load": [3.0]}, "outside"),
    ({"grid.target_load": []}, "non-empty list"),
    ({"grid.bogus_axis": [1]}, "unknown axis"),
    ({"trace": [{"name": "no-such-trace"}]}, "unknown trace"),
    ({"trace": []}, "at least one"),
    ({"grid.od_frac": [0.9], "grid.malleable_frac": [0.9]}, "rigid"),
    ({"grid.batch_rounds": [-5]}, "batch_rounds"),
])
def test_spec_validation_errors(over, err):
    with pytest.raises(CampaignSpecError, match=err):
        CampaignSpec.from_dict(_spec(**over))


def test_spec_batch_rounds_axis_threads_into_cells():
    spec = CampaignSpec.from_dict(_spec(**{
        "grid.batch_rounds": [0, 900]}))
    assert spec.n_cells == 2 * 2 * 2 * 2   # x2 for the new axis
    got = {sc.batch_rounds for _regime, sc in spec.cells()}
    assert got == {0.0, 900.0}
    assert any(sc.label.endswith("/b:900")
               for _regime, sc in spec.cells())


def test_spec_toml_file_loads(tmp_path):
    spec = CampaignSpec.load(os.path.join("examples", "campaigns",
                                          "mini.toml"))
    assert spec.name == "mini"
    assert spec.n_cells == 16
    # every expanded cell replays through the streaming path
    for _regime, sc in spec.cells():
        assert sc.streamable


def test_spec_per_trace_axis_override():
    spec = CampaignSpec.from_dict(_spec(**{
        "trace": [{"name": "mini-steady", "target_load": [0.6, 0.9, 1.2]},
                  {"name": "mini-bursty"}]}))
    # 3 points for steady, 1 (grid) for bursty, x 2 mech x 2 seeds
    assert spec.n_cells == (3 + 1) * 2 * 2


# ------------------------------------------------------------------- reports
def _rows():
    rows = []
    for trace in ("a", "b"):
        for mech, od in (("BASE", 2.0), ("CUA&SPAA", 1.0)):
            for seed in range(3):
                rows.append({
                    "regime": {"trace": trace, "target_load": 0.8},
                    "mechanism": mech, "seed": seed,
                    "metrics": {"avg_turnaround_od_h": od + 0.01 * seed,
                                "avg_bounded_slowdown": od,
                                "system_utilization": 0.5}})
    return rows


def test_report_winners_and_determinism():
    agg1, agg2 = aggregate(_rows()), aggregate(list(reversed(_rows())))
    assert agg1 == agg2  # row order must not matter
    won = winners(agg1)
    assert len(won) == 2
    for row in won:
        w = row["winners"]["avg_turnaround_od_h"]
        assert w["mechanism"] == "CUA&SPAA"
        assert w["decisive"]  # CIs are far apart
        # exact utilization tie: name order breaks it deterministically
        assert row["winners"]["system_utilization"]["mechanism"] == "BASE"
        assert not row["winners"]["system_utilization"]["decisive"]


def test_bootstrap_ci_is_seeded_by_key():
    lo1, hi1 = bootstrap_ci([1.0, 2.0, 3.0], key="k")
    lo2, hi2 = bootstrap_ci([1.0, 2.0, 3.0], key="k")
    assert (lo1, hi1) == (lo2, hi2)
    assert bootstrap_ci([5.0], key="k") == (5.0, 5.0)
    nan_lo, nan_hi = bootstrap_ci([], key="k")
    assert np.isnan(nan_lo) and np.isnan(nan_hi)


# ------------------------------------------------------- end-to-end campaigns
def test_campaign_end_to_end_deterministic(tmp_path):
    spec = CampaignSpec.from_dict(SPEC_DICT)
    out1, out2 = tmp_path / "run1", tmp_path / "run2"
    paths1 = run_campaign(spec, out_dir=str(out1), processes=0)
    paths2 = run_campaign(spec, out_dir=str(out2), processes=0)
    for key in ("rows", "report_json", "report_md"):
        b1 = open(paths1[key], "rb").read()
        b2 = open(paths2[key], "rb").read()
        assert b1 == b2, f"{key} not byte-identical across runs"
    payload = json.load(open(paths1["rows"]))
    assert len(payload["rows"]) == spec.n_cells
    traces = {r["regime"]["trace"] for r in payload["rows"]}
    assert traces == {"mini-steady", "mini-bursty"}
    # metrics carry the new BSLD field
    assert all(r["metrics"]["avg_bounded_slowdown"] >= 1.0
               for r in payload["rows"])


def test_campaign_checkpoint_resume_matches_uninterrupted(tmp_path):
    """Satellite: kill a multi-trace campaign mid-grid, resume, and the
    completed-cell set + aggregated artifacts match the uninterrupted
    run byte for byte."""
    spec = CampaignSpec.from_dict(SPEC_DICT)
    baseline = run_campaign(spec, out_dir=str(tmp_path / "full"),
                            processes=0)

    out = tmp_path / "killed"
    out.mkdir()
    exp, _regimes = spec.to_experiment(processes=0)
    ckpt = str(out / "checkpoint.json")
    killed_after = 3
    for i, _result in enumerate(exp.run_stream(checkpoint=ckpt), 1):
        if i == killed_after:
            break  # simulated kill mid-grid (two traces still pending)
    saved = json.load(open(ckpt))
    assert len(saved["runs"]) == killed_after
    assert saved["grid_key"] == exp.grid_key()

    executed = []
    run_campaign(spec, out_dir=str(out), processes=0,
                 progress=lambda d, t, r: executed.append(
                     (r.spec.workload.label, r.spec.mechanism,
                      r.spec.seed, r.elapsed_s)))
    # the first killed_after cells were restored (elapsed saved from the
    # first attempt), and every cell is accounted for exactly once
    assert len(executed) == spec.n_cells
    assert len({e[:3] for e in executed}) == spec.n_cells
    for key in ("rows", "report_json", "report_md"):
        b_full = open(baseline[key], "rb").read()
        b_resumed = open(os.path.join(
            str(out), os.path.basename(baseline[key])), "rb").read()
        assert b_full == b_resumed


def test_campaign_checkpoint_refuses_foreign_grid(tmp_path):
    spec = CampaignSpec.from_dict(SPEC_DICT)
    other = CampaignSpec.from_dict(_spec(**{"campaign.seeds": [7]}))
    out = tmp_path / "c"
    run_campaign(spec, out_dir=str(out), processes=0)
    with pytest.raises(ValueError, match="different"):
        run_campaign(other, out_dir=str(out), processes=0)


def test_campaign_fresh_discards_checkpoint(tmp_path):
    spec = CampaignSpec.from_dict(SPEC_DICT)
    other = CampaignSpec.from_dict(_spec(**{"campaign.seeds": [7]}))
    out = tmp_path / "c"
    run_campaign(spec, out_dir=str(out), processes=0)
    # resume=False: the stale grid's checkpoint is discarded, not refused
    run_campaign(other, out_dir=str(out), processes=0, resume=False)
    payload = json.load(open(out / "rows.json"))
    assert {r["seed"] for r in payload["rows"]} == {7}
