"""Batched scheduling rounds (``SimConfig.batch_rounds``) semantics.

Per-event mode (``batch_rounds=0``, the default) must stay bit-identical
to the engine without the knob; batch mode defers queue passes to fixed
round boundaries while on-demand arrivals keep the immediate path
(Obs-10).  The measured fidelity-vs-speed curve lives in
benchmarks/bench_scale.bench_batch_fidelity; these are the semantic
contracts it relies on.
"""
import dataclasses

import pytest

from repro.core import (JobSpec, JobType, NoticeKind, SimConfig, Simulator,
                        StreamingMetrics, WorkloadConfig, generate)
from repro.core.experiment import RunSpec, _sim_kw
from repro.core.metrics import decision_p99_ms
from repro.core.workloads import get_scenario

N = 100  # cluster size for micro-scenarios


def rigid(jid, t, size, rt, est=None, **kw):
    return JobSpec(jid, JobType.RIGID, "p", t, size, est or rt * 2, rt, **kw)


def od(jid, t, size, rt, kind=NoticeKind.NONE, notice=None, est_arr=None):
    return JobSpec(jid, JobType.ONDEMAND, "p", t, size, rt * 2, rt,
                   notice_kind=kind, notice_time=notice, est_arrival=est_arr)


def run(jobs, mech="N&PAA", n=N, **kw):
    sim = Simulator(SimConfig(n_nodes=n, mechanism=mech, **kw), jobs)
    sim.run()
    return sim


def _outcomes(sim):
    return sorted((r.job.jid, r.first_start, r.completion, r.killed,
                   r.n_preempted, r.n_shrunk, r.instant)
                  for r in sim.records.values())


# --------------------------------------------------------- per-event identity
def test_batch_zero_identical_to_default():
    """batch_rounds=0 must be the per-event engine bit for bit — same
    outcome tuples on an organic workload with on-demand traffic."""
    cfg = WorkloadConfig(n_jobs=150, n_nodes=512, n_projects=12,
                         horizon_days=4.0, seed=3, frac_od_projects=0.3)
    jobs = generate(cfg)
    ref = run(list(jobs), mech="CUA&SPAA", n=512)
    b0 = run(list(jobs), mech="CUA&SPAA", n=512, batch_rounds=0.0)
    assert _outcomes(ref) == _outcomes(b0)


# ------------------------------------------------------------ round deferral
def test_batch_job_start_deferred_to_round_boundary():
    """Free nodes are available at submit, but the scheduling pass for a
    batch job waits for the next round boundary."""
    jobs = [rigid(0, 0.0, 10, 500.0), rigid(1, 50.0, 10, 100.0)]
    per_event = run([dataclasses.replace(j) for j in jobs])
    batched = run(jobs, batch_rounds=300.0)
    assert per_event.records[1].first_start == 50.0
    # t=0 lands exactly on a boundary, so job 0 still starts at 0
    assert batched.records[0].first_start == 0.0
    assert batched.records[1].first_start == 300.0


def test_od_arrival_immediate_despite_huge_rounds():
    """On-demand arrivals keep the immediate path (Obs-10): a round
    length longer than the whole run must not delay an od start."""
    jobs = [rigid(0, 0.0, 10, 1000.0), od(1, 50.0, 10, 100.0)]
    sim = run(jobs, mech="CUA&SPAA", batch_rounds=1e6)
    assert sim.records[1].first_start == 50.0
    assert sim.records[1].instant


def test_od_forced_pass_supersedes_pending_round():
    """The immediate od pass is a full pass: queued batch work start
    there too, and the pending boundary pass is cancelled, not re-run."""
    jobs = [rigid(0, 10.0, 10, 500.0), od(1, 50.0, 10, 100.0)]
    sim = run(jobs, mech="CUA&SPAA", batch_rounds=300.0)
    # job 0's pass was deferred to t=300, but the od arrival at t=50
    # forces a pass that starts it then
    assert sim.records[0].first_start == 50.0
    assert sim.records[1].first_start == 50.0


# ------------------------------------------------------- incremental driving
def test_next_event_time_reports_round_boundary():
    jobs = [rigid(0, 0.0, 10, 1000.0), rigid(1, 50.0, 10, 100.0)]
    sim = Simulator(SimConfig(n_nodes=N, batch_rounds=300.0), jobs)
    nxt = sim.step_until(50.0)
    # the deferred pass is the next "event": both the return value and
    # the peek must report the boundary, and peeking is non-perturbing
    assert nxt == 300.0
    assert sim.next_event_time() == 300.0
    assert sim.next_event_time() == 300.0
    assert sim.step_until(300.0) == 400.0       # job 1 ran 300 -> 400
    sim.run()
    assert sim.records[1].first_start == 300.0


def test_step_until_partitioning_identity_in_batch_mode():
    """Any non-decreasing sequence of limits must replay the exact event
    sequence of a single run() — with deferred round passes carried
    across step_until calls."""
    jobs, n_nodes = get_scenario("bursty-od", n_jobs=30).realize(seed=6)
    cfg = SimConfig(n_nodes=n_nodes, mechanism="CUA&SPAA",
                    batch_rounds=240.0)
    ref = Simulator(cfg, list(jobs)).run()
    sim = Simulator(cfg, list(jobs))
    t = 0.0
    while True:
        nxt = sim.step_until(t)
        if nxt is None:
            break
        t = nxt + 1.0
    sim.finalize()
    assert _outcomes(sim) == sorted(
        (r.job.jid, r.first_start, r.completion, r.killed,
         r.n_preempted, r.n_shrunk, r.instant) for r in ref.values())


# -------------------------------------------------------------- config plumb
def test_scenario_batch_rounds_validation():
    sc = get_scenario("bursty-od", n_jobs=10)
    for bad in (-1.0, float("inf"), float("nan"), True):
        with pytest.raises(ValueError, match="batch_rounds"):
            dataclasses.replace(sc, batch_rounds=bad).validate()
    dataclasses.replace(sc, batch_rounds=900.0).validate()  # fine


def test_experiment_threads_scenario_batch_rounds():
    sc = dataclasses.replace(get_scenario("bursty-od", n_jobs=10),
                             batch_rounds=600.0)
    kw = _sim_kw(RunSpec(mechanism="CUA&SPAA", workload=sc, seed=0))
    assert kw["batch_rounds"] == 600.0
    # an explicit override wins over the scenario field
    kw = _sim_kw(RunSpec(mechanism="CUA&SPAA", workload=sc, seed=0,
                         sim_kw=(("batch_rounds", 0.0),)))
    assert kw["batch_rounds"] == 0.0


# --------------------------------------------------- decision-time tracking
def test_scheduling_passes_are_timed_without_od_traffic():
    """track_decision_time must time scheduling passes themselves, not
    just od-arrival handling — a workload with zero od jobs still
    yields samples."""
    jobs = [rigid(0, 0.0, 10, 500.0), rigid(1, 50.0, 10, 100.0)]
    sim = run(jobs, track_decision_time=True)
    assert len(sim.decision_times) > 0
    assert decision_p99_ms(sim) is not None


def test_decision_sketch_replaces_list_on_streaming_runs():
    jobs = [rigid(0, 0.0, 10, 500.0), rigid(1, 50.0, 10, 100.0),
            od(2, 60.0, 10, 100.0)]
    cfg = SimConfig(n_nodes=N, mechanism="CUA&SPAA",
                    track_decision_time=True)
    sink = StreamingMetrics(instant_eps=cfg.instant_eps)
    sim = Simulator(cfg, jobs, record_sink=sink)
    sim.run()
    assert sim.decision_times == []          # the unbounded list stays empty
    assert sim._decision_sketch is not None
    assert sim._decision_sketch.count > 0
    assert decision_p99_ms(sim) is not None


# ------------------------------------------------------- od_timeout clamping
def test_late_notice_timeout_never_precedes_notice():
    """Regression: a LATE notice near t=0 can put est_arrival (and so
    the reservation timeout) before simulation start; the timeout is
    floored at the notice so the clock never runs backwards."""
    jobs = [od(0, 100.0, 10, 100.0, kind=NoticeKind.LATE, notice=5.0,
               est_arr=-2000.0)]
    sim = run(jobs, mech="CUA&SPAA")         # pre-fix: negative-time event
    rec = sim.records[0]
    assert rec.completion is not None
    assert rec.first_start == 100.0
