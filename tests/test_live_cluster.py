"""LiveCluster scheduling mechanics, driven by duck-typed fake jobs.

LiveCluster's scheduling layer is plain Python over the policy registry
(ElasticJob is a type-only import), so these tests run jax-free in
tier-1 CI and again in the kernels job.
"""
import os
import subprocess
import sys

import pytest

from repro.core.policy import UnknownPolicyError
from repro.runtime import LiveCluster


class FakeElasticJob:
    """Duck-type of repro.runtime.ElasticJob's scheduling surface."""

    def __init__(self, jid, kind="malleable", ckpt_every=50,
                 ckpt_dir="/tmp/ckpt"):
        self.jid = jid
        self.kind = kind
        self.ckpt_every = ckpt_every
        self.ckpt_dir = ckpt_dir
        self.state = None
        self.step_idx = 0
        self.events = []

    def start(self, devices):
        self.state = object()
        self.events.append(("start", len(devices)))

    def resume(self, devices):
        self.events.append(("resume", len(devices)))

    def step(self):
        self.step_idx += 1
        return {}

    def preempt(self, warning=True):
        self.events.append(("preempt", warning))

    def resize(self, devices):
        self.events.append(("resize", len(devices)))
        return 0.01


def _cluster(n=8, **kw):
    return LiveCluster([f"dev{i}" for i in range(n)], **kw)


def test_import_is_jax_free():
    """Importing LiveCluster must not pull in jax (CPU-only CI contract).
    Checked in a fresh interpreter: this process may have jax loaded
    from sibling test modules."""
    code = ("import sys; from repro.runtime import LiveCluster; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          env={**os.environ,
                               "PYTHONPATH": os.pathsep.join(sys.path)})
    assert proc.returncode == 0


def test_unknown_policies_raise():
    with pytest.raises(UnknownPolicyError):
        _cluster(arrival_policy="NOPE")
    with pytest.raises(UnknownPolicyError):
        _cluster(elasticity_policy="NADA")


def test_default_policy_pairing():
    assert _cluster().arrival_policy == "SPAA"
    assert _cluster().elasticity_policy == "NONE"
    c = _cluster(arrival_policy="STEAL")
    assert c.elasticity_policy == "BALANCE"   # preferred pairing
    c2 = _cluster(arrival_policy="PAA", elasticity_policy="BALANCE")
    assert (c2.arrival_policy, c2.elasticity_policy) == ("PAA", "BALANCE")


def test_submit_starts_malleable_at_available_width():
    c = _cluster(8)
    info = c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=6)
    assert info.status == "running" and len(info.node_ids) == 6
    info2 = c.submit(FakeElasticJob(2), min_nodes=3, max_nodes=4)
    assert info2.status == "waiting"          # only 2 free < min_nodes
    assert c.utilization() == 6 / 8


def test_rigid_requires_full_width():
    c = _cluster(4)
    info = c.submit(FakeElasticJob(1, kind="rigid"), min_nodes=2, max_nodes=3)
    assert info.status == "running" and len(info.node_ids) == 3
    info2 = c.submit(FakeElasticJob(2, kind="rigid"), min_nodes=1, max_nodes=2)
    assert info2.status == "waiting"          # 1 free < rigid width 2


def test_step_all_finishes_and_restarts_waiting():
    c = _cluster(4)
    a = c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=4, target_steps=3)
    b = c.submit(FakeElasticJob(2), min_nodes=2, max_nodes=2, target_steps=3)
    assert (a.status, b.status) == ("running", "waiting")
    c.step_all(3)
    assert a.status == "done"
    assert b.status == "running"              # restarted on freed nodes
    assert len(c.free) == 2


def test_ondemand_from_free_pool_only():
    c = _cluster(8)
    c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=4)
    got = c.acquire_for_ondemand(3)
    assert len(got) == 3 and len(c.free) == 1
    assert c.jobs[1].shrink_count == 0        # free pool sufficed
    c.release_ondemand(got)
    assert len(c.free) == 4


def test_spaa_shrinks_then_lease_repays():
    c = _cluster(8)
    j = FakeElasticJob(1)
    c.submit(j, min_nodes=2, max_nodes=6)
    c.submit(FakeElasticJob(2, kind="rigid"), min_nodes=2, max_nodes=2)
    got = c.acquire_for_ondemand(4)           # 0 free: shrink 6 -> 2
    assert len(got) == 4
    assert len(c.jobs[1].node_ids) == 2 and c.jobs[1].shrink_count == 1
    assert ("resize", 2) in j.events
    c.release_ondemand(got)                   # §III-B3: lender repaid
    assert len(c.jobs[1].node_ids) == 6
    assert ("resize", 6) in j.events
    assert c.jobs[1].preempt_count == 0


def test_paa_fallback_preempts_ascending_overhead():
    c = _cluster(8)
    cheap = FakeElasticJob(1, kind="rigid", ckpt_every=5)
    dear = FakeElasticJob(2, kind="rigid", ckpt_every=5)
    c.submit(cheap, min_nodes=4, max_nodes=4, target_steps=100)
    c.submit(dear, min_nodes=4, max_nodes=4, target_steps=100)
    c.step_all(4)                             # dear == cheap == 4 steps
    c.jobs[1].steps_done = 5                  # cheap: just checkpointed
    got = c.acquire_for_ondemand(4)
    assert len(got) == 4
    assert c.jobs[1].status == "preempted"    # lowest overhead victim
    assert c.jobs[2].status == "running"
    c.release_ondemand(got)
    assert c.jobs[1].status == "running"      # resumed after release


def test_acquire_failure_raises_without_side_effects():
    c = _cluster(4)
    with pytest.raises(ValueError):
        c.acquire_for_ondemand(5)             # more than the machine
    info = c.submit(FakeElasticJob(1), min_nodes=4, max_nodes=4)
    before = list(info.node_ids)
    got = c.acquire_for_ondemand(4)           # must preempt (no slack)
    assert c.jobs[1].status == "preempted"
    c.release_ondemand(got)
    assert sorted(c.jobs[1].node_ids) == sorted(before)


def test_balance_elasticity_expands_on_idle():
    c = _cluster(8, arrival_policy="STEAL")
    c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=8)
    got = c.acquire_for_ondemand(4)           # steal 8 -> 4
    assert len(c.jobs[1].node_ids) == 4
    c.release_ondemand(got)
    assert len(c.jobs[1].node_ids) == 8       # repaid back to n_max
    # finish a coexisting job: BALANCE absorbs the idle nodes
    c2 = _cluster(8, arrival_policy="STEAL")
    j1 = FakeElasticJob(1)
    c2.submit(j1, min_nodes=2, max_nodes=8, target_steps=50)
    # j1 grabbed all 8; vacate 2 so a short job can run beside it
    got2 = c2.acquire_for_ondemand(2)
    c2.free.extend(got2)                      # demand evaporates unleased
    c2.submit(FakeElasticJob(2), min_nodes=2, max_nodes=2, target_steps=1)
    assert len(c2.jobs[1].node_ids) == 6
    c2.step_all(1)                            # job 2 finishes
    assert c2.jobs[2].status == "done"
    assert len(c2.jobs[1].node_ids) == 8      # on_idle grew j1 back
    assert len(c2.free) == 0


def test_event_log_uses_monotonic_relative_time():
    c = _cluster(4)
    c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=4)
    assert c.started_wall > 1e9               # the wall-clock anchor
    assert all(0.0 <= row["t"] < 60.0 for row in c.log)
    assert [r["event"] for r in c.log] == ["start"]


def test_utilization_tracks_running_nodes():
    c = _cluster(8)
    assert c.utilization() == 0.0
    c.submit(FakeElasticJob(1), min_nodes=2, max_nodes=4)
    assert c.utilization() == 0.5
