"""Per-architecture smoke tests on REDUCED same-family configs (CPU).

For every assigned arch: one train step (finite loss, shapes), and a
prefill -> decode consistency check: decoding token t+1 after prefilling
t tokens must reproduce the full-forward logits at position t.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.configs.reduced import reduced
from repro.models import (TrainBatch, decode_step, forward, init_cache,
                          init_params, loss_fn, prefill)
from repro.training import AdamW, make_train_state, make_train_step, \
    synthetic_batch

B, S = 2, 32


def _extra(cfg):
    if cfg.family == "vlm":
        return jnp.asarray(np.random.default_rng(0).standard_normal(
            (B, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.family == "audio":
        return jnp.asarray(np.random.default_rng(0).standard_normal(
            (B, cfg.enc_len, cfg.d_model)) * 0.02, jnp.float32)
    return None


@pytest.fixture(scope="module")
def rigs():
    return {}


def _rig(rigs, arch):
    if arch not in rigs:
        cfg = reduced(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rigs[arch] = (cfg, params)
    return rigs[arch]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(rigs, arch):
    cfg, params = _rig(rigs, arch)
    opt = AdamW(warmup=2, total_steps=10)
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = synthetic_batch(cfg, B, S, seed=0, step=0)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_no_nans(rigs, arch):
    cfg, params = _rig(rigs, arch)
    batch = synthetic_batch(cfg, B, S, seed=1, step=0)
    logits, aux = forward(params, batch, cfg)
    assert logits.shape == (B, batch.tokens.shape[1], cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(rigs, arch):
    """Teacher-forced consistency: full forward logits at position t ==
    prefill(t tokens) -> decode logits (same inputs, same params)."""
    cfg, params = _rig(rigs, arch)
    if cfg.family == "audio":
        pytest.skip("enc-dec prefill tested separately below")
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = TrainBatch(tokens=toks, labels=toks, extra=_extra(cfg))
    full_logits, _ = forward(params, batch, cfg)
    t = S - 1
    if cfg.family in ("dense", "moe", "vlm"):
        npatch = cfg.n_patches if (cfg.family == "vlm"
                                   and batch.extra is not None) else 0
        plen = npatch + t        # cache length after prefill
        logits_p, cache = prefill(params, toks[:, :t], cfg,
                                  extra=batch.extra)
        # grow every cache seq axis by one slot for the decode step
        cache = jax.tree.map(
            lambda c: jnp.pad(c, [(0, 0)] * (c.ndim - 3)
                              + [(0, 1), (0, 0), (0, 0)])
            if c.ndim >= 4 and c.shape[-3] == plen else
            (jnp.pad(c, [(0, 0)] * (c.ndim - 2) + [(0, 1), (0, 0)])
             if c.ndim >= 3 and c.shape[-2] == plen else c), cache)
        logits_d, _ = decode_step(params, cache, toks[:, t:t + 1], plen, cfg)
        a = jax.nn.log_softmax(full_logits[:, t].astype(jnp.float32))
        b = jax.nn.log_softmax(logits_d.astype(jnp.float32))
        assert float(jnp.abs(a - b).max()) < 2e-2
    elif cfg.family in ("ssm", "hybrid"):
        logits_p, cache = prefill(params, toks[:, :t], cfg)
        if cfg.family == "hybrid":
            # grow attention cache by one slot
            ck, cv = cache["attn"]
            pad = [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]
            cache = {"mamba": cache["mamba"],
                     "attn": (jnp.pad(ck, pad), jnp.pad(cv, pad))}
        logits_d, _ = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
        a = jax.nn.log_softmax(full_logits[:, t].astype(jnp.float32))
        b = jax.nn.log_softmax(logits_d.astype(jnp.float32))
        assert float(jnp.abs(a - b).max()) < 5e-2
    # prefill's own last logits must match forward at t-1
    a = jax.nn.log_softmax(full_logits[:, t - 1].astype(jnp.float32))
    b = jax.nn.log_softmax(logits_p.astype(jnp.float32))
    assert float(jnp.abs(a - b).max()) < 5e-2


def test_encdec_prefill_decode(rigs):
    cfg, params = _rig(rigs, "seamless_m4t_medium")
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    extra = _extra(cfg)
    batch = TrainBatch(tokens=toks, labels=toks, extra=extra)
    full_logits, _ = forward(params, batch, cfg)
    t = S - 1
    logits_p, cache = prefill(params, toks[:, :t], cfg, extra=extra)
    pad = [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]
    cache["self"] = tuple(jnp.pad(c, pad) for c in cache["self"])
    logits_d, _ = decode_step(params, cache, toks[:, t:t + 1], t, cfg)
    a = jax.nn.log_softmax(full_logits[:, t].astype(jnp.float32))
    b = jax.nn.log_softmax(logits_d.astype(jnp.float32))
    assert float(jnp.abs(a - b).max()) < 2e-2
