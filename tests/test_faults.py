"""repro.faults: deterministic node failure/repair injection.

Covers the registry/spec surface, stream determinism, the
``faults="none"`` bit-for-bit invariance gate, per-job-type fault
semantics on hand-crafted traces (numbers derived in docs/faults.md),
fault metrics, ledger invariants, and shadow fidelity under faults.
"""

import pytest

from repro.core import JobSpec, JobType, SimConfig, Simulator
from repro.core.metrics import StreamingMetrics, collect, records_sha256
from repro.core.policy import SchedulerView
from repro.core.workloads import Scenario, get_scenario
from repro.faults import (ExpMtbfFaults, FaultEvent, NoFaults, TraceFaults,
                          UnknownFaultModelError, WeibullFaults,
                          fault_spec_label, parse_fault_spec,
                          registered_fault_models, resolve_faults)


def _trace(events):
    return {"model": "trace", "events": events}


def _scenario_jobs(n_jobs=40, seed=0):
    return get_scenario("bursty-od", n_jobs=n_jobs).realize(seed)


# ------------------------------------------------------------ registry/spec
def test_registry_lists_builtin_models():
    assert {"none", "exp-mtbf", "weibull", "trace"} <= \
        set(registered_fault_models())


def test_parse_compact_spec():
    assert parse_fault_spec("exp-mtbf:mtbf_h=168,mttr_h=2") == {
        "model": "exp-mtbf", "mtbf_h": 168, "mttr_h": 2}
    assert parse_fault_spec("none") == {"model": "none"}
    with pytest.raises(ValueError):
        parse_fault_spec("exp-mtbf:mtbf_h168")


def test_resolve_accepts_all_forms(tmp_path):
    assert isinstance(resolve_faults(None), NoFaults)
    assert isinstance(resolve_faults("none"), NoFaults)
    m = resolve_faults("exp-mtbf:mtbf_h=100,mttr_h=1")
    assert isinstance(m, ExpMtbfFaults) and m.mtbf_h == 100.0
    m2 = resolve_faults({"model": "weibull", "shape": 0.5})
    assert isinstance(m2, WeibullFaults) and m2.shape == 0.5
    m3 = resolve_faults(_trace([(5.0, 0, "down"), (9.0, 0, "up")]))
    assert isinstance(m3, TraceFaults)
    assert resolve_faults(m3) is m3


def test_resolve_rejects_garbage():
    with pytest.raises(UnknownFaultModelError):
        resolve_faults("mtbf-exp")
    with pytest.raises(ValueError):
        resolve_faults("exp-mtbf:nonsense_param=3")
    with pytest.raises(ValueError):
        resolve_faults({"no_model_key": 1})
    with pytest.raises(ValueError):
        resolve_faults("exp-mtbf:mtbf_h=-5")
    with pytest.raises(TypeError):
        resolve_faults(3.14)


def test_fault_spec_label_forms():
    assert fault_spec_label(None) == "none"
    assert fault_spec_label("exp-mtbf:mtbf_h=100") == "exp-mtbf:mtbf_h=100"
    assert fault_spec_label({"model": "weibull", "shape": 0.5}) == \
        "weibull:shape=0.5"


def test_trace_model_file_roundtrip(tmp_path):
    p = tmp_path / "faults.jsonl"
    p.write_text('{"t": 5.0, "node": 1, "kind": "down"}\n'
                 '# comment line\n'
                 '9.0,1,up\n')
    evs = TraceFaults(path=str(p)).events(4)
    assert evs == [FaultEvent(5.0, 1, "down"), FaultEvent(9.0, 1, "up")]
    with pytest.raises(ValueError):
        TraceFaults(path=str(p), events=[(1.0, 0, "down")])
    with pytest.raises(ValueError):
        TraceFaults(events=[(1.0, 0, "explode")])


# ------------------------------------------------------------- determinism
def test_event_stream_deterministic_and_seed_sensitive():
    a = ExpMtbfFaults(mtbf_h=50, mttr_h=2, horizon_days=2, seed=7)
    b = ExpMtbfFaults(mtbf_h=50, mttr_h=2, horizon_days=2, seed=7)
    c = ExpMtbfFaults(mtbf_h=50, mttr_h=2, horizon_days=2, seed=8)
    assert a.events(16) == b.events(16)
    assert a.events(16) != c.events(16)
    w = WeibullFaults(shape=0.7, scale_h=50, mttr_h=2, horizon_days=2,
                      seed=7)
    assert w.events(16) == w.events(16)


def test_event_stream_well_formed():
    evs = ExpMtbfFaults(mtbf_h=20, mttr_h=4, horizon_days=5,
                        seed=3).events(8)
    assert evs == sorted(evs)
    assert all(0.0 < e.t for e in evs)
    per_node = {}
    for e in evs:
        per_node.setdefault(e.node, []).append(e.kind)
    for kinds in per_node.values():
        # strict alternation starting at "down" (renewal process)
        assert kinds == ["down", "up"] * (len(kinds) // 2)


def test_node_streams_independent_of_cluster_size():
    """Node i's personal stream must not change when more nodes exist —
    the per-node rng keying contract."""
    small = ExpMtbfFaults(mtbf_h=30, mttr_h=2, horizon_days=5, seed=1)
    big = ExpMtbfFaults(mtbf_h=30, mttr_h=2, horizon_days=5, seed=1)
    ev4 = [e for e in small.events(4) if e.node < 4]
    ev4_of_16 = [e for e in big.events(16) if e.node < 4]
    assert ev4 == ev4_of_16


def test_fault_run_job_for_job_deterministic():
    jobs, n_nodes = _scenario_jobs(n_jobs=40, seed=2)
    kw = dict(n_nodes=n_nodes, mechanism="CUA&SPAA",
              faults="exp-mtbf:mtbf_h=40,mttr_h=2,horizon_days=2")
    d1 = records_sha256(Simulator(SimConfig(**kw), list(jobs)).run())
    d2 = records_sha256(Simulator(SimConfig(**kw), list(jobs)).run())
    assert d1 == d2


def test_none_is_bit_for_bit_legacy():
    """faults="none" / None / omitted must be indistinguishable."""
    jobs, n_nodes = _scenario_jobs(n_jobs=40, seed=0)
    base = dict(n_nodes=n_nodes, mechanism="CUP&STEAL")
    ref = records_sha256(Simulator(SimConfig(**base), list(jobs)).run())
    for spec in ("none", None):
        got = records_sha256(
            Simulator(SimConfig(**base, faults=spec), list(jobs)).run())
        assert got == ref
    # and the fault axis actually changes outcomes when enabled
    faulty = records_sha256(Simulator(
        SimConfig(**base, faults="exp-mtbf:mtbf_h=40,mttr_h=2,"
                                 "horizon_days=2"), list(jobs)).run())
    assert faulty != ref


# -------------------------------------------------- per-type fault semantics
def test_rigid_restarts_from_last_checkpoint():
    """2-node rigid job, ckpt every 300s; node dies at t=500 (one full
    checkpoint = 600 node-s protected, 400 node-s lost), repaired at
    t=600.  Remaining 3400 node-s on 2 nodes => completion 600+1700."""
    j = JobSpec(jid=0, jtype=JobType.RIGID, project="t", submit_time=0.0,
                size=2, t_estimate=4000.0, t_actual=2000.0, t_setup=0.0,
                ckpt_interval=300.0, ckpt_overhead=0.0)
    cfg = SimConfig(n_nodes=2, mechanism="CUA&SPAA",
                    faults=_trace([(500.0, 0, "down"), (600.0, 0, "up")]))
    sim = Simulator(cfg, [j])
    rec = sim.run()[0]
    assert not rec.killed
    assert rec.n_preempted == 1
    assert rec.completion == pytest.approx(2300.0)
    m = collect(sim)
    assert m.n_node_failures == 1
    assert m.n_interruptions == 1
    assert m.lost_work_node_h == pytest.approx(400.0 / 3600.0)


def test_malleable_shrinks_then_expands_back():
    """4-node malleable (n_min=2) loses a node at t=200 and keeps
    running at 3; repair at t=400 expands it back.  Work ledger:
    200*4 + 200*3 + rest at 4 => completion 1050, no restart."""
    j = JobSpec(jid=0, jtype=JobType.MALLEABLE, project="t",
                submit_time=0.0, size=4, t_estimate=3000.0,
                t_actual=1000.0, t_setup=0.0, n_min=2)
    cfg = SimConfig(n_nodes=4, mechanism="CUA&SPAA",
                    faults=_trace([(200.0, 1, "down"), (400.0, 1, "up")]))
    sim = Simulator(cfg, [j])
    rec = sim.run()[0]
    assert not rec.killed
    assert rec.n_shrunk == 1
    assert rec.n_preempted == 0       # never vacated, no setup re-paid
    assert rec.completion == pytest.approx(1050.0)


def test_malleable_at_n_min_is_killed_not_shrunk():
    """At cur_size == n_min the job cannot shed the node: it restarts
    like a rigid job (malleable checkpoint = all done work)."""
    j = JobSpec(jid=0, jtype=JobType.MALLEABLE, project="t",
                submit_time=0.0, size=2, t_estimate=3000.0,
                t_actual=1000.0, t_setup=0.0, n_min=2)
    cfg = SimConfig(n_nodes=2, mechanism="CUA&SPAA",
                    faults=_trace([(200.0, 0, "down"), (300.0, 0, "up")]))
    sim = Simulator(cfg, [j])
    rec = sim.run()[0]
    assert rec.n_preempted == 1
    assert not rec.killed
    # malleable ckpt == done work: no work lost, only the outage window
    assert rec.completion == pytest.approx(1100.0)


def test_ondemand_redispatched_with_wait_clock_running():
    """On-demand job loses a node mid-hold: all progress is lost, the
    survivor becomes its reservation, and it restarts the full hold when
    the repair completes the reservation — turnaround measured through
    the failure."""
    j = JobSpec(jid=0, jtype=JobType.ONDEMAND, project="od",
                submit_time=100.0, size=2, t_estimate=300.0,
                t_actual=300.0)
    cfg = SimConfig(n_nodes=2, mechanism="CUA&SPAA",
                    faults=_trace([(200.0, 0, "down"), (250.0, 0, "up")]))
    sim = Simulator(cfg, [j])
    rec = sim.run()[0]
    assert rec.first_start == pytest.approx(100.0)
    assert rec.n_preempted == 1
    assert not rec.killed
    assert rec.completion == pytest.approx(550.0)   # 250 + full 300s hold


def test_free_pool_failure_delays_start():
    """A failure that lands on an idle node starves the queue: a 2-node
    job cannot start until the repair restores capacity."""
    j = JobSpec(jid=0, jtype=JobType.RIGID, project="t", submit_time=100.0,
                size=2, t_estimate=1000.0, t_actual=400.0)
    cfg = SimConfig(n_nodes=2, mechanism="CUA&SPAA",
                    faults=_trace([(50.0, 0, "down"), (500.0, 0, "up")]))
    sim = Simulator(cfg, [j])
    rec = sim.run()[0]
    assert rec.first_start == pytest.approx(500.0)
    assert rec.completion == pytest.approx(900.0)


# ------------------------------------------------------- metrics & invariants
def test_fault_metrics_absent_on_perfect_machine():
    jobs, n_nodes = _scenario_jobs(n_jobs=20, seed=1)
    sim = Simulator(SimConfig(n_nodes=n_nodes), list(jobs))
    sim.run()
    d = collect(sim).as_dict()
    for key in ("n_node_failures", "n_interruptions", "lost_work_node_h",
                "goodput"):
        assert key not in d


def test_fault_metrics_present_and_streaming_agrees():
    jobs, n_nodes = _scenario_jobs(n_jobs=40, seed=2)
    spec = "exp-mtbf:mtbf_h=40,mttr_h=2,horizon_days=2"
    cfg = SimConfig(n_nodes=n_nodes, faults=spec)
    sim = Simulator(cfg, list(jobs))
    sim.run()
    m = collect(sim)
    assert m.n_node_failures > 0
    assert m.goodput == m.goodput        # not NaN
    assert 0.0 < m.goodput <= 1.0

    sm = StreamingMetrics()
    sim2 = Simulator(SimConfig(n_nodes=n_nodes, faults=spec), list(jobs),
                     record_sink=sm)
    sim2.run()
    m2 = sm.result(sim2)
    assert m2.goodput == pytest.approx(m.goodput, abs=1e-12)
    assert m2.lost_work_node_h == pytest.approx(m.lost_work_node_h)
    assert m2.n_interruptions == m.n_interruptions


def test_ledger_balanced_after_all_repairs():
    jobs, n_nodes = _scenario_jobs(n_jobs=30, seed=3)
    sim = Simulator(SimConfig(
        n_nodes=n_nodes,
        faults="exp-mtbf:mtbf_h=40,mttr_h=1,horizon_days=2"), list(jobs))
    sim.run()
    assert sim.fault_downs > 0
    assert sim.fault_ups == sim.fault_downs    # every outage repaired
    sim.ledger.check()
    assert sim.ledger.down == 0
    assert sim.ledger.free + sim.ledger.occupied <= sim.cfg.n_nodes


def test_view_exposes_fault_state():
    j = JobSpec(jid=0, jtype=JobType.RIGID, project="t", submit_time=0.0,
                size=1, t_estimate=5000.0, t_actual=4000.0)
    cfg = SimConfig(n_nodes=4, mechanism="CUA&SPAA",
                    faults=_trace([(100.0, 2, "down"), (900.0, 2, "up")]))
    sim = Simulator(cfg, [j])
    view = SchedulerView(sim)
    assert view.fault_model == "trace"
    sim.step_until(500.0)
    assert view.down == 1
    sim.step_until(1000.0)
    assert view.down == 0
    assert view.draining == 0

    sim_plain = Simulator(SimConfig(n_nodes=4), [
        JobSpec(jid=0, jtype=JobType.RIGID, project="t", submit_time=0.0,
                size=1, t_estimate=100.0, t_actual=50.0)])
    v = SchedulerView(sim_plain)
    assert v.fault_model == "none" and v.down == 0 and v.draining == 0


# ------------------------------------------------------- scenario/experiment
def test_scenario_validates_fault_spec():
    sc = get_scenario("bursty-od", n_jobs=10)
    ok = Scenario(**{**sc.__dict__, "faults": "exp-mtbf:mtbf_h=100"})
    ok.validate()
    bad = Scenario(**{**sc.__dict__, "faults": "not-a-model"})
    with pytest.raises(UnknownFaultModelError):
        bad.validate()


def test_shadow_fidelity_holds_under_faults():
    from repro.service import ServiceConfig, shadow_fidelity
    jobs, n_nodes = _scenario_jobs(n_jobs=40, seed=3)
    for mech in ("CUA&SPAA", "CUP&STEAL"):
        cfg = ServiceConfig(
            n_nodes=n_nodes, mechanism=mech,
            sim_overrides={"faults":
                           "exp-mtbf:mtbf_h=40,mttr_h=2,horizon_days=2"})
        fr = shadow_fidelity(list(jobs), cfg)
        assert fr.ok, (mech, fr.mismatched_jids)


def test_service_core_narrates_fault_events():
    from repro.service import NullLauncher, ServiceConfig, ServiceCore
    jobs, n_nodes = _scenario_jobs(n_jobs=40, seed=3)
    cfg = ServiceConfig(
        n_nodes=n_nodes,
        sim_overrides={"faults":
                       "exp-mtbf:mtbf_h=40,mttr_h=2,horizon_days=2"})
    core = ServiceCore(cfg.sim_config(), list(jobs), launcher=NullLauncher())
    core.run()
    events = {r["event"] for r in core.drain_decisions()}
    assert "node_down" in events and "node_up" in events


