"""repro.service: shadow-mode scheduler daemon over the policy engine.

Everything here is jax-free (tier-1): the service replays scenarios
through DryrunLauncher / NullLauncher on CPU.
"""
import json
import math
import time

import pytest

from repro.core import JobSpec, JobType, NoticeKind, SimConfig, Simulator
from repro.core.workloads import get_scenario
from repro.service import (AdmissionQueue, DecisionLog, DryrunLauncher,
                           NullLauncher, ReplayClock, SchedulerService,
                           ServiceConfig, ServiceCore, ShadowLaunchError,
                           SloMonitor, SloPolicy, decision_digest,
                           plan_requests, read_decision_log, shadow_fidelity)


def _jobs_small():
    """A hand-rolled hybrid mix exercising shrink, preempt, and notice."""
    return [
        JobSpec(jid=0, jtype=JobType.MALLEABLE, project="t", submit_time=0.0,
                size=6, t_estimate=9000.0, t_actual=6000.0, t_setup=30.0,
                n_min=2),
        JobSpec(jid=1, jtype=JobType.RIGID, project="t", submit_time=10.0,
                size=2, t_estimate=4000.0, t_actual=3000.0, t_setup=30.0),
        JobSpec(jid=2, jtype=JobType.ONDEMAND, project="od", submit_time=600.0,
                size=4, t_estimate=1200.0, t_actual=1200.0,
                notice_kind=NoticeKind.ACCURATE, notice_time=300.0,
                est_arrival=600.0),
        JobSpec(jid=3, jtype=JobType.RIGID, project="t", submit_time=700.0,
                size=3, t_estimate=2000.0, t_actual=1500.0, t_setup=30.0),
    ]


def _scenario_jobs(n_jobs=40, seed=0):
    return get_scenario("bursty-od", n_jobs=n_jobs).realize(seed)


# ------------------------------------------------------------- replay clock
def test_replay_clock_inf_never_sleeps():
    clock = ReplayClock()
    assert not clock.realtime
    t0 = time.monotonic()
    assert clock.sleep_until(1e12) == 0.0
    assert time.monotonic() - t0 < 0.05
    assert clock.now_sim() == math.inf


def test_replay_clock_scales_and_sleeps():
    clock = ReplayClock(speed=1000.0, origin=500.0)
    assert clock.realtime
    slept = clock.sleep_until(520.0)          # 20 sim-s = 20ms wall
    assert slept > 0.0
    assert clock.now_sim() >= 520.0


def test_replay_clock_rejects_bad_speed():
    for bad in (0.0, -1.0, float("nan")):
        with pytest.raises(ValueError):
            ReplayClock(speed=bad)


# ------------------------------------------------------------- decision log
def test_decision_log_jsonl_roundtrip_and_digest(tmp_path):
    path = str(tmp_path / "decisions.jsonl")
    rows = [{"seq": 0, "event": "start", "jid": 1, "t_sim": 0.0},
            {"seq": 1, "event": "end", "jid": 1, "t_sim": 9.5}]
    with DecisionLog(path) as log:
        log.append(rows[0], latency_ms=0.5)
        log.append(rows[1], latency_ms=1.5)
        digest = log.digest
    back = read_decision_log(path)
    assert len(back) == 2
    assert back[0]["event"] == "start" and "wall" in back[0]
    assert back[1]["latency_ms"] == 1.5
    # measurement fields are digest-excluded: re-digesting the file rows
    # (different wall/mono) reproduces the live digest
    assert decision_digest(back) == digest == decision_digest(rows)


def test_decision_log_latency_summary():
    log = DecisionLog()
    for ms in (1.0, 2.0, 3.0, 4.0):
        log.append({"seq": 0, "event": "x", "jid": 0}, latency_ms=ms)
    s = log.latency_summary()
    assert s["n"] == 4 and s["max_ms"] == 4.0
    assert 1.0 <= s["p50_ms"] <= 3.0 <= s["p99_ms"] <= 4.0
    assert DecisionLog().latency_summary()["n"] == 0


def test_digest_sensitive_to_order_and_content():
    a = [{"seq": 0, "event": "start", "jid": 1}]
    b = [{"seq": 0, "event": "start", "jid": 2}]
    assert decision_digest(a) != decision_digest(b)
    two = [{"seq": 0, "event": "s", "jid": 1}, {"seq": 1, "event": "e", "jid": 1}]
    assert decision_digest(two) != decision_digest(list(reversed(two)))


# -------------------------------------------------------------- slo monitor
def test_slo_monitor_gates_decision_latency():
    mon = SloMonitor(SloPolicy(decision_p99_ms=1.0))
    for _ in range(10):
        mon.add_decision_latency(0.2)
    assert mon.report().ok
    mon.add_decision_latency(500.0)   # >1% of samples: moves the p99
    rep = mon.report()
    assert not rep.ok and "decision p99" in rep.violations[0]


def test_slo_monitor_od_wait_gate():
    mon = SloMonitor(SloPolicy(od_wait_p99_s=10.0))
    sim = Simulator(SimConfig(n_nodes=8), _jobs_small(),
                    record_sink=mon.add_record)
    sim.run()
    rep = mon.report()
    assert rep.n_od == 1
    assert rep.ok  # CUA&SPAA starts the od instantly on this trace


# ----------------------------------------------------------- dryrun launcher
def test_dryrun_launcher_validates_transitions():
    lau = DryrunLauncher(n_nodes=4)
    od = JobSpec(jid=9, jtype=JobType.ONDEMAND, project="od", submit_time=0.0,
                 size=2, t_estimate=10.0, t_actual=10.0)
    with pytest.raises(ShadowLaunchError):
        lau.resize(od, 1)                     # resize before start
    lau.start_job(od, 2)
    with pytest.raises(ShadowLaunchError):
        lau.start_job(od, 2)                  # double start
    assert lau.counts["od_start"] == 1
    assert lau.request_plans[9] == plan_requests(od)
    big = JobSpec(jid=10, jtype=JobType.RIGID, project="t", submit_time=0.0,
                  size=3, t_estimate=10.0, t_actual=10.0)
    with pytest.raises(ShadowLaunchError):
        lau.start_job(big, 3)                 # 5 > 4 nodes: over-commit
    with pytest.raises(ShadowLaunchError):
        lau.close()                           # od still marked running


def test_plan_requests_deterministic_and_bounded():
    od = JobSpec(jid=3, jtype=JobType.ONDEMAND, project="od", submit_time=0.0,
                 size=20, t_estimate=10.0, t_actual=10.0)
    plan = plan_requests(od, max_batch=8)
    assert plan == plan_requests(od, max_batch=8)
    assert len(plan) == 8
    assert all(8 <= r["prompt_len"] < 64 for r in plan)


# ------------------------------------------------------- core + replay loop
def test_service_core_decision_stream_matches_offline_reference():
    jobs, n_nodes = _scenario_jobs()
    cfg = ServiceConfig(n_nodes=n_nodes)
    svc = SchedulerService(cfg, list(jobs), launcher=DryrunLauncher(n_nodes))
    rep = svc.run_replay()
    ref = ServiceCore(cfg.sim_config(), list(jobs), launcher=NullLauncher())
    ref.run()
    assert rep.digest == decision_digest(ref.drain_decisions())
    assert rep.n_decisions > 0


def test_shadow_fidelity_job_for_job_all_mechanisms():
    jobs, n_nodes = _scenario_jobs(n_jobs=30, seed=1)
    for mech in ("BASE", "N&PAA", "CUA&SPAA", "CUP&STEAL"):
        cfg = ServiceConfig(n_nodes=n_nodes, mechanism=mech)
        rep = shadow_fidelity(jobs, cfg)
        assert rep.ok, (mech, rep.mismatched_jids)
        assert rep.digests_match and rep.records_match


def test_service_replay_writes_decision_log(tmp_path):
    jobs, n_nodes = _scenario_jobs(n_jobs=20, seed=8)
    path = str(tmp_path / "d.jsonl")
    cfg = ServiceConfig(n_nodes=n_nodes, decision_log_path=path)
    svc = SchedulerService(cfg, jobs, launcher=DryrunLauncher(n_nodes))
    rep = svc.run_replay()
    rows = read_decision_log(path)
    assert len(rows) == rep.n_decisions
    assert decision_digest(rows) == rep.digest
    assert all("latency_ms" in r and "wall" in r and "mono" in r
               for r in rows)
    starts = [r for r in rows if r["event"] == "start"]
    assert starts and all("size" in r and "jtype" in r for r in starts)


def test_service_realtime_pacing_spreads_decisions():
    jobs = _jobs_small()
    # 1000 sim-s per wall-s: the 700s trace span replays in ~0.7s wall
    cfg = ServiceConfig(n_nodes=8, speed=5000.0)
    svc = SchedulerService(cfg, jobs, launcher=DryrunLauncher(8))
    rep = svc.run_replay()
    assert rep.wall_s > 0.1               # actually slept between events
    assert rep.digest == shadow_fidelity(
        _jobs_small(), ServiceConfig(n_nodes=8)).digest_reference


def test_service_streaming_record_sink():
    jobs, n_nodes = _scenario_jobs(n_jobs=25, seed=3)
    seen = []
    cfg = ServiceConfig(n_nodes=n_nodes)
    svc = SchedulerService(cfg, jobs, launcher=DryrunLauncher(n_nodes),
                           record_sink=seen.append)
    rep = svc.run_replay()
    assert len(seen) == rep.n_jobs
    assert not svc.core.records              # everything retired


def test_shadow_report_is_json_serializable():
    jobs, n_nodes = _scenario_jobs(n_jobs=15, seed=4)
    rep = shadow_fidelity(jobs, ServiceConfig(n_nodes=n_nodes))
    json.dumps(rep.as_dict(), default=str)


# ---------------------------------------------------------------- live mode
def test_live_admission_end_to_end():
    cfg = ServiceConfig(n_nodes=8, speed=5000.0)
    adm = AdmissionQueue()
    svc = SchedulerService(cfg, [], launcher=DryrunLauncher(8))
    adm.submit_training(n_max=6, runtime_s=600.0, n_min=2)
    adm.submit_rigid(nodes=2, runtime_s=300.0)
    adm.submit_inference(nodes=4, hold_s=200.0, submit_time=100.0,
                         notice_lead_s=60.0)
    adm.close()
    rep = svc.run_live(adm)
    events = [r["event"] for r in svc.log.rows]
    assert events.count("admit") == 3
    assert "shrink" in events             # SPAA vacated the malleable
    assert "expand" in events             # lease repaid after od end
    assert rep.launcher_counts["od_start"] == 1
    assert rep.launcher_counts["finish"] == 3


def test_live_admission_clamps_past_times():
    core = ServiceCore(SimConfig(n_nodes=4), [], launcher=NullLauncher())
    core.step_until(0.0)
    core.now = 100.0
    spec = JobSpec(jid=7, jtype=JobType.RIGID, project="t", submit_time=5.0,
                   size=1, t_estimate=10.0, t_actual=10.0)
    admitted = core.admit(spec)
    assert admitted.submit_time == 100.0
    with pytest.raises(ValueError):
        core.admit(admitted)              # duplicate jid


def test_admit_rejected_on_trace_replaying_core():
    jobs, n_nodes = _scenario_jobs(n_jobs=10, seed=5)
    core = ServiceCore(SimConfig(n_nodes=n_nodes), iter(jobs))
    with pytest.raises(RuntimeError):
        core.admit(jobs[0])


def test_admission_queue_thread_safety_and_close():
    adm = AdmissionQueue(base_jid=50)
    s1 = adm.submit_training(n_max=2, runtime_s=10.0)
    s2 = adm.submit_inference(nodes=1, hold_s=5.0)
    assert (s1.jid, s2.jid) == (50, 51)
    assert len(adm) == 2
    got = adm.drain()
    assert [j.jid for j in got] == [50, 51] and len(adm) == 0
    adm.close()
    with pytest.raises(RuntimeError):
        adm.submit_rigid(nodes=1, runtime_s=1.0)


# ------------------------------------------------------------ incremental API
def test_step_until_partitioning_matches_single_run():
    jobs, n_nodes = _scenario_jobs(n_jobs=30, seed=6)
    cfg = SimConfig(n_nodes=n_nodes)
    ref = Simulator(cfg, list(jobs)).run()
    sim = Simulator(cfg, list(jobs))
    t = 0.0
    while True:
        nxt = sim.step_until(t)
        if nxt is None:
            break
        t = nxt + 1.0                     # arbitrary non-decreasing limits
    got = sim.records
    assert set(got) == set(ref)
    for jid in ref:
        assert got[jid].completion == ref[jid].completion
        assert got[jid].n_preempted == ref[jid].n_preempted


def test_next_event_time_monotone_nonperturbing():
    jobs, n_nodes = _scenario_jobs(n_jobs=10, seed=7)
    sim = Simulator(SimConfig(n_nodes=n_nodes), iter(list(jobs)))
    t1 = sim.next_event_time()
    assert t1 == sim.next_event_time()    # peeking is idempotent
    sim.step_until(t1)
    t2 = sim.next_event_time()
    assert t2 is None or t2 > t1
