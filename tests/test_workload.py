"""Workload synthesis checks (paper §IV-A/B) + compression numerics.

Randomized (hypothesis) workload invariants live in
tests/test_properties.py, which importorskips hypothesis so a checkout
without the dev extras still collects and runs these deterministic tests.
"""
import numpy as np
import pytest

from repro.core import JobType, NoticeKind, WorkloadConfig, generate


def test_notice_mix_respected():
    cfg = WorkloadConfig(n_jobs=3000, n_nodes=2048, seed=3, notice_mix="W2",
                         frac_od_projects=0.5, frac_rigid_projects=0.3)
    jobs = generate(cfg)
    od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
    assert len(od) > 100
    frac_acc = np.mean([j.notice_kind is NoticeKind.ACCURATE for j in od])
    assert 0.55 < frac_acc < 0.85  # W2: 70% accurate notice


def test_offered_load_near_target():
    cfg = WorkloadConfig(n_jobs=1500, n_nodes=4392, seed=0, target_load=1.15,
                         horizon_days=60.0)  # horizon must not clip the span
    jobs = generate(cfg)
    span = max(j.submit_time for j in jobs) - min(j.submit_time for j in jobs)
    work = sum(j.t_actual * j.size for j in jobs)
    load = work / (span * cfg.n_nodes)
    assert 0.9 < load < 1.5


def test_int8_compression_error_feedback():
    """Quantize+error-feedback must be unbiased over steps: the residual
    carries, so the cumulative applied update converges to the true sum."""
    pytest.importorskip("jax")
    from repro.training.train_step import _dequantize_int8, _quantize_int8
    rng = np.random.default_rng(0)
    g_true = rng.standard_normal((64, 64)).astype(np.float32)
    ef = np.zeros_like(g_true)
    applied = np.zeros_like(g_true)
    for _ in range(50):
        g = g_true + ef
        q, amax = _quantize_int8(g)
        gq = np.asarray(_dequantize_int8(q, amax))
        ef = g - gq
        applied += gq
    # mean applied update ~= true gradient (error feedback closes the gap)
    assert np.abs(applied / 50 - g_true).max() < 0.02
