"""Workload synthesis properties (paper §IV-A/B) + compression numerics."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import JobType, NoticeKind, WorkloadConfig, generate


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_workload_invariants(seed):
    cfg = WorkloadConfig(n_jobs=200, n_nodes=2048, seed=seed)
    jobs = generate(cfg)
    assert len(jobs) == 200
    for j in jobs:
        assert 1 <= j.size <= cfg.n_nodes
        assert j.t_actual <= j.t_estimate + 1e-6
        assert j.t_setup < j.t_actual
        if j.jtype is JobType.MALLEABLE:
            assert 1 <= j.n_min <= j.size
        if j.jtype is JobType.ONDEMAND:
            # paper: large on-demand jobs reassigned
            assert j.size <= cfg.n_nodes // 2
            if j.notice_kind is not NoticeKind.NONE:
                assert j.notice_time <= j.submit_time
                assert j.est_arrival is not None
                if j.notice_kind is NoticeKind.LATE:
                    assert j.submit_time >= j.est_arrival - 1e-6
                if j.notice_kind is NoticeKind.EARLY:
                    assert j.submit_time <= j.est_arrival + 1e-6
    # submit times sorted, ids consecutive
    assert all(a.submit_time <= b.submit_time
               for a, b in zip(jobs, jobs[1:]))
    assert [j.jid for j in jobs] == list(range(200))


def test_notice_mix_respected():
    cfg = WorkloadConfig(n_jobs=3000, n_nodes=2048, seed=3, notice_mix="W2",
                         frac_od_projects=0.5, frac_rigid_projects=0.3)
    jobs = generate(cfg)
    od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
    assert len(od) > 100
    frac_acc = np.mean([j.notice_kind is NoticeKind.ACCURATE for j in od])
    assert 0.55 < frac_acc < 0.85  # W2: 70% accurate notice


def test_offered_load_near_target():
    cfg = WorkloadConfig(n_jobs=1500, n_nodes=4392, seed=0, target_load=1.15,
                         horizon_days=60.0)  # horizon must not clip the span
    jobs = generate(cfg)
    span = max(j.submit_time for j in jobs) - min(j.submit_time for j in jobs)
    work = sum(j.t_actual * j.size for j in jobs)
    load = work / (span * cfg.n_nodes)
    assert 0.9 < load < 1.5


def test_int8_compression_error_feedback():
    """Quantize+error-feedback must be unbiased over steps: the residual
    carries, so the cumulative applied update converges to the true sum."""
    from repro.training.train_step import _dequantize_int8, _quantize_int8
    rng = np.random.default_rng(0)
    g_true = rng.standard_normal((64, 64)).astype(np.float32)
    ef = np.zeros_like(g_true)
    applied = np.zeros_like(g_true)
    for _ in range(50):
        g = g_true + ef
        q, amax = _quantize_int8(g)
        gq = np.asarray(_dequantize_int8(q, amax))
        ef = g - gq
        applied += gq
    # mean applied update ~= true gradient (error feedback closes the gap)
    assert np.abs(applied / 50 - g_true).max() < 0.02
