"""Unit tests for the scan-aware HLO analyzer (pure text parsing)."""
from repro.launch.hlo_analysis import analyze, split_computations

HLO = """\
HloModule jit_step, entry_computation_layout={()->f32[]}

%body.1 (param: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %w = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %x = f32[128,64]{1,0} constant({...})
  %dot.1 = f32[64,64]{1,0} dot(%w, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[64,64]) tuple(%i, %ar)
}

%cond.2 (param.1: (s32[], f32[64,64])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.3 (arg: f32[64,64]) -> f32[] {
  %w0 = f32[64,32]{1,0} parameter(0)
  %k = f32[32,64]{1,0} constant({...})
  %dot.9 = f32[64,64]{1,0} dot(%w0, %k), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %wl = (s32[], f32[64,64]) while(%init), condition=%cond.2, body=%body.1
  %ag = f32[64,256]{1,0} all-gather(%dot.9), dimensions={1}
  ROOT %r = f32[] reduce(%ag, %z), to_apply=%sum
}
"""


def test_split_computations_finds_entry():
    comps = split_computations(HLO)
    assert comps["__entry__"] == "main.3"
    assert "body.1" in comps and "cond.2" in comps


def test_trip_count_multiplication():
    res = analyze(HLO)
    # entry dot: 2*64*64*32 = 262144; body dot 2*64*64*128 = 1048576 x 12
    assert res["dot_flops"] == 262144 + 12 * 1048576
    assert 12 in res["while_trip_counts"]


def test_collective_accounting():
    res = analyze(HLO)
    # all-reduce in body: 64*64*4 bytes * 2 (ring) * 12 trips
    # all-gather in entry: 64*256*4 bytes
    expected = 64 * 64 * 4 * 2 * 12 + 64 * 256 * 4
    assert res["collective_bytes"] == expected
    assert res["collective_ops"]["all-reduce"] == 12
    assert res["collective_ops"]["all-gather"] == 1


def test_no_entry_graceful():
    assert "error" in analyze("nothing here")
