"""cProfile the replay hot loop: the top-frame table behind the PR-9
optimizations (prebound handler dispatch, debug-gated ledger.check,
unrolled P2Quantile.add).

One profiled month-dense replay (the scheduling-bound regime) under
cProfile, then the top frames by total time as rows — committed to
results/bench/profile.json and uploaded as a CI artifact so a future
"why is the engine slow" question starts from data, not guesses.

cProfile's tracing overhead inflates absolute times ~2x; the table is
for *ranking* frames, not for wall-clock claims (those live in
scale.json).  The summary row therefore also reports the untraced wall
clock of the same replay.
"""
from __future__ import annotations

import cProfile
import os
import pstats
import time
from typing import List

from repro.core import SimConfig, Simulator, WorkloadConfig, generate

N_NODES = 4392  # Theta


def bench_profile(n_jobs: int = 6000, horizon_days: float = 30.0,
                  mechanism: str = "CUA&SPAA", seed: int = 0,
                  batch_rounds: float = 0.0, top_n: int = 12) -> List[dict]:
    """Profile one replay; return the top-``top_n`` frames by tottime.

    ``batch_rounds=0`` profiles the per-event engine (the default and
    the worst case — every event can trigger a scheduling pass); pass a
    round length to see where the time goes once passes are batched.
    """
    wl = WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                        horizon_days=horizon_days, target_load=1.15,
                        notice_mix="W5", seed=seed)
    jobs = generate(wl)
    cfg = SimConfig(n_nodes=N_NODES, mechanism=mechanism,
                    batch_rounds=batch_rounds)

    # untraced reference wall clock first (cProfile inflates ~2x)
    ref = Simulator(cfg, list(jobs))
    t0 = time.perf_counter()
    ref.run()
    wall_s = time.perf_counter() - t0

    sim = Simulator(cfg, list(jobs))
    prof = cProfile.Profile()
    prof.enable()
    sim.run()
    prof.disable()

    st = pstats.Stats(prof)
    total_tt = sum(rec[2] for rec in st.stats.values())
    frames = sorted(st.stats.items(), key=lambda kv: kv[1][2], reverse=True)

    rows = [{"name": f"profile_{n_jobs}job_{horizon_days:g}d_b"
                     f"{batch_rounds:g}",
             "n_jobs": n_jobs, "horizon_days": horizon_days,
             "mechanism": mechanism, "seed": seed,
             "batch_rounds": batch_rounds,
             "seconds": round(wall_s, 3),
             "profiled_seconds": round(total_tt, 3),
             "derived": (f"untraced {wall_s:.2f}s, traced {total_tt:.2f}s; "
                         f"top {top_n} frames follow")}]
    for rank, ((fname, lineno, func), (cc, nc, tt, ct, _callers)) \
            in enumerate(frames[:top_n], start=1):
        where = (f"{os.path.basename(fname)}:{lineno}:{func}"
                 if fname not in ("~", "") else func)  # "~" = builtins
        rows.append({
            "name": f"profile_frame_{rank:02d}",
            "frame": where,
            "ncalls": nc,
            "tottime_s": round(tt, 3),
            "cumtime_s": round(ct, 3),
            "tottime_pct": round(tt / total_tt * 100.0, 1),
            "us_per_call": round(tt / max(nc, 1) * 1e6, 2),
            "derived": (f"{where} {tt:.2f}s ({tt / total_tt * 100.0:.1f}%) "
                        f"over {nc} calls")})
    return rows
