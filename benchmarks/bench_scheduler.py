"""Scheduler benchmarks: one per paper table/figure.

  baseline     -> Table II   (FCFS/EASY, no special treatment)
  mechanisms   -> Figure 6   (6 mechanisms x W1-W5 notice mixes)
  checkpoint   -> Figure 7   (rigid checkpoint frequency sweep)
  dispatch     -> policy-API overhead vs the pre-refactor seed

Each returns a list of row dicts; run.py prints them and asserts the
paper's qualitative observations (Obs 1-13) where they are trace-robust.
All sweeps run through repro.core.experiment.Experiment (process fan-out).
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from repro.core import (MECHANISMS, NOTICE_MIXES, Experiment, SimConfig,
                        Simulator, WorkloadConfig, generate)

N_NODES = 4392  # Theta

# Pre-refactor (monolithic Simulator, commit 5189395) CPU time for one
# 600-job CUA&SPAA run on the reference container (process_time, best of
# 6 batches of 10).  bench_policy_dispatch reports overhead against it and
# flags rows over DISPATCH_BUDGET via within_budget; the comparison is
# only meaningful on hardware comparable to the reference container.
SEED_600JOB_SECONDS = 0.179
DISPATCH_BUDGET = 1.05  # refactor may cost at most 5%


def _wl(seed: int, mix: str = "W5", n_jobs: int = 600,
        ckpt_freq_factor: float = 1.0) -> WorkloadConfig:
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs, horizon_days=21.0,
                          target_load=1.15, notice_mix=mix, seed=seed,
                          ckpt_freq_factor=ckpt_freq_factor)


def bench_baseline(seeds=(0, 1, 2), n_jobs=600) -> dict:
    """Paper Table II."""
    t0 = time.perf_counter()
    res = Experiment(mechanisms=("BASE",), workloads=(_wl(0, n_jobs=n_jobs),),
                     seeds=seeds).run()
    row = res.mean(("mechanism",))[0]
    row.update(name="baseline_FCFS_EASY", seconds=time.perf_counter() - t0)
    return row


def bench_mechanisms(seeds=(0, 1, 2), mixes=tuple(NOTICE_MIXES),
                     n_jobs=600, mechanisms=MECHANISMS) -> List[dict]:
    """Paper Figure 6: all six mechanisms x W1-W5.

    One Experiment per (mechanism, mix) cell — seeds fan out in parallel
    inside each — so every row keeps its own honest wall time per the
    harness CSV contract."""
    rows = []
    for mix in mixes:
        wl = _wl(0, mix=mix, n_jobs=n_jobs)
        for mech in mechanisms:
            t0 = time.perf_counter()
            res = Experiment(mechanisms=(mech,), workloads=(wl,),
                             seeds=seeds).run()
            row = res.mean(("mechanism", "notice_mix"))[0]
            row.update(name=f"{mech}/{mix}", mix=mix,
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def bench_checkpoint(seeds=(0, 1), factors=(0.5, 1.0, 2.0),
                     mechanisms=("CUA&PAA", "CUA&SPAA"),
                     n_jobs=600) -> List[dict]:
    """Paper Figure 7: 0.5 = twice as frequent as the Daly optimum."""
    res = Experiment(mechanisms=mechanisms,
                     workloads=[_wl(0, n_jobs=n_jobs, ckpt_freq_factor=f)
                                for f in factors],
                     seeds=seeds).run()
    rows = res.mean(("mechanism", "ckpt_freq_factor"))
    for row in rows:
        f = row["ckpt_freq_factor"]
        row.update(name=f"ckpt_{f:g}x/{row['mechanism']}", factor=f)
    return rows


def bench_policy_dispatch(n_jobs=600, reps=3, batch=5,
                          out_path="BENCH_scheduler.json") -> dict:
    """Policy-dispatch overhead: 600-job CUA&SPAA runs, refactored
    simulator vs the recorded seed CPU time; result is written to
    BENCH_scheduler.json at the repo root.  Uses process_time amortized
    over batches so a loaded machine cannot skew the comparison."""
    jobs = generate(_wl(0, n_jobs=n_jobs))
    times = []
    for _ in range(reps):
        t0 = time.process_time()
        for _ in range(batch):
            sim = Simulator(SimConfig(n_nodes=N_NODES, mechanism="CUA&SPAA"),
                            [j for j in jobs])
            sim.run()
        times.append((time.process_time() - t0) / batch)
    best = min(times)
    overhead = best / SEED_600JOB_SECONDS - 1.0
    row = {"name": "policy_dispatch_600job",
           "us_per_call": round(best * 1e6, 1),
           "seed_seconds": SEED_600JOB_SECONDS,
           "policy_seconds": round(best, 4),
           "overhead_pct": round(overhead * 100.0, 2),
           "budget_pct": round((DISPATCH_BUDGET - 1.0) * 100.0, 1),
           "within_budget": bool(best <= SEED_600JOB_SECONDS * DISPATCH_BUDGET),
           "derived": f"overhead={overhead * 100.0:+.1f}% vs seed "
                      f"(budget {DISPATCH_BUDGET * 100 - 100:.0f}%)"}
    try:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root, out_path), "w") as f:
            json.dump(row, f, indent=1)
    except OSError:  # read-only checkout: the printed row still reports it
        pass
    return row


# ------------------------------------------------- qualitative validations
def validate_observations(base: dict, mech_rows: List[dict]) -> List[str]:
    """Check the paper's trace-robust claims; returns failure strings."""
    fails = []
    by = {r["name"]: r for r in mech_rows}

    def avg_over_mixes(mech, key):
        vals = [r[key] for r in mech_rows if r["mechanism"] == mech]
        return float(np.mean(vals))

    inst_base = base["od_instant_start_rate"]
    inst_mech = np.mean([avg_over_mixes(m, "od_instant_start_rate")
                         for m in MECHANISMS])
    # Obs 1/9: instant start rate jumps to ~1 under every mechanism
    if not inst_mech > inst_base + 0.3:
        fails.append(f"Obs1/9: instant {inst_mech:.2f} !>> base {inst_base:.2f}")
    for m in MECHANISMS:
        if avg_over_mixes(m, "od_instant_start_rate") < 0.90:
            fails.append(f"Obs9: {m} instant < 0.90")
    # Obs 3: SPAA reduces malleable preemption ratio vs PAA
    paa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                   for m in MECHANISMS if m.endswith("&PAA")])
    spaa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                    for m in MECHANISMS if m.endswith("&SPAA")])
    if not spaa < paa:
        fails.append(f"Obs3: malleable preempt SPAA {spaa:.3f} !< PAA {paa:.3f}")
    # Obs 8: malleable preemption ratio > rigid preemption ratio
    pm = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                  for m in MECHANISMS])
    pr = np.mean([avg_over_mixes(m, "preemption_ratio_rigid")
                  for m in MECHANISMS])
    if not pm > pr:
        fails.append(f"Obs8: malleable {pm:.3f} !> rigid {pr:.3f}")
    # Obs 6: malleable turnaround < rigid turnaround (honesty incentive)
    tm = np.mean([avg_over_mixes(m, "avg_turnaround_malleable_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    tr = np.mean([avg_over_mixes(m, "avg_turnaround_rigid_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    if not tm < tr:
        fails.append(f"Obs6: malleable turn {tm:.1f}h !< rigid {tr:.1f}h")
    return fails
