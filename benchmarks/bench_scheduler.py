"""Scheduler benchmarks: one per paper table/figure.

  baseline     -> Table II   (FCFS/EASY, no special treatment)
  mechanisms   -> Figure 6   (6 mechanisms x W1-W5 notice mixes)
  checkpoint   -> Figure 7   (rigid checkpoint frequency sweep)
  scenarios    -> registry-named scenario presets x mechanisms
  dispatch     -> policy-API overhead vs the pre-refactor seed

Each returns a list of row dicts; run.py prints them and asserts the
paper's qualitative observations (Obs 1-13) where they are trace-robust.
All sweeps run through repro.core.experiment.Experiment (process fan-out).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import types
from typing import List, Optional, Tuple

import numpy as np

from repro.core import (MECHANISMS, NOTICE_MIXES, Experiment, SimConfig,
                        Simulator, WorkloadConfig, generate, get_scenario)

N_NODES = 4392  # Theta

# Last commit with the monolithic pre-refactor Simulator.  Its support
# modules (cluster/decision/job) are unchanged since, so the old class can
# run against the current package and the baseline is measured on the same
# machine as the refactored simulator (needs full git history; shallow
# clones fall back to reporting absolute cost only).
PRE_REFACTOR_COMMIT = "5189395"
DISPATCH_BUDGET = 1.05  # refactor may cost at most 5%


def _wl(seed: int, mix: str = "W5", n_jobs: int = 600,
        ckpt_freq_factor: float = 1.0) -> WorkloadConfig:
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs, horizon_days=21.0,
                          target_load=1.15, notice_mix=mix, seed=seed,
                          ckpt_freq_factor=ckpt_freq_factor)


def bench_baseline(seeds=(0, 1, 2), n_jobs=600) -> dict:
    """Paper Table II."""
    t0 = time.perf_counter()
    res = Experiment(mechanisms=("BASE",), workloads=(_wl(0, n_jobs=n_jobs),),
                     seeds=seeds).run()
    row = res.mean(("mechanism",))[0]
    row.update(name="baseline_FCFS_EASY", seconds=time.perf_counter() - t0)
    return row


def bench_mechanisms(seeds=(0, 1, 2), mixes=tuple(NOTICE_MIXES),
                     n_jobs=600, mechanisms=MECHANISMS) -> List[dict]:
    """Paper Figure 6: all six mechanisms x W1-W5.

    One Experiment per (mechanism, mix) cell — seeds fan out in parallel
    inside each — so every row keeps its own honest wall time per the
    harness CSV contract."""
    rows = []
    for mix in mixes:
        wl = _wl(0, mix=mix, n_jobs=n_jobs)
        for mech in mechanisms:
            t0 = time.perf_counter()
            res = Experiment(mechanisms=(mech,), workloads=(wl,),
                             seeds=seeds).run()
            row = res.mean(("mechanism", "notice_mix"))[0]
            row.update(name=f"{mech}/{mix}", mix=mix,
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def bench_checkpoint(seeds=(0, 1), factors=(0.5, 1.0, 2.0),
                     mechanisms=("CUA&PAA", "CUA&SPAA"),
                     n_jobs=600) -> List[dict]:
    """Paper Figure 7: 0.5 = twice as frequent as the Daly optimum."""
    res = Experiment(mechanisms=mechanisms,
                     workloads=[_wl(0, n_jobs=n_jobs, ckpt_freq_factor=f)
                                for f in factors],
                     seeds=seeds).run()
    rows = res.mean(("mechanism", "ckpt_freq_factor"))
    for row in rows:
        f = row["ckpt_freq_factor"]
        row.update(name=f"ckpt_{f:g}x/{row['mechanism']}", factor=f)
    return rows


def bench_scenarios(seeds=(0, 1), n_jobs=600,
                    scenario_names=("W1", "W5", "bursty-od", "diurnal"),
                    mechanisms=("BASE", "CUA&SPAA", "CUA&STEAL"),
                    swf_trace: Optional[str] = None) -> List[dict]:
    """Registry-named scenario presets x mechanisms (docs/workloads.md).

    Beyond-the-paper coverage: the Figure 6 grid only varies notice
    mixes; this sweep adds the stress presets (injected od bursts,
    diurnal arrival modulation) and, when ``swf_trace`` is given, SWF
    trace replay through the same mechanism set."""
    workloads = [get_scenario(name, n_nodes=N_NODES, n_jobs=n_jobs,
                              horizon_days=21.0, target_load=1.15)
                 for name in scenario_names]
    if swf_trace is not None:
        workloads.append(get_scenario("trace-replay", trace=swf_trace))
    rows = []
    for wl in workloads:
        for mech in mechanisms:
            t0 = time.perf_counter()
            res = Experiment(mechanisms=(mech,), workloads=(wl,),
                             seeds=seeds).run()
            row = res.mean(("mechanism", "scenario"))[0]
            row.update(name=f"{mech}/{row['scenario']}",
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def _load_seed_simulator() -> Optional[Tuple[type, type]]:
    """Load the pre-refactor monolithic Simulator out of git history.

    Returns (Simulator, SimConfig) from PRE_REFACTOR_COMMIT, executed as a
    synthetic ``repro.core`` submodule so its relative imports resolve
    against the (unchanged) current cluster/decision/job modules, or None
    when git/history is unavailable (e.g. shallow CI clone) or when those
    support modules have since diverged from the baseline commit — in
    which case old-loop + new-kernels would no longer measure the
    policy-API refactor."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    support = [f"src/repro/core/{m}.py"
               for m in ("cluster", "decision", "job")]
    try:
        unchanged = subprocess.run(
            ["git", "diff", "--quiet", PRE_REFACTOR_COMMIT, "--", *support],
            cwd=root, capture_output=True, timeout=30).returncode == 0
        if not unchanged:
            return None
        src = subprocess.run(
            ["git", "show", f"{PRE_REFACTOR_COMMIT}:src/repro/core/simulator.py"],
            cwd=root, capture_output=True, text=True, check=True,
            timeout=30).stdout
    except (OSError, subprocess.SubprocessError):
        return None
    mod = types.ModuleType("repro.core._seed_simulator")
    mod.__package__ = "repro.core"
    # dataclass creation resolves cls.__module__ through sys.modules
    sys.modules[mod.__name__] = mod
    try:
        exec(compile(src, f"<simulator.py@{PRE_REFACTOR_COMMIT}>", "exec"),
             mod.__dict__)
    except Exception:
        del sys.modules[mod.__name__]
        return None
    return mod.Simulator, mod.SimConfig


def bench_policy_dispatch(n_jobs=600, reps=8, batch=3,
                          out_path="BENCH_scheduler.json") -> dict:
    """Policy-dispatch overhead: 600-job CUA&SPAA runs, refactored
    simulator vs the pre-refactor seed simulator re-measured on *this*
    machine (loaded from git history); result is written to
    BENCH_scheduler.json at the repo root.  ``us_per_call`` is the
    per-job cost of one run; ``run_us`` is the whole-run CPU time.

    Overhead is the median of per-rep CPU-time ratios between adjacent
    refactored/seed batches (order alternating per rep): each ratio's two
    batches run back-to-back on the same machine moment, so speed drift
    on a noisy shared box cancels where a best-of-each-side comparison
    swings by +-10%.  An over-budget median is re-measured up to two more
    times — a real regression fails every attempt, a noise spike does
    not — and the attempt count is recorded."""
    jobs = generate(_wl(0, n_jobs=n_jobs))
    cfg = SimConfig(n_nodes=N_NODES, mechanism="CUA&SPAA")
    seed = _load_seed_simulator()

    def run_batch(make_sim) -> float:
        t0 = time.process_time()
        for _ in range(batch):
            make_sim().run()
        return (time.process_time() - t0) / batch

    cur_f = lambda: run_batch(lambda: Simulator(cfg, list(jobs)))
    if seed is not None:
        seed_sim, seed_cfg_cls = seed
        seed_cfg = seed_cfg_cls(n_nodes=N_NODES, mechanism="CUA&SPAA")
        seed_f = lambda: run_batch(lambda: seed_sim(seed_cfg, list(jobs)))
        seed_f()  # warm allocator/caches on both paths before timing
    t0 = time.perf_counter()
    Simulator(cfg, list(jobs)).run()
    one_run = max(time.perf_counter() - t0, 1e-4)
    # size batches to span >= 0.3s so process_time tick quantization (10ms
    # granularity seen on some kernels) stays well under the 5% budget and
    # a fast machine cannot measure a whole batch as 0.0
    batch = max(batch, int(0.3 / one_run) + 1)

    overhead = None
    for attempt in range(1, 4):
        # times reset per attempt so run_us/seed_run_us and overhead_pct
        # all describe the attempt whose ratios are published
        cur_times, seed_times, ratios = [], [], []
        for i in range(reps):
            if seed is None:
                cur_times.append(cur_f())
                continue
            if i % 2 == 0:
                c, s = cur_f(), seed_f()
            else:
                s, c = seed_f(), cur_f()
            cur_times.append(c)
            seed_times.append(s)
            if s > 0.0:  # a zero batch time means the clock tick won
                ratios.append(c / s)
        if seed is None or not ratios:
            break
        overhead = float(np.median(ratios)) - 1.0
        if 1.0 + overhead <= DISPATCH_BUDGET:
            break
    best = min(cur_times)
    row = {"name": f"policy_dispatch_{n_jobs}job",
           "us_per_call": round(best / n_jobs * 1e6, 2),
           "run_us": round(best * 1e6, 1),
           "n_jobs": n_jobs,
           "budget_pct": round((DISPATCH_BUDGET - 1.0) * 100.0, 1)}
    if seed is not None and overhead is not None:
        row.update(
            baseline_source=f"measured@{PRE_REFACTOR_COMMIT}",
            timing_stat="run_us/seed_run_us are best-of-reps; overhead_pct "
                        "is the median paired ratio, not their quotient",
            seed_run_us=round(min(seed_times) * 1e6, 1),
            overhead_pct=round(overhead * 100.0, 2),
            attempts=attempt,
            within_budget=bool(1.0 + overhead <= DISPATCH_BUDGET),
            derived=f"overhead={overhead * 100.0:+.1f}% vs seed "
                    f"(median of {reps} paired ratios, attempt {attempt}, "
                    f"budget {DISPATCH_BUDGET * 100 - 100:.0f}%)")
    else:
        why = ("no git history" if seed is None
               else "process_time tick too coarse for ratios")
        row.update(
            baseline_source=f"unavailable ({why})",
            derived=f"run={best * 1e6:.0f}us; seed baseline not measurable "
                    "on this checkout, overhead not reported")
    try:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root, out_path), "w") as f:
            json.dump(row, f, indent=1)
    except OSError:  # read-only checkout: the printed row still reports it
        pass
    return row


# ------------------------------------------------- qualitative validations
def validate_observations(base: dict, mech_rows: List[dict]) -> List[str]:
    """Check the paper's trace-robust claims; returns failure strings."""
    fails = []
    by = {r["name"]: r for r in mech_rows}

    def avg_over_mixes(mech, key):
        vals = [r[key] for r in mech_rows if r["mechanism"] == mech]
        return float(np.mean(vals))

    inst_base = base["od_instant_start_rate"]
    inst_mech = np.mean([avg_over_mixes(m, "od_instant_start_rate")
                         for m in MECHANISMS])
    # Obs 1/9: instant start rate jumps to ~1 under every mechanism
    if not inst_mech > inst_base + 0.3:
        fails.append(f"Obs1/9: instant {inst_mech:.2f} !>> base {inst_base:.2f}")
    for m in MECHANISMS:
        if avg_over_mixes(m, "od_instant_start_rate") < 0.90:
            fails.append(f"Obs9: {m} instant < 0.90")
    # Obs 3: SPAA reduces malleable preemption ratio vs PAA
    paa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                   for m in MECHANISMS if m.endswith("&PAA")])
    spaa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                    for m in MECHANISMS if m.endswith("&SPAA")])
    if not spaa < paa:
        fails.append(f"Obs3: malleable preempt SPAA {spaa:.3f} !< PAA {paa:.3f}")
    # Obs 8: malleable preemption ratio > rigid preemption ratio
    pm = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                  for m in MECHANISMS])
    pr = np.mean([avg_over_mixes(m, "preemption_ratio_rigid")
                  for m in MECHANISMS])
    if not pm > pr:
        fails.append(f"Obs8: malleable {pm:.3f} !> rigid {pr:.3f}")
    # Obs 6: malleable turnaround < rigid turnaround (honesty incentive)
    tm = np.mean([avg_over_mixes(m, "avg_turnaround_malleable_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    tr = np.mean([avg_over_mixes(m, "avg_turnaround_rigid_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    if not tm < tr:
        fails.append(f"Obs6: malleable turn {tm:.1f}h !< rigid {tr:.1f}h")
    return fails
