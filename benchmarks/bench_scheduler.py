"""Scheduler benchmarks: one per paper table/figure.

  baseline     -> Table II   (FCFS/EASY, no special treatment)
  mechanisms   -> Figure 6   (6 mechanisms x W1-W5 notice mixes)
  checkpoint   -> Figure 7   (rigid checkpoint frequency sweep)
  scenarios    -> registry-named scenario presets x mechanisms
  dispatch     -> policy-API overhead vs the pre-refactor seed
  scale        -> incremental-engine wall clock 600 -> 6k -> 50k jobs,
                  paired against the pre-PR O(n log n)-per-event engine
                  (the streaming-identity and full-year rows live in
                  benchmarks/bench_scale.py)

Each returns a list of row dicts; run.py prints them and asserts the
paper's qualitative observations (Obs 1-13) where they are trace-robust.
All sweeps run through repro.core.experiment.Experiment (process fan-out).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import subprocess
import sys
import tarfile
import tempfile
import time
import types
from typing import List, Optional, Tuple

import numpy as np

from repro.core import (MECHANISMS, NOTICE_MIXES, Experiment, SimConfig,
                        Simulator, WorkloadConfig, generate, get_scenario)

N_NODES = 4392  # Theta

# Last commit with the monolithic pre-refactor Simulator; the dispatch
# bench re-measures it on this machine by loading that commit's whole
# module set (simulator + its support modules) out of git history, so
# later additive changes to the current support modules cannot skew or
# disable the comparison (needs full git history; shallow clones fall
# back to reporting absolute cost only).
PRE_REFACTOR_COMMIT = "5189395"
DISPATCH_BUDGET = 1.05  # refactor may cost at most 5%

# Last commit before the incremental O(log n) engine (per-event full
# queue re-sort, O(n) membership ops, Python shadow loop); bench_scale
# pairs against it for the speedup claim in BENCH_scheduler.json.
PRE_ENGINE_COMMIT = "0c1e348"
SCALE_SPEEDUP_TARGET = 10.0  # acceptance: >= 10x on the 6k month-dense run


def _wl(seed: int, mix: str = "W5", n_jobs: int = 600,
        ckpt_freq_factor: float = 1.0) -> WorkloadConfig:
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs, horizon_days=21.0,
                          target_load=1.15, notice_mix=mix, seed=seed,
                          ckpt_freq_factor=ckpt_freq_factor)


def bench_baseline(seeds=(0, 1, 2), n_jobs=600) -> dict:
    """Paper Table II."""
    t0 = time.perf_counter()
    res = Experiment(mechanisms=("BASE",), workloads=(_wl(0, n_jobs=n_jobs),),
                     seeds=seeds).run()
    row = res.mean(("mechanism",))[0]
    row.update(name="baseline_FCFS_EASY", seconds=time.perf_counter() - t0)
    return row


def bench_mechanisms(seeds=(0, 1, 2), mixes=tuple(NOTICE_MIXES),
                     n_jobs=600, mechanisms=MECHANISMS) -> List[dict]:
    """Paper Figure 6: all six mechanisms x W1-W5.

    One Experiment per (mechanism, mix) cell — seeds fan out in parallel
    inside each — so every row keeps its own honest wall time per the
    harness CSV contract."""
    rows = []
    for mix in mixes:
        wl = _wl(0, mix=mix, n_jobs=n_jobs)
        for mech in mechanisms:
            t0 = time.perf_counter()
            res = Experiment(mechanisms=(mech,), workloads=(wl,),
                             seeds=seeds).run()
            row = res.mean(("mechanism", "notice_mix"))[0]
            row.update(name=f"{mech}/{mix}", mix=mix,
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def bench_checkpoint(seeds=(0, 1), factors=(0.5, 1.0, 2.0),
                     mechanisms=("CUA&PAA", "CUA&SPAA"),
                     n_jobs=600) -> List[dict]:
    """Paper Figure 7: 0.5 = twice as frequent as the Daly optimum."""
    res = Experiment(mechanisms=mechanisms,
                     workloads=[_wl(0, n_jobs=n_jobs, ckpt_freq_factor=f)
                                for f in factors],
                     seeds=seeds).run()
    rows = res.mean(("mechanism", "ckpt_freq_factor"))
    for row in rows:
        f = row["ckpt_freq_factor"]
        row.update(name=f"ckpt_{f:g}x/{row['mechanism']}", factor=f)
    return rows


def bench_scenarios(seeds=(0, 1), n_jobs=600,
                    scenario_names=("W1", "W5", "bursty-od", "diurnal"),
                    mechanisms=("BASE", "CUA&SPAA", "CUA&STEAL"),
                    swf_trace: Optional[str] = None) -> List[dict]:
    """Registry-named scenario presets x mechanisms (docs/workloads.md).

    Beyond-the-paper coverage: the Figure 6 grid only varies notice
    mixes; this sweep adds the stress presets (injected od bursts,
    diurnal arrival modulation) and, when ``swf_trace`` is given, SWF
    trace replay through the same mechanism set."""
    workloads = [get_scenario(name, n_nodes=N_NODES, n_jobs=n_jobs,
                              horizon_days=21.0, target_load=1.15)
                 for name in scenario_names]
    if swf_trace is not None:
        workloads.append(get_scenario("trace-replay", trace=swf_trace))
    rows = []
    for wl in workloads:
        for mech in mechanisms:
            t0 = time.perf_counter()
            res = Experiment(mechanisms=(mech,), workloads=(wl,),
                             seeds=seeds).run()
            row = res.mean(("mechanism", "scenario"))[0]
            row.update(name=f"{mech}/{row['scenario']}",
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_commit_core(commit: str,
                      modules: Tuple[str, ...]) -> Optional[types.ModuleType]:
    """Materialize ``src/repro/core/<m>.py`` files of a past commit as a
    synthetic package ``repro.core._hist_<commit>`` (exec'd in dependency
    order so relative imports resolve against the *old* siblings, and the
    old module set is self-consistent — old JobType enums compare ``is``
    against old-generated jobs).  Returns the package or None when git
    history is unavailable (e.g. shallow CI clone)."""
    pkg_name = f"repro.core._hist_{commit}"
    pkg = sys.modules.get(pkg_name)
    if pkg is not None:
        return pkg
    sources = {}
    try:
        for m in modules:
            sources[m] = subprocess.run(
                ["git", "show", f"{commit}:src/repro/core/{m}.py"],
                cwd=_repo_root(), capture_output=True, text=True, check=True,
                timeout=30).stdout
    except (OSError, subprocess.SubprocessError):
        return None
    pkg = types.ModuleType(pkg_name)
    pkg.__path__ = []  # mark as package so relative imports resolve
    sys.modules[pkg_name] = pkg
    try:
        for m in modules:
            mod = types.ModuleType(f"{pkg_name}.{m}")
            mod.__package__ = pkg_name
            # dataclass creation resolves cls.__module__ through sys.modules
            sys.modules[mod.__name__] = mod
            exec(compile(sources[m], f"<{m}.py@{commit}>", "exec"),
                 mod.__dict__)
            setattr(pkg, m, mod)
    except Exception:
        for m in modules:
            sys.modules.pop(f"{pkg_name}.{m}", None)
        del sys.modules[pkg_name]
        return None
    return pkg


def _jobs_fingerprint(jobs) -> list:
    """Field-level trace identity across module generations (enum values
    compared by .value: old and new JobType/NoticeKind are distinct enum
    classes)."""
    return [(j.jid, j.jtype.value, j.project, j.submit_time, j.size,
             j.t_estimate, j.t_actual, j.t_setup, j.n_min,
             j.notice_kind.value, j.notice_time, j.est_arrival,
             j.ckpt_overhead, j.ckpt_interval) for j in jobs]


def _load_seed_simulator(n_jobs: int = 600) -> Optional[Tuple[type, type, list]]:
    """The pre-refactor monolithic engine, self-consistently loaded from
    PRE_REFACTOR_COMMIT (simulator + cluster/decision/job/workload).

    Returns (Simulator, SimConfig, seed-generated n_jobs trace) or None
    when history is unavailable or the old generator no longer produces
    the bit-identical trace the current one does — in which case the
    paired comparison would no longer measure engine overhead alone."""
    pkg = _load_commit_core(
        PRE_REFACTOR_COMMIT,
        ("job", "cluster", "decision", "workload", "simulator"))
    if pkg is None:
        return None
    old_cfg = pkg.workload.WorkloadConfig(
        n_nodes=N_NODES, n_jobs=n_jobs, horizon_days=21.0, target_load=1.15,
        notice_mix="W5", seed=0, ckpt_freq_factor=1.0)
    old_jobs = pkg.workload.generate(old_cfg)
    if _jobs_fingerprint(old_jobs) != \
            _jobs_fingerprint(generate(_wl(0, n_jobs=n_jobs))):
        return None  # generators diverged; paired timing would be bogus
    return pkg.simulator.Simulator, pkg.simulator.SimConfig, old_jobs


def bench_policy_dispatch(n_jobs=600, reps=8, batch=3,
                          out_path="BENCH_scheduler.json") -> dict:
    """Policy-dispatch overhead: 600-job CUA&SPAA runs, refactored
    simulator vs the pre-refactor seed simulator re-measured on *this*
    machine (loaded from git history); result is written to
    BENCH_scheduler.json at the repo root.  ``us_per_call`` is the
    per-job cost of one run; ``run_us`` is the whole-run CPU time.

    Overhead is the median of per-rep CPU-time ratios between adjacent
    refactored/seed batches (order alternating per rep): each ratio's two
    batches run back-to-back on the same machine moment, so speed drift
    on a noisy shared box cancels where a best-of-each-side comparison
    swings by +-10%.  An over-budget median is re-measured up to two more
    times — a real regression fails every attempt, a noise spike does
    not — and the attempt count is recorded."""
    jobs = generate(_wl(0, n_jobs=n_jobs))
    cfg = SimConfig(n_nodes=N_NODES, mechanism="CUA&SPAA")
    seed = _load_seed_simulator(n_jobs)

    def run_batch(make_sim) -> float:
        t0 = time.process_time()
        for _ in range(batch):
            make_sim().run()
        return (time.process_time() - t0) / batch

    cur_f = lambda: run_batch(lambda: Simulator(cfg, list(jobs)))
    if seed is not None:
        seed_sim, seed_cfg_cls, seed_jobs = seed
        seed_cfg = seed_cfg_cls(n_nodes=N_NODES, mechanism="CUA&SPAA")
        seed_f = lambda: run_batch(lambda: seed_sim(seed_cfg, list(seed_jobs)))
        seed_f()  # warm allocator/caches on both paths before timing
    t0 = time.perf_counter()
    Simulator(cfg, list(jobs)).run()
    one_run = max(time.perf_counter() - t0, 1e-4)
    # size batches to span >= 0.3s so process_time tick quantization (10ms
    # granularity seen on some kernels) stays well under the 5% budget and
    # a fast machine cannot measure a whole batch as 0.0
    batch = max(batch, int(0.3 / one_run) + 1)

    overhead = None
    for attempt in range(1, 4):
        # times reset per attempt so run_us/seed_run_us and overhead_pct
        # all describe the attempt whose ratios are published
        cur_times, seed_times, ratios = [], [], []
        for i in range(reps):
            if seed is None:
                cur_times.append(cur_f())
                continue
            if i % 2 == 0:
                c, s = cur_f(), seed_f()
            else:
                s, c = seed_f(), cur_f()
            cur_times.append(c)
            seed_times.append(s)
            if s > 0.0:  # a zero batch time means the clock tick won
                ratios.append(c / s)
        if seed is None or not ratios:
            break
        overhead = float(np.median(ratios)) - 1.0
        if 1.0 + overhead <= DISPATCH_BUDGET:
            break
    best = min(cur_times)
    row = {"name": f"policy_dispatch_{n_jobs}job",
           "us_per_call": round(best / n_jobs * 1e6, 2),
           "run_us": round(best * 1e6, 1),
           "n_jobs": n_jobs,
           "budget_pct": round((DISPATCH_BUDGET - 1.0) * 100.0, 1)}
    if seed is not None and overhead is not None:
        row.update(
            baseline_source=f"measured@{PRE_REFACTOR_COMMIT}",
            timing_stat="run_us/seed_run_us are best-of-reps; overhead_pct "
                        "is the median paired ratio, not their quotient",
            seed_run_us=round(min(seed_times) * 1e6, 1),
            overhead_pct=round(overhead * 100.0, 2),
            attempts=attempt,
            within_budget=bool(1.0 + overhead <= DISPATCH_BUDGET),
            derived=f"overhead={overhead * 100.0:+.1f}% vs seed "
                    f"(median of {reps} paired ratios, attempt {attempt}, "
                    f"budget {DISPATCH_BUDGET * 100 - 100:.0f}%)")
    else:
        why = ("no git history" if seed is None
               else "process_time tick too coarse for ratios")
        row.update(
            baseline_source=f"unavailable ({why})",
            derived=f"run={best * 1e6:.0f}us; seed baseline not measurable "
                    "on this checkout, overhead not reported")
    _merge_root_bench("dispatch", row, out_path)
    return row


def _merge_root_bench(section: str, payload, out_path: str) -> None:
    """Read-modify-write one section of the repo-root BENCH artifact
    ({"dispatch": {...}, "scale": [...]}); a legacy single-row file is
    folded into its "dispatch" section."""
    path = os.path.join(_repo_root(), out_path)
    data = {}
    try:
        with open(path) as f:
            data = json.load(f)
        if "name" in data:  # legacy layout: the bare dispatch row
            data = {"dispatch": data}
    except (OSError, ValueError):
        data = {}
    data[section] = payload
    try:
        with open(path, "w") as f:
            json.dump(data, f, indent=1)
    except OSError:  # read-only checkout: the printed rows still report it
        pass


# ---------------------------------------------------------------- scale
def _record_digest(records) -> str:
    """Order-independent digest of the job-for-job outcome of one run —
    comparable across engine generations and processes."""
    recs = sorted((r.job.jid, r.first_start, r.completion, r.killed,
                   r.n_preempted, r.n_shrunk, r.instant)
                  for r in records.values())
    return hashlib.sha256(repr(recs).encode()).hexdigest()


_PRE_ENGINE_SCRIPT = """\
import json, sys, time
import hashlib
from repro.core import SimConfig, Simulator, WorkloadConfig, generate
cfg = json.loads(sys.argv[1])
wl = WorkloadConfig(n_nodes=cfg["n_nodes"], n_jobs=cfg["n_jobs"],
                    horizon_days=cfg["horizon_days"], target_load=1.15,
                    notice_mix="W5", seed=cfg["seed"])
jobs = generate(wl)
t0 = time.perf_counter()
sim = Simulator(SimConfig(n_nodes=cfg["n_nodes"], mechanism=cfg["mechanism"]),
                jobs)
sim.run()
seconds = time.perf_counter() - t0
recs = sorted((r.job.jid, r.first_start, r.completion, r.killed,
               r.n_preempted, r.n_shrunk, r.instant)
              for r in sim.records.values())
digest = hashlib.sha256(repr(recs).encode()).hexdigest()
print(json.dumps({"seconds": seconds, "digest": digest}))
"""


def _pre_engine_run(n_jobs: int, horizon_days: float, seed: int,
                    mechanism: str, commit: str = PRE_ENGINE_COMMIT,
                    timeout: float = 3600.0) -> Optional[dict]:
    """One run on the pre-PR engine: ``git archive`` the whole ``src``
    tree of `commit` into a temp dir and execute there in a subprocess
    (full module isolation — no enum-identity or shared-module hazards),
    timing only the simulation.  Returns {"seconds", "digest"} or None
    when history/subprocesses are unavailable."""
    try:
        tar_bytes = subprocess.run(
            ["git", "archive", "--format=tar", commit, "src"],
            cwd=_repo_root(), capture_output=True, check=True,
            timeout=60).stdout
    except (OSError, subprocess.SubprocessError):
        return None
    params = json.dumps({"n_nodes": N_NODES, "n_jobs": n_jobs,
                         "horizon_days": horizon_days, "seed": seed,
                         "mechanism": mechanism})
    try:
        with tempfile.TemporaryDirectory(prefix="pre_engine_") as tmp:
            with tarfile.open(fileobj=io.BytesIO(tar_bytes)) as tf:
                tf.extractall(tmp)
            env = dict(os.environ,
                       PYTHONPATH=os.path.join(tmp, "src"))
            out = subprocess.run(
                [sys.executable, "-c", _PRE_ENGINE_SCRIPT, params],
                capture_output=True, text=True, check=True, env=env,
                timeout=timeout)
        return json.loads(out.stdout.strip().splitlines()[-1])
    except (OSError, subprocess.SubprocessError, ValueError, IndexError):
        return None


def bench_scale(scales=((600, 21.0), (6000, 210.0), (6000, 30.0),
                        (50000, 1750.0)),
                mechanism="CUA&SPAA", seed=0, baseline_max_jobs=6000,
                repeats=2, out_path="BENCH_scheduler.json") -> List[dict]:
    """Incremental-engine wall clock across trace scales at Theta size.

    ``scales`` holds (n_jobs, horizon_days) pairs: horizon growing with
    n_jobs keeps offered load at the paper's 1.15, while the month-dense
    pair (6k jobs / 30 days — the issue's "month-scale trace replay",
    one month of Theta-rate submissions) drives the backlog into the
    thousands, the regime where the pre-PR engine's per-event re-sorts
    go quadratic.

    Every run tracks decision times (p99 must stay under the paper's
    10 ms Obs-10 bound at every scale) and, up to ``baseline_max_jobs``,
    the same trace replays on the pre-PR engine (git archive of
    PRE_ENGINE_COMMIT in a subprocess) for a paired wall-clock speedup
    and a job-for-job record-digest identity check.  The rows land in
    results/bench/scale.json and the "scale" section of
    BENCH_scheduler.json.
    """
    rows = []
    for n_jobs, horizon_days in scales:
        wl = WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                            horizon_days=horizon_days, target_load=1.15,
                            notice_mix="W5", seed=seed)
        jobs = generate(wl)
        best, digest, p99_ms = float("inf"), "", None
        for _ in range(repeats):
            sim = Simulator(SimConfig(n_nodes=N_NODES, mechanism=mechanism,
                                      track_decision_time=True), list(jobs))
            t0 = time.perf_counter()
            sim.run()
            best = min(best, time.perf_counter() - t0)
            digest = _record_digest(sim.records)
            if sim.decision_times:
                p99 = float(np.percentile(
                    np.asarray(sim.decision_times) * 1e3, 99))
                p99_ms = p99 if p99_ms is None else min(p99_ms, p99)
        row = {"name": f"scale_{n_jobs}job_{horizon_days:g}d",
               "n_jobs": n_jobs, "horizon_days": horizon_days,
               "mechanism": mechanism, "seed": seed,
               "seconds": round(best, 3),
               "us_per_job": round(best / n_jobs * 1e6, 2),
               "decision_p99_ms": None if p99_ms is None
               else round(p99_ms, 3),
               "decision_bound_ms": 10.0,
               "decision_within_bound": bool(p99_ms is not None
                                             and p99_ms <= 10.0)}
        if n_jobs <= baseline_max_jobs:
            base = _pre_engine_run(n_jobs, horizon_days, seed, mechanism)
            if base is not None:
                speedup = base["seconds"] / max(best, 1e-9)
                row.update(
                    baseline_source=f"measured@{PRE_ENGINE_COMMIT}",
                    baseline_seconds=round(base["seconds"], 3),
                    speedup=round(speedup, 2),
                    records_match=bool(base["digest"] == digest))
            else:
                row["baseline_source"] = \
                    "unavailable (no git history or no subprocesses)"
        row["derived"] = (
            f"{row['seconds']}s ({row['us_per_job']}us/job)"
            + (f", {row['speedup']}x vs pre-engine"
               if "speedup" in row else "")
            + (f", p99={row['decision_p99_ms']}ms"
               if row["decision_p99_ms"] is not None else ""))
        rows.append(row)
    _merge_root_bench("scale", rows, out_path)
    return rows


# ------------------------------------------------- qualitative validations
def validate_observations(base: dict, mech_rows: List[dict]) -> List[str]:
    """Check the paper's trace-robust claims; returns failure strings."""
    fails = []
    by = {r["name"]: r for r in mech_rows}

    def avg_over_mixes(mech, key):
        vals = [r[key] for r in mech_rows if r["mechanism"] == mech]
        return float(np.mean(vals))

    inst_base = base["od_instant_start_rate"]
    inst_mech = np.mean([avg_over_mixes(m, "od_instant_start_rate")
                         for m in MECHANISMS])
    # Obs 1/9: instant start rate jumps to ~1 under every mechanism
    if not inst_mech > inst_base + 0.3:
        fails.append(f"Obs1/9: instant {inst_mech:.2f} !>> base {inst_base:.2f}")
    for m in MECHANISMS:
        if avg_over_mixes(m, "od_instant_start_rate") < 0.90:
            fails.append(f"Obs9: {m} instant < 0.90")
    # Obs 3: SPAA reduces malleable preemption ratio vs PAA
    paa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                   for m in MECHANISMS if m.endswith("&PAA")])
    spaa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                    for m in MECHANISMS if m.endswith("&SPAA")])
    if not spaa < paa:
        fails.append(f"Obs3: malleable preempt SPAA {spaa:.3f} !< PAA {paa:.3f}")
    # Obs 8: malleable preemption ratio > rigid preemption ratio
    pm = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                  for m in MECHANISMS])
    pr = np.mean([avg_over_mixes(m, "preemption_ratio_rigid")
                  for m in MECHANISMS])
    if not pm > pr:
        fails.append(f"Obs8: malleable {pm:.3f} !> rigid {pr:.3f}")
    # Obs 6: malleable turnaround < rigid turnaround (honesty incentive)
    tm = np.mean([avg_over_mixes(m, "avg_turnaround_malleable_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    tr = np.mean([avg_over_mixes(m, "avg_turnaround_rigid_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    if not tm < tr:
        fails.append(f"Obs6: malleable turn {tm:.1f}h !< rigid {tr:.1f}h")
    return fails
