"""Scheduler benchmarks: one per paper table/figure.

  baseline     -> Table II   (FCFS/EASY, no special treatment)
  mechanisms   -> Figure 6   (6 mechanisms x W1-W5 notice mixes)
  checkpoint   -> Figure 7   (rigid checkpoint frequency sweep)

Each returns a list of row dicts; run.py prints them and asserts the
paper's qualitative observations (Obs 1-13) where they are trace-robust.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (MECHANISMS, NOTICE_MIXES, Metrics, SimConfig,
                        Simulator, WorkloadConfig, collect, generate)

N_NODES = 4392  # Theta


def _wl(seed: int, mix: str = "W5", n_jobs: int = 600,
        ckpt_freq_factor: float = 1.0) -> WorkloadConfig:
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs, horizon_days=21.0,
                          target_load=1.15, notice_mix=mix, seed=seed,
                          ckpt_freq_factor=ckpt_freq_factor)


def _run(mech: str, wcfg: WorkloadConfig) -> Metrics:
    jobs = generate(wcfg)
    sim = Simulator(SimConfig(n_nodes=wcfg.n_nodes, mechanism=mech), jobs)
    sim.run()
    return collect(sim)


def _avg(ms: List[Metrics]) -> Dict[str, float]:
    keys = [k for k, v in ms[0].as_dict().items()
            if isinstance(v, (int, float))]
    out = {}
    for k in keys:
        vals = [m.as_dict().get(k) for m in ms]
        vals = [v for v in vals if v is not None and np.isfinite(v)]
        out[k] = float(np.mean(vals)) if vals else float("nan")
    return out


def bench_baseline(seeds=(0, 1, 2), n_jobs=600) -> dict:
    """Paper Table II."""
    t0 = time.perf_counter()
    ms = [_run("BASE", _wl(s, n_jobs=n_jobs)) for s in seeds]
    row = _avg(ms)
    row.update(name="baseline_FCFS_EASY", seconds=time.perf_counter() - t0)
    return row


def bench_mechanisms(seeds=(0, 1, 2), mixes=tuple(NOTICE_MIXES),
                     n_jobs=600) -> List[dict]:
    """Paper Figure 6: all six mechanisms x W1-W5."""
    rows = []
    for mix in mixes:
        for mech in MECHANISMS:
            t0 = time.perf_counter()
            ms = [_run(mech, _wl(s, mix=mix, n_jobs=n_jobs)) for s in seeds]
            row = _avg(ms)
            row.update(name=f"{mech}/{mix}", mechanism=mech, mix=mix,
                       seconds=time.perf_counter() - t0)
            rows.append(row)
    return rows


def bench_checkpoint(seeds=(0, 1), factors=(0.5, 1.0, 2.0),
                     mechanisms=("CUA&PAA", "CUA&SPAA"),
                     n_jobs=600) -> List[dict]:
    """Paper Figure 7: 0.5 = twice as frequent as the Daly optimum."""
    rows = []
    for f in factors:
        for mech in mechanisms:
            ms = [_run(mech, _wl(s, ckpt_freq_factor=f, n_jobs=n_jobs))
                  for s in seeds]
            row = _avg(ms)
            row.update(name=f"ckpt_{f:g}x/{mech}", mechanism=mech, factor=f)
            rows.append(row)
    return rows


# ------------------------------------------------- qualitative validations
def validate_observations(base: dict, mech_rows: List[dict]) -> List[str]:
    """Check the paper's trace-robust claims; returns failure strings."""
    fails = []
    by = {r["name"]: r for r in mech_rows}

    def avg_over_mixes(mech, key):
        vals = [r[key] for r in mech_rows if r["mechanism"] == mech]
        return float(np.mean(vals))

    inst_base = base["od_instant_start_rate"]
    inst_mech = np.mean([avg_over_mixes(m, "od_instant_start_rate")
                         for m in MECHANISMS])
    # Obs 1/9: instant start rate jumps to ~1 under every mechanism
    if not inst_mech > inst_base + 0.3:
        fails.append(f"Obs1/9: instant {inst_mech:.2f} !>> base {inst_base:.2f}")
    for m in MECHANISMS:
        if avg_over_mixes(m, "od_instant_start_rate") < 0.90:
            fails.append(f"Obs9: {m} instant < 0.90")
    # Obs 3: SPAA reduces malleable preemption ratio vs PAA
    paa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                   for m in MECHANISMS if m.endswith("&PAA")])
    spaa = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                    for m in MECHANISMS if m.endswith("&SPAA")])
    if not spaa < paa:
        fails.append(f"Obs3: malleable preempt SPAA {spaa:.3f} !< PAA {paa:.3f}")
    # Obs 8: malleable preemption ratio > rigid preemption ratio
    pm = np.mean([avg_over_mixes(m, "preemption_ratio_malleable")
                  for m in MECHANISMS])
    pr = np.mean([avg_over_mixes(m, "preemption_ratio_rigid")
                  for m in MECHANISMS])
    if not pm > pr:
        fails.append(f"Obs8: malleable {pm:.3f} !> rigid {pr:.3f}")
    # Obs 6: malleable turnaround < rigid turnaround (honesty incentive)
    tm = np.mean([avg_over_mixes(m, "avg_turnaround_malleable_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    tr = np.mean([avg_over_mixes(m, "avg_turnaround_rigid_h")
                  for m in MECHANISMS if not m.startswith("N&")])
    if not tm < tr:
        fails.append(f"Obs6: malleable turn {tm:.1f}h !< rigid {tr:.1f}h")
    return fails
