"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract and
writes the full row dicts to results/bench/*.json.  Sections:

  table2      baseline FCFS/EASY                    (paper Table II)
  fig6        6 mechanisms x W1-W5                  (paper Figure 6)
  fig7        checkpoint frequency sweep            (paper Figure 7)
  obs10       decision latency                      (paper Obs 10)
  dispatch    policy-API overhead vs seed           (BENCH_scheduler.json)
  roofline    per (arch x shape) roofline terms     (EXPERIMENTS §Roofline)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import bench_decision, bench_roofline, bench_scheduler

OUT = "results/bench"


def _emit(section: str, rows, t0: float) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{section}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if isinstance(rows, dict):
        rows = [rows]
    for r in rows:
        us = r.get("us_per_call")
        if us is None:
            us = round(r.get("seconds", time.perf_counter() - t0) * 1e6, 1)
        derived = r.get("derived") or ",".join(
            f"{k}={v:.4g}" for k, v in r.items()
            if isinstance(v, (int, float)) and k not in
            ("seconds", "us_per_call"))
        print(f"{r.get('name', section)},{us},{derived}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workloads (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale averaging (10 traces)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    seeds = (0,) if args.quick else tuple(range(10)) if args.full else (0, 1, 2)
    n_jobs = 300 if args.quick else 900 if args.full else 600

    want = lambda s: args.only is None or args.only == s
    failures = []

    base = None
    mech_rows = None
    if want("table2"):
        t0 = time.perf_counter()
        base = bench_scheduler.bench_baseline(seeds=seeds, n_jobs=n_jobs)
        _emit("table2", base, t0)
    if want("fig6"):
        t0 = time.perf_counter()
        mech_rows = bench_scheduler.bench_mechanisms(seeds=seeds,
                                                     n_jobs=n_jobs)
        _emit("fig6", mech_rows, t0)
    if base is not None and mech_rows is not None:
        fails = bench_scheduler.validate_observations(base, mech_rows)
        for f in fails:
            print(f"VALIDATION-FAIL,{f}", file=sys.stderr)
        failures += fails
        if not fails:
            print("validate_observations,0,all paper observations hold")
    if want("fig7"):
        t0 = time.perf_counter()
        rows = bench_scheduler.bench_checkpoint(
            seeds=seeds[:2], n_jobs=n_jobs)
        _emit("fig7", rows, t0)
    if want("obs10"):
        t0 = time.perf_counter()
        rows = bench_decision.bench_decision_kernels()
        rows.append(bench_decision.bench_decision_e2e())
        _emit("obs10", rows, t0)
    if want("dispatch"):
        t0 = time.perf_counter()
        # always the 600-job trace: the recorded seed baseline is 600 jobs
        row = bench_scheduler.bench_policy_dispatch()
        _emit("dispatch", row, t0)
    if want("roofline"):
        t0 = time.perf_counter()
        rows = bench_roofline.rows(multi_pod=False)
        if rows:
            _emit("roofline", rows, t0)
        else:
            print("roofline,0,no dry-run artifacts found (run "
                  "repro.launch.dryrun first)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
