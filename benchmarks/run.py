"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract and
writes the full row dicts to results/bench/*.json.  Sections:

  table2      baseline FCFS/EASY                    (paper Table II)
  fig6        6 mechanisms x W1-W5                  (paper Figure 6)
  fig7        checkpoint frequency sweep            (paper Figure 7)
  scenarios   scenario presets x mechanisms         (docs/workloads.md)
  obs10       decision latency                      (paper Obs 10)
  dispatch    policy-API overhead vs seed           (BENCH_scheduler.json)
  profile     cProfile top-frame table of the      (results/bench/
              month-dense replay hot loop           profile.json; CI artifact)
  scale       engine wall clock 600 -> 6k -> 50k,   (results/bench/scale.json
              streaming==materialized sha gates,     + BENCH_scheduler.json)
              the batch-rounds fidelity-vs-speed
              curve (+ digest gate at rounds=0),
              the 1M-job multi-year rung, and the
              full-year streaming rung with
              per-mode peak RSS
  service     shadow scheduler service replay:      (results/bench/
              fidelity digest vs offline simulator   service.json;
              + decision-latency SLO gates           docs/service.md)
  faults      chaos gate: SIGKILL-style crash ->    (results/bench/
              recover -> digest == uninterrupted,    faults.json;
              + MTBF-sweep determinism + goodput     docs/faults.md)
  campaign    mini trace-zoo campaign run twice:    (results/bench/
              cells/sec + peak RSS + byte-identical  campaign.json;
              artifact gate                          docs/campaigns.md)
  device      sweeps-on-device: a >= 600-cell       (results/bench/
              mechanism grid replayed as ONE jitted  device_sweep.json;
              device program, parity-gated per cell  docs/performance.md)
              against the numpy engine
  roofline    per (arch x shape) roofline terms     (EXPERIMENTS §Roofline)

Scale tiers: --quick runs (600, 2k) with the paired pre-PR baseline at
600 jobs; the default adds the 6k steady-load and month-dense pairs
(the latter gates the >= 10x speedup acceptance); --full adds the
50k-job Theta-scale sweep.  Every mode appends the streaming-identity
sha rows, the batch-rounds fidelity curve (--quick probes a single
round size on the small tier; other modes run the full curve plus the
1M-job multi-year rung) and a full-year streaming replay
(benchmarks/bench_scale: 110k jobs/365d, or a density-preserving 20k
"quick year" under --quick) with per-mode peak RSS.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from . import (bench_campaign, bench_decision, bench_faults, bench_profile,
               bench_roofline, bench_scale, bench_scheduler, bench_service)

OUT = "results/bench"


def _provenance(mode: str, seeds, n_jobs: int) -> dict:
    """Stamped into every artifact so quick CI output cannot be mistaken
    for paper-scale reference results."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, check=True, timeout=10
        ).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--untracked-files=no"],
            cwd=root, capture_output=True, text=True, check=True,
            timeout=10).stdout.strip()
        if dirty:
            commit += "+dirty"
    except (OSError, subprocess.SubprocessError):
        commit = "unknown"
    return {"mode": mode, "seeds": list(seeds), "n_jobs": n_jobs,
            "commit": commit,
            "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}


def _emit(section: str, rows, t0: float, provenance: dict) -> None:
    os.makedirs(OUT, exist_ok=True)
    if isinstance(rows, dict):
        rows = [rows]
    with open(os.path.join(OUT, f"{section}.json"), "w") as f:
        json.dump({"provenance": provenance, "rows": rows}, f, indent=1,
                  default=str)
    for r in rows:
        us = r.get("us_per_call")
        if us is None:
            us = round(r.get("seconds", time.perf_counter() - t0) * 1e6, 1)
        derived = r.get("derived") or ",".join(
            f"{k}={v:.4g}" for k, v in r.items()
            if isinstance(v, (int, float)) and k not in
            ("seconds", "us_per_call"))
        print(f"{r.get('name', section)},{us},{derived}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workloads (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale averaging (10 traces)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mode = "quick" if args.quick else "full" if args.full else "default"
    seeds = (0,) if args.quick else tuple(range(10)) if args.full else (0, 1, 2)
    n_jobs = 300 if args.quick else 900 if args.full else 600
    prov = _provenance(mode, seeds, n_jobs)

    want = lambda s: args.only is None or args.only == s
    failures = []

    base = None
    mech_rows = None
    if want("table2"):
        t0 = time.perf_counter()
        base = bench_scheduler.bench_baseline(seeds=seeds, n_jobs=n_jobs)
        _emit("table2", base, t0, prov)
    if want("fig6"):
        t0 = time.perf_counter()
        mech_rows = bench_scheduler.bench_mechanisms(seeds=seeds,
                                                     n_jobs=n_jobs)
        _emit("fig6", mech_rows, t0, prov)
    if base is not None and mech_rows is not None:
        fails = bench_scheduler.validate_observations(base, mech_rows)
        for f in fails:
            print(f"VALIDATION-FAIL,{f}", file=sys.stderr)
        failures += fails
        if not fails:
            print("validate_observations,0,all paper observations hold")
    if want("fig7"):
        t0 = time.perf_counter()
        rows = bench_scheduler.bench_checkpoint(
            seeds=seeds[:2], n_jobs=n_jobs)
        _emit("fig7", rows, t0, dict(prov, seeds=list(seeds[:2])))
    if want("scenarios"):
        t0 = time.perf_counter()
        trace = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "tests", "data", "sample.swf")
        rows = bench_scheduler.bench_scenarios(
            seeds=seeds[:2], n_jobs=n_jobs,
            swf_trace=trace if os.path.exists(trace) else None)
        _emit("scenarios", rows, t0, dict(prov, seeds=list(seeds[:2])))
    if want("obs10"):
        t0 = time.perf_counter()
        rows = bench_decision.bench_decision_kernels()
        e2e = bench_decision.bench_decision_e2e()
        rows.append(e2e)
        # e2e always runs at full-system scale regardless of --quick/--full
        _emit("obs10", rows, t0,
              dict(prov, seeds=list(bench_decision.E2E_SEEDS),
                   n_jobs=bench_decision.E2E_N_JOBS,
                   note="seeds/n_jobs describe od_arrival_decision; kernel "
                        "rows are synthetic (scale in their derived field)"))
        if not e2e["within_bound"]:
            fail = (f"Obs10: od_arrival_decision p99 {e2e['p99_us']:.0f}us "
                    f"> bound {e2e['bound_us']:.0f}us")
            print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
            failures.append(fail)
    if want("dispatch"):
        t0 = time.perf_counter()
        # always the seed-0 600-job trace, independent of --quick/--full
        row = bench_scheduler.bench_policy_dispatch()
        _emit("dispatch", row, t0,
              dict(prov, seeds=[0], n_jobs=row["n_jobs"]))
        if row.get("within_budget") is False:
            fail = (f"dispatch: overhead {row['overhead_pct']:+.1f}% "
                    f"> budget {row['budget_pct']:.0f}%")
            print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
            failures.append(fail)
    if want("profile"):
        t0 = time.perf_counter()
        # quick profiles a smaller month-dense slice; ranking is what
        # matters and it is stable across the scale-down
        rows = bench_profile.bench_profile(
            n_jobs=1500 if args.quick else 6000,
            horizon_days=7.5 if args.quick else 30.0)
        _emit("profile", rows, t0, dict(prov, seeds=[0],
                                        n_jobs=rows[0]["n_jobs"]))
    if want("scale"):
        t0 = time.perf_counter()
        if args.quick:
            scales = ((600, 21.0), (2000, 70.0))
            baseline_max = 600
        elif args.full:
            scales = ((600, 21.0), (6000, 210.0), (6000, 30.0),
                      (50000, 1750.0))
            baseline_max = 6000
        else:
            scales = ((600, 21.0), (6000, 210.0), (6000, 30.0))
            baseline_max = 6000
        rows = bench_scheduler.bench_scale(scales=scales,
                                           baseline_max_jobs=baseline_max)
        # streaming == materialized identity tiers + the full-year rung
        # (scaled-down 20k "quick year" under --quick; see bench_scale)
        identity_tiers = ((600, 21.0),) if args.quick \
            else ((600, 21.0), (6000, 210.0))
        rows += bench_scale.bench_stream_identity(tiers=identity_tiers)
        # batch-rounds fidelity-vs-speed curve (quick: one round size on
        # the small tier — digest + drift gates only; else the full
        # >= 5-point curve on the month-dense scheduling-bound tier)
        if args.quick:
            batch_rows = bench_scale.bench_batch_fidelity(
                n_jobs=600, horizon_days=21.0, round_sizes=(0.0, 900.0),
                repeats=1)
        else:
            batch_rows = bench_scale.bench_batch_fidelity()
        rows += batch_rows
        if not args.quick:
            rows += bench_scale.bench_million()
        rows += bench_scale.bench_full_year(
            n_jobs=20_000 if args.quick else bench_scale.YEAR_N_JOBS)
        _emit("scale", rows, t0,
              dict(prov, seeds=[0],
                   note="n_jobs varies per row; see each row"))
        for r in rows:
            if r.get("jobs_match") is False:
                fail = (f"scale: {r['name']} streamed job trace diverges "
                        "from the materialized trace")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
            if r.get("mode") == "stream" and r.get("n_completed") is not None \
                    and r["n_completed"] < r["n_jobs"]:
                fail = (f"scale: {r['name']} completed only "
                        f"{r['n_completed']}/{r['n_jobs']} jobs")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
        for r in rows:
            if r.get("records_match") is False:
                fail = (f"scale: {r['name']} records diverge from the "
                        f"paired reference run")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
            if r.get("decision_p99_ms") is not None \
                    and not r["decision_within_bound"]:
                fail = (f"scale: {r['name']} decision p99 "
                        f"{r['decision_p99_ms']}ms > 10ms bound")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
            # the acceptance gate: month-dense 6k replay >= 10x
            if r["name"].startswith("scale_") and "speedup" in r \
                    and r["n_jobs"] >= 6000 \
                    and r["horizon_days"] <= 31.0 \
                    and r["speedup"] < bench_scheduler.SCALE_SPEEDUP_TARGET:
                fail = (f"scale: {r['name']} speedup {r['speedup']}x < "
                        f"{bench_scheduler.SCALE_SPEEDUP_TARGET}x target")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
        # batch-rounds gates: the rounds=0 digest gate rides the
        # records_match loop above; here the fidelity/speed acceptance
        curve = [r for r in batch_rows if r["batch_rounds"] > 0]
        drifted = [r for r in curve
                   if abs(r["od_drift_pct"]) > bench_scale.BATCH_OD_DRIFT_PCT]
        if args.quick:
            # CI smoke: bounded od drift at the single probed round size
            for r in drifted:
                fail = (f"scale: {r['name']} od drift "
                        f"{r['od_drift_pct']:+.2f}% > "
                        f"{bench_scale.BATCH_OD_DRIFT_PCT:.0f}% bound")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
        elif any("speedup" in r for r in curve) and not any(
                r.get("speedup", 0.0) >= bench_scale.BATCH_SPEEDUP_TARGET
                and abs(r["od_drift_pct"])
                <= bench_scale.BATCH_OD_DRIFT_PCT for r in curve):
            # "speedup" is the scale_* rows' convention: measured vs the
            # pre-PR engine (hot loop + batching combined).  Like the
            # >= 10x scale gate, this one can only run where git history
            # is available to rebuild that baseline.
            fail = (f"scale: no batch round size reaches "
                    f"{bench_scale.BATCH_SPEEDUP_TARGET:.0f}x speedup "
                    f"(vs pre-engine) at "
                    f"<= {bench_scale.BATCH_OD_DRIFT_PCT:.0f}% od drift")
            print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
            failures.append(fail)
    if want("service"):
        t0 = time.perf_counter()
        svc_cells = bench_service.CELLS[:1] if args.quick \
            else bench_service.CELLS
        svc_jobs = 150 if args.quick else 300
        rows = bench_service.bench_service(cells=svc_cells, n_jobs=svc_jobs)
        _emit("service", rows, t0,
              dict(prov, seeds=[0], n_jobs=svc_jobs))
        for r in rows:
            if not r["fidelity_ok"]:
                fail = (f"service: {r['name']} shadow decisions diverge "
                        "from the offline simulator (digests_match="
                        f"{r['digests_match']}, records_match="
                        f"{r['records_match']})")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
            if not r["slo_ok"]:
                fail = (f"service: {r['name']} decision p99 "
                        f"{r['decision_p99_ms']}ms > "
                        f"{r['decision_bound_ms']}ms bound")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
    if want("faults"):
        t0 = time.perf_counter()
        rows = bench_faults.bench_faults(
            n_jobs=100 if args.quick else 150, quick=args.quick)
        _emit("faults", rows, t0,
              dict(prov, seeds=[2, 3],
                   n_jobs=100 if args.quick else 150,
                   note="recover rows use seed 3, mtbf rows seed 2"))
        for r in rows:
            if r.get("digest_match") is False:
                fail = (f"faults: {r['name']} recovered decision stream "
                        "diverges from the uninterrupted run")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
            if r.get("deterministic") is False:
                fail = (f"faults: {r['name']} fault-injected cell is not "
                        "job-for-job reproducible")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
    if want("campaign"):
        t0 = time.perf_counter()
        try:
            rows = bench_campaign.bench_campaign()
        except ValueError as e:  # CampaignSpecError / zoo integrity
            fail = f"campaign: spec/zoo validation failed: {e}"
            print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
            failures.append(fail)
            rows = []
        if rows:
            # the mini campaign runs at fixed fixture scale; seeds and
            # job counts come from the spec, not --quick/--full
            _emit("campaign", rows, t0,
                  dict(prov, seeds="per-spec", n_jobs="per-spec",
                       note="spec-defined scale; see each row"))
        for r in rows:
            if not r["deterministic"]:
                fail = (f"campaign: {r['name']} artifacts differ between "
                        "two identical runs (rows/report must be "
                        "byte-deterministic)")
                print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                failures.append(fail)
    if want("device"):
        # jax is optional in lightweight CI: skip (with a visible row)
        # rather than fail when the device backend is absent
        try:
            import jax  # noqa: F401
            have_jax = True
        except ImportError:
            have_jax = False
        if have_jax:
            from . import bench_device_sweep
            t0 = time.perf_counter()
            rows = bench_device_sweep.bench_device_sweep(quick=args.quick)
            _emit("device_sweep", rows, t0,
                  dict(prov, seeds="per-row", n_jobs="per-row",
                       note="grid tier fixed per mode; see each row"))
            for r in rows:
                if not r["parity_ok"]:
                    fail = (f"device: {r['name']} {r['n_mismatches']} device "
                            "decision(s) diverge from the numpy engine "
                            f"(sample: {r['mismatch_sample'][:1]})")
                    print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                    failures.append(fail)
                if not r["within_bound"]:
                    fail = (f"device: {r['name']} {r['us_per_call']}us/call "
                            f"> bound {r['bound_us']}us (program likely "
                            "fragmented or retracing)")
                    print(f"VALIDATION-FAIL,{fail}", file=sys.stderr)
                    failures.append(fail)
        else:
            print("device_sweep,0,skipped: jax not installed")
    if want("roofline"):
        t0 = time.perf_counter()
        rows = bench_roofline.rows(multi_pod=False)
        if rows:
            _emit("roofline", rows, t0, prov)
        else:
            print("roofline,0,no dry-run artifacts found (run "
                  "repro.launch.dryrun first)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
