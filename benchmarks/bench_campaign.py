"""Campaign throughput + determinism bench (--only campaign).

Runs the checked-in mini campaign (examples/campaigns/mini.toml —
fixture traces only, fully offline) twice into scratch directories and
reports:

  * ``cells_per_s`` — grid cells completed per second (serial, so the
    number is machine-comparable rather than core-count-comparable);
  * ``peak_rss_mb`` — in-process VmRSS high-water while the campaign
    streams (the cells run the bounded-memory Scenario path; this
    documents the bound at campaign scale);
  * ``deterministic`` — the acceptance gate: both runs' rows.json /
    report.json / report.md must be byte-identical.  The digest of the
    artifact set is reported so regressions name the differing bytes.

VALIDATION-FAIL (non-zero exit via benchmarks.run) on determinism or
spec-validation errors.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import tempfile
import threading
import time
from typing import List

SPEC_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples", "campaigns", "mini.toml")

ARTIFACTS = ("rows.json", "report.json", "report.md")


def _rss_mb() -> float:
    try:
        with open("/proc/self/statm") as f:
            page_mb = os.sysconf("SC_PAGE_SIZE") / 1048576.0
            return int(f.read().split()[1]) * page_mb
    except OSError:  # non-procfs platform: resource fallback
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _artifact_digest(out_dir: str) -> str:
    h = hashlib.sha256()
    for name in ARTIFACTS:
        with open(os.path.join(out_dir, name), "rb") as f:
            h.update(name.encode())
            h.update(f.read())
    return h.hexdigest()


def bench_campaign(spec_path: str = SPEC_PATH) -> List[dict]:
    """Two serial offline runs of the mini campaign; see module doc."""
    from repro.campaign import CampaignSpec, run_campaign

    spec = CampaignSpec.load(spec_path)  # spec-validation gate
    peak = [0.0]
    stop = threading.Event()

    def sampler():
        while not stop.is_set():
            peak[0] = max(peak[0], _rss_mb())
            stop.wait(0.02)

    digests = []
    seconds = []
    tmp = tempfile.mkdtemp(prefix="bench_campaign_")
    try:
        threading.Thread(target=sampler, daemon=True).start()
        for k in range(2):
            out = os.path.join(tmp, f"run{k}")
            t0 = time.perf_counter()
            run_campaign(spec, out_dir=out, offline=True, processes=0)
            seconds.append(time.perf_counter() - t0)
            digests.append(_artifact_digest(out))
        stop.set()
        peak[0] = max(peak[0], _rss_mb())
    finally:
        stop.set()
        shutil.rmtree(tmp, ignore_errors=True)
    deterministic = digests[0] == digests[1]
    return [{
        "name": "campaign_mini",
        "spec": os.path.relpath(spec_path),
        "n_cells": spec.n_cells,
        "seconds": round(seconds[0], 3),
        "seconds_second_run": round(seconds[1], 3),
        "cells_per_s": round(spec.n_cells / seconds[0], 2),
        "peak_rss_mb": round(peak[0], 1),
        "artifact_sha256": digests[0][:16],
        "deterministic": deterministic,
        "derived": (f"cells={spec.n_cells},"
                    f"cells_per_s={spec.n_cells / seconds[0]:.1f},"
                    f"peak_rss_mb={peak[0]:.0f},"
                    f"deterministic={deterministic}"),
    }]
