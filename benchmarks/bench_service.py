"""Shadow-mode scheduler service benchmark + CI gate.

One row per (scenario, mechanism) cell: replay the scenario through the
live service loop (ReplayClock at speed=inf, DryrunLauncher validating
every action) and gate on the tentpole acceptance criteria:

* **fidelity** — the paced decision stream's digest equals the offline
  reference core's, and job records match a plain Simulator job-for-job
  (`fidelity_ok`);
* **SLO** — per-event-batch decision latency p99 < 10 ms (paper Obs 10,
  `slo_ok` / `decision_p99_ms`).

`track_decision_time` stays off in every run so the decision sequence —
and therefore the digest — contains no nondeterministic measurement
state.  Rows land in results/bench/service.json (the CI artifact with
the latency distribution per cell).
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

from repro.core.workloads import get_scenario
from repro.service import ServiceConfig, SloPolicy, shadow_fidelity

#: (scenario, mechanism) cells the benchmark sweeps
CELLS: Tuple[Tuple[str, str], ...] = (
    ("bursty-od", "CUA&SPAA"),
    ("bursty-od", "CUP&STEAL"),
    ("diurnal", "CUA&SPAA"),
)
DECISION_P99_BOUND_MS = 10.0   # paper Obs 10


def bench_service(cells: Sequence[Tuple[str, str]] = CELLS,
                  n_jobs: int = 300, seed: int = 0) -> List[dict]:
    rows = []
    for scenario, mechanism in cells:
        scn = get_scenario(scenario, n_jobs=n_jobs)
        jobs, n_nodes = scn.realize(seed)
        cfg = ServiceConfig(
            n_nodes=n_nodes, mechanism=mechanism,
            slo=SloPolicy(decision_p99_ms=DECISION_P99_BOUND_MS))
        t0 = time.perf_counter()
        rep = shadow_fidelity(jobs, cfg)
        wall = time.perf_counter() - t0
        svc = rep.service
        rows.append({
            "name": f"service_{scenario}_{mechanism.replace('&', '_')}",
            "scenario": scenario, "mechanism": mechanism,
            "n_jobs": len(jobs), "n_nodes": n_nodes,
            "n_decisions": svc.n_decisions,
            "fidelity_ok": rep.ok,
            "digests_match": rep.digests_match,
            "records_match": rep.records_match,
            "digest": svc.digest,
            "slo_ok": svc.ok,
            "decision_p99_ms": round(svc.slo["decision_p99_ms"], 4),
            "decision_bound_ms": DECISION_P99_BOUND_MS,
            "latency": svc.latency,
            "od_wait_p99_s": round(svc.slo["od_wait_p99_s"], 2),
            "launcher_counts": svc.launcher_counts,
            "replay_wall_s": svc.wall_s,
            "seconds": round(wall, 3),
            "us_per_call": round(wall / max(svc.n_decisions, 1) * 1e6, 1),
            "derived": (f"decisions={svc.n_decisions},"
                        f"p99_ms={svc.slo['decision_p99_ms']:.3f},"
                        f"fidelity={int(rep.ok)},slo={int(svc.ok)}"),
        })
    return rows
