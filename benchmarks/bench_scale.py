"""Year-scale streaming replay benchmarks (ROADMAP's "full-year, 100k+"
rung).

Two sections, both landing in results/bench/scale.json next to the
legacy engine-wall-clock rows (benchmarks/bench_scheduler.bench_scale):

  stream-identity   streaming mode (lazy source -> incremental arrival
                    feed -> record sink) vs materialized mode on the
                    600- and 6k-job tiers across BASE/CUA&SPAA: per-row
                    sha256 digests of the *job trace* and of the
                    *job-for-job outcome records* must match exactly.
  full-year         a >= 100k-job, 365-day Theta-density replay through
                    Experiment.run_stream, executed in a fresh
                    subprocess per mode; the child samples its own
                    VmRSS (/proc/self/statm) for the peak-RSS
                    high-water, because ru_maxrss is fork-inherited
                    from the parent on this kernel and would report the
                    harness's footprint.  The streaming row documents
                    the bounded-memory claim; the paired materialized
                    row is the reference point.

The year workload keeps the offered-load regime of the existing scale
tiers (~1.05-1.15) at one-year density: ~300 jobs/day needs a smaller
runtime median than the 2h default or a year of Theta-sized jobs would
overflow the machine several times over.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

from repro.core import SimConfig, Simulator, WorkloadConfig, generate
from repro.core.workloads import ThetaGenerator, trace_sha256

N_NODES = 4392  # Theta

#: the full-year reference point: ~300 jobs/day for 365 days at offered
#: load ~1.1 (runtime median tuned down so a year of arrivals fits the
#: machine at the paper's load regime)
YEAR_N_JOBS = 110_000
YEAR_HORIZON_DAYS = 365.0
YEAR_RUNTIME_MEDIAN_S = 1500.0


def year_workload(n_jobs: int = YEAR_N_JOBS, seed: int = 0,
                  horizon_days: Optional[float] = None) -> WorkloadConfig:
    """The full-year workload, or a density-preserving scale-down of it
    (horizon shrinks with n_jobs, so 20k jobs is a "quick year" at the
    same arrival rate and load)."""
    if horizon_days is None:
        horizon_days = YEAR_HORIZON_DAYS * n_jobs / YEAR_N_JOBS
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                          horizon_days=horizon_days, target_load=1.05,
                          runtime_median_s=YEAR_RUNTIME_MEDIAN_S,
                          notice_mix="W5", seed=seed)


def _record_sha(records) -> str:
    """Order-independent sha256 of the job-for-job outcome tuples —
    comparable between a retained record dict and a sink's stream."""
    recs = sorted((r.job.jid, r.first_start, r.completion, r.killed,
                   r.n_preempted, r.n_shrunk, r.instant) for r in records)
    return hashlib.sha256(repr(recs).encode()).hexdigest()


# ---------------------------------------------------------- stream identity
def bench_stream_identity(tiers: Tuple[Tuple[int, float], ...] = (
        (600, 21.0), (6000, 210.0)),
        mechanisms: Tuple[str, ...] = ("BASE", "CUA&SPAA"),
        seed: int = 0) -> List[dict]:
    """Per (tier x mechanism) row: sha256 of the generated job trace
    (materialized ``generate`` vs lazy ``iter_jobs``) and of the
    simulated outcome records (retained dict vs record sink).  Both
    must match bit-for-bit — the acceptance gate for swapping the
    data-flow mode freely."""
    rows = []
    for n_jobs, horizon_days in tiers:
        wl = WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                            horizon_days=horizon_days, target_load=1.15,
                            notice_mix="W5", seed=seed)
        jobs = generate(wl)
        jobs_sha = trace_sha256(jobs)
        # one generator instance: iter_jobs() re-yields from its memoized
        # columns, so the trace is sampled once, not once per mechanism
        gen = ThetaGenerator(wl)
        stream_jobs_sha = trace_sha256(gen.iter_jobs())
        for mech in mechanisms:
            cfg = SimConfig(n_nodes=N_NODES, mechanism=mech)
            mat = Simulator(cfg, list(jobs))
            t0 = time.perf_counter()
            mat.run()
            mat_s = time.perf_counter() - t0
            mat_sha = _record_sha(mat.records.values())

            retired: List = []
            stream = Simulator(cfg, gen.iter_jobs(),
                               record_sink=retired.append)
            t0 = time.perf_counter()
            stream.run()
            stream_s = time.perf_counter() - t0
            stream_sha = _record_sha(retired)
            rows.append({
                "name": f"stream_identity_{n_jobs}job_{mech}",
                "n_jobs": n_jobs, "mechanism": mech, "seed": seed,
                "job_sha256": jobs_sha,
                "jobs_match": bool(jobs_sha == stream_jobs_sha),
                "record_sha256": mat_sha,
                "records_match": bool(mat_sha == stream_sha),
                "seconds": round(stream_s, 3),
                "materialized_seconds": round(mat_s, 3),
                "derived": (f"jobs {'==' if jobs_sha == stream_jobs_sha else '!='} "
                            f"records {'==' if mat_sha == stream_sha else '!='} "
                            f"({stream_s:.2f}s vs {mat_s:.2f}s)")})
    return rows


# -------------------------------------------------------------- full year
_YEAR_SCRIPT = """\
import json, os, sys, threading, time
from benchmarks.bench_scale import year_workload
from repro.core import Experiment

# Peak RSS by sampling VmRSS (/proc/self/statm): ru_maxrss is useless
# here — a child forked from a large benchmark harness inherits the
# parent's resident high-water on this kernel, so the measured process
# must track its *own* resident set while it runs.
PAGE_MB = os.sysconf("SC_PAGE_SIZE") / 1048576.0
peak = [0.0]
stop = threading.Event()

def _rss_mb():
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * PAGE_MB
    except OSError:            # non-procfs platform: resource fallback
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

def _sampler():
    while not stop.is_set():
        peak[0] = max(peak[0], _rss_mb())
        stop.wait(0.02)

threading.Thread(target=_sampler, daemon=True).start()

cfg = json.loads(sys.argv[1])
wl = year_workload(cfg["n_jobs"], seed=cfg["seed"])
exp = Experiment(mechanisms=(cfg["mechanism"],), workloads=(wl,),
                 seeds=(cfg["seed"],), processes=1,
                 stream=cfg["stream"])
t0 = time.perf_counter()
rows = [r for r in exp.run_stream()]
seconds = time.perf_counter() - t0
stop.set()
peak[0] = max(peak[0], _rss_mb())
m = rows[0].metrics
print(json.dumps({
    "seconds": seconds,
    "peak_rss_mb": peak[0],
    "n_jobs": m.n_jobs, "n_completed": m.n_completed,
    "avg_turnaround_h": m.avg_turnaround_h,
    "system_utilization": m.system_utilization}))
"""


def _year_subprocess(n_jobs: int, mechanism: str, seed: int,
                     stream: bool, timeout: float = 3600.0
                     ) -> Optional[dict]:
    """One full-year replay in a fresh interpreter (self-sampled VmRSS).

    Returns None only when subprocesses themselves are unavailable
    (OSError spawning).  A child that *crashes*, times out, or prints
    garbage is a genuine engine failure and raises RuntimeError with
    the child's stderr — it must never be silently re-labelled as
    "no subprocess support"."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH")) if p)
    params = json.dumps({"n_jobs": n_jobs, "mechanism": mechanism,
                         "seed": seed, "stream": stream})
    try:
        out = subprocess.run([sys.executable, "-c", _YEAR_SCRIPT, params],
                             capture_output=True, text=True, check=True,
                             env=env, timeout=timeout)
    except OSError:
        return None  # cannot spawn at all: caller measures in-process
    except subprocess.CalledProcessError as e:
        raise RuntimeError(
            f"full-year {'stream' if stream else 'materialized'} replay "
            f"subprocess failed (exit {e.returncode}); stderr tail:\n"
            f"{(e.stderr or '')[-2000:]}") from None
    except subprocess.TimeoutExpired:
        raise RuntimeError(
            f"full-year {'stream' if stream else 'materialized'} replay "
            f"did not finish within {timeout}s") from None
    try:
        return json.loads(out.stdout.strip().splitlines()[-1])
    except (ValueError, IndexError):
        raise RuntimeError(
            "full-year replay subprocess printed no result row; stdout "
            f"tail:\n{out.stdout[-2000:]}") from None


def bench_full_year(n_jobs: int = YEAR_N_JOBS, mechanism: str = "CUA&SPAA",
                    seed: int = 0, compare_materialized: bool = True
                    ) -> List[dict]:
    """The full-year rung: a >= 100k-job replay through
    ``Experiment.run_stream`` with the peak-RSS high-water of each data
    flow measured in its own subprocess.  Falls back to an in-process
    streaming run (RSS reported as the parent's, labelled) when
    subprocesses are unavailable."""
    wl = year_workload(n_jobs, seed=seed)
    label = f"year_{n_jobs}job_{wl.horizon_days:g}d"
    rows = []
    stream_res = _year_subprocess(n_jobs, mechanism, seed, stream=True)
    in_process = stream_res is None
    if in_process:  # no subprocess support: measure in-process, loudly
        import resource
        from repro.core import Experiment
        exp = Experiment(mechanisms=(mechanism,), workloads=(wl,),
                         seeds=(seed,), processes=1, stream=True)
        t0 = time.perf_counter()
        results = list(exp.run_stream())
        m = results[0].metrics
        stream_res = {
            "seconds": time.perf_counter() - t0,
            "peak_rss_mb": resource.getrusage(
                resource.RUSAGE_SELF).ru_maxrss / 1024.0,
            "n_jobs": m.n_jobs, "n_completed": m.n_completed,
            "avg_turnaround_h": m.avg_turnaround_h,
            "system_utilization": m.system_utilization}
    def _res_cols(res: dict) -> dict:
        # n_jobs stays the REQUESTED trace length (the sink-counted one
        # goes to n_jobs_simulated), so run.py's lost-job gate compares
        # retired records against the ask instead of against itself
        out = {k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in res.items() if k != "n_jobs"}
        out["n_jobs_simulated"] = res["n_jobs"]
        return out

    row = {"name": f"{label}_stream", "n_jobs": n_jobs,
           "horizon_days": wl.horizon_days, "mechanism": mechanism,
           "seed": seed, "mode": "stream",
           "rss_source": ("parent process ru_maxrss (no subprocess "
                          "support)" if in_process
                          else "subprocess VmRSS sampling"),
           **_res_cols(stream_res)}
    rows.append(row)
    if compare_materialized and not in_process:
        mat_res = _year_subprocess(n_jobs, mechanism, seed, stream=False)
        if mat_res is not None:
            rows.append({"name": f"{label}_materialized", "n_jobs": n_jobs,
                         "horizon_days": wl.horizon_days,
                         "mechanism": mechanism, "seed": seed,
                         "mode": "materialized",
                         "rss_source": "subprocess VmRSS sampling",
                         **_res_cols(mat_res)})
            row["rss_vs_materialized"] = round(
                stream_res["peak_rss_mb"] / max(mat_res["peak_rss_mb"], 1e-9),
                3)
    for r in rows:
        r["derived"] = (f"{r['seconds']}s, peak RSS {r['peak_rss_mb']:.0f}MB"
                        + (f" ({row['rss_vs_materialized']:.0%} of "
                           "materialized)"
                           if r is row and "rss_vs_materialized" in row
                           else ""))
    return rows
