"""Year-scale streaming replay benchmarks (ROADMAP's "full-year, 100k+"
rung).

Four sections, all landing in results/bench/scale.json next to the
legacy engine-wall-clock rows (benchmarks/bench_scheduler.bench_scale):

  stream-identity   streaming mode (lazy source -> incremental arrival
                    feed -> record sink) vs materialized mode on the
                    600- and 6k-job tiers across BASE/CUA&SPAA: per-row
                    sha256 digests of the *job trace* and of the
                    *job-for-job outcome records* must match exactly.
  batch-fidelity    the fidelity-vs-speed curve for batched scheduling
                    rounds (SimConfig.batch_rounds): the month-dense
                    scheduling-bound replay at >= 5 round sizes, each
                    row reporting wall-clock speedup vs the pre-PR
                    engine (the scale_* rows' measured@PRE_ENGINE_COMMIT
                    convention; hot loop + batching combined) AND vs
                    this engine's own per-event run (batching alone),
                    plus the od-turnaround / BSLD / utilization drift
                    each round length buys.  The batch_rounds=0 row
                    must be record-digest-identical to both the
                    per-event engine and the pre-PR engine.
  million           the 1M-job multi-year interactive-replay rung:
                    streaming source -> batched rounds -> streaming
                    metrics sink, wall clock against the 60 s
                    interactivity target.
  full-year         a >= 100k-job, 365-day Theta-density replay through
                    Experiment.run_stream, executed in a fresh
                    subprocess per mode; the child samples its own
                    VmRSS (/proc/self/statm) for the peak-RSS
                    high-water, because ru_maxrss is fork-inherited
                    from the parent on this kernel and would report the
                    harness's footprint.  The streaming row documents
                    the bounded-memory claim; the paired materialized
                    row is the reference point.

The year workload keeps the offered-load regime of the existing scale
tiers (~1.05-1.15) at one-year density: ~300 jobs/day needs a smaller
runtime median than the 2h default or a year of Theta-sized jobs would
overflow the machine several times over.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

from repro.core import (SimConfig, Simulator, StreamingMetrics,
                        WorkloadConfig, collect, generate)
from repro.core.workloads import ThetaGenerator, trace_sha256

from .bench_scheduler import PRE_ENGINE_COMMIT, _pre_engine_run

N_NODES = 4392  # Theta

#: the full-year reference point: ~300 jobs/day for 365 days at offered
#: load ~1.1 (runtime median tuned down so a year of arrivals fits the
#: machine at the paper's load regime)
YEAR_N_JOBS = 110_000
YEAR_HORIZON_DAYS = 365.0
YEAR_RUNTIME_MEDIAN_S = 1500.0


def year_workload(n_jobs: int = YEAR_N_JOBS, seed: int = 0,
                  horizon_days: Optional[float] = None) -> WorkloadConfig:
    """The full-year workload, or a density-preserving scale-down of it
    (horizon shrinks with n_jobs, so 20k jobs is a "quick year" at the
    same arrival rate and load)."""
    if horizon_days is None:
        horizon_days = YEAR_HORIZON_DAYS * n_jobs / YEAR_N_JOBS
    return WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                          horizon_days=horizon_days, target_load=1.05,
                          runtime_median_s=YEAR_RUNTIME_MEDIAN_S,
                          notice_mix="W5", seed=seed)


def _record_sha(records) -> str:
    """Order-independent sha256 of the job-for-job outcome tuples —
    comparable between a retained record dict and a sink's stream."""
    recs = sorted((r.job.jid, r.first_start, r.completion, r.killed,
                   r.n_preempted, r.n_shrunk, r.instant) for r in records)
    return hashlib.sha256(repr(recs).encode()).hexdigest()


# ---------------------------------------------------------- stream identity
def bench_stream_identity(tiers: Tuple[Tuple[int, float], ...] = (
        (600, 21.0), (6000, 210.0)),
        mechanisms: Tuple[str, ...] = ("BASE", "CUA&SPAA"),
        seed: int = 0) -> List[dict]:
    """Per (tier x mechanism) row: sha256 of the generated job trace
    (materialized ``generate`` vs lazy ``iter_jobs``) and of the
    simulated outcome records (retained dict vs record sink).  Both
    must match bit-for-bit — the acceptance gate for swapping the
    data-flow mode freely."""
    rows = []
    for n_jobs, horizon_days in tiers:
        wl = WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                            horizon_days=horizon_days, target_load=1.15,
                            notice_mix="W5", seed=seed)
        jobs = generate(wl)
        jobs_sha = trace_sha256(jobs)
        # one generator instance: iter_jobs() re-yields from its memoized
        # columns, so the trace is sampled once, not once per mechanism
        gen = ThetaGenerator(wl)
        stream_jobs_sha = trace_sha256(gen.iter_jobs())
        for mech in mechanisms:
            cfg = SimConfig(n_nodes=N_NODES, mechanism=mech)
            mat = Simulator(cfg, list(jobs))
            t0 = time.perf_counter()
            mat.run()
            mat_s = time.perf_counter() - t0
            mat_sha = _record_sha(mat.records.values())

            retired: List = []
            stream = Simulator(cfg, gen.iter_jobs(),
                               record_sink=retired.append)
            t0 = time.perf_counter()
            stream.run()
            stream_s = time.perf_counter() - t0
            stream_sha = _record_sha(retired)
            rows.append({
                "name": f"stream_identity_{n_jobs}job_{mech}",
                "n_jobs": n_jobs, "mechanism": mech, "seed": seed,
                "job_sha256": jobs_sha,
                "jobs_match": bool(jobs_sha == stream_jobs_sha),
                "record_sha256": mat_sha,
                "records_match": bool(mat_sha == stream_sha),
                "seconds": round(stream_s, 3),
                "materialized_seconds": round(mat_s, 3),
                "derived": (f"jobs {'==' if jobs_sha == stream_jobs_sha else '!='} "
                            f"records {'==' if mat_sha == stream_sha else '!='} "
                            f"({stream_s:.2f}s vs {mat_s:.2f}s)")})
    return rows


# -------------------------------------------------------------- full year
_YEAR_SCRIPT = """\
import json, os, sys, threading, time
from benchmarks.bench_scale import year_workload
from repro.core import Experiment

# Peak RSS by sampling VmRSS (/proc/self/statm): ru_maxrss is useless
# here — a child forked from a large benchmark harness inherits the
# parent's resident high-water on this kernel, so the measured process
# must track its *own* resident set while it runs.
PAGE_MB = os.sysconf("SC_PAGE_SIZE") / 1048576.0
peak = [0.0]
stop = threading.Event()

def _rss_mb():
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * PAGE_MB
    except OSError:            # non-procfs platform: resource fallback
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

def _sampler():
    while not stop.is_set():
        peak[0] = max(peak[0], _rss_mb())
        stop.wait(0.02)

threading.Thread(target=_sampler, daemon=True).start()

cfg = json.loads(sys.argv[1])
wl = year_workload(cfg["n_jobs"], seed=cfg["seed"])
exp = Experiment(mechanisms=(cfg["mechanism"],), workloads=(wl,),
                 seeds=(cfg["seed"],), processes=1,
                 stream=cfg["stream"])
t0 = time.perf_counter()
rows = [r for r in exp.run_stream()]
seconds = time.perf_counter() - t0
stop.set()
peak[0] = max(peak[0], _rss_mb())
m = rows[0].metrics
print(json.dumps({
    "seconds": seconds,
    "peak_rss_mb": peak[0],
    "n_jobs": m.n_jobs, "n_completed": m.n_completed,
    "avg_turnaround_h": m.avg_turnaround_h,
    "system_utilization": m.system_utilization}))
"""


def _year_subprocess(n_jobs: int, mechanism: str, seed: int,
                     stream: bool, timeout: float = 3600.0
                     ) -> Optional[dict]:
    """One full-year replay in a fresh interpreter (self-sampled VmRSS).

    Returns None only when subprocesses themselves are unavailable
    (OSError spawning).  A child that *crashes*, times out, or prints
    garbage is a genuine engine failure and raises RuntimeError with
    the child's stderr — it must never be silently re-labelled as
    "no subprocess support"."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH")) if p)
    params = json.dumps({"n_jobs": n_jobs, "mechanism": mechanism,
                         "seed": seed, "stream": stream})
    try:
        out = subprocess.run([sys.executable, "-c", _YEAR_SCRIPT, params],
                             capture_output=True, text=True, check=True,
                             env=env, timeout=timeout)
    except OSError:
        return None  # cannot spawn at all: caller measures in-process
    except subprocess.CalledProcessError as e:
        raise RuntimeError(
            f"full-year {'stream' if stream else 'materialized'} replay "
            f"subprocess failed (exit {e.returncode}); stderr tail:\n"
            f"{(e.stderr or '')[-2000:]}") from None
    except subprocess.TimeoutExpired:
        raise RuntimeError(
            f"full-year {'stream' if stream else 'materialized'} replay "
            f"did not finish within {timeout}s") from None
    try:
        return json.loads(out.stdout.strip().splitlines()[-1])
    except (ValueError, IndexError):
        raise RuntimeError(
            "full-year replay subprocess printed no result row; stdout "
            f"tail:\n{out.stdout[-2000:]}") from None


def bench_full_year(n_jobs: int = YEAR_N_JOBS, mechanism: str = "CUA&SPAA",
                    seed: int = 0, compare_materialized: bool = True
                    ) -> List[dict]:
    """The full-year rung: a >= 100k-job replay through
    ``Experiment.run_stream`` with the peak-RSS high-water of each data
    flow measured in its own subprocess.  Falls back to an in-process
    streaming run (RSS reported as the parent's, labelled) when
    subprocesses are unavailable."""
    wl = year_workload(n_jobs, seed=seed)
    label = f"year_{n_jobs}job_{wl.horizon_days:g}d"
    rows = []
    stream_res = _year_subprocess(n_jobs, mechanism, seed, stream=True)
    in_process = stream_res is None
    if in_process:  # no subprocess support: measure in-process, loudly
        import resource
        from repro.core import Experiment
        exp = Experiment(mechanisms=(mechanism,), workloads=(wl,),
                         seeds=(seed,), processes=1, stream=True)
        t0 = time.perf_counter()
        results = list(exp.run_stream())
        m = results[0].metrics
        stream_res = {
            "seconds": time.perf_counter() - t0,
            "peak_rss_mb": resource.getrusage(
                resource.RUSAGE_SELF).ru_maxrss / 1024.0,
            "n_jobs": m.n_jobs, "n_completed": m.n_completed,
            "avg_turnaround_h": m.avg_turnaround_h,
            "system_utilization": m.system_utilization}
    def _res_cols(res: dict) -> dict:
        # n_jobs stays the REQUESTED trace length (the sink-counted one
        # goes to n_jobs_simulated), so run.py's lost-job gate compares
        # retired records against the ask instead of against itself
        out = {k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in res.items() if k != "n_jobs"}
        out["n_jobs_simulated"] = res["n_jobs"]
        return out

    row = {"name": f"{label}_stream", "n_jobs": n_jobs,
           "horizon_days": wl.horizon_days, "mechanism": mechanism,
           "seed": seed, "mode": "stream",
           "rss_source": ("parent process ru_maxrss (no subprocess "
                          "support)" if in_process
                          else "subprocess VmRSS sampling"),
           **_res_cols(stream_res)}
    rows.append(row)
    if compare_materialized and not in_process:
        mat_res = _year_subprocess(n_jobs, mechanism, seed, stream=False)
        if mat_res is not None:
            rows.append({"name": f"{label}_materialized", "n_jobs": n_jobs,
                         "horizon_days": wl.horizon_days,
                         "mechanism": mechanism, "seed": seed,
                         "mode": "materialized",
                         "rss_source": "subprocess VmRSS sampling",
                         **_res_cols(mat_res)})
            row["rss_vs_materialized"] = round(
                stream_res["peak_rss_mb"] / max(mat_res["peak_rss_mb"], 1e-9),
                3)
    for r in rows:
        r["derived"] = (f"{r['seconds']}s, peak RSS {r['peak_rss_mb']:.0f}MB"
                        + (f" ({row['rss_vs_materialized']:.0%} of "
                           "materialized)"
                           if r is row and "rss_vs_materialized" in row
                           else ""))
    return rows


# ------------------------------------------------- batch fidelity vs speed
#: the fidelity-vs-speed curve's round lengths (seconds of simulated time
#: between scheduling passes); 0 is the per-event reference engine
BATCH_ROUND_SIZES = (0.0, 300.0, 900.0, 3600.0, 7200.0, 14400.0)
BATCH_SPEEDUP_TARGET = 5.0   # acceptance: >= 5x somewhere on the curve...
BATCH_OD_DRIFT_PCT = 5.0     # ...while od turnaround drifts <= 5%


def bench_batch_fidelity(n_jobs: int = 6000, horizon_days: float = 30.0,
                         mechanism: str = "CUA&SPAA", seed: int = 0,
                         round_sizes: Tuple[float, ...] = BATCH_ROUND_SIZES,
                         repeats: int = 2) -> List[dict]:
    """The fidelity-vs-speed curve for ``SimConfig.batch_rounds``.

    The month-dense tier (6k jobs / 30 days, offered load 1.15) drives
    the backlog into the thousands — the scheduling-bound regime.  Per
    round size the row reports two wall-clock speedups and the fidelity
    cost:

    ``speedup``
        vs the pre-PR engine, measured live at ``PRE_ENGINE_COMMIT`` in
        a subprocess — the same baseline and convention as the existing
        ``scale_*`` rows, and the number the >= 5x acceptance gate
        reads.  It bundles this PR's hot-loop restructuring (profiled
        in bench_profile) with the batched rounds, which is what the
        replay user experiences.  Absent when git history or
        subprocesses are unavailable.
    ``speedup_vs_per_event``
        vs this engine's own ``batch_rounds=0`` run — batching's
        marginal contribution alone.  Measured honest range on organic
        workloads: ~1-2x, because after the incremental-queue engine
        (PR 3) and this PR's dispatch/invariant-gating work the
        per-event engine is no longer pass-dominated; batching's big
        wins are reserved for unstable-key policies (e.g. queue=XFACTOR
        re-sorts the backlog every pass) and for pacing live
        service-mode control plans.

    Fidelity columns: od-turnaround drift (must stay tiny — od arrivals
    keep the immediate path), BSLD and utilization drift (these degrade
    with round length; that is the knob's honest price, not a bug).

    The ``batch_rounds=0`` row is the engine-identity gate: its record
    digest must equal both the default-config per-event run and the
    pre-PR engine's digest bit for bit.
    """
    wl = WorkloadConfig(n_nodes=N_NODES, n_jobs=n_jobs,
                        horizon_days=horizon_days, target_load=1.15,
                        notice_mix="W5", seed=seed)
    jobs = generate(wl)
    pre = _pre_engine_run(n_jobs, horizon_days, seed, mechanism)

    def _run(**cfg_kw):
        best, sha, metrics = float("inf"), "", None
        for _ in range(repeats):
            sim = Simulator(SimConfig(n_nodes=N_NODES, mechanism=mechanism,
                                      **cfg_kw), list(jobs))
            t0 = time.perf_counter()
            sim.run()
            best = min(best, time.perf_counter() - t0)
            sha = _record_sha(sim.records.values())
            metrics = collect(sim)
        return best, sha, metrics

    base_s, base_sha, base_m = _run()   # per-event reference (no kwarg)

    def _drift(v, ref):
        return round((v - ref) / ref * 100.0, 3) if ref else None

    rows = []
    for batch in round_sizes:
        s, sha, m = _run(batch_rounds=batch)
        row = {"name": f"batch_fidelity_{n_jobs}job_{horizon_days:g}d"
                       f"_b{batch:g}",
               "n_jobs": n_jobs, "horizon_days": horizon_days,
               "mechanism": mechanism, "seed": seed,
               "batch_rounds": batch,
               "seconds": round(s, 3),
               "speedup_vs_per_event": round(base_s / max(s, 1e-9), 2),
               "n_completed": m.n_completed,
               "od_turnaround_h": round(m.avg_turnaround_od_h, 4),
               "od_drift_pct": _drift(m.avg_turnaround_od_h,
                                      base_m.avg_turnaround_od_h),
               "bsld": round(m.avg_bounded_slowdown, 3),
               "bsld_drift_pct": _drift(m.avg_bounded_slowdown,
                                        base_m.avg_bounded_slowdown),
               "utilization": round(m.system_utilization, 4),
               "util_drift_pct": _drift(m.system_utilization,
                                        base_m.system_utilization)}
        if pre is not None:
            row["baseline_source"] = f"measured@{PRE_ENGINE_COMMIT}"
            row["baseline_seconds"] = round(pre["seconds"], 3)
            row["speedup"] = round(pre["seconds"] / max(s, 1e-9), 2)
        if batch == 0.0:
            match = sha == base_sha
            if pre is not None:
                row["records_match_pre_engine"] = bool(sha == pre["digest"])
                match = match and row["records_match_pre_engine"]
            row["records_match"] = bool(match)
        head = (f"{row['speedup']}x vs pre-engine, "
                if "speedup" in row else "")
        row["derived"] = (
            f"{row['seconds']}s {head}"
            f"{row['speedup_vs_per_event']}x vs per-event, od drift "
            f"{row['od_drift_pct']:+.2f}% bsld {row['bsld_drift_pct']:+.1f}% "
            f"util {row['util_drift_pct']:+.1f}%"
            + (", digest==per-event==pre-engine"
               if row.get("records_match")
               and row.get("records_match_pre_engine")
               else (", digest==per-event" if row.get("records_match")
                     else (", DIGEST MISMATCH"
                           if row.get("records_match") is False else ""))))
        rows.append(row)
    return rows


# ------------------------------------------------------------ million rung
MILLION_N_JOBS = 1_000_000
MILLION_TARGET_S = 60.0  # the "interactive replay" target (informational)


def bench_million(n_jobs: int = MILLION_N_JOBS, mechanism: str = "CUA&SPAA",
                  seed: int = 0, batch_rounds: float = 900.0) -> List[dict]:
    """The 1M-job multi-year rung: lazy trace source -> batched scheduling
    rounds -> streaming metrics sink, O(1) memory end to end.

    The workload is the full-year generator scaled up density-preserving
    (1M jobs is ~9 years of Theta-rate submissions at offered load
    1.05).  Wall clock is reported against the 60 s interactivity
    *target* — informational, not a gate: the floor is the intrinsic
    per-event cost (heap + ledger + sink), which batching cannot remove.
    """
    wl = year_workload(n_jobs, seed=seed)
    gen = ThetaGenerator(wl)
    cfg = SimConfig(n_nodes=N_NODES, mechanism=mechanism,
                    batch_rounds=batch_rounds)
    acc = StreamingMetrics(instant_eps=cfg.instant_eps)
    sim = Simulator(cfg, gen.iter_jobs(), record_sink=acc)
    t0 = time.perf_counter()
    sim.run()
    seconds = time.perf_counter() - t0
    m = acc.result(sim)
    row = {"name": f"million_{n_jobs}job_{wl.horizon_days:g}d",
           "n_jobs": n_jobs, "horizon_days": wl.horizon_days,
           "mechanism": mechanism, "seed": seed,
           "batch_rounds": batch_rounds, "mode": "stream",
           "seconds": round(seconds, 1),
           "us_per_job": round(seconds / n_jobs * 1e6, 2),
           "jobs_per_s": round(n_jobs / seconds),
           "n_completed": m.n_completed,
           "system_utilization": round(m.system_utilization, 4),
           "avg_turnaround_h": round(m.avg_turnaround_h, 3),
           "target_s": MILLION_TARGET_S,
           "within_target": bool(seconds <= MILLION_TARGET_S)}
    row["derived"] = (f"{row['seconds']}s ({row['us_per_job']}us/job, "
                      f"{row['jobs_per_s']} jobs/s) over "
                      f"{wl.horizon_days / 365.0:.1f} sim-years; target "
                      f"{MILLION_TARGET_S:.0f}s "
                      f"{'met' if row['within_target'] else 'missed'}")
    return [row]
