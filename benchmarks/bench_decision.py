"""Obs 10: scheduler decisions must be fast (paper: < 10 ms, ours: us).

Times the two decision kernels at full-system scale (Theta: 4392 nodes,
hundreds of running jobs) and the end-to-end arrival handling inside a
live simulation.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (SimConfig, Simulator, WorkloadConfig,
                        apportion_shrink, generate,
                        select_preemption_victims)


def bench_decision_kernels(n_running=500, reps=200) -> list:
    rng = np.random.default_rng(0)
    sizes = rng.integers(64, 2048, n_running)
    overheads = rng.uniform(0, 1e6, n_running)
    cur = rng.integers(64, 2048, n_running)
    mn = np.maximum(cur // 5, 1)
    rows = []
    for name, fn in [
        ("paa_select", lambda: select_preemption_victims(sizes, overheads, 3000)),
        ("spaa_apportion", lambda: apportion_shrink(cur, mn, 3000)),
    ]:
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": f"n_running={n_running}"})
    return rows


def bench_decision_e2e(seed=0) -> dict:
    """p99 of the full on-demand-arrival decision inside a simulation."""
    wcfg = WorkloadConfig(n_nodes=4392, n_jobs=600, horizon_days=21.0,
                          target_load=1.15, seed=seed)
    sim = Simulator(SimConfig(n_nodes=4392, mechanism="CUA&SPAA",
                              track_decision_time=True), generate(wcfg))
    sim.run()
    times = np.asarray(sim.decision_times) * 1e6
    return {"name": "od_arrival_decision", "us_per_call": round(float(np.mean(times)), 1),
            "derived": f"p99={np.percentile(times, 99):.0f}us n={len(times)} "
                       f"(paper bound: 10ms)"}
