"""Obs 10: scheduler decisions must be fast (paper: < 10 ms, ours: us).

Times the decision kernels at full-system scale (Theta: 4392 nodes,
hundreds of running jobs) — including the incremental engine's EASY
shadow-window and backfill-prefilter kernels at 50k-job-trace queue
depths — and the end-to-end arrival handling inside a live simulation.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (SimConfig, Simulator, WorkloadConfig,
                        apportion_shrink, backfill_prefilter,
                        backfill_shadow_filter, easy_shadow, generate,
                        select_preemption_victims)

DECISION_BOUND_US = 10_000.0  # paper Obs 10: every decision under 10 ms
# always full-system scale, independent of the harness --quick/--full mode
E2E_SEEDS = (0, 1, 2)
E2E_N_JOBS = 600


def bench_decision_kernels(n_running=500, queue_depth=100, reps=200) -> list:
    """Synthetic-kernel latencies.  ``n_running`` is deliberately ~10x a
    Theta steady state (so the shadow kernel row bounds a 50k-job trace's
    worst running set); ``queue_depth`` is the backfill window the
    prefilter scans per event regardless of total queue length."""
    rng = np.random.default_rng(0)
    sizes = rng.integers(64, 2048, n_running)
    overheads = rng.uniform(0, 1e6, n_running)
    cur = rng.integers(64, 2048, n_running)
    mn = np.maximum(cur // 5, 1)
    est_bases = rng.uniform(0.0, 1e6, n_running)
    needs = rng.integers(1, 4096, queue_depth).astype(np.float64)
    ests = rng.uniform(600.0, 86400.0, queue_depth)
    cand = np.arange(queue_depth)
    rows = []
    for name, scale, fn in [
        ("paa_select", f"n_running={n_running}",
         lambda: select_preemption_victims(sizes, overheads, 3000)),
        ("spaa_apportion", f"n_running={n_running}",
         lambda: apportion_shrink(cur, mn, 3000)),
        ("easy_shadow", f"n_running={n_running}",
         lambda: easy_shadow(64, 3000, est_bases, sizes, 5e5)),
        ("backfill_prefilter", f"queue_depth={queue_depth}",
         lambda: backfill_prefilter(needs, 512.0)),
        ("backfill_shadow_filter", f"queue_depth={queue_depth}",
         lambda: backfill_shadow_filter(needs, ests, cand, 64, 5e5, 5e5 + 7200.0)),
    ]:
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": scale})
    return rows


def bench_decision_e2e(seeds=E2E_SEEDS, repeats=2) -> dict:
    """p99 of the full on-demand-arrival decision inside a simulation.

    Pools arrivals from several seeded traces (~40 per trace) so the p99
    is not just the single-trace maximum, repeats the whole measurement
    and keeps the best repeat (each sample is a single wall-clock
    interval, so one descheduling stall on a loaded machine can poison a
    repeat's tail), and checks the p99 against the paper bound
    (`within_bound`); run.py treats a violated bound as a failure."""
    n = 0
    means, p99s = [], []
    for _ in range(repeats):
        samples = []
        for seed in seeds:
            wcfg = WorkloadConfig(n_nodes=4392, n_jobs=E2E_N_JOBS,
                                  horizon_days=21.0, target_load=1.15,
                                  seed=seed)
            sim = Simulator(SimConfig(n_nodes=4392, mechanism="CUA&SPAA",
                                      track_decision_time=True),
                            generate(wcfg))
            sim.run()
            samples.extend(sim.decision_times)
        times = np.asarray(samples) * 1e6
        n = len(times)
        means.append(float(np.mean(times)))
        p99s.append(float(np.percentile(times, 99)))
    p99 = min(p99s)
    return {"name": "od_arrival_decision",
            "us_per_call": round(min(means), 1),
            "p99_us": round(p99, 1),
            "bound_us": DECISION_BOUND_US,
            "within_bound": bool(p99 <= DECISION_BOUND_US),
            "derived": f"p99={p99:.0f}us n={n} best-of-{repeats} "
                       f"(paper bound: {DECISION_BOUND_US / 1000:.0f}ms)"}
