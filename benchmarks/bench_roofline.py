"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
  compute    = dot_flops_per_device / peak
  memory     = hbm_traffic_per_device / bw   (analytic; see below)
  collective = collective_bytes_per_device / link_bw
plus MODEL_FLOPS = 6ND (train) / 2·N_active·tokens (decode/prefill) and the
useful-compute ratio.

FLOPs and collective bytes come from the scan-aware HLO analysis (XLA's
cost_analysis counts while bodies once; see launch/hlo_analysis.py).  The
memory term is analytic — params + optimizer traffic + activation/cache
traffic — because per-op HBM bytes are not recoverable from the HLO text;
the compiled memory_analysis (peak residency) is reported alongside.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import SHAPES_BY_NAME

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def model_flops_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
        if cfg.remat == "full":
            flops *= 8.0 / 6.0            # recompute forward once
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        flops = 2.0 * n_active * shape.global_batch
    return flops / n_dev


def hbm_traffic_per_device(arch: str, shape_name: str, res: dict) -> float:
    """Analytic HBM bytes per device per step (lower bound)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mem = res.get("memory", {})
    arg_bytes = mem.get("argument_bytes", 0)
    if shape.kind == "train":
        # params read (fwd+bwd+remat) + fp32 opt m/v read+write + grads
        # arg_bytes ~ state per device (params + opt + ef)
        return 3.0 * arg_bytes + 2.0 * arg_bytes
    # serving: read params + read/write cache slice
    return arg_bytes + mem.get("output_bytes", 0)


def rows(multi_pod: bool = False) -> List[dict]:
    out = []
    tag = "2pod" if multi_pod else "1pod"
    n_dev = 512 if multi_pod else 256
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            path = os.path.join(RESULTS, f"{arch}.{shape}.{tag}.json")
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            if r["status"] == "skipped":
                out.append({"name": f"{arch}/{shape}", "status": "skipped",
                            "reason": r["reason"][:60]})
                continue
            if r["status"] != "ok":
                out.append({"name": f"{arch}/{shape}", "status": "ERROR",
                            "reason": r.get("error", "?")[:80]})
                continue
            sa = r.get("scan_aware", {})
            flops = sa.get("dot_flops", 0.0) + sa.get("conv_flops", 0.0)
            coll = sa.get("collective_bytes", 0.0)
            t_comp = flops / PEAK_FLOPS_BF16
            t_mem = hbm_traffic_per_device(arch, shape, r) / HBM_BW
            t_coll = coll / ICI_BW
            dom = max((t_comp, "compute"), (t_mem, "memory"),
                      (t_coll, "collective"))[1]
            mf = model_flops_per_device(arch, shape, n_dev)
            out.append({
                "name": f"{arch}/{shape}", "status": "ok",
                "t_compute_s": round(t_comp, 4),
                "t_memory_s": round(t_mem, 4),
                "t_collective_s": round(t_coll, 4),
                "bottleneck": dom,
                "model_flops_ratio": round(mf / flops, 3) if flops else None,
                "roofline_frac": round(
                    max(t_comp, t_mem, t_coll) and
                    t_comp / max(t_comp, t_mem, t_coll), 3),
                "peak_gb": round(r["memory"].get("peak_bytes", 0) / 1e9, 2)
                if isinstance(r.get("memory"), dict) else None,
            })
    return out
