"""Sweeps-on-device rung: a whole mechanism grid as ONE device program.

Runs an ``Experiment(device="jax")`` grid spanning every registered
mechanism (x notice mixes x seeds; >= 600 cells at the default tier),
captures each cell's decision stream, replays the entire grid as a
single jitted XLA call, and gates:

* ``parity_ok`` — every replayed decision equals the numpy engine's
  recorded output exactly (x64), per cell, job for job.  The numpy
  process-fan-out sweep stays the identity baseline: its metrics are
  the sweep's numbers, the device program must reproduce them.
* ``within_bound`` — steady-state device time per decision stays under
  ``DEVICE_US_PER_CALL_BOUND`` (generous: ~100x the measured CPU-backend
  steady state, so the gate catches structural regressions such as the
  grid fragmenting into per-cell programs, not machine noise).

The row also reports the host-side numpy replay time of the exact same
captured calls, so ``device_speedup`` isolates kernel-dispatch gains
from everything the simulator does around the kernels (see
docs/performance.md "When device dispatch wins").

Methodology follows bench_roofline.py: measured terms + analytic
context in one artifact row, provenance-stamped by run.py into
results/bench/device_sweep.json.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import decision as D
from repro.core.experiment import Experiment
from repro.core.policy import registered_mechanisms
from repro.core.workloads import WorkloadConfig

#: steady-state device time per replayed decision (CPU backend measures
#: ~0.4 us/call; the bound is deliberately loose — it exists to catch a
#: fragmented or retracing program, not scheduler jitter)
DEVICE_US_PER_CALL_BOUND = 40.0
#: calls captured per kernel per cell (bounded prefix; the parity gate
#: covers exactly the captured calls)
CAPTURE_LIMIT = 32


def _host_replay_s(cells, repeats: int = 3) -> float:
    """Re-execute every captured call through the numpy kernels (the
    process-fan-out baseline's per-call cost, minus simulator overhead)."""
    fns = {"easy_shadow": D.easy_shadow,
           "select_preemption_victims": D.select_preemption_victims,
           "apportion_shrink": D.apportion_shrink,
           "backfill_prefilter": D.backfill_prefilter,
           "backfill_shadow_filter": D.backfill_shadow_filter}
    import numpy as np
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _label, trace in cells:
            for kernel, calls in trace.calls.items():
                fn = fns[kernel]
                if kernel == "backfill_shadow_filter":
                    # the trace records the *gathered* needs/ests rows:
                    # replay with identity candidates (same work)
                    for (needs, ests, _cand, budget, now, ts), _o in calls:
                        fn(needs, ests, np.arange(len(needs)), budget,
                           now, ts)
                else:
                    for inputs, _out in calls:
                        fn(*inputs)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best or 0.0


def bench_device_sweep(quick: bool = False) -> List[dict]:
    """One row per grid tier; --quick runs the small CI grid only."""
    mechs = registered_mechanisms()
    if quick:
        mixes, seeds, n_jobs = ("W1", "W4"), range(4), 30
    else:
        # 13 mechanisms x 4 mixes x 12 seeds = 624 cells
        mixes, seeds, n_jobs = ("W1", "W2", "W4", "W5"), range(12), 40
    workloads = [WorkloadConfig(n_jobs=n_jobs, notice_mix=m) for m in mixes]
    exp = Experiment(mechanisms=mechs, workloads=workloads,
                     seeds=tuple(seeds), device="jax",
                     device_capture=CAPTURE_LIMIT)
    t0 = time.perf_counter()
    res = exp.run()
    sweep_s = time.perf_counter() - t0
    rep = res.device_report
    cells = [(f"{r.spec.mechanism}/s{r.spec.seed}", r.decision_trace)
             for r in res.runs if r.decision_trace is not None]
    host_s = _host_replay_s(cells)
    us = rep.device_us_per_call
    row = {"name": "device_sweep_quick" if quick else "device_sweep",
           "n_cells": rep.n_cells,
           "n_mechanisms": len(mechs),
           "n_jobs": n_jobs,
           "n_calls": rep.n_calls,
           "n_programs": rep.n_programs,
           "n_dropped": rep.n_dropped,
           "dtype": rep.dtype,
           "parity_ok": rep.parity_ok,
           "n_mismatches": rep.n_mismatches,
           "mismatch_sample": [repr(m) for m in rep.mismatches[:3]],
           "calls_per_kernel": rep.calls_per_kernel,
           "pad_per_kernel": rep.pad_per_kernel,
           "sweep_s": round(sweep_s, 3),
           "build_s": round(rep.build_s, 4),
           "compile_s": round(rep.compile_s, 4),
           "device_s": round(rep.device_s, 6),
           "host_replay_s": round(host_s, 4),
           "device_speedup": round(host_s / rep.device_s, 1)
           if rep.device_s > 0 else float("inf"),
           "us_per_call": round(us, 3),
           "bound_us": DEVICE_US_PER_CALL_BOUND,
           "within_bound": bool(us <= DEVICE_US_PER_CALL_BOUND),
           "derived": (f"cells={rep.n_cells},calls={rep.n_calls},"
                       f"parity={'ok' if rep.parity_ok else 'FAIL'},"
                       f"programs={rep.n_programs}")}
    return [row]
