"""Fault-tolerance benchmark + CI chaos gate.

Two row families, both hard gates in benchmarks/run.py:

* **kill/recover** — run the shadow service partway, abandon it
  mid-flight with a torn byte-tail on the decision log (what a SIGKILL
  leaves behind), recover from the rotated on-disk segments, finish,
  and require the concatenated decision stream's sha256 to equal an
  uninterrupted run's (`digest_match`).  Swept across mechanisms.
* **MTBF sweep** — simulate a fault-injected scenario cell twice per
  MTBF point and require job-for-job identical records
  (`deterministic`, via records_sha256); rows also carry goodput,
  lost work, and on-demand turnaround so the artifact shows how the
  hybrid mechanisms degrade as the machine gets flakier.

Rows land in results/bench/faults.json (the chaos-smoke CI artifact).
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List, Sequence

from repro.core import SimConfig, Simulator
from repro.core.metrics import collect, records_sha256
from repro.core.workloads import get_scenario
from repro.service import (SchedulerService, ServiceConfig, decision_digest,
                           read_decision_log)

MECHANISMS: Sequence[str] = ("CUA&SPAA", "CUP&STEAL")
#: node MTBF points swept (hours); mttr and horizon fixed per sweep
MTBF_SWEEP_H: Sequence[float] = (40.0, 160.0, 720.0)


def bench_kill_recover(n_jobs: int = 150, seed: int = 3,
                       kill_after: int = 25,
                       mechanisms: Sequence[str] = MECHANISMS) -> List[dict]:
    """Crash-recovery digest gate: partial run + torn tail -> recover ->
    finish == uninterrupted, per mechanism."""
    rows = []
    jobs, n_nodes = get_scenario("bursty-od", n_jobs=n_jobs).realize(seed)
    for mech in mechanisms:
        t0 = time.perf_counter()
        ref = SchedulerService(
            ServiceConfig(n_nodes=n_nodes, mechanism=mech), list(jobs))
        ref_digest = ref.run_replay().digest

        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "decisions.jsonl")
            cfg = ServiceConfig(n_nodes=n_nodes, mechanism=mech,
                                decision_log_path=path,
                                log_rotate_bytes=2048)
            crashed = SchedulerService(cfg, list(jobs))
            while crashed.core.n_decisions < kill_after:
                t = crashed.core.next_event_time()
                if t is None:
                    break
                crashed._step_batch(t)
            # simulate the SIGKILL aftermath: no close, half-written row
            with open(path, "a") as fh:
                fh.write('{"seq": -999, "event": "to')

            import warnings
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")   # torn-tail warning is the point
                svc, rec_report = SchedulerService.recover(cfg, list(jobs))
            rep = svc.run_replay()
            disk_digest = decision_digest(read_decision_log(path))

        ok = (rec_report.ok and rep.digest == ref_digest
              and disk_digest == ref_digest)
        rows.append({
            "name": f"faults_recover_{mech.replace('&', '_')}",
            "mechanism": mech, "n_jobs": len(jobs), "n_nodes": n_nodes,
            "kill_after": kill_after,
            "n_recovered": rec_report.n_decisions_recovered,
            "prefix_match": rec_report.digests_match,
            "digest_match": ok,
            "digest": rep.digest,
            "seconds": round(time.perf_counter() - t0, 4),
        })
    return rows


def bench_mtbf_sweep(n_jobs: int = 150, seed: int = 2,
                     mechanism: str = "CUA&SPAA",
                     mtbf_sweep_h: Sequence[float] = MTBF_SWEEP_H,
                     mttr_h: float = 2.0,
                     horizon_days: float = 5.0) -> List[dict]:
    """Determinism + degradation rows across node MTBF."""
    rows = []
    jobs, n_nodes = get_scenario("bursty-od", n_jobs=n_jobs).realize(seed)
    for mtbf_h in mtbf_sweep_h:
        spec = (f"exp-mtbf:mtbf_h={mtbf_h},mttr_h={mttr_h},"
                f"horizon_days={horizon_days}")
        cfg = SimConfig(n_nodes=n_nodes, mechanism=mechanism, faults=spec)
        t0 = time.perf_counter()
        sim = Simulator(cfg, list(jobs))
        recs = sim.run()
        wall = time.perf_counter() - t0
        sha1 = records_sha256(recs)
        sha2 = records_sha256(Simulator(cfg, list(jobs)).run())
        m = collect(sim)
        rows.append({
            "name": f"faults_mtbf_{mtbf_h:g}h",
            "mechanism": mechanism, "fault_spec": spec,
            "n_jobs": len(jobs), "n_nodes": n_nodes,
            "deterministic": sha1 == sha2,
            "records_sha256": sha1,
            "n_node_failures": m.n_node_failures,
            "n_interruptions": m.n_interruptions,
            "lost_work_node_h": round(m.lost_work_node_h, 3),
            "goodput": round(m.goodput, 4),
            "od_turnaround_h": round(m.avg_turnaround_od_h, 4),
            "seconds": round(wall, 4),
        })
    return rows


def bench_faults(n_jobs: int = 150, quick: bool = False) -> List[dict]:
    mechs = MECHANISMS[:1] if quick else MECHANISMS
    sweep = MTBF_SWEEP_H[:2] if quick else MTBF_SWEEP_H
    return (bench_kill_recover(n_jobs=n_jobs, mechanisms=mechs)
            + bench_mtbf_sweep(n_jobs=n_jobs, mtbf_sweep_h=sweep))
