"""Train step: grad + AdamW, with microbatch accumulation and optional
int8 gradient compression (error feedback) for the cross-pod reduction.

Distributed-optimization knobs (DESIGN.md §5):
  * microbatches > 1   — gradient accumulation via lax.scan (activation
    memory / pipeline-style overlap lever).
  * compress_grads     — simulate-able int8 quantized all-reduce with error
    feedback: quantize per-tensor, dequantize, residual kept in fp32 state.
    On real multi-host meshes the quantized tensor is what crosses the pod
    link (XLA reduces the int8->fp32 dequantized values; bytes recorded in
    the roofline as 1/4 of fp32).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import TrainBatch, loss_fn
from repro.models.config import ModelConfig
from .optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any = None          # error-feedback residuals (compression only)


def make_train_state(params, opt: AdamW, compress: bool = False) -> TrainState:
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if compress else None
    return TrainState(params=params, opt=opt.init(params), ef=ef)


def _quantize_int8(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    q = jnp.clip(jnp.round(g / amax * 127.0), -127, 127).astype(jnp.int8)
    return q, amax


def _dequantize_int8(q, amax):
    return q.astype(jnp.float32) * (amax / 127.0)


def make_train_step(cfg: ModelConfig, opt: AdamW, *,
                    microbatches: int = 1, compress_grads: bool = False,
                    grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    `grad_shardings` (optional NamedSharding pytree matching params) pins
    the accumulated-gradient layout so XLA's scan partitioner cannot drift
    into involuntary resharding inside the accumulation loop.
    """
    from repro.models import dist

    def grads_of(params, batch: TrainBatch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: TrainBatch):
        params = state.params
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                x = x.reshape(microbatches, b // microbatches, *x.shape[1:])
                # microbatch dim replicated; per-microbatch batch stays
                # sharded over pod x data
                return dist.constrain(x, None, "batch",
                                      *([None] * (x.ndim - 2)))
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                loss_a, grads_a = carry
                b = jax.tree.map(dist.constrain_batch, b)
                loss, metrics, grads = grads_of(params, b)
                grads = jax.tree.map(jnp.add, grads_a, grads)
                grads = dist.constrain_tree(grads, grad_shardings)
                return (loss_a + loss, grads), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            zeros = dist.constrain_tree(zeros, grad_shardings)
            (loss, grads), metrics = jax.lax.scan(
                acc_fn, (jnp.zeros(()), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        ef = state.ef
        if compress_grads:
            def comp(g, e):
                g = g.astype(jnp.float32) + e
                q, amax = _quantize_int8(g)
                gq = _dequantize_int8(q, amax)
                return gq, g - gq
            out = jax.tree.map(comp, grads, ef)
            two = lambda t: isinstance(t, tuple) and len(t) == 2
            grads = jax.tree.map(lambda t: t[0], out, is_leaf=two)
            ef = jax.tree.map(lambda t: t[1], out, is_leaf=two)

        new_params, new_opt, gnorm = opt.update(grads, state.opt, params)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm,
                       lr=opt.lr_at(new_opt.step))
        return TrainState(new_params, new_opt, ef), metrics

    return train_step
