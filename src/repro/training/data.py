"""Synthetic token pipeline: deterministic, shardable, infinite.

Produces TrainBatch streams per (arch config x shape); the generator is
seeded per (job id, step) so elastic restarts resume the exact stream —
a requirement for the scheduler's checkpoint/restart semantics.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import TrainBatch
from repro.models.config import ModelConfig, ShapeSpec


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, *,
                    seed: int = 0, step: int = 0,
                    np_rng: bool = True) -> TrainBatch:
    """One deterministic batch.  Markov-ish token stream (not uniform noise,
    so losses move during the examples' short trainings)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) % (2 ** 63))
    base = rng.integers(0, cfg.vocab, size=(batch, 1), dtype=np.int64)
    drift = rng.integers(-32, 33, size=(batch, seq + 1), dtype=np.int64)
    toks = np.abs(base + np.cumsum(drift, axis=1)) % cfg.vocab
    tokens = jnp.asarray(toks[:, :-1], jnp.int32)
    labels = jnp.asarray(toks[:, 1:], jnp.int32)
    extra = None
    if cfg.family == "vlm":
        e = rng.standard_normal((batch, cfg.n_patches, cfg.d_model)) * 0.02
        extra = jnp.asarray(e, jnp.float32)
    elif cfg.family == "audio":
        e = rng.standard_normal((batch, cfg.enc_len, cfg.d_model)) * 0.02
        extra = jnp.asarray(e, jnp.float32)
    return TrainBatch(tokens=tokens, labels=labels, extra=extra)


def stream(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
           start_step: int = 0) -> Iterator[TrainBatch]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, batch, seq, seed=seed, step=step)
        step += 1


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        extra = None
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.n_patches
            extra = sds((B, cfg.n_patches, cfg.d_model), f32)
        elif cfg.family == "audio":
            extra = sds((B, cfg.enc_len, cfg.d_model), f32)
        return TrainBatch(tokens=sds((B, s_text), i32),
                          labels=sds((B, s_text), i32), extra=extra)
    if shape.kind == "prefill":
        extra = None
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.n_patches
            extra = sds((B, cfg.n_patches, cfg.d_model), f32)
        elif cfg.family == "audio":
            extra = sds((B, cfg.enc_len, cfg.d_model), f32)
        return {"tokens": sds((B, s_text), i32), "extra": extra}
    # decode: one new token against a seq_len cache
    return {"tokens": sds((B, 1), i32)}
