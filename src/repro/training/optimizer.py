"""AdamW + schedules, pure-JAX pytree implementation (no optax).

Optimizer state dtype is fp32 regardless of param dtype (mixed precision);
update() is shape-polymorphic over the param pytree so the same code serves
every architecture and any sharding.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdamW(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0

    # -- schedule -------------------------------------------------------------
    def lr_at(self, step) -> jax.Array:
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(self.warmup, 1), 1.0)
        t = jnp.clip((step - self.warmup)
                     / jnp.maximum(self.total_steps - self.warmup, 1), 0, 1)
        cos = self.min_lr_frac + (1 - self.min_lr_frac) * 0.5 * \
            (1 + jnp.cos(jnp.pi * t))
        return self.lr * warm * cos

    # -- state ---------------------------------------------------------------
    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    # -- update ----------------------------------------------------------------
    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, jax.Array]:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.lr_at(step)
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            u = (m2 / c1) / (jnp.sqrt(v2 / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * u
            return p2.astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)
