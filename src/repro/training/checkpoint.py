"""Checkpoint save/restore for train state (fault tolerance substrate).

Sharded-friendly: each leaf is pulled to host as numpy and written into a
single .npz per step with a flattened key path; restore rebuilds the exact
pytree (using a template for structure) and can re-shard onto a *different*
mesh — this is what the elastic runtime uses for shrink/expand and what the
scheduler's preempt/resume relies on.

A lightweight manifest (latest.txt) gives atomic "latest checkpoint"
semantics: write npz -> fsync -> update manifest.
"""
from __future__ import annotations

import io
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip bf16
            flat[key + ".bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save(path: str, step: int, tree: Any) -> str:
    """Write `tree` to <path>/step_<n>.npz atomically; returns file path."""
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"step_{step:08d}.npz")
    flat = _flatten(tree)
    with tempfile.NamedTemporaryFile(dir=path, delete=False) as tmp:
        np.savez(tmp, **flat)
        tmp.flush()
        os.fsync(tmp.fileno())
        tmpname = tmp.name
    os.replace(tmpname, fname)
    manifest = os.path.join(path, "latest.txt")
    with tempfile.NamedTemporaryFile("w", dir=path, delete=False) as tmp:
        tmp.write(f"{step}\n{fname}\n")
        tmp.flush()
        os.fsync(tmp.fileno())
        tmpname = tmp.name
    os.replace(tmpname, manifest)
    return fname


def latest_step(path: str) -> Optional[int]:
    manifest = os.path.join(path, "latest.txt")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        return int(f.readline().strip())


def restore(path: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Rebuild the pytree of `template`'s structure from the checkpoint.

    With `shardings` (a matching pytree of NamedSharding), leaves are placed
    directly onto the (possibly different) mesh — elastic re-sharding.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    fname = os.path.join(path, f"step_{step:08d}.npz")
    data = np.load(fname)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    import ml_dtypes
    out = []
    for (pth, leaf), sh in zip(leaves_p, shard_leaves):
        key = "/".join(str(p) for p in pth)
        if key + ".bf16" in data:
            arr = np.asarray(data[key + ".bf16"]).view(ml_dtypes.bfloat16)
        else:
            arr = np.asarray(data[key])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
