"""Training substrate: optimizer, train step, data, checkpointing."""
from .optimizer import AdamW, AdamWState, global_norm
from .train_step import TrainState, make_train_state, make_train_step
from .data import input_specs, stream, synthetic_batch
from . import checkpoint

__all__ = ["AdamW", "AdamWState", "global_norm", "TrainState",
           "make_train_state", "make_train_step", "input_specs", "stream",
           "synthetic_batch", "checkpoint"]
