"""Deterministic node failure/repair models (the ``faults=`` axis).

A :class:`FaultModel` turns ``(n_nodes,)`` into a finite, sorted stream of
:class:`FaultEvent`\\ s — ``down``/``up`` pairs per node — that the
simulator injects into its event heap as first-class ``node_down`` /
``node_up`` events.  Models are string-keyed in a registry exactly like
policies and workloads, so ``faults="exp-mtbf:mtbf_h=168"`` works anywhere
a :class:`~repro.core.simulator.SimConfig`, a
:class:`~repro.core.workloads.base.Scenario`, or a campaign grid accepts
the knob.

Determinism contract (docs/faults.md):

* ``events(n_nodes)`` is a pure function of the model's parameters — each
  node draws from its own ``default_rng([seed, node, salt])`` stream, so
  the event list is independent of call order, platform, and n_jobs.
* The simulator consumes victim-selection draws from a single
  ``default_rng([seed, salt])`` stream in event order, so a (mechanism,
  scenario, seed, fault-spec) cell is job-for-job identical across runs.
* ``"none"`` produces no events and the simulator takes the legacy code
  path untouched — every golden digest stays bit-for-bit.

Specs are accepted in three forms, normalized by :func:`resolve_faults`:

* ``"none"`` / ``None`` — no faults.
* a compact string ``"<model>"`` or ``"<model>:k=v,k=v"`` (floats/ints
  parsed, everything else kept as a string) — the form campaign TOML and
  CLI flags use.
* a dict ``{"model": "<model>", ...params}`` — the programmatic form
  (the only way to pass ``events=`` inline to the ``trace`` model).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Union

__all__ = [
    "FaultEvent", "FaultModel", "NoFaults", "ExpMtbfFaults", "WeibullFaults",
    "TraceFaults", "UnknownFaultModelError", "register_fault_model",
    "get_fault_model", "registered_fault_models", "parse_fault_spec",
    "resolve_faults", "fault_spec_label",
]

FaultSpec = Union[None, str, Mapping[str, object]]


class UnknownFaultModelError(ValueError):
    """Raised for a fault spec naming no registered model."""


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One node transition; ``kind`` is ``"down"`` or ``"up"``.

    The dataclass order (t, node, kind) is the canonical sort: at equal
    times lower node ids fire first and ``down`` precedes ``up``.
    """

    t: float
    node: int
    kind: str


class FaultModel:
    """Base class: a named, parameterized failure/repair process.

    Subclasses implement :meth:`events` and set :attr:`name`.  ``seed``
    is the determinism anchor every stochastic model must honor; models
    without randomness (``trace``) ignore it.
    """

    name = "?"

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def events(self, n_nodes: int) -> List[FaultEvent]:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class NoFaults(FaultModel):
    """The default: a perfect machine, zero events, legacy code path."""

    name = "none"

    def events(self, n_nodes: int) -> List[FaultEvent]:
        return []


def _renewal_events(n_nodes: int, horizon_s: float, mttr_s: float,
                    seed: int, draw_ttf: Callable) -> List[FaultEvent]:
    """Per-node renewal process: alternate draw_ttf(rng) up-time with an
    exponential(mttr) repair, truncated at the horizon.  Each node owns an
    independent rng keyed (seed, node), so streams never interact."""
    import numpy as np

    out: List[FaultEvent] = []
    for node in range(n_nodes):
        rng = np.random.default_rng([seed, node, 0xFA17])
        t = 0.0
        while True:
            t += float(draw_ttf(rng))
            if t >= horizon_s:
                break
            out.append(FaultEvent(t, node, "down"))
            repair = float(rng.exponential(mttr_s))
            repair = max(repair, 1.0)  # zero-length outages are unobservable
            out.append(FaultEvent(t + repair, node, "up"))
            t += repair
    out.sort()
    return out


class ExpMtbfFaults(FaultModel):
    """Memoryless failures: per-node exponential time-to-failure with mean
    ``mtbf_h`` hours and exponential repair with mean ``mttr_h`` hours."""

    name = "exp-mtbf"

    def __init__(self, mtbf_h: float = 720.0, mttr_h: float = 4.0,
                 horizon_days: float = 30.0, seed: int = 0):
        super().__init__(seed)
        if mtbf_h <= 0 or mttr_h <= 0 or horizon_days <= 0:
            raise ValueError("exp-mtbf: mtbf_h, mttr_h, horizon_days must be > 0")
        self.mtbf_h = float(mtbf_h)
        self.mttr_h = float(mttr_h)
        self.horizon_days = float(horizon_days)

    def events(self, n_nodes: int) -> List[FaultEvent]:
        mtbf_s = self.mtbf_h * 3600.0
        return _renewal_events(n_nodes, self.horizon_days * 86400.0,
                               self.mttr_h * 3600.0, self.seed,
                               lambda rng: rng.exponential(mtbf_s))

    def describe(self) -> str:
        return f"exp-mtbf(mtbf={self.mtbf_h}h, mttr={self.mttr_h}h)"


class WeibullFaults(FaultModel):
    """Weibull time-to-failure (shape < 1 reproduces the infant-mortality
    burstiness HPC failure logs show) with exponential repair."""

    name = "weibull"

    def __init__(self, shape: float = 0.7, scale_h: float = 720.0,
                 mttr_h: float = 4.0, horizon_days: float = 30.0,
                 seed: int = 0):
        super().__init__(seed)
        if shape <= 0 or scale_h <= 0 or mttr_h <= 0 or horizon_days <= 0:
            raise ValueError("weibull: shape, scale_h, mttr_h, horizon_days must be > 0")
        self.shape = float(shape)
        self.scale_h = float(scale_h)
        self.mttr_h = float(mttr_h)
        self.horizon_days = float(horizon_days)

    def events(self, n_nodes: int) -> List[FaultEvent]:
        scale_s = self.scale_h * 3600.0
        return _renewal_events(n_nodes, self.horizon_days * 86400.0,
                               self.mttr_h * 3600.0, self.seed,
                               lambda rng: scale_s * rng.weibull(self.shape))

    def describe(self) -> str:
        return f"weibull(k={self.shape}, scale={self.scale_h}h, mttr={self.mttr_h}h)"


class TraceFaults(FaultModel):
    """Replay a recorded failure log: either ``path`` to a JSONL file of
    ``{"t":..., "node":..., "kind":"down"|"up"}`` rows (or ``t,node,kind``
    CSV lines), or an inline ``events`` list of (t, node, kind) triples."""

    name = "trace"

    def __init__(self, path: Optional[str] = None,
                 events: Optional[Iterable] = None, seed: int = 0):
        super().__init__(seed)
        if (path is None) == (events is None):
            raise ValueError("trace: exactly one of path= / events= required")
        self.path = path
        self._events = None if events is None else [
            self._coerce(e) for e in events]

    @staticmethod
    def _coerce(e) -> FaultEvent:
        if isinstance(e, FaultEvent):
            ev = e
        elif isinstance(e, Mapping):
            ev = FaultEvent(float(e["t"]), int(e["node"]), str(e["kind"]))
        else:
            t, node, kind = e
            ev = FaultEvent(float(t), int(node), str(kind))
        if ev.kind not in ("down", "up"):
            raise ValueError(f"fault trace: bad kind {ev.kind!r} (want down|up)")
        if ev.t < 0:
            raise ValueError(f"fault trace: negative time {ev.t}")
        return ev

    def _load(self) -> List[FaultEvent]:
        out: List[FaultEvent] = []
        with open(self.path, "r", encoding="utf-8") as fh:
            for ln, line in enumerate(fh, 1):
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                try:
                    if line.startswith("{"):
                        out.append(self._coerce(json.loads(line)))
                    else:
                        out.append(self._coerce(line.split(",")))
                except (ValueError, KeyError, json.JSONDecodeError) as exc:
                    raise ValueError(
                        f"fault trace {self.path}:{ln}: {exc}") from exc
        return out

    def events(self, n_nodes: int) -> List[FaultEvent]:
        evs = list(self._events) if self._events is not None else self._load()
        evs.sort()
        return evs

    def describe(self) -> str:
        return f"trace({self.path or 'inline'})"


# ----------------------------------------------------------------- registry
_FAULT_MODELS: Dict[str, Callable[..., FaultModel]] = {}


def register_fault_model(name: str, factory: Callable[..., FaultModel]) -> None:
    """Register a fault-model factory under a string key (last wins,
    matching the policy/workload registries)."""
    _FAULT_MODELS[name] = factory


def get_fault_model(name: str) -> Callable[..., FaultModel]:
    try:
        return _FAULT_MODELS[name]
    except KeyError:
        raise UnknownFaultModelError(
            f"unknown fault model {name!r}; registered: "
            f"{sorted(_FAULT_MODELS)}") from None


def registered_fault_models() -> List[str]:
    return sorted(_FAULT_MODELS)


register_fault_model("none", NoFaults)
register_fault_model("exp-mtbf", ExpMtbfFaults)
register_fault_model("weibull", WeibullFaults)
register_fault_model("trace", TraceFaults)


def _parse_value(v: str) -> object:
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def parse_fault_spec(spec: str) -> Dict[str, object]:
    """``"exp-mtbf:mtbf_h=168,mttr_h=2"`` -> ``{"model": "exp-mtbf",
    "mtbf_h": 168, "mttr_h": 2}``."""
    name, _, rest = spec.partition(":")
    params: Dict[str, object] = {"model": name.strip()}
    if rest.strip():
        for pair in rest.split(","):
            k, eq, v = pair.partition("=")
            if not eq:
                raise ValueError(
                    f"fault spec {spec!r}: expected k=v, got {pair!r}")
            params[k.strip()] = _parse_value(v.strip())
    return params


def resolve_faults(spec: FaultSpec) -> FaultModel:
    """Normalize any accepted spec form into a constructed FaultModel.

    Raises :class:`UnknownFaultModelError` for unregistered names and
    ``ValueError``/``TypeError`` for bad parameters — both before any
    simulation starts, which is what lets campaign spec validation fail
    fast on a typo'd axis value.
    """
    if spec is None:
        return NoFaults()
    if isinstance(spec, FaultModel):
        return spec
    if isinstance(spec, str):
        params = parse_fault_spec(spec)
    elif isinstance(spec, Mapping):
        params = dict(spec)
        if "model" not in params:
            raise ValueError(f"fault spec dict needs a 'model' key: {spec!r}")
    else:
        raise TypeError(f"unsupported fault spec type: {type(spec).__name__}")
    name = str(params.pop("model"))
    factory = get_fault_model(name)
    try:
        return factory(**params)
    except TypeError as exc:
        raise ValueError(f"fault model {name!r}: {exc}") from exc


def fault_spec_label(spec: FaultSpec) -> str:
    """A short deterministic label for cell names and regime keys."""
    if spec is None:
        return "none"
    if isinstance(spec, str):
        return spec
    if isinstance(spec, Mapping):
        name = spec.get("model", "?")
        rest = ",".join(f"{k}={spec[k]}" for k in sorted(spec) if k != "model")
        return f"{name}:{rest}" if rest else str(name)
    return getattr(spec, "name", str(spec))
