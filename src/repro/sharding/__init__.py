from .rules import (batch_axes, batch_sharding, cache_shardings, dp_axes,
                    param_spec, tree_shardings)

__all__ = ["batch_axes", "batch_sharding", "cache_shardings", "dp_axes",
           "param_spec", "tree_shardings"]
