"""PartitionSpec rules: params, optimizer state, batches, caches.

Layout (DESIGN.md §5):
  * `model` axis: TP for attention heads / FFN hidden / vocab; EP for MoE
    experts; sequence dim of KV caches when heads cannot shard.
  * `data` (x `pod`) axes: batch; with cfg.fsdp also the largest weight dim
    (ZeRO-3-like; XLA all-gathers per scan step).

Rules are name-based over flattened tree paths and divisibility-checked:
a dim is only sharded if its size divides the axis size (so reduced smoke
configs fall back to replication automatically).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = _axsize(mesh, axes)
    return n > 1 and dim % n == 0


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_axes(cfg: ModelConfig, mesh: Mesh) -> Tuple[str, ...]:
    """Axes the batch (and fsdp weights) shard over.  layout="fsdp" folds
    the model axis into data parallelism (pure ZeRO-3, no TP)."""
    ax = batch_axes(mesh)
    if cfg.layout == "fsdp" and "model" in mesh.axis_names:
        ax = ax + ("model",)
    return ax


# ------------------------------------------------------------------ params
_RULES = [
    # pattern over the joined path           -> dims spec builder
    (r"embed/(tok|unembed)$", lambda d: ("model", "fsdp")),
    (r"patch_proj$", lambda d: ("fsdp", None)),
    (r"(attn|xattn)/wq$", lambda d: ("fsdp", "model", None)),
    (r"(attn|xattn)/w(k|v)$", lambda d: ("fsdp", "model", None)),
    (r"(attn|xattn)/wo$", lambda d: ("model", None, "fsdp")),
    (r"attn/wq_a$", lambda d: ("fsdp", None)),
    (r"attn/wq_b$", lambda d: (None, "model", None)),
    (r"attn/wkv_a$", lambda d: ("fsdp", None)),
    (r"attn/wk_rope$", lambda d: ("fsdp", None)),
    (r"attn/wkv_b$", lambda d: (None, "model", None)),
    (r"ffn/w_(gate|up)$", lambda d: ("fsdp", "model")),
    (r"ffn/w_down$", lambda d: ("model", "fsdp")),
    (r"moe/router$", lambda d: (None, None)),
    (r"moe/w[13]$", lambda d: ("model", "fsdp", None)),
    (r"moe/w2$", lambda d: ("model", None, "fsdp")),
    (r"moe/shared/w_(gate|up)$", lambda d: ("fsdp", "model")),
    (r"moe/shared/w_down$", lambda d: ("model", "fsdp")),
    # mamba: Megatron-style channel/head TP over `model`
    (r"w_(x|z)$", lambda d: (None, "model")),
    (r"w_dt$", lambda d: (None, "model")),
    (r"w_bc$", lambda d: (None, None)),
    (r"conv_x_[wb]$", lambda d: (None, "model")[:d]),
    (r"(a_log|d_skip|dt_bias)$", lambda d: ("model",)),
    (r"mamba.*norm$|layers/norm$", lambda d: ("model",)),
    (r"w_out$", lambda d: ("model", None)),
    (r"w_(up|down|q|k|v|if|x|ff1|ff2)$", lambda d: ("fsdp", None)[:d] + (None,) * max(0, d - 2)),
]


def param_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, stacked) -> P:
    n_stack = int(stacked)
    dims: Optional[Tuple] = None
    for pat, builder in _RULES:
        if re.search(pat, path):
            dims = builder(len(shape) - n_stack)
            break
    if dims is None:
        dims = (None,) * (len(shape) - n_stack)
    body = shape[n_stack:]
    spec = []
    pure_fsdp = cfg.layout == "fsdp"
    fsdp_ax = dp_axes(cfg, mesh) if (cfg.fsdp or pure_fsdp) else None
    for size, want in zip(body, tuple(dims) + (None,) * (len(body) - len(dims))):
        ax = None
        if pure_fsdp and want == "model":
            want = "fsdp" if "fsdp" not in dims else None
        if want == "model" and _fits(size, mesh, "model"):
            ax = "model"
        elif want == "fsdp" and fsdp_ax and _fits(size, mesh, fsdp_ax):
            ax = fsdp_ax if len(fsdp_ax) > 1 else fsdp_ax[0]
        spec.append(ax)
    spec = [None] * n_stack + spec
    return P(*spec)


def _is_layer_path(path: str) -> bool:
    return bool(re.search(r"(^|/)((pre_)?layers|enc_layers|slstm|mlstm)(/|$)", path))


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def path_str(path) -> str:
    return "/".join(_key_str(p) for p in path)


def tree_shardings(tree, cfg: ModelConfig, mesh: Mesh):
    """NamedSharding pytree matching `tree` (params / full train state)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = path_str(path)
        stacked = _is_layer_path(pstr)
        if re.search(r"(^|/)mlstm(/|$)", pstr):
            stacked = 2          # (n_groups, n_m, ...) double stack
        spec = param_spec(pstr, leaf.shape, cfg, mesh, stacked)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------- batches
def batch_sharding(tree, mesh: Mesh, axes: Optional[Tuple[str, ...]] = None):
    """Shard dim 0 (global batch) over the dp axes; replicate the rest."""
    ba = axes or batch_axes(mesh)
    ax = ba if len(ba) > 1 else ba[0]

    def spec(leaf):
        if leaf is None:
            return None
        if leaf.shape and _fits(leaf.shape[0], mesh, ba):
            return NamedSharding(mesh, P(ax, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(spec, tree)


# ------------------------------------------------------------------ caches
def cache_shardings(tree, cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    """KV caches: batch over pod x data; heads over model when divisible,
    else the sequence dim goes to model (ring-ish decode).  Recurrent
    states shard their head dim over model when possible."""
    ba = batch_axes(mesh)
    bax = ba if len(ba) > 1 else ba[0]

    def spec(leaf):
        shp = leaf.shape
        dims = [None] * len(shp)
        if len(shp) >= 4 and shp[-3] == shape.seq_len or \
                (len(shp) >= 3 and shp[-2] == shape.seq_len):
            # attention cache: (L?, B, S, K, Dh) or (L?, B, S, C)
            off = 1 if shp[0] not in (shape.global_batch,) else 0
            b_i = off
            s_i = off + 1
            if _fits(shp[b_i], mesh, ba):
                dims[b_i] = bax
            k_i = s_i + 1 if len(shp) > s_i + 1 else None
            if k_i is not None and len(shp) >= s_i + 3 and \
                    _fits(shp[k_i], mesh, "model"):
                dims[k_i] = "model"
            elif _fits(shp[s_i], mesh, "model"):
                dims[s_i] = "model"
            if dims[b_i] is None and shp[b_i] == 1 and _fits(shp[s_i], mesh, ba) \
                    and dims[s_i] == "model":
                dims[s_i] = None
                if _fits(shp[s_i], mesh, ba + ("model",)):
                    dims[s_i] = ba + ("model",)
        else:
            # recurrent state: shard batch, then heads over model
            for i, d in enumerate(shp):
                if dims.count(bax) == 0 and _fits(d, mesh, ba) and \
                        d == shape.global_batch:
                    dims[i] = bax
                    break
            for i, d in enumerate(shp):
                if dims[i] is None and _fits(d, mesh, "model"):
                    dims[i] = "model"
                    break
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map(spec, tree)
