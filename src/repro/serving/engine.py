"""Batched serving engine for on-demand jobs.

Prefill + greedy decode with a fixed-capacity KV cache and simple
continuous batching: requests are grouped into a padded batch, prefilled
once, then decoded step-by-step; finished sequences are masked out.  This
is the execution payload of the paper's *on-demand* job class.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclass
class Request:
    """One inference request.

    ``submitted_at`` / ``first_token_at`` / ``done_at`` are monotonic
    timestamps (``time.monotonic``): they exist to be subtracted — TTFB,
    decode time, SLO accounting — and must not jump with wall-clock
    adjustments.  ``submitted_wall`` is the one wall-clock stamp, kept
    for human-readable logs; never diff it against the monotonic fields.
    """

    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    submitted_at: float = field(default_factory=time.monotonic)
    submitted_wall: float = field(default_factory=time.time)
    tokens_out: List[int] = field(default_factory=list)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


class ServeEngine:
    """Greedy batched decoding over a fixed max_seq cache."""

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 512,
                 eos_id: Optional[int] = None, donate_cache: bool = True):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "ServeEngine drives attention-family LMs; recurrent archs "
                "serve via decode_step directly")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, t: prefill(p, t, cfg))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg),
            donate_argnums=(1,) if donate_cache else ())

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        """Run a padded batch of requests to completion."""
        B = len(requests)
        lens = [len(r.prompt) for r in requests]
        S = max(lens)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - lens[i]:] = r.prompt    # left-pad to align last token
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # grow cache to max_seq
        cache = jax.tree.map(
            lambda c: _grow(c, self.max_seq), cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        live = np.ones((B,), bool)
        n_steps = max(r.max_new_tokens for r in requests)
        now = time.monotonic()
        for i, r in enumerate(requests):
            r.first_token_at = now
            r.tokens_out.append(int(next_tok[i]))
        for step in range(1, n_steps):
            pos = S + step - 1
            if pos >= self.max_seq:
                break
            logits, cache = self._decode(self.params, cache,
                                         next_tok[:, None], pos)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks_np = np.asarray(next_tok)
            for i, r in enumerate(requests):
                if not live[i]:
                    continue
                r.tokens_out.append(int(toks_np[i]))
                if len(r.tokens_out) >= r.max_new_tokens or \
                        (self.eos_id is not None and toks_np[i] == self.eos_id):
                    live[i] = False
                    r.done_at = time.monotonic()
            if not live.any():
                break
        now = time.monotonic()
        for r in requests:
            r.done_at = r.done_at or now
        return requests


def _grow(c, max_seq: int):
    """Pad a prefill-sized cache array out to max_seq on its seq axis."""
    # attention caches have the seq axis at -3 (L,B,S,K,D) or -2 (L,B,S,C)
    for ax in (-3, -2):
        if c.ndim >= abs(ax) and c.shape[ax] not in (0,) and \
                c.ndim >= 3 and c.shape[ax] < max_seq and _looks_seq(c, ax):
            pad = [(0, 0)] * c.ndim
            pad[ax] = (0, max_seq - c.shape[ax])
            return jnp.pad(c, pad)
    return c


def _looks_seq(c, ax: int) -> bool:
    # heuristic: the seq axis is the largest axis of an attention cache
    return c.shape[ax] == max(c.shape)
