"""Straggler detection (large-scale runnability substrate).

Per-step wall times feed an exponential moving average + deviation; a
step slower than `threshold` x the EMA flags a straggler event.  The
mitigation hook is pluggable: at cluster scale the scheduler treats a
persistent straggler like a failing node (checkpoint + restart elsewhere,
which the ElasticJob ops already implement); in-process we record and
expose the events so the cluster runtime / tests can assert on them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 3.0
    warmup: int = 3
    ema: Optional[float] = None
    n: int = 0
    events: List[dict] = field(default_factory=list)
    on_straggler: Optional[Callable[[dict], None]] = None

    def observe(self, step_time: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = step_time
            return False
        is_straggler = (self.n > self.warmup
                        and step_time > self.threshold * self.ema)
        if is_straggler:
            ev = {"step": self.n, "time": step_time, "ema": self.ema}
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        else:
            # stragglers do not poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * step_time
        return is_straggler
