"""repro.runtime — elastic execution on real devices.

`LiveCluster`/`LiveJobInfo` import lazily without jax (PEP 562): the
scheduling layer is plain Python over the policy registry, so shadow
tests and the service package use it on CPU-only CI.  `ElasticJob` and
`StragglerMonitor` pull in jax on first access.
"""
from .cluster import LiveCluster, LiveJobInfo

__all__ = ["ElasticJob", "StragglerMonitor", "LiveCluster", "LiveJobInfo"]

_LAZY = {"ElasticJob": "elastic", "StragglerMonitor": "straggler"}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
