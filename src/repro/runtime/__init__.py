from .elastic import ElasticJob
from .straggler import StragglerMonitor
from .cluster import LiveCluster, LiveJobInfo

__all__ = ["ElasticJob", "StragglerMonitor", "LiveCluster", "LiveJobInfo"]
