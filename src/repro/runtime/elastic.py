"""Elastic job runtime: the execution half of the paper's job classes.

An ElasticJob owns a training job's full state and implements the five
operations the scheduler issues (paper §I: "start, preemption, shrink,
expansion" + resume):

  start(devices)        jit + (init | restore) onto a mesh over `devices`
  step(batch?)          one train step (auto data pipeline)
  preempt(warning)      malleable: 2-min-warning checkpoint at the exact
                        step; rigid: fall back to the last periodic ckpt
  shrink/expand(devs)   re-shard the *live* train state onto a different
                        mesh (checkpoint-free elastic resize)
  resume(devices)       start() from the persisted checkpoint

Re-sharding uses jax.device_put with the new mesh's NamedShardings — the
runtime-measured cost of the paper's "negligible" malleable resize
assumption (recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models import init_params, set_mesh
from repro.models.config import ModelConfig
from repro.sharding import batch_axes, tree_shardings
from repro.training import (AdamW, checkpoint, make_train_state,
                            make_train_step, synthetic_batch)
from .straggler import StragglerMonitor


class ElasticJob:
    def __init__(self, jid: int, cfg: ModelConfig, *, kind: str = "malleable",
                 batch: int = 8, seq: int = 128, opt: Optional[AdamW] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 seed: int = 0):
        assert kind in ("rigid", "malleable")
        self.jid = jid
        self.cfg = cfg
        self.kind = kind
        self.batch = batch
        self.seq = seq
        self.opt = opt or AdamW(warmup=10, total_steps=10_000)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.step_idx = 0
        self.state = None
        self.mesh: Optional[Mesh] = None
        self.devices: Sequence = ()
        self.monitor = StragglerMonitor()
        self.resize_costs: List[float] = []
        self._step_fn = None

    # ------------------------------------------------------------------ mesh
    def _build(self, devices: Sequence) -> Mesh:
        n = len(devices)
        mesh = Mesh(np.asarray(devices).reshape(n, 1), ("data", "model"))
        return mesh

    def _jit(self):
        set_mesh(self.mesh, batch_axes(self.mesh))
        shardings = None
        step = make_train_step(self.cfg, self.opt)
        self._step_fn = jax.jit(step, donate_argnums=(0,))

    # ----------------------------------------------------------------- start
    def start(self, devices: Sequence) -> None:
        self.devices = list(devices)
        self.mesh = self._build(self.devices)
        self._jit()
        if self.state is None:
            with self.mesh:
                params = init_params(jax.random.PRNGKey(self.seed), self.cfg)
                self.state = make_train_state(params, self.opt)
        else:
            self._reshard()

    def resume(self, devices: Sequence) -> None:
        assert self.ckpt_dir is not None
        self.devices = list(devices)
        self.mesh = self._build(self.devices)
        self._jit()
        template = self.state
        if template is None:
            with self.mesh:
                params = init_params(jax.random.PRNGKey(self.seed), self.cfg)
                template = make_train_state(params, self.opt)
        self.state = checkpoint.restore(self.ckpt_dir, template)
        self.step_idx = checkpoint.latest_step(self.ckpt_dir)
        self._reshard()

    # ------------------------------------------------------------------ step
    def step(self) -> dict:
        t0 = time.perf_counter()
        batch = synthetic_batch(self.cfg, self.batch, self.seq,
                                seed=self.seed, step=self.step_idx)
        # tracing happens on the first call after (re)jit: the sharding-
        # constraint mesh context must be THIS job's mesh at that moment
        set_mesh(self.mesh, batch_axes(self.mesh))
        with self.mesh:
            self.state, metrics = self._step_fn(self.state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        self.step_idx += 1
        self.monitor.observe(time.perf_counter() - t0)
        if self.ckpt_dir and self.step_idx % self.ckpt_every == 0:
            self.checkpoint()
        return metrics

    def checkpoint(self) -> None:
        assert self.ckpt_dir is not None
        checkpoint.save(self.ckpt_dir, self.step_idx, self.state)

    # -------------------------------------------------------------- preempt
    def preempt(self, warning: bool = True) -> None:
        """warning=True is the 2-minute-warning path (malleable): snapshot
        the exact current step.  Rigid jobs lose work since the last
        periodic checkpoint (paper §III-A)."""
        if self.ckpt_dir is not None and (warning or self.kind == "malleable"):
            self.checkpoint()
        self.mesh = None
        self._step_fn = None
        self.devices = ()

    # -------------------------------------------------------- shrink/expand
    def resize(self, devices: Sequence) -> float:
        """Checkpoint-free elastic resize onto a new device set.  Returns
        the wall-clock resharding cost in seconds."""
        t0 = time.perf_counter()
        self.devices = list(devices)
        self.mesh = self._build(self.devices)
        self._jit()
        self._reshard()
        dt = time.perf_counter() - t0
        self.resize_costs.append(dt)
        return dt

    def _reshard(self) -> None:
        sh = tree_shardings(self.state, self.cfg, self.mesh)
        # batch-dim arrays in the state are only params/opt (no batch): the
        # rules give everything a valid spec on the new mesh.
        self.state = jax.device_put(self.state, sh)
