"""LiveCluster: the paper's mechanisms driving REAL JAX jobs.

Where `repro.core.Simulator` advances a clock over a trace, LiveCluster
applies the same *registered* policies to actual ElasticJobs training on
actual devices, and serves actual on-demand inference on the nodes it
vacates.  This is the integration point that makes the paper's scheduler
a first-class feature of the framework rather than a standalone
simulator.

Policies are resolved from the `repro.core.policy` registry by name —
any registered :class:`~repro.core.policy.ArrivalPolicy` (SPAA, PAA,
STEAL, POOL, or a user-registered one) decides which running jobs shed
nodes when on-demand demand arrives, and any
:class:`~repro.core.policy.ElasticityPolicy` (NONE, BALANCE) decides how
malleables expand back into spare nodes.  The policies act through a
duck-typed adapter (:class:`_LiveOps`) exposing the SchedulerOps subset
they consult, so the identical policy code drives both the simulator's
node ledger and this cluster's real device lists.  An unknown name
raises :class:`~repro.core.policy.UnknownPolicyError` at construction.

Node = one jax device (the demo runs on host platform devices; on a real
cluster a node is a chip group and the device lists come from the
launcher).  Event-log timestamps are monotonic seconds since cluster
construction (never wall clock — they feed latency summaries);
``started_wall`` keeps the single wall-clock anchor for humans.

This module imports nothing from jax: `ElasticJob` is a type-only
import, so shadow-mode tests drive LiveCluster with duck-typed fakes on
CPU-only CI (tests/test_live_cluster.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

from repro.core.job import JobType
from repro.core.policy import ArrivalPolicy, ElasticityPolicy, get_policy

if TYPE_CHECKING:  # jax-free at runtime
    from .elastic import ElasticJob

_KIND_TO_JTYPE = {"rigid": JobType.RIGID, "malleable": JobType.MALLEABLE}


@dataclass
class LiveJobInfo:
    job: "ElasticJob"
    min_nodes: int
    max_nodes: int
    node_ids: List[int] = field(default_factory=list)
    status: str = "waiting"       # waiting|running|preempted|done
    steps_done: int = 0
    target_steps: int = 100
    preempt_count: int = 0
    shrink_count: int = 0


class _LiveSpec:
    """The JobSpec fields policies consult, projected from live state."""

    __slots__ = ("jid", "jtype", "n_min", "n_max", "size", "t_setup")

    def __init__(self, jid: int, jtype: JobType, n_min: int, n_max: int,
                 t_setup: float = 0.0):
        self.jid = jid
        self.jtype = jtype
        self.n_min = n_min
        self.n_max = n_max
        self.size = n_max
        self.t_setup = t_setup


class _LiveRunState:
    """RunState facade over a running :class:`LiveJobInfo`."""

    __slots__ = ("info", "job", "borrowed")

    def __init__(self, info: LiveJobInfo):
        self.info = info
        self.job = _LiveSpec(info.job.jid, _KIND_TO_JTYPE[info.job.kind],
                             info.min_nodes, info.max_nodes)
        self.borrowed: Dict[int, int] = {}   # live jobs never backfill

    @property
    def cur_size(self) -> int:
        return len(self.info.node_ids)

    def preemption_overhead(self, now: float) -> float:
        """Steps lost since the last periodic checkpoint, node-weighted
        (rigid), plus the restart cost proxy — the live analogue of the
        simulator's node-second overhead that PAA sorts victims by."""
        info = self.info
        n = len(info.node_ids)
        lost = (info.steps_done % info.job.ckpt_every) \
            if info.job.kind == "rigid" else 0
        return lost * n + n


class _LiveOps:
    """Duck-typed SchedulerOps subset adapting registered arrival and
    elasticity policies onto LiveCluster state.

    The mutators move *real node ids*: ``preempt``/``shrink`` push the
    vacated ids into the pending on-demand reservation, ``start_od``
    hands the reservation (topped up from the free pool) to the
    acquisition in progress, and the expand hooks grow running jobs out
    of a released-node pool or the free pool.  One adapter is built per
    policy invocation — live clusters run tens of jobs, not thousands.
    """

    def __init__(self, cluster: "LiveCluster", od_jid: int = -1,
                 od_size: int = 0, pool: Optional[List[int]] = None):
        self.cluster = cluster
        self._od_jid = od_jid
        self._pool = pool if pool is not None else []
        self._reserved: List[int] = []
        self.acquired: Optional[List[int]] = None
        self.jobs: Dict[int, _LiveSpec] = {
            od_jid: _LiveSpec(od_jid, JobType.ONDEMAND, od_size, od_size)}
        self.running: Dict[int, _LiveRunState] = {}
        for jid, info in cluster.jobs.items():
            if info.status == "running":
                rs = _LiveRunState(info)
                self.running[jid] = rs
                self.jobs[jid] = rs.job

    # ------------------------------------------------------------------ views
    @property
    def now(self) -> float:
        return self.cluster.elapsed()

    @property
    def free(self) -> int:
        return len(self.cluster.free)

    @property
    def queue(self) -> List[int]:
        return [jid for jid, info in self.cluster.jobs.items()
                if info.status in ("waiting", "preempted")]

    def reserved_of(self, jid: int) -> int:
        return len(self._reserved) if jid == self._od_jid else 0

    # --------------------------------------------------------------- mutators
    def preempt(self, rid: int, beneficiary: Optional[int] = None) -> None:
        self._reserved += self.cluster._preempt(rid)

    def shrink(self, rid: int, k: int, od: int) -> None:
        self._reserved += self.cluster._shrink(rid, k)

    def start_od(self, jid: int) -> None:
        total = self.jobs[jid].size
        take = min(len(self._reserved), total)
        ids, surplus = self._reserved[:take], self._reserved[take:]
        self.cluster.free.extend(surplus)     # over-vacated: back to the pool
        self._reserved = []
        ids += [self.cluster.free.pop() for _ in range(total - take)]
        self.acquired = ids

    def expand_occupied(self, rid: int, k: int) -> None:
        k = min(k, len(self._pool))
        if k > 0:
            self.cluster._expand(rid, [self._pool.pop() for _ in range(k)])

    def expand_from_free(self, rid: int, k: int) -> int:
        info = self.cluster.jobs[rid]
        k = min(k, len(self.cluster.free),
                info.max_nodes - len(info.node_ids))
        if k <= 0:
            return 0
        self.cluster._expand(rid, [self.cluster.free.pop()
                                   for _ in range(k)])
        return k


class LiveCluster:
    """A pool of device-backed nodes scheduled by registry policies.

    ``arrival_policy`` / ``elasticity_policy`` name registered policies;
    ``elasticity_policy=None`` pairs the arrival policy's preferred
    elasticity exactly as ``resolve_mechanism`` does (SPAA/PAA -> NONE,
    STEAL/POOL -> BALANCE), so the demo default (SPAA) behaves as it
    always has.
    """

    def __init__(self, devices: Sequence, arrival_policy: str = "SPAA",
                 elasticity_policy: Optional[str] = None):
        self.devices = list(devices)
        self.free: List[int] = list(range(len(self.devices)))
        self.jobs: Dict[int, LiveJobInfo] = {}
        arrival = get_policy("arrival", arrival_policy)
        assert isinstance(arrival, ArrivalPolicy)
        if elasticity_policy is None:
            elasticity_policy = arrival.preferred_elasticity
        elasticity = get_policy("elasticity", elasticity_policy)
        assert isinstance(elasticity, ElasticityPolicy)
        self.arrival = arrival
        self.elasticity = elasticity
        self._lease_book: Dict[int, int] = {}   # lender jid -> nodes owed
        self._od_count = 0
        self.log: List[dict] = []
        self.started_wall = time.time()         # the one wall-clock anchor
        self._t0 = time.monotonic()

    @property
    def arrival_policy(self) -> str:
        return self.arrival.name

    @property
    def elasticity_policy(self) -> str:
        return self.elasticity.name

    def elapsed(self) -> float:
        """Monotonic seconds since construction (the event-log clock)."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------------- lifecycle
    def submit(self, job: "ElasticJob", *, min_nodes: int, max_nodes: int,
               target_steps: int = 100) -> LiveJobInfo:
        info = LiveJobInfo(job=job, min_nodes=min_nodes, max_nodes=max_nodes,
                           target_steps=target_steps)
        self.jobs[job.jid] = info
        self._try_start(info)
        return info

    def _try_start(self, info: LiveJobInfo) -> bool:
        want = min(info.max_nodes, len(self.free))
        if want < info.min_nodes or \
                (info.job.kind == "rigid" and want < info.max_nodes):
            return False
        ids = [self.free.pop() for _ in range(
            info.max_nodes if info.job.kind == "rigid" else want)]
        info.node_ids = ids
        devs = [self.devices[i] for i in ids]
        if info.job.state is None and info.job.step_idx == 0:
            info.job.start(devs)
        elif info.status == "preempted" and info.job.ckpt_dir:
            info.job.resume(devs)
        else:
            info.job.start(devs)
        info.status = "running"
        self._log("start", info.job.jid, nodes=len(ids))
        return True

    def step_all(self, n: int = 1) -> None:
        """Round-robin n train steps on every running job."""
        for _ in range(n):
            for info in self.jobs.values():
                if info.status == "running":
                    info.job.step()
                    info.steps_done += 1
                    if info.steps_done >= info.target_steps:
                        self._finish(info)

    def _finish(self, info: LiveJobInfo) -> None:
        info.status = "done"
        self.free.extend(info.node_ids)
        info.node_ids = []
        self._lease_book.pop(info.job.jid, None)
        self._log("finish", info.job.jid)
        self._restart_waiting()

    def _restart_waiting(self) -> None:
        for info in self.jobs.values():
            if info.status in ("waiting", "preempted"):
                self._try_start(info)
        self._on_idle()

    # ------------------------------------------- policy-facing primitives
    def _preempt(self, jid: int) -> List[int]:
        info = self.jobs[jid]
        info.job.preempt(warning=info.job.kind == "malleable")
        info.status = "preempted"
        info.preempt_count += 1
        ids, info.node_ids = info.node_ids, []
        self._log("preempt", jid)
        return ids

    def _shrink(self, jid: int, k: int) -> List[int]:
        info = self.jobs[jid]
        keep, shed = info.node_ids[:-k], info.node_ids[-k:]
        info.node_ids = keep
        info.shrink_count += 1
        cost = info.job.resize([self.devices[i] for i in keep])
        self._lease_book[jid] = self._lease_book.get(jid, 0) + k
        self._log("shrink", jid, shed=k, reshard_s=round(cost, 3))
        return shed

    def _expand(self, jid: int, ids: List[int]) -> None:
        info = self.jobs[jid]
        info.node_ids = info.node_ids + ids
        cost = info.job.resize([self.devices[i] for i in info.node_ids])
        self._log("expand", jid, grow=len(ids), reshard_s=round(cost, 3))

    # ---------------------------------------------------- on-demand arrival
    def acquire_for_ondemand(self, need: int) -> List[int]:
        """Vacate `need` nodes via the configured arrival policy (paper
        §III-B2) and return their ids.  Raises RuntimeError when the
        policy cannot meet the demand (nothing is mutated in that case:
        a failed acquire found no victims to touch)."""
        if not (0 < need <= len(self.devices)):
            raise ValueError(f"cannot acquire {need} of "
                             f"{len(self.devices)} nodes")
        self._od_count += 1
        od_jid = -self._od_count          # below any real jid
        if need <= len(self.free):
            got = [self.free.pop() for _ in range(need)]
            self._log("od_acquire", od_jid, source="free", nodes=need)
            return got
        ops = _LiveOps(self, od_jid, need)
        if not self.arrival.acquire(ops, od_jid, need - len(self.free)) \
                or ops.acquired is None:
            raise RuntimeError(
                f"cannot vacate {need} nodes "
                f"(arrival policy {self.arrival.name})")
        self._log("od_acquire", od_jid, source=self.arrival.name, nodes=need)
        return ops.acquired

    def release_ondemand(self, node_ids: List[int]) -> None:
        """On-demand completion: lease repayment first (shrunk lenders
        reclaim their nodes, paper §III-B3 — core mechanics, independent
        of policy), then the elasticity policy absorbs the remainder,
        then the free pool / waiting jobs."""
        pool = list(node_ids)
        for jid in list(self._lease_book):
            if not pool:
                break
            info = self.jobs.get(jid)
            if info is None or info.status != "running":
                del self._lease_book[jid]
                continue
            grow = min(self._lease_book[jid], len(pool),
                       info.max_nodes - len(info.node_ids))
            if grow > 0:
                self._expand(jid, [pool.pop() for _ in range(grow)])
            if self._lease_book[jid] - grow > 0:
                self._lease_book[jid] -= grow
            else:
                del self._lease_book[jid]
        if pool:
            ops = _LiveOps(self, pool=pool)
            self.elasticity.absorb_release(ops, len(pool))
            self.free.extend(pool)        # whatever absorb left behind
            pool = []
        self._restart_waiting()

    def _on_idle(self) -> None:
        """Post-scheduling elasticity hook: BALANCE-style policies grow
        running malleables into genuinely spare nodes."""
        if self.free:
            self.elasticity.on_idle(_LiveOps(self))

    def _log(self, event: str, jid: int, **kw) -> None:
        self.log.append({"t": round(self.elapsed(), 6),
                         "event": event, "jid": jid, **kw})

    def utilization(self) -> float:
        used = sum(len(i.node_ids) for i in self.jobs.values()
                   if i.status == "running")
        return used / len(self.devices)
