"""LiveCluster: the paper's mechanisms driving REAL JAX jobs.

Where `repro.core.Simulator` advances a clock over a trace, LiveCluster
applies the same decision kernels (select_preemption_victims /
apportion_shrink) to actual ElasticJobs training on actual devices, and
serves actual on-demand inference on the nodes it vacates.  This is the
integration point that makes the paper's scheduler a first-class feature
of the framework rather than a standalone simulator.

Node = one jax device (the demo runs on host platform devices; on a real
cluster a node is a chip group and the device lists come from the
launcher).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.decision import apportion_shrink, select_preemption_victims
from .elastic import ElasticJob


@dataclass
class LiveJobInfo:
    job: ElasticJob
    min_nodes: int
    max_nodes: int
    node_ids: List[int] = field(default_factory=list)
    status: str = "waiting"       # waiting|running|preempted|done
    steps_done: int = 0
    target_steps: int = 100
    preempt_count: int = 0
    shrink_count: int = 0


class LiveCluster:
    def __init__(self, devices: Sequence, arrival_policy: str = "SPAA"):
        self.devices = list(devices)
        self.free: List[int] = list(range(len(self.devices)))
        self.jobs: Dict[int, LiveJobInfo] = {}
        self.arrival_policy = arrival_policy
        self.log: List[dict] = []

    # ------------------------------------------------------------- lifecycle
    def submit(self, job: ElasticJob, *, min_nodes: int, max_nodes: int,
               target_steps: int = 100) -> LiveJobInfo:
        info = LiveJobInfo(job=job, min_nodes=min_nodes, max_nodes=max_nodes,
                           target_steps=target_steps)
        self.jobs[job.jid] = info
        self._try_start(info)
        return info

    def _try_start(self, info: LiveJobInfo) -> bool:
        want = min(info.max_nodes, len(self.free))
        if want < info.min_nodes or \
                (info.job.kind == "rigid" and want < info.max_nodes):
            return False
        ids = [self.free.pop() for _ in range(
            info.max_nodes if info.job.kind == "rigid" else want)]
        info.node_ids = ids
        devs = [self.devices[i] for i in ids]
        if info.job.state is None and info.job.step_idx == 0:
            info.job.start(devs)
        elif info.status == "preempted" and info.job.ckpt_dir:
            info.job.resume(devs)
        else:
            info.job.start(devs)
        info.status = "running"
        self._log("start", info.job.jid, nodes=len(ids))
        return True

    def step_all(self, n: int = 1) -> None:
        """Round-robin n train steps on every running job."""
        for _ in range(n):
            for info in self.jobs.values():
                if info.status == "running":
                    info.job.step()
                    info.steps_done += 1
                    if info.steps_done >= info.target_steps:
                        self._finish(info)

    def _finish(self, info: LiveJobInfo) -> None:
        info.status = "done"
        self.free.extend(info.node_ids)
        info.node_ids = []
        self._log("finish", info.job.jid)
        self._restart_waiting()

    def _restart_waiting(self) -> None:
        for info in self.jobs.values():
            if info.status in ("waiting", "preempted"):
                self._try_start(info)

    # ---------------------------------------------------- on-demand arrival
    def acquire_for_ondemand(self, need: int) -> List[int]:
        """Vacate `need` nodes using the configured mechanism (paper
        §III-B2) and return their ids.  Raises if impossible."""
        got: List[int] = []
        take = min(need, len(self.free))
        got += [self.free.pop() for _ in range(take)]
        if len(got) == need:
            self._log("od_acquire", -1, source="free", nodes=need)
            return got
        rest = need - len(got)
        if self.arrival_policy == "SPAA":
            run_m = [i for i in self.jobs.values()
                     if i.status == "running" and i.job.kind == "malleable"
                     and len(i.node_ids) > i.min_nodes]
            sheds = apportion_shrink([len(i.node_ids) for i in run_m],
                                     [i.min_nodes for i in run_m], rest)
            if sheds:
                for info, k in zip(run_m, sheds):
                    if k == 0:
                        continue
                    keep = info.node_ids[:-k]
                    got += info.node_ids[-k:]
                    info.node_ids = keep
                    info.shrink_count += 1
                    cost = info.job.resize([self.devices[i] for i in keep])
                    self._log("shrink", info.job.jid, shed=k,
                              reshard_s=round(cost, 3))
                return got
        # PAA fallback: preempt in ascending overhead (steps since ckpt x n)
        cand = [i for i in self.jobs.values() if i.status == "running"]
        over = [((i.steps_done % i.job.ckpt_every)
                 if i.job.kind == "rigid" else 0) * len(i.node_ids) +
                len(i.node_ids) for i in cand]
        victims, _ = select_preemption_victims(
            [len(i.node_ids) for i in cand], over, rest)
        if not victims:
            for i in got:
                self.free.append(i)
            raise RuntimeError(f"cannot vacate {need} nodes")
        for vi in victims:
            info = cand[vi]
            info.job.preempt(warning=info.job.kind == "malleable")
            info.status = "preempted"
            info.preempt_count += 1
            got += info.node_ids
            info.node_ids = []
            self._log("preempt", info.job.jid)
        surplus = len(got) - need
        for _ in range(surplus):
            self.free.append(got.pop())
        return got

    def release_ondemand(self, node_ids: List[int]) -> None:
        """On-demand completion: return leased nodes (paper §III-B3) —
        expand shrunk jobs, resume preempted ones, rest to the pool."""
        pool = list(node_ids)
        for info in self.jobs.values():
            if info.status == "running" and info.shrink_count and \
                    len(info.node_ids) < info.max_nodes and pool:
                grow = min(info.max_nodes - len(info.node_ids), len(pool))
                info.node_ids += [pool.pop() for _ in range(grow)]
                cost = info.job.resize(
                    [self.devices[i] for i in info.node_ids])
                self._log("expand", info.job.jid, grow=grow,
                          reshard_s=round(cost, 3))
        self.free.extend(pool)
        self._restart_waiting()

    def _log(self, event: str, jid: int, **kw) -> None:
        self.log.append({"t": time.time(), "event": event, "jid": jid, **kw})

    def utilization(self) -> float:
        used = sum(len(i.node_ids) for i in self.jobs.values()
                   if i.status == "running")
        return used / len(self.devices)
