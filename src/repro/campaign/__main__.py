"""Campaign CLI::

    python -m repro.campaign list
    python -m repro.campaign fetch mini-steady kth-sp2
    python -m repro.campaign run examples/campaigns/mini.toml
    python -m repro.campaign report results/campaigns/mini

``run`` is offline-first: zoo fixtures need no network, remote traces
resolve through the cache ($REPRO_TRACE_CACHE), and ``--offline``
(or $REPRO_OFFLINE) turns any would-be download into a clear error.
A killed run resumes from its checkpoint; ``--fresh`` discards it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.workloads.base import WorkloadDataError

from .report import write_report
from .runner import run_campaign
from .spec import CampaignSpec, CampaignSpecError, default_output_dir
from .zoo import fetch, get_trace, is_cached, registered_traces


def _cmd_list(args) -> int:
    rows = []
    for name in registered_traces():
        spec = get_trace(name)
        rows.append((name,
                     "fixture" if spec.fixture else "remote",
                     "yes" if is_cached(name) else "no",
                     spec.license,
                     spec.description))
    widths = [max(len(r[i]) for r in rows + [_LIST_HEADER])
              for i in range(4)]
    for r in [_LIST_HEADER] + rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths))
              + "  " + r[4])
    return 0


_LIST_HEADER = ("name", "kind", "cached", "license", "description")


def _cmd_fetch(args) -> int:
    rc = 0
    for name in args.traces:
        try:
            path = fetch(name, offline=args.offline or None,
                         cache=args.cache)
        except WorkloadDataError as e:
            print(f"fetch {name}: FAILED: {e}", file=sys.stderr)
            rc = 1
            continue
        print(f"fetch {name}: ok -> {path}")
    return rc


def _cmd_run(args) -> int:
    try:
        spec = CampaignSpec.load(args.spec)
    except (CampaignSpecError, OSError) as e:
        print(f"spec error: {e}", file=sys.stderr)
        return 2
    out_dir = args.out or default_output_dir(spec)
    print(f"campaign {spec.name}: {spec.n_cells} cells "
          f"({len(spec.traces)} trace(s) x {len(spec.mechanisms)} "
          f"mechanism(s) x {len(spec.seeds)} seed(s) x grid) -> {out_dir}")

    def progress(done, total, result):
        wl = result.spec.workload
        print(f"  [{done}/{total}] {wl.label} x {result.spec.mechanism} "
              f"seed={result.spec.seed} "
              f"({result.elapsed_s:.1f}s)" if result.elapsed_s else
              f"  [{done}/{total}] {wl.label} x {result.spec.mechanism} "
              f"seed={result.spec.seed} (restored)")

    try:
        paths = run_campaign(
            spec, out_dir=out_dir, offline=args.offline or None,
            resume=not args.fresh,
            processes=0 if args.serial else None,
            progress=progress if not args.quiet else None)
    except WorkloadDataError as e:
        print(f"campaign failed: {e}", file=sys.stderr)
        return 1
    for k in sorted(paths):
        print(f"wrote {paths[k]}")
    return 0


def _cmd_report(args) -> int:
    rows_path = args.rows
    if os.path.isdir(rows_path):
        rows_path = os.path.join(rows_path, "rows.json")
    try:
        with open(rows_path, encoding="utf-8") as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"cannot read rows: {e}", file=sys.stderr)
        return 2
    out_dir = args.out or os.path.dirname(os.path.abspath(rows_path))
    paths = write_report(out_dir, payload.get("campaign", "campaign"),
                         payload["rows"], payload.get("provenance", {}))
    for k in sorted(paths):
        print(f"wrote {paths[k]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="trace-zoo campaigns: declarative mechanism "
                    "robustness sweeps over real and fixture traces")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list zoo traces and cache state")

    p = sub.add_parser("fetch", help="fetch + verify traces into the cache")
    p.add_argument("traces", nargs="+")
    p.add_argument("--cache", default=None, help="cache dir override")
    p.add_argument("--offline", action="store_true",
                   help="fail instead of downloading")

    p = sub.add_parser("run", help="run a campaign spec end to end")
    p.add_argument("spec", help="path to a .toml or .json campaign spec")
    p.add_argument("--out", default=None,
                   help="output dir (default results/campaigns/<name>)")
    p.add_argument("--offline", action="store_true")
    p.add_argument("--fresh", action="store_true",
                   help="discard any existing checkpoint")
    p.add_argument("--serial", action="store_true",
                   help="no process fan-out (deterministic single-proc)")
    p.add_argument("--quiet", action="store_true")

    p = sub.add_parser("report",
                       help="re-render report artifacts from rows.json")
    p.add_argument("rows", help="rows.json or a campaign output dir")
    p.add_argument("--out", default=None)

    args = ap.parse_args(argv)
    return {"list": _cmd_list, "fetch": _cmd_fetch,
            "run": _cmd_run, "report": _cmd_report}[args.cmd](args)


if __name__ == "__main__":
    raise SystemExit(main())
