"""A minimal TOML-subset reader for campaign specs.

The container's Python 3.10 predates stdlib ``tomllib`` and the repo
bakes in no third-party TOML package, so campaign specs are parsed by
this deliberately small reader.  The supported subset — everything
``examples/campaigns/*.toml`` and docs/campaigns.md use:

  * ``[table]`` and ``[[array-of-tables]]`` headers, dotted names;
  * ``key = value`` with bare or dotted keys;
  * values: basic ``"strings"`` (``\\" \\\\ \\n \\t`` escapes),
    integers, floats (incl. ``1e-3``), booleans, and (nested) arrays —
    arrays may span lines with trailing commas;
  * ``#`` comments anywhere outside a string.

Unsupported TOML (literal strings, dates, inline tables, multi-line
strings) raises :class:`TomlError` with a line number rather than
misparsing.  Not a validator — the campaign spec layer does schema
checks; this only guarantees the value tree is what the file says.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple


class TomlError(ValueError):
    """A campaign spec file is not in the supported TOML subset."""


def loads(text: str) -> Dict[str, Any]:
    """Parse TOML-subset ``text`` into nested dicts/lists."""
    root: Dict[str, Any] = {}
    current = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        lineno = i + 1
        line = _strip_comment(lines[i], lineno).strip()
        i += 1
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise TomlError(f"line {lineno}: malformed table-array "
                                f"header: {line!r}")
            parent, leaf = _descend(root, line[2:-2].strip(), lineno)
            arr = parent.setdefault(leaf, [])
            if not isinstance(arr, list):
                raise TomlError(f"line {lineno}: {leaf!r} is not an "
                                "array of tables")
            current = {}
            arr.append(current)
        elif line.startswith("["):
            if not line.endswith("]"):
                raise TomlError(f"line {lineno}: malformed table header: "
                                f"{line!r}")
            parent, leaf = _descend(root, line[1:-1].strip(), lineno)
            current = parent.setdefault(leaf, {})
            if not isinstance(current, dict):
                raise TomlError(f"line {lineno}: {leaf!r} redefined as "
                                "a table")
        else:
            if "=" not in line:
                raise TomlError(f"line {lineno}: expected 'key = value', "
                                f"got {line!r}")
            key, _, rhs = line.partition("=")
            key = key.strip()
            rhs = rhs.strip()
            # arrays may continue over following lines until brackets close
            while _open_brackets(rhs, lineno):
                if i >= len(lines):
                    raise TomlError(f"line {lineno}: unterminated array")
                rhs += " " + _strip_comment(lines[i], i + 1).strip()
                i += 1
            parent, leaf = _descend(current, key, lineno)
            if leaf in parent:
                raise TomlError(f"line {lineno}: duplicate key {key!r}")
            value, rest = _parse_value(rhs, lineno)
            if rest.strip():
                raise TomlError(f"line {lineno}: trailing garbage "
                                f"{rest.strip()!r}")
            parent[leaf] = value
    return root


def load(path: str) -> Dict[str, Any]:
    with open(path, encoding="utf-8") as f:
        return loads(f.read())


def _strip_comment(line: str, lineno: int) -> str:
    """Drop a ``#`` comment, honoring string quoting."""
    out = []
    in_str = False
    escaped = False
    for ch in line:
        if in_str:
            out.append(ch)
            if escaped:
                escaped = False
            elif ch == "\\":
                escaped = True
            elif ch == '"':
                in_str = False
        elif ch == "#":
            break
        else:
            if ch == '"':
                in_str = True
            out.append(ch)
    if in_str:
        raise TomlError(f"line {lineno}: unterminated string")
    return "".join(out)


def _open_brackets(s: str, lineno: int) -> bool:
    """True while an array value still has unclosed ``[``."""
    depth = 0
    in_str = False
    escaped = False
    for ch in s:
        if in_str:
            if escaped:
                escaped = False
            elif ch == "\\":
                escaped = True
            elif ch == '"':
                in_str = False
        elif ch == '"':
            in_str = True
        elif ch == "[":
            depth += 1
        elif ch == "]":
            depth -= 1
    return depth > 0


def _descend(tree: Dict[str, Any], dotted: str, lineno: int
             ) -> Tuple[Dict[str, Any], str]:
    """Walk ``a.b.c`` creating intermediate tables; return (parent, leaf)."""
    parts = [p.strip() for p in dotted.split(".")]
    if not parts or any(not p for p in parts):
        raise TomlError(f"line {lineno}: bad key {dotted!r}")
    for p in parts[:-1]:
        nxt = tree.setdefault(p, {})
        if isinstance(nxt, list):  # [[x]] then [x.y]: attach to last entry
            nxt = nxt[-1]
        if not isinstance(nxt, dict):
            raise TomlError(f"line {lineno}: {p!r} is not a table")
        tree = nxt
    return tree, parts[-1]


_ESCAPES = {"n": "\n", "t": "\t", "r": "\r", '"': '"', "\\": "\\"}


def _parse_value(s: str, lineno: int) -> Tuple[Any, str]:
    """Parse one value at the head of ``s``; return (value, remainder)."""
    s = s.lstrip()
    if not s:
        raise TomlError(f"line {lineno}: missing value")
    if s[0] == '"':
        out = []
        i = 1
        while i < len(s):
            ch = s[i]
            if ch == "\\":
                if i + 1 >= len(s) or s[i + 1] not in _ESCAPES:
                    raise TomlError(f"line {lineno}: unsupported escape "
                                    f"in string: {s[i:i+2]!r}")
                out.append(_ESCAPES[s[i + 1]])
                i += 2
            elif ch == '"':
                return "".join(out), s[i + 1:]
            else:
                out.append(ch)
                i += 1
        raise TomlError(f"line {lineno}: unterminated string")
    if s[0] == "[":
        items: List[Any] = []
        rest = s[1:].lstrip()
        while True:
            if not rest:
                raise TomlError(f"line {lineno}: unterminated array")
            if rest[0] == "]":
                return items, rest[1:]
            item, rest = _parse_value(rest, lineno)
            items.append(item)
            rest = rest.lstrip()
            if rest.startswith(","):
                rest = rest[1:].lstrip()
            elif not rest.startswith("]"):
                raise TomlError(f"line {lineno}: expected ',' or ']' in "
                                f"array, got {rest[:10]!r}")
    # bare scalar: boolean / integer / float
    token = s
    for stop in (",", "]"):
        cut = token.find(stop)
        if cut != -1:
            token = token[:cut]
    token = token.strip()
    if not token:
        raise TomlError(f"line {lineno}: missing value")
    rest = s[len(token):]  # s is lstripped, so the token is its prefix
    if token == "true":
        return True, rest
    if token == "false":
        return False, rest
    try:
        if any(c in token for c in ".eE") and not token.startswith("0x"):
            return float(token), rest
        return int(token, 0), rest
    except ValueError:
        raise TomlError(f"line {lineno}: unsupported value {token!r} "
                        "(subset: strings, numbers, booleans, arrays)"
                        ) from None
