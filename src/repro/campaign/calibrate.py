"""Per-trace calibration: measured trace profile -> scenario knobs.

Real traces differ wildly in offered load, so comparing mechanisms
"at 0.8 load" across traces needs a per-trace correction.  This module
keeps the correction *inside the existing workload algebra*: a
:class:`TraceProfile` is measured in one bounded-memory pass
(:func:`profile_trace`, built on the streaming SWF reader), and
:func:`calibrated_scenario` expresses every knob through already
registered pieces so the calibrated trace replays through the
unchanged streaming ``Scenario`` path:

  * **target_load** -> a ``load_scale`` transform with
    ``factor = target_load / offered_load`` (compressing or stretching
    the arrival span; work content untouched);
  * **malleable_frac / od_frac** -> the ``swf`` source's per-project
    type fractions (type assignment must happen at annotation time to
    keep the stack streamable — the ``type_mix`` transform re-draws
    content-dependently and would force the materialized fallback);
  * **notice** -> a ``notice_mix`` transform (streamable re-draw).

Offered load is the standard trace measure:
``sum(size * runtime) / (n_nodes * submit_span)``.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.workloads import Scenario
from repro.core.workloads.base import WorkloadDataError
from repro.core.workloads.swf import iter_swf

from .zoo import TraceSpec, fetch, get_trace

#: profiles are deterministic per file: cache one pass per (path, mtime)
_PROFILE_CACHE: Dict[tuple, "TraceProfile"] = {}


@dataclass(frozen=True)
class TraceProfile:
    """Cheap whole-trace aggregates from one streaming pass."""

    name: str
    path: str
    n_jobs: int
    n_nodes: int
    span_s: float
    #: sum(size * runtime) / (n_nodes * span): the dimensionless offered
    #: load the raw trace would put on its own machine
    offered_load: float
    mean_size: float
    mean_runtime_s: float

    def load_factor(self, target_load: float) -> float:
        """The ``load_scale`` factor that rescales this trace's offered
        load to ``target_load`` (factor > 1 compresses arrivals)."""
        if target_load <= 0:
            raise ValueError(f"target_load must be > 0, got {target_load}")
        if self.offered_load <= 0 or self.span_s <= 0:
            raise WorkloadDataError(
                f"trace {self.name!r}: cannot calibrate load (offered "
                f"load {self.offered_load}, span {self.span_s}s)")
        return target_load / self.offered_load


def profile_trace(name: str, path: Optional[str] = None,
                  offline: Optional[bool] = None) -> TraceProfile:
    """Measure a zoo trace (or an explicit SWF ``path``) in one
    bounded-memory streaming pass, applying the same usability filter
    the ``swf`` source applies (drop cancelled / unsized / zero-runtime
    records) so the measured load matches what is replayed."""
    spec = get_trace(name) if path is None else None
    if path is None:
        path = fetch(name, offline=offline)
    try:
        key = (os.path.abspath(path), os.stat(path).st_mtime_ns)
    except OSError:
        key = None
    if key is not None and key in _PROFILE_CACHE:
        return _PROFILE_CACHE[key]
    drop_cancelled = True
    if spec is not None:
        drop_cancelled = bool(spec.swf_params.get("drop_cancelled", True))
    header: Dict[str, str] = {}
    n = 0
    t_min = float("inf")
    t_max = float("-inf")
    node_seconds = 0.0
    size_sum = 0.0
    run_sum = 0.0
    largest = 0
    for rec in iter_swf(path, header=header):
        alloc = int(rec["allocated_procs"])
        size = alloc if alloc > 0 else int(rec["req_procs"])
        largest = max(largest, size)
        if drop_cancelled and rec["status"] == 5:
            continue
        if size <= 0 or rec["run_time"] <= 0:
            continue
        n += 1
        t_min = min(t_min, rec["submit_time"])
        t_max = max(t_max, rec["submit_time"])
        node_seconds += size * rec["run_time"]
        size_sum += size
        run_sum += rec["run_time"]
    if n == 0:
        raise WorkloadDataError(
            f"trace {name!r} ({path}): no usable jobs to profile")
    n_nodes = _system_size(header, largest, path)
    span = t_max - t_min
    profile = TraceProfile(
        name=name, path=path, n_jobs=n, n_nodes=n_nodes, span_s=span,
        offered_load=(node_seconds / (n_nodes * span) if span > 0
                      else float("inf")),
        mean_size=size_sum / n, mean_runtime_s=run_sum / n)
    if key is not None:
        _PROFILE_CACHE[key] = profile
    return profile


def _system_size(header: Dict[str, str], largest: int, path: str) -> int:
    for k in ("MaxNodes", "MaxProcs"):
        raw = header.get(k)
        if raw:
            m = re.match(r"\d+", raw.replace(",", ""))
            if m:
                return int(m.group())
    if largest <= 0:
        raise WorkloadDataError(
            f"{path}: cannot infer system size (no MaxNodes/MaxProcs "
            "header and no sized jobs)")
    return largest


def calibrated_scenario(name: str,
                        target_load: Optional[float] = None,
                        malleable_frac: Optional[float] = None,
                        od_frac: Optional[float] = None,
                        notice: Optional[str] = None,
                        max_jobs: Optional[int] = None,
                        label: Optional[str] = None,
                        offline: Optional[bool] = None,
                        extra_transforms: Tuple[Tuple[str, dict], ...] = (),
                        ) -> Scenario:
    """Build a streaming-ready Scenario for a zoo trace.

    Every knob maps onto registered source params / streamable
    transforms (module docstring); the returned Scenario's stack is
    fully streamable unless ``extra_transforms`` adds a transform that
    is not.  ``label`` defaults to a regime-describing name used by the
    campaign report's grouping columns.
    """
    spec: TraceSpec = get_trace(name)
    path = fetch(name, offline=offline)
    params: Dict[str, object] = dict(spec.swf_params)
    params["path"] = path
    params["stream"] = True
    if max_jobs is not None:
        params["max_jobs"] = max_jobs
    if malleable_frac is not None or od_frac is not None:
        od = 0.10 if od_frac is None else od_frac
        mall = (1.0 - od - 0.60) if malleable_frac is None else malleable_frac
        if od < 0 or mall < 0 or od + mall > 1.0:
            raise ValueError(
                f"trace {name!r}: od_frac={od} + malleable_frac={mall} "
                "must be >= 0 and sum <= 1")
        params["frac_od_projects"] = od
        params["frac_rigid_projects"] = 1.0 - od - mall
    transforms = []
    if target_load is not None:
        prof = profile_trace(name, offline=offline)
        transforms.append(("load_scale",
                           {"factor": prof.load_factor(target_load)}))
    if notice is not None:
        transforms.append(("notice_mix", {"mix": notice}))
    transforms.extend(extra_transforms)
    if label is None:
        bits = [name]
        if target_load is not None:
            bits.append(f"load{target_load:g}")
        if malleable_frac is not None:
            bits.append(f"mall{malleable_frac:g}")
        if notice is not None:
            bits.append(notice)
        label = "/".join(bits)
    return Scenario("swf", params=params, transforms=tuple(transforms),
                    name=label)
