"""Trace zoo: a registry of named workload traces with provenance.

Every trace a campaign references is a :class:`TraceSpec` — where the
SWF file comes from (a checked-in fixture or a Parallel Workloads
Archive URL), its sha256, its license note, and the SWF quirks the
reader must honor (``project_field``, cancelled-job handling, ...).
Resolution is **offline-first**:

  * fixture specs resolve to the gzipped files checked in under
    ``repro/campaign/fixtures/`` — CI and the test suite never touch
    the network;
  * remote specs resolve through a local cache directory
    (``$REPRO_TRACE_CACHE``, default ``.cache/trace_zoo``); a cache
    miss downloads only when the environment allows it
    (``$REPRO_OFFLINE`` unset and ``offline=False``), verifies sha256
    when the spec pins one, and installs atomically.

Integrity: :func:`fetch` always re-hashes the resolved file and
refuses a digest mismatch (a truncated download or a locally edited
fixture produces a :class:`~repro.core.workloads.base.WorkloadDataError`,
never a silently different campaign).
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import urllib.error
import urllib.request
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

from repro.core.workloads.base import WorkloadDataError

#: default cache directory for remote traces (overridable by env)
CACHE_ENV = "REPRO_TRACE_CACHE"
OFFLINE_ENV = "REPRO_OFFLINE"
DEFAULT_CACHE = os.path.join(".cache", "trace_zoo")

_FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "fixtures")


@dataclass(frozen=True)
class TraceSpec:
    """One named trace: provenance, integrity, and reader quirks."""

    name: str
    description: str
    #: license / redistribution note shown by ``repro.campaign list``
    license: str
    #: sha256 of the (possibly gzipped) SWF file; None = record on fetch
    sha256: Optional[str] = None
    #: download URL for archive traces; None = checked-in fixture
    url: Optional[str] = None
    #: repo-relative fixture filename under repro/campaign/fixtures/
    fixture: Optional[str] = None
    #: extra SwfTrace params this trace needs (SWF quirks: e.g. traces
    #: whose user_id is useless use project_field="group_id"; traces
    #: with unreliable status fields set drop_cancelled=False)
    swf_params: Mapping[str, object] = field(default_factory=dict)

    @property
    def remote(self) -> bool:
        return self.url is not None


_ZOO: Dict[str, TraceSpec] = {}


def register_trace(spec: TraceSpec) -> TraceSpec:
    """Add a trace to the zoo (idempotent for identical re-registration)."""
    old = _ZOO.get(spec.name)
    if old is not None and old != spec:
        raise ValueError(f"trace {spec.name!r} already registered "
                         "with a different spec")
    _ZOO[spec.name] = spec
    return spec


def get_trace(name: str) -> TraceSpec:
    try:
        return _ZOO[name]
    except KeyError:
        raise WorkloadDataError(
            f"unknown trace {name!r}; zoo has: "
            f"{', '.join(sorted(_ZOO))}") from None


def registered_traces() -> Tuple[str, ...]:
    return tuple(sorted(_ZOO))


def cache_dir() -> str:
    return os.environ.get(CACHE_ENV) or DEFAULT_CACHE


def is_offline(offline: Optional[bool] = None) -> bool:
    if offline is not None:
        return offline
    return bool(os.environ.get(OFFLINE_ENV))


def file_sha256(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                return h.hexdigest()
            h.update(b)


def trace_path(name: str, offline: Optional[bool] = None) -> str:
    """Resolve a zoo trace to a local file, fetching if needed+allowed."""
    return fetch(name, offline=offline)


def fetch(name: str, offline: Optional[bool] = None,
          cache: Optional[str] = None) -> str:
    """Resolve ``name`` to a verified local SWF path.

    Fixtures verify in place; remote traces resolve via the cache and
    download on a miss unless offline.  Always re-hashes: a spec with
    a pinned sha256 refuses a mismatching file (WorkloadDataError)."""
    spec = get_trace(name)
    if spec.fixture is not None:
        path = os.path.join(_FIXTURE_DIR, spec.fixture)
        if not os.path.exists(path):
            raise WorkloadDataError(
                f"trace {name!r}: missing checked-in fixture {path}")
        return _verified(spec, path)
    assert spec.url is not None
    cdir = cache or cache_dir()
    path = os.path.join(cdir, os.path.basename(spec.url))
    if os.path.exists(path):
        return _verified(spec, path)
    if is_offline(offline):
        raise WorkloadDataError(
            f"trace {name!r} is not cached at {path} and the environment "
            f"is offline ({OFFLINE_ENV} set or offline=True); run "
            f"'python -m repro.campaign fetch {name}' where the network "
            "is available, or point "
            f"{CACHE_ENV} at a pre-populated cache")
    os.makedirs(cdir, exist_ok=True)
    tmp_fd, tmp = tempfile.mkstemp(dir=cdir, suffix=".part")
    os.close(tmp_fd)
    try:
        try:
            with urllib.request.urlopen(spec.url, timeout=60) as resp, \
                    open(tmp, "wb") as out:
                while True:
                    b = resp.read(1 << 20)
                    if not b:
                        break
                    out.write(b)
        except (urllib.error.URLError, OSError) as e:
            raise WorkloadDataError(
                f"trace {name!r}: download failed from {spec.url}: {e}"
            ) from None
        _verified(spec, tmp)
        os.replace(tmp, path)  # atomic install after verification
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def _verified(spec: TraceSpec, path: str) -> str:
    digest = file_sha256(path)
    if spec.sha256 is not None and digest != spec.sha256:
        raise WorkloadDataError(
            f"trace {spec.name!r}: sha256 mismatch for {path}: expected "
            f"{spec.sha256}, got {digest} (corrupt download or locally "
            "modified file; delete it and re-fetch)")
    return path


def is_cached(name: str) -> bool:
    """True when the trace resolves without any network access."""
    spec = get_trace(name)
    if spec.fixture is not None:
        return os.path.exists(os.path.join(_FIXTURE_DIR, spec.fixture))
    assert spec.url is not None
    return os.path.exists(os.path.join(cache_dir(),
                                       os.path.basename(spec.url)))


# --------------------------------------------------------------- built-ins
# Checked-in fixtures: tiny synthetic SWF traces with deliberately
# different regimes (steady / bursty / near-saturation), gzipped with
# mtime=0 so their bytes — and these digests — are reproducible.
register_trace(TraceSpec(
    name="mini-steady",
    description="340 jobs, 64 nodes, steady Poisson arrivals, ~0.77 load",
    license="CC0 (synthetic, generated for this repo)",
    sha256="12fce044776eebab3ea13312a93023f30f97fd31551f24fa2ba779c118d3b8d6",
    fixture="mini-steady.swf.gz"))
register_trace(TraceSpec(
    name="mini-bursty",
    description="329 jobs, 64 nodes, clustered bursts with idle valleys",
    license="CC0 (synthetic, generated for this repo)",
    sha256="15ab1f5b274892a83d5a01dd9ca52f7cf96a90049a4c9c9b3b45ec7718949d61",
    fixture="mini-bursty.swf.gz"))
register_trace(TraceSpec(
    name="mini-heavy",
    description="380 jobs, 64 nodes, near-saturation (~1.16 offered load)",
    license="CC0 (synthetic, generated for this repo)",
    sha256="d99b1af0fbc39fde891acc981307a5ad182c6358e0a781e35c747bbcc12543bc",
    fixture="mini-heavy.swf.gz"))

# Parallel Workloads Archive traces (Feitelson's archive).  The PWA
# permits research use with attribution of the contributing site; each
# note names the contributor per the archive's citation policy.  No
# sha256 pinned — the archive occasionally re-packs files — so fetch
# verifies transport integrity (gzip CRC at read time) and campaigns
# record the observed digest in their provenance block instead.
register_trace(TraceSpec(
    name="kth-sp2",
    description="KTH IBM SP2, 28k jobs / 11 months, 100 nodes",
    license="PWA research use; credit Lars Malinowsky (KTH)",
    url="https://www.cs.huji.ac.il/labs/parallel/workload/l_kth_sp2/"
        "KTH-SP2-1996-2.1-cln.swf.gz"))
register_trace(TraceSpec(
    name="sdsc-sp2",
    description="SDSC IBM SP2, 59k jobs / 24 months, 128 nodes",
    license="PWA research use; credit Victor Hazlewood (SDSC)",
    url="https://www.cs.huji.ac.il/labs/parallel/workload/l_sdsc_sp2/"
        "SDSC-SP2-1998-4.2-cln.swf.gz"))
register_trace(TraceSpec(
    name="ctc-sp2",
    description="CTC IBM SP2, 77k jobs / 11 months, 338 nodes",
    license="PWA research use; credit the Cornell Theory Center",
    url="https://www.cs.huji.ac.il/labs/parallel/workload/l_ctc_sp2/"
        "CTC-SP2-1996-3.1-cln.swf.gz",
    # the CTC trace's queue/partition fields are the meaningful grouping;
    # user_id works but group_id matches published analyses
    swf_params={"project_field": "group_id"}))
