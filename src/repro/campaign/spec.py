"""Declarative campaign specs: traces x mechanisms x regimes x seeds.

A campaign is a TOML (or JSON) file that expands into an
``Experiment(stream=True)`` grid — every knob validated *before* any
simulation starts, so a typo fails in milliseconds, not after an hour
of replay.  Schema (see docs/campaigns.md for the full reference)::

    [campaign]
    name = "mini"                      # -> results/campaigns/<name>/
    mechanisms = ["BASE", "CUA&SPAA"]  # registered mechanism names
    seeds = [0, 1]
    max_jobs = 300                     # optional per-trace job cap
    # scale = 1.0                      # optional Experiment.scale
    # [campaign.sim]                   # optional SimConfig overrides
    # queue_policy = "EASY"

    [grid]                             # regime axes (cross product)
    target_load = [0.7, 0.9]           # calibrated per trace
    malleable_frac = [0.2]             # per-project type fractions
    notice = ["W2", "W5"]              # Table III notice mixes

    [[trace]]
    name = "mini-steady"               # a trace-zoo entry
    # target_load = [0.8]              # per-trace axis override

Each ``[[trace]]`` entry may override any ``[grid]`` axis; every
(trace x grid-point) pair becomes one calibrated streaming Scenario
(repro.campaign.calibrate), and the experiment sweeps those against
mechanisms x seeds with checkpoint/resume via
``Experiment.run_stream(checkpoint=...)``.
"""
from __future__ import annotations

import itertools
import json
import os
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.experiment import Experiment
from repro.core.policy import resolve_mechanism
from repro.core.workloads import Scenario
from repro.core.workloads.synthetic import notice_mix as _notice_mix

from . import _toml
from .calibrate import calibrated_scenario
from .zoo import get_trace

#: the regime axes a [grid] (or [[trace]]) table may sweep, with their
#: validators (value -> error string or None).  ``faults`` values are
#: compact repro.faults spec strings ("none", "exp-mtbf:mtbf_h=168");
#: they thread into Scenario.faults -> SimConfig.faults per cell.
#: ``batch_rounds`` values are scheduling-round intervals in seconds
#: (0 = per-event engine); they thread into Scenario.batch_rounds ->
#: SimConfig.batch_rounds per cell, so one campaign can sweep the
#: fidelity-vs-speed knob alongside the regime axes.
GRID_AXES = ("target_load", "malleable_frac", "od_frac", "notice", "faults",
             "batch_rounds")


class CampaignSpecError(ValueError):
    """A campaign spec fails validation; message names the field."""


@dataclass(frozen=True)
class TraceEntry:
    """One [[trace]] table: a zoo name plus per-trace axis overrides."""

    name: str
    axes: Mapping[str, tuple] = field(default_factory=dict)


@dataclass(frozen=True)
class CampaignSpec:
    """A validated campaign definition."""

    name: str
    mechanisms: Tuple[str, ...]
    seeds: Tuple[int, ...]
    traces: Tuple[TraceEntry, ...]
    grid: Mapping[str, tuple] = field(default_factory=dict)
    sim: Mapping[str, object] = field(default_factory=dict)
    scale: float = 1.0
    max_jobs: Optional[int] = None

    # ------------------------------------------------------------- loading
    @classmethod
    def load(cls, path: str) -> "CampaignSpec":
        """Load and validate a spec from ``.toml`` or ``.json``."""
        if path.endswith(".json"):
            with open(path, encoding="utf-8") as f:
                data = json.load(f)
        else:
            try:
                data = _toml.load(path)
            except _toml.TomlError as e:
                raise CampaignSpecError(f"{path}: {e}") from None
        return cls.from_dict(data, origin=path)

    @classmethod
    def from_dict(cls, data: Mapping, origin: str = "<dict>"
                  ) -> "CampaignSpec":
        def fail(msg: str) -> CampaignSpecError:
            return CampaignSpecError(f"{origin}: {msg}")

        if not isinstance(data, Mapping):
            raise fail("top level must be a table")
        unknown = set(data) - {"campaign", "grid", "trace"}
        if unknown:
            raise fail(f"unknown top-level table(s): {sorted(unknown)}")
        camp = data.get("campaign")
        if not isinstance(camp, Mapping):
            raise fail("missing [campaign] table")
        known = {"name", "mechanisms", "seeds", "sim", "scale", "max_jobs"}
        extra = set(camp) - known
        if extra:
            raise fail(f"[campaign]: unknown key(s) {sorted(extra)}; "
                       f"known: {sorted(known)}")
        name = camp.get("name")
        if not isinstance(name, str) or not name \
                or any(c in name for c in "/\\ "):
            raise fail("[campaign].name must be a non-empty string "
                       "without spaces or path separators")
        mechanisms = camp.get("mechanisms")
        if not isinstance(mechanisms, list) or not mechanisms \
                or not all(isinstance(m, str) for m in mechanisms):
            raise fail("[campaign].mechanisms must be a non-empty "
                       "list of strings")
        seeds = camp.get("seeds", [0])
        if not isinstance(seeds, list) or not seeds \
                or not all(isinstance(s, int) and not isinstance(s, bool)
                           for s in seeds):
            raise fail("[campaign].seeds must be a non-empty list of ints")
        sim = camp.get("sim", {})
        if not isinstance(sim, Mapping):
            raise fail("[campaign.sim] must be a table")
        scale = camp.get("scale", 1.0)
        if not isinstance(scale, (int, float)) or isinstance(scale, bool) \
                or scale <= 0:
            raise fail("[campaign].scale must be a positive number")
        max_jobs = camp.get("max_jobs")
        if max_jobs is not None and (not isinstance(max_jobs, int)
                                     or isinstance(max_jobs, bool)
                                     or max_jobs <= 0):
            raise fail("[campaign].max_jobs must be a positive int")

        grid = _axes_of(data.get("grid", {}), "[grid]", fail)
        traces_raw = data.get("trace")
        if not isinstance(traces_raw, list) or not traces_raw:
            raise fail("need at least one [[trace]] entry")
        traces: List[TraceEntry] = []
        for k, t in enumerate(traces_raw):
            where = f"[[trace]] #{k + 1}"
            if not isinstance(t, Mapping):
                raise fail(f"{where} must be a table")
            tname = t.get("name")
            if not isinstance(tname, str) or not tname:
                raise fail(f"{where}: missing trace name")
            axes = _axes_of({a: v for a, v in t.items() if a != "name"},
                            where, fail)
            traces.append(TraceEntry(tname, axes))

        spec = cls(name=name, mechanisms=tuple(mechanisms),
                   seeds=tuple(seeds), traces=tuple(traces), grid=grid,
                   sim=dict(sim), scale=float(scale), max_jobs=max_jobs)
        spec.validate(origin)
        return spec

    # ---------------------------------------------------------- validation
    def validate(self, origin: str = "<spec>") -> None:
        """Fail fast on every statically checkable error: registry
        names, axis value ranges, duplicate cells."""
        def fail(msg: str) -> CampaignSpecError:
            return CampaignSpecError(f"{origin}: {msg}")

        queue_policy = dict(self.sim).get("queue_policy", "EASY")
        for m in dict.fromkeys(self.mechanisms):
            try:
                resolve_mechanism(m, queue_policy)
            except ValueError as e:
                raise fail(f"mechanism {m!r}: {e}") from None
        if len(set(self.mechanisms)) != len(self.mechanisms):
            raise fail("duplicate mechanisms in [campaign].mechanisms")
        if len(set(self.seeds)) != len(self.seeds):
            raise fail("duplicate seeds in [campaign].seeds")
        seen = set()
        for t in self.traces:
            try:
                get_trace(t.name)
            except ValueError as e:  # re-raise with the zoo listing
                raise fail(str(e)) from None
            key = (t.name, tuple(sorted(t.axes.items())))
            if key in seen:
                raise fail(f"duplicate [[trace]] entry for {t.name!r}")
            seen.add(key)
            for axis, values in self._axes_for(t).items():
                for v in values:
                    if v is None:  # axis not swept for this trace
                        continue
                    err = _validate_axis(axis, v)
                    if err:
                        raise fail(f"trace {t.name!r}: {err}")
            # a bad od/malleable combination should fail here, not when
            # cells() builds scenarios mid-run-setup
            axes = self._axes_for(t)
            for od in axes["od_frac"]:
                for mall in axes["malleable_frac"]:
                    if od is None and mall is None:
                        continue
                    # same defaulting as calibrated_scenario: missing
                    # od -> 0.10; missing malleable -> keep rigid at 0.60
                    o = 0.10 if od is None else od
                    m = (1.0 - o - 0.60) if mall is None else mall
                    if o < 0 or m < 0 or o + m > 1.0:
                        raise fail(f"trace {t.name!r}: od_frac={o:g} and "
                                   f"malleable_frac={m:g} leave no valid "
                                   "rigid fraction (need >= 0, sum <= 1)")

    def _axes_for(self, t: TraceEntry) -> Dict[str, tuple]:
        """Effective regime axes for one trace: [grid] with per-trace
        overrides; absent axes default to a single None (uncalibrated)."""
        axes = dict(self.grid)
        axes.update(t.axes)
        return {a: tuple(axes.get(a) or (None,)) for a in GRID_AXES}

    # ----------------------------------------------------------- expansion
    def cells(self, offline: Optional[bool] = None
              ) -> List[Tuple[Dict[str, object], Scenario]]:
        """Expand traces x grid into ``(regime, scenario)`` pairs.

        ``regime`` is the flat dict of grouping keys the report
        aggregates on (trace name + every non-None axis value); the
        scenario is calibrated and streaming-ready.  Deterministic
        order: traces in spec order, axes in GRID_AXES order.
        """
        out: List[Tuple[Dict[str, object], Scenario]] = []
        for t in self.traces:
            axes = self._axes_for(t)
            for combo in itertools.product(*(axes[a] for a in GRID_AXES)):
                point = dict(zip(GRID_AXES, combo))
                regime: Dict[str, object] = {"trace": t.name}
                regime.update({a: v for a, v in point.items()
                               if v is not None})
                scenario = calibrated_scenario(
                    t.name,
                    target_load=point["target_load"],
                    malleable_frac=point["malleable_frac"],
                    od_frac=point["od_frac"],
                    notice=point["notice"],
                    max_jobs=self.max_jobs,
                    offline=offline)
                faults = point["faults"]
                if faults is not None:
                    # suffix keeps scenario labels (the runner's
                    # regime-mapping key) unique across fault cells
                    scenario = replace(
                        scenario, faults=faults,
                        name=f"{scenario.label}/f:{faults}")
                batch = point["batch_rounds"]
                if batch is not None:
                    scenario = replace(
                        scenario, batch_rounds=float(batch),
                        name=f"{scenario.label}/b:{batch:g}")
                out.append((regime, scenario))
        return out

    def to_experiment(self, offline: Optional[bool] = None,
                      processes: Optional[int] = None
                      ) -> Tuple[Experiment,
                                 List[Dict[str, object]]]:
        """Build the streaming Experiment plus the per-workload regime
        dicts (index-aligned with the experiment's workload list)."""
        pairs = self.cells(offline=offline)
        exp = Experiment(mechanisms=self.mechanisms,
                         workloads=[s for _r, s in pairs],
                         seeds=self.seeds, sim_kw=dict(self.sim),
                         scale=self.scale, processes=processes,
                         stream=True)
        return exp, [r for r, _s in pairs]

    @property
    def n_cells(self) -> int:
        total = 0
        for t in self.traces:
            axes = self._axes_for(t)
            point = 1
            for a in GRID_AXES:
                point *= len(axes[a])
            total += point
        return total * len(self.mechanisms) * len(self.seeds)


def _axes_of(table: Mapping, where: str, fail) -> Dict[str, tuple]:
    if not isinstance(table, Mapping):
        raise fail(f"{where} must be a table")
    unknown = set(table) - set(GRID_AXES)
    if unknown:
        raise fail(f"{where}: unknown axis(es) {sorted(unknown)}; "
                   f"known: {list(GRID_AXES)}")
    axes: Dict[str, tuple] = {}
    for a, v in table.items():
        if not isinstance(v, list) or not v:
            raise fail(f"{where}.{a} must be a non-empty list")
        if len(set(map(repr, v))) != len(v):
            raise fail(f"{where}.{a} has duplicate values")
        axes[a] = tuple(v)
    return axes


def _validate_axis(axis: str, v: object) -> Optional[str]:
    if axis == "faults":
        if not isinstance(v, str):
            return f"faults value {v!r} must be a fault-spec string"
        from repro.faults import resolve_faults
        try:
            resolve_faults(v)
        except ValueError as e:
            return str(e)
        return None
    if axis == "notice":
        if not isinstance(v, str):
            return f"notice value {v!r} must be a mix name string"
        try:
            _notice_mix(v)
        except ValueError as e:
            return str(e)
        return None
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        return f"{axis} value {v!r} must be a number"
    if axis == "target_load" and not 0.0 < v <= 2.0:
        return f"target_load {v} outside (0, 2]"
    if axis in ("malleable_frac", "od_frac") and not 0.0 <= v <= 1.0:
        return f"{axis} {v} outside [0, 1]"
    if axis == "batch_rounds" and v < 0:
        return f"batch_rounds {v} must be >= 0 seconds"
    return None


def spec_fingerprint(path: str) -> str:
    """sha256 of the spec file bytes (campaign provenance stamp)."""
    import hashlib
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def default_output_dir(spec: CampaignSpec) -> str:
    return os.path.join("results", "campaigns", spec.name)
