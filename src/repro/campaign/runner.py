"""Campaign execution: spec -> streamed grid -> report artifacts.

Thin glue over the pieces that already exist: the spec expands to an
``Experiment(stream=True)``, cells run through
``run_stream(checkpoint=)`` (so a killed campaign resumes instead of
restarting), and the completed rows go through the regime report
writer.  Rows are re-sorted into deterministic grid order before
writing — process-pool completion order varies run to run, the
artifacts must not.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Mapping, Optional

from repro.core.experiment import RunResult

from .report import regime_key, write_report
from .spec import CampaignSpec, default_output_dir
from .zoo import file_sha256, get_trace

#: checkpoint filename inside the campaign output directory
CHECKPOINT = "checkpoint.json"


def campaign_provenance(spec: CampaignSpec, grid_key: str,
                        trace_paths: Mapping[str, str]) -> Dict[str, object]:
    """Stable identifiers only (no timestamps): what ran, on which
    trace bytes, over which grid — reports must be byte-reproducible."""
    return {
        "campaign": spec.name,
        "grid_key": grid_key,
        "n_cells": spec.n_cells,
        "mechanisms": ",".join(spec.mechanisms),
        "seeds": ",".join(str(s) for s in spec.seeds),
        "traces": ";".join(
            f"{name}:{file_sha256(path)[:12]}"
            for name, path in sorted(trace_paths.items())),
    }


def run_campaign(spec: CampaignSpec, out_dir: Optional[str] = None,
                 offline: Optional[bool] = None, resume: bool = True,
                 processes: Optional[int] = None,
                 progress: Optional[Callable[[int, int, RunResult],
                                             None]] = None
                 ) -> Dict[str, str]:
    """Run every cell of ``spec`` and write the report artifacts.

    Returns the artifact paths (see :func:`report.write_report`).
    ``resume=True`` keeps a grid-keyed checkpoint in ``out_dir`` —
    completed cells are never re-simulated after a crash/kill;
    ``resume=False`` ignores and overwrites any existing checkpoint.
    ``progress`` (done_count, total, result) fires per completed cell.
    """
    out_dir = out_dir or default_output_dir(spec)
    os.makedirs(out_dir, exist_ok=True)
    exp, regimes = spec.to_experiment(offline=offline, processes=processes)
    # regimes are index-aligned with exp.workloads; scenario labels are
    # unique (validated spec: no duplicate trace/grid points), so label
    # -> regime is a total, unambiguous mapping for result rows
    regime_of = {wl.label: reg
                 for wl, reg in zip(exp.workloads, regimes)}
    assert len(regime_of) == len(regimes), "duplicate scenario labels"
    checkpoint = os.path.join(out_dir, CHECKPOINT)
    if not resume and os.path.exists(checkpoint):
        os.unlink(checkpoint)
    grid_key = exp.grid_key()
    total = spec.n_cells
    rows: List[dict] = []
    for done, result in enumerate(exp.run_stream(checkpoint=checkpoint), 1):
        wl = result.spec.workload
        rows.append({"regime": regime_of[wl.label],
                     "mechanism": result.spec.mechanism,
                     "seed": result.spec.seed,
                     "metrics": result.metrics.as_dict()})
        if progress is not None:
            progress(done, total, result)
    # completion order is pool-dependent; artifacts must not be
    rows.sort(key=lambda r: (repr(regime_key(r["regime"])),
                             r["mechanism"], r["seed"]))
    trace_paths = {t.name: spec_path for t in spec.traces
                   for spec_path in
                   [_resolved_path(t.name, exp)]}
    prov = campaign_provenance(spec, grid_key, trace_paths)
    return write_report(out_dir, spec.name, rows, prov)


def _resolved_path(trace_name: str, exp) -> str:
    """The local file a trace resolved to (for the provenance digest).
    Every scenario of that trace shares the path; read it off the
    first matching workload instead of re-fetching."""
    get_trace(trace_name)  # keep zoo errors uniform
    for wl in exp.workloads:
        if wl.label.split("/")[0] == trace_name:
            return str(wl.params["path"])
    raise KeyError(trace_name)
