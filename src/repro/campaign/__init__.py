"""Cross-trace robustness campaigns (docs/campaigns.md).

The subsystem that answers "which mechanism wins under which regime"
over *real* traces, end to end and offline-first:

  * :mod:`repro.campaign.zoo` — named traces with provenance (checked-in
    fixtures + Parallel Workloads Archive entries), sha256-verified,
    cached locally;
  * :mod:`repro.campaign.calibrate` — per-trace knobs (target offered
    load, type fractions, notice mix) expressed through the existing
    registered sources/transforms so every cell replays through the
    unchanged streaming Scenario path;
  * :mod:`repro.campaign.spec` — declarative TOML/JSON campaign specs
    that validate up front and expand into an
    ``Experiment(stream=True)`` grid with checkpoint/resume;
  * :mod:`repro.campaign.report` — per-regime winner tables with
    bootstrap CIs, rendered byte-deterministically as markdown + JSON;
  * ``python -m repro.campaign`` — the ``list`` / ``fetch`` / ``run`` /
    ``report`` CLI.
"""
from .calibrate import TraceProfile, calibrated_scenario, profile_trace
from .report import aggregate, winners, write_report
from .runner import run_campaign
from .spec import CampaignSpec, CampaignSpecError, default_output_dir
from .zoo import (TraceSpec, fetch, file_sha256, get_trace, is_cached,
                  register_trace, registered_traces, trace_path)

__all__ = [
    "CampaignSpec", "CampaignSpecError", "TraceProfile", "TraceSpec",
    "aggregate", "calibrated_scenario", "default_output_dir", "fetch",
    "file_sha256", "get_trace", "is_cached", "profile_trace",
    "register_trace", "registered_traces", "run_campaign", "trace_path",
    "winners", "write_report",
]
