"""Regime reports: which mechanism wins under which workload regime.

The campaign runner streams one compact row per completed cell
(regime keys + mechanism + seed + metrics).  This module aggregates
those rows into the deliverable — per-regime winner tables with
bootstrap confidence intervals — rendered as markdown and JSON under
``results/campaigns/<name>/``.

Determinism contract: given the same rows, both artifacts are
**byte-identical** across runs and machines — no timestamps, sorted
JSON keys, fixed float formatting, and bootstrap resampling seeded
from a sha256 of the regime/mechanism/metric key rather than from
global RNG state.  The CI smoke and ``benchmarks --only campaign``
gate on exactly this property.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

#: metric -> (row key, better-direction, display label).  od-wait is
#: represented by the on-demand turnaround (wait dominates it for the
#: instant-start question the paper asks).
REPORT_METRICS: Tuple[Tuple[str, str, str], ...] = (
    ("avg_turnaround_od_h", "min", "od turnaround [h]"),
    ("avg_bounded_slowdown", "min", "bounded slowdown"),
    ("system_utilization", "max", "utilization"),
)

#: bootstrap resamples for the per-(regime, mechanism) CI
BOOTSTRAP_B = 200


def regime_key(regime: Mapping[str, object]) -> Tuple[Tuple[str, object], ...]:
    return tuple(sorted(regime.items()))


def _fmt(x: Optional[float], nd: int = 4) -> str:
    if x is None or (isinstance(x, float) and not np.isfinite(x)):
        return "nan"
    return f"{x:.{nd}f}"


def bootstrap_ci(values: Sequence[float], key: str,
                 b: int = BOOTSTRAP_B, alpha: float = 0.05
                 ) -> Tuple[float, float]:
    """Percentile bootstrap CI of the mean, deterministically seeded
    from ``key`` (so reports are byte-stable regardless of row arrival
    order or process count)."""
    vals = np.asarray([v for v in values if np.isfinite(v)], dtype=float)
    if vals.size == 0:
        return float("nan"), float("nan")
    if vals.size == 1:
        return float(vals[0]), float(vals[0])
    seed = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")
    rng = np.random.default_rng(seed)
    means = rng.choice(vals, size=(b, vals.size), replace=True).mean(axis=1)
    lo, hi = np.percentile(means, (100 * alpha / 2, 100 * (1 - alpha / 2)))
    return float(lo), float(hi)


def aggregate(rows: Sequence[Mapping]) -> List[dict]:
    """Collapse per-seed rows into per-(regime, mechanism) summaries.

    Each input row: ``{"regime": {...}, "mechanism": str, "seed": int,
    "metrics": {...}}``.  Output entries carry the mean, the seed
    count, and the bootstrap CI for every REPORT_METRICS metric, in a
    deterministic order (sorted regime key, then mechanism).
    """
    groups: Dict[tuple, Dict[str, List[Tuple[int, float]]]] = {}
    seeds: Dict[tuple, set] = {}
    for row in rows:
        k = (regime_key(row["regime"]), row["mechanism"])
        g = groups.setdefault(k, {m: [] for m, _d, _l in REPORT_METRICS})
        seeds.setdefault(k, set()).add(row["seed"])
        for m, _d, _l in REPORT_METRICS:
            v = row["metrics"].get(m)
            if v is not None:
                g[m].append((row["seed"], float(v)))
    out = []
    for (rkey, mech) in sorted(groups, key=lambda k: (repr(k[0]), k[1])):
        g = groups[(rkey, mech)]
        entry = {"regime": dict(rkey), "mechanism": mech,
                 "n_seeds": len(seeds[(rkey, mech)]), "metrics": {}}
        for m, _d, _l in REPORT_METRICS:
            # seed order, not arrival order: the CI resamples index into
            # this list and must not depend on pool completion order
            ordered = [v for _s, v in sorted(g[m])]
            vals = [v for v in ordered if np.isfinite(v)]
            ci_lo, ci_hi = bootstrap_ci(
                ordered, key=f"{rkey!r}|{mech}|{m}")
            entry["metrics"][m] = {
                "mean": float(np.mean(vals)) if vals else None,
                "ci_lo": None if not np.isfinite(ci_lo) else ci_lo,
                "ci_hi": None if not np.isfinite(ci_hi) else ci_hi,
                "n": len(vals)}
        out.append(entry)
    return out


def winners(aggregated: Sequence[Mapping]) -> List[dict]:
    """Per-regime winner per metric: the mechanism with the best mean;
    ``decisive`` marks wins whose CI does not overlap the runner-up's."""
    by_regime: Dict[tuple, List[Mapping]] = {}
    for e in aggregated:
        by_regime.setdefault(regime_key(e["regime"]), []).append(e)
    out = []
    for rkey in sorted(by_regime, key=repr):
        entries = by_regime[rkey]
        row = {"regime": dict(rkey), "winners": {}}
        for m, direction, _l in REPORT_METRICS:
            scored = [(e["mechanism"], e["metrics"][m]) for e in entries
                      if e["metrics"][m]["mean"] is not None]
            if not scored:
                row["winners"][m] = None
                continue
            sign = 1.0 if direction == "min" else -1.0
            # mechanism name breaks exact ties deterministically
            scored.sort(key=lambda t: (sign * t[1]["mean"], t[0]))
            best_name, best = scored[0]
            decisive = True
            if len(scored) > 1:
                _n2, second = scored[0][0], scored[1][1]
                if None in (best["ci_lo"], best["ci_hi"],
                            second["ci_lo"], second["ci_hi"]):
                    decisive = False
                elif direction == "min":
                    decisive = best["ci_hi"] < second["ci_lo"]
                else:
                    decisive = best["ci_lo"] > second["ci_hi"]
            row["winners"][m] = {"mechanism": best_name,
                                 "mean": best["mean"],
                                 "ci_lo": best["ci_lo"],
                                 "ci_hi": best["ci_hi"],
                                 "decisive": bool(decisive)}
        out.append(row)
    return out


def _regime_label(regime: Mapping[str, object]) -> str:
    parts = [str(regime.get("trace", "?"))]
    for k in sorted(regime):
        if k != "trace":
            v = regime[k]
            parts.append(f"{k}={v:g}" if isinstance(v, float) else
                         f"{k}={v}")
    return " ".join(parts)


def render_markdown(campaign: str, aggregated: Sequence[Mapping],
                    won: Sequence[Mapping], provenance: Mapping) -> str:
    """The human-readable report.  Deterministic bytes (no timestamps;
    provenance carries only stable identifiers)."""
    lines = [f"# Campaign report: {campaign}", ""]
    lines.append("Provenance: " + ", ".join(
        f"{k}={provenance[k]}" for k in sorted(provenance)))
    lines += ["", "## Winners by regime", ""]
    header = "| regime | " + " | ".join(
        label for _m, _d, label in REPORT_METRICS) + " |"
    lines.append(header)
    lines.append("|" + "---|" * (1 + len(REPORT_METRICS)))
    for row in won:
        cells = [_regime_label(row["regime"])]
        for m, _d, _l in REPORT_METRICS:
            w = row["winners"][m]
            if w is None:
                cells.append("—")
            else:
                mark = "**" if w["decisive"] else ""
                cells.append(
                    f"{mark}{w['mechanism']}{mark} "
                    f"({_fmt(w['mean'])} "
                    f"[{_fmt(w['ci_lo'])}, {_fmt(w['ci_hi'])}])")
        lines.append("| " + " | ".join(cells) + " |")
    lines += ["", "Bold winner: 95% bootstrap CI clear of the runner-up "
              f"(B={BOOTSTRAP_B}, seeded from the regime key).", "",
              "## Per-regime detail", ""]
    for row in won:
        rkey = regime_key(row["regime"])
        lines.append(f"### {_regime_label(row['regime'])}")
        lines.append("")
        lines.append("| mechanism | seeds | " + " | ".join(
            label for _m, _d, label in REPORT_METRICS) + " |")
        lines.append("|" + "---|" * (2 + len(REPORT_METRICS)))
        entries = [e for e in aggregated
                   if regime_key(e["regime"]) == rkey]
        for e in sorted(entries, key=lambda e: e["mechanism"]):
            cells = [e["mechanism"], str(e["n_seeds"])]
            for m, _d, _l in REPORT_METRICS:
                s = e["metrics"][m]
                cells.append(
                    "—" if s["mean"] is None else
                    f"{_fmt(s['mean'])} "
                    f"[{_fmt(s['ci_lo'])}, {_fmt(s['ci_hi'])}]")
            lines.append("| " + " | ".join(cells) + " |")
        lines.append("")
    return "\n".join(lines) + "\n"


def write_report(out_dir: str, campaign: str, rows: Sequence[Mapping],
                 provenance: Mapping) -> Dict[str, str]:
    """Aggregate ``rows`` and write the three artifacts; returns their
    paths.  rows.json preserves every per-seed row; report.json the
    aggregation + winners; report.md the rendered tables."""
    os.makedirs(out_dir, exist_ok=True)
    aggregated = aggregate(rows)
    won = winners(aggregated)
    paths = {
        "rows": os.path.join(out_dir, "rows.json"),
        "report_json": os.path.join(out_dir, "report.json"),
        "report_md": os.path.join(out_dir, "report.md"),
    }
    with open(paths["rows"], "w", encoding="utf-8") as f:
        json.dump({"campaign": campaign, "provenance": dict(provenance),
                   "rows": list(rows)}, f, indent=1, sort_keys=True)
        f.write("\n")
    with open(paths["report_json"], "w", encoding="utf-8") as f:
        json.dump({"campaign": campaign, "provenance": dict(provenance),
                   "aggregated": aggregated, "winners": won},
                  f, indent=1, sort_keys=True)
        f.write("\n")
    with open(paths["report_md"], "w", encoding="utf-8") as f:
        f.write(render_markdown(campaign, aggregated, won, provenance))
    return paths
