"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_ff=1536,
    vocab=102400, d_head=128,
    mla=MLAConfig(kv_lora=512, q_lora=1536, d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1, d_first_dense=12288, token_chunk=8192),
    fsdp=True, remat="full", train_microbatches=8,
)
