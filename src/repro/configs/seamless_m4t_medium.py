"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].
input_specs() provides precomputed audio-frame embeddings (stub frontend)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv=16, d_ff=4096, vocab=256206,
    n_enc_layers=12, enc_len=1024,
    remat="full", train_microbatches=2,
)
