"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    xlstm=XLSTMConfig(slstm_every=6),
    tie_embeddings=True,
    remat="full", train_microbatches=4, fsdp=True,
)
