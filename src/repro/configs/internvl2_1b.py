"""internvl2-1b [vlm] — InternViT (stub frontend) + InternLM2/Qwen2 backbone
[arXiv:2404.16821; hf].  input_specs() provides precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864, vocab=151655,
    n_patches=256, rope_theta=1_000_000.0,
    remat="full", train_microbatches=8, fsdp=True,
)
