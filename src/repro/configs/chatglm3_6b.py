"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA kv=2 [arXiv:2406.12793; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, d_ff=13696, vocab=65024,
    rope_fraction=0.5,
    fsdp=True, remat="full", train_microbatches=8,
)
