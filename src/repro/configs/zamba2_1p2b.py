"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    ssm=SSMConfig(d_state=64, expand=2, d_head=64, chunk=256),
    attn_every=6,
    remat="full", train_microbatches=4,
)
