"""Reduced same-family configs for CPU smoke tests.

Each assigned architecture gets a shrunken sibling — same family, block
structure, and code paths; small widths, few layers/experts, tiny vocab —
so one forward/train step runs on CPU in seconds.  The FULL configs are
only ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from . import get_config


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv > 1 else 1,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        attn_block_q=64, attn_block_kv=64,
        remat="none", fsdp=False, train_microbatches=1,
        # f32 so cached-vs-direct formulations must agree to fp precision
        # (bf16 numerics are exercised by the kernel test sweeps)
        param_dtype="float32", compute_dtype="float32",
    )
    if cfg.moe:
        # capacity_factor high enough that smoke tests never drop tokens
        # (dropping makes prefill/forward outputs differ by construction)
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=64, capacity_factor=4.0,
            d_first_dense=256 if cfg.moe.first_dense else 0)
    if cfg.mla:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora=64, q_lora=96, d_nope=32, d_rope=16, d_v=32)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, d_head=32,
                                        chunk=32)
        kw["attn_every"] = 2
        kw["n_layers"] = 4
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_every=2)
    if cfg.is_encdec:
        kw["n_enc_layers"] = 2
        kw["enc_len"] = 32
    if cfg.n_patches:
        kw["n_patches"] = 8
    return cfg.with_(**kw)


def reduced(name: str) -> ModelConfig:
    return reduce_config(get_config(name))
