"""Assigned architecture configs (one module per arch) + registry."""
from importlib import import_module

ARCH_IDS = (
    "xlstm_350m", "yi_9b", "llama3_8b", "chatglm3_6b", "granite_34b",
    "deepseek_v2_236b", "olmoe_1b_7b", "zamba2_1p2b", "internvl2_1b",
    "seamless_m4t_medium",
)

# public --arch names (dashes) -> module names
ALIASES = {i.replace("_", "-").replace("-1p2b", "-1.2b"): i for i in ARCH_IDS}


def get_config(name: str):
    mod = name.replace("-", "_").replace("_1.2b", "_1p2b")
    if mod not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return import_module(f"repro.configs.{mod}").CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
