"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --mesh 16x16 --steps 100 --ckpt-dir /ckpt/llama3

On real hardware the mesh spans jax.devices(); `--reduced` swaps in the
same-family smoke config so the full path (mesh, shardings, train loop,
checkpointing, restart) can be exercised anywhere, including this CPU
container.  Restart-after-failure = re-running the same command: the
launcher resumes from the newest checkpoint automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.configs.reduced import reduce_config
from repro.launch.mesh import make_mesh
from repro.models import init_params, set_mesh
from repro.sharding import batch_axes, batch_sharding, tree_shardings
from repro.training import (AdamW, checkpoint, make_train_state,
                            make_train_step, synthetic_batch)


def parse_mesh(spec: str, axis_names=("data", "model")):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        axis_names = ("pod", "data", "model")
    return make_mesh(dims, axis_names[:len(dims)])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="same-family smoke config (CPU-sized)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.microbatches:
        cfg = cfg.with_(train_microbatches=args.microbatches)

    if args.mesh:
        mesh = parse_mesh(args.mesh)
    else:
        n = jax.device_count()
        mesh = make_mesh((n, 1), ("data", "model"))
    set_mesh(mesh, batch_axes(mesh))
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.0f}M "
          f"mesh={dict(mesh.shape)} microbatches={cfg.train_microbatches}")

    opt = AdamW(lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                total_steps=args.steps)
    with mesh:
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = make_train_state(params, opt,
                                 compress=args.compress_grads)
        sh = tree_shardings(state, cfg, mesh)
        state = jax.device_put(state, sh)
        start = 0
        if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
            start = checkpoint.latest_step(args.ckpt_dir)
            state = checkpoint.restore(args.ckpt_dir, state, shardings=sh)
            print(f"resumed from step {start}")
        step_fn = jax.jit(
            make_train_step(cfg, opt,
                            microbatches=cfg.train_microbatches,
                            compress_grads=args.compress_grads,
                            grad_shardings=sh.params),
            in_shardings=(sh, batch_sharding(
                synthetic_batch(cfg, args.batch, args.seq), mesh)),
            out_shardings=(sh, None), donate_argnums=(0,))
        t0 = time.time()
        for i in range(start, args.steps):
            batch = synthetic_batch(cfg, args.batch, args.seq, step=i)
            state, m = step_fn(state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(m['loss']):.3f} "
                      f"gnorm={float(m['grad_norm']):.2f}")
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, i + 1, state)
        dt = time.time() - t0
        print(f"{args.steps - start} steps in {dt:.1f}s "
              f"({(args.steps - start) / max(dt, 1e-9):.2f} steps/s)")


if __name__ == "__main__":
    main()
