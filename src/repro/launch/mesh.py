"""Production mesh builders (a FUNCTION, never module-level state)."""
import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
