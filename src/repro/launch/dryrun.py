"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real
train/prefill/serve step with full shardings, compiles, and records
memory/cost/collective analyses for the roofline (EXPERIMENTS.md).

Usage:
    python -m repro.launch.dryrun                    # all cells, both meshes
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --multi-pod        # 2x16x16 cells only
"""
# The two lines below MUST run before any other import (jax locks the
# device count at first init).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ALIASES, ARCH_IDS, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.models import (SHAPES_BY_NAME, applicable_shapes, decode_step,
                          init_cache, init_params, prefill, set_mesh)  # noqa: E402
from repro.models.config import ModelConfig, ShapeSpec    # noqa: E402
from repro.sharding import (batch_axes, batch_sharding, cache_shardings,
                            dp_axes, tree_shardings)               # noqa: E402
from repro.training import AdamW, input_specs, make_train_state, make_train_step  # noqa: E402

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}

_COLL_RE = re.compile(
    r"= \(?([a-z0-9]+\[[0-9,]*\][^)]*?)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> dict:
    """Per-device collective traffic from the post-SPMD HLO (result-shape
    proxy; all-reduce counted 2x for the ring reduce+broadcast)."""
    out = {}
    bytes_total = 0.0
    for m in _COLL_RE.finditer(hlo):
        shapes, op = m.group(1), m.group(2)
        b = _shape_bytes(shapes)
        factor = 2.0 if op == "all-reduce" else 1.0
        key = op
        out[key] = out.get(key, {"count": 0, "bytes": 0})
        out[key]["count"] += 1
        out[key]["bytes"] += int(b * factor)
        bytes_total += b * factor
    out["total_bytes"] = int(bytes_total)
    return out


def cost_analysis_dict(compiled) -> dict:
    """Version-compat wrapper for ``Compiled.cost_analysis()``: newer jax
    returns a per-program list of dicts where older jax returned the dict
    itself.  Returns the (first) program's flat {counter: value} dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _arch_cfg(arch: str) -> ModelConfig:
    return get_config(arch)


def build_lowerable(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate)."""
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if shape.kind == "train":
        opt = AdamW()
        state_sds = jax.eval_shape(
            lambda k: make_train_state(init_params(k, cfg), opt),
            jax.random.PRNGKey(0))
        state_sh = tree_shardings(state_sds, cfg, mesh)
        batch_sds = input_specs(cfg, shape)
        batch_sh = batch_sharding(batch_sds, mesh, axes=dp_axes(cfg, mesh))
        fn = make_train_step(cfg, opt, microbatches=cfg.train_microbatches,
                             grad_shardings=state_sh.params)
        return fn, (state_sds, batch_sds), (state_sh, batch_sh), \
            (state_sh, None), (0,)
    params_sds = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    params_sh = tree_shardings(params_sds, cfg, mesh)
    if shape.kind == "prefill":
        spec = input_specs(cfg, shape)
        tok_sh = batch_sharding(spec["tokens"], mesh)
        extra_sh = batch_sharding(spec["extra"], mesh) \
            if spec["extra"] is not None else None
        cache_out_sds = jax.eval_shape(
            lambda p, t, e: prefill(p, t, cfg, extra=e),
            params_sds, spec["tokens"], spec["extra"])[1]
        cache_sh = cache_shardings(cache_out_sds, cfg, mesh, shape)
        fn = lambda p, t, e: prefill(p, t, cfg, extra=e)
        return fn, (params_sds, spec["tokens"], spec["extra"]), \
            (params_sh, tok_sh, extra_sh), (None, cache_sh), ()
    # decode
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cache_sh = cache_shardings(cache_sds, cfg, mesh, shape)
    tok_sds = input_specs(cfg, shape)["tokens"]
    tok_sh = batch_sharding(tok_sds, mesh)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, c, t, pos: decode_step(p, c, t, pos, cfg)
    return fn, (params_sds, cache_sds, tok_sds, pos_sds), \
        (params_sh, cache_sh, tok_sh, NamedSharding(mesh, P())), \
        (None, cache_sh), (1,)


def _apply_overrides(cfg: ModelConfig, overrides: dict) -> ModelConfig:
    """Flat (remat=full) and nested (xlstm.chunk=64) config overrides."""
    import dataclasses
    flat = {k: v for k, v in overrides.items() if "." not in k}
    if flat:
        cfg = cfg.with_(**flat)
    for k, v in overrides.items():
        if "." in k:
            sub, field_ = k.split(".", 1)
            cfg = cfg.with_(**{sub: dataclasses.replace(
                getattr(cfg, sub), **{field_: v})})
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict = None) -> dict:
    cfg = _arch_cfg(arch)
    if overrides:
        cfg = _apply_overrides(cfg, overrides)
    shape = SHAPES_BY_NAME[shape_name]
    if shape.name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "full-attention arch; 500k decode is out of family "
                          "contract (DESIGN.md #4)"}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh, dp_axes(cfg, mesh))
    fn, args, in_sh, out_sh, donate = build_lowerable(cfg, shape, mesh)
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    res = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "status": "ok", "mesh": dict(mesh.shape),
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}
    try:
        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        res["memory"] = {"error": repr(e)[:200]}
    try:
        ca = cost_analysis_dict(compiled)
        res["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed", "transcendentals",
                                "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        res["cost"] = {"error": repr(e)[:200]}
    try:
        hlo = compiled.as_text()
        res["collectives"] = collective_stats(hlo)
        from repro.launch import hlo_analysis
        res["scan_aware"] = hlo_analysis.analyze(hlo)
    except Exception as e:  # pragma: no cover
        res["collectives"] = {"error": repr(e)[:200]}
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument("--set", nargs="*", default=[], metavar="K=V",
                    help="config overrides, e.g. layout=fsdp remat=full")
    ap.add_argument("--tag", default="", help="suffix for result files")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False}.get(v.lower(), v)
        overrides[k] = v

    archs = [ALIASES.get(args.arch, args.arch)] if args.arch else list(ARCH_IDS)
    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.insert(0, False)
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        cfg = _arch_cfg(arch)
        shapes = [args.shape] if args.shape else \
            [s.name for s in applicable_shapes(cfg)] + \
            (["long_500k"] if not cfg.subquadratic else [])
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}.{shape}.{'2pod' if mp else '1pod'}"
                if args.tag:
                    tag += f".{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp, overrides=overrides)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e)[:500],
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={res['compile_s']}s flops/dev="
                             f"{res['cost'].get('flops', 0):.3e} coll="
                             f"{res['collectives'].get('total_bytes', 0):.2e}B")
                print(f"  -> {status}{extra}", flush=True)
    print("dry-run complete; failures:", failures)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
