"""Scan-aware HLO analysis.

XLA's HloCostAnalysis counts a `while` body exactly once, so for scanned
layer stacks both `flops` and textual collective ops are undercounted by
the trip count.  This module parses the post-SPMD HLO text, builds the
computation call graph (fusion `calls=`, `while` body/cond, `call`
to_apply), extracts each while's trip count from its condition's compare
constant, and accumulates

  * dot FLOPs          2 x prod(result dims) x prod(contracted dims)
  * convolution FLOPs  2 x prod(result dims) x prod(kernel dims)/features
  * collective bytes   result-shape bytes (all-reduce counted 2x)

weighted by the product of enclosing trip counts from ENTRY.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3": 1, "f8e5m2": 1, "c64": 8, "u64": 8}

_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w.\-]+)(?:\.clone)? \(.*\)\s*->", re.M)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT )?%([\w.\-]+) = \(?([a-z0-9]+\[[0-9,]*\])",
                  re.M)
_WHILE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_DOT = re.compile(
    r"%[\w.\-]+ = ([a-z0-9]+\[[0-9,]*\])[^=]*? dot\(%?([\w.\-]+),"
    r".*?lhs_contracting_dims=\{([0-9,]*)\}")
_CONV = re.compile(
    r"%[\w.\-]+ = ([a-z0-9]+\[[0-9,]*\])[^=]*? convolution\(")
_COLL = re.compile(
    r"= \(?((?:[a-z0-9]+\[[0-9,]*\][^)=]*?)+)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE.search(shape_str)
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


def _nbytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (entry name stored under '__entry__')."""
    comps: Dict[str, str] = {}
    spans = [(m.start(), m.group(2), bool(m.group(1)))
             for m in _COMP_HDR.finditer(hlo)]
    for i, (start, name, is_entry) in enumerate(spans):
        end = spans[i + 1][0] if i + 1 < len(spans) else len(hlo)
        comps[name] = hlo[start:end]
        if is_entry:
            comps["__entry__"] = name
    return comps


def _shape_table(body: str) -> Dict[str, str]:
    table = {}
    for m in _DEF.finditer(body):
        table[m.group(1)] = m.group(2)
    # parameters in the header:  (param_0.2: f32[6,128,32], ...)
    hdr = body.split("{", 1)[0]
    for pm in re.finditer(r"([\w.\-]+): \(?([a-z0-9]+\[[0-9,]*\])", hdr):
        table[pm.group(1)] = pm.group(2)
    return table


def _comp_stats(body: str) -> dict:
    table = _shape_table(body)
    flops = 0.0
    for m in _DOT.finditer(body):
        res, lhs_name, contract = m.group(1), m.group(2), m.group(3)
        _, rdims = _dims(res)
        lhs_shape = table.get(lhs_name)
        if lhs_shape is None:
            continue
        _, ldims = _dims(lhs_shape)
        cdims = [int(c) for c in contract.split(",") if c]
        csize = math.prod(ldims[c] for c in cdims) if cdims else 1
        flops += 2.0 * math.prod(rdims) * csize
    conv_flops = 0.0
    for m in _CONV.finditer(body):
        _, rdims = _dims(m.group(1))
        conv_flops += 2.0 * math.prod(rdims)  # lower bound (kernel ~1)
    coll_bytes = 0.0
    coll_ops: Dict[str, int] = {}
    for m in _COLL.finditer(body):
        b = _nbytes(m.group(1))
        op = m.group(2)
        factor = 2.0 if op == "all-reduce" else 1.0
        coll_bytes += b * factor
        coll_ops[op] = coll_ops.get(op, 0) + 1
    return {"flops": flops, "conv_flops": conv_flops,
            "coll_bytes": coll_bytes, "coll_ops": coll_ops,
            "whiles": _WHILE.findall(body),
            "children": set(_CALLS.findall(body))}


def analyze(hlo: str) -> dict:
    """Scan-aware totals for one partition of the compiled module."""
    comps = split_computations(hlo)
    entry = comps.pop("__entry__", None)
    stats = {name: _comp_stats(body) for name, body in comps.items()}

    trip: Dict[str, int] = {}          # body name -> trip count
    for name, st in stats.items():
        for cond, body in st["whiles"]:
            cond_text = comps.get(cond, "")
            consts = [int(c) for c in _CONST_INT.findall(cond_text)]
            trip[body] = max(consts) if consts else 1

    memo: Dict[str, Tuple[float, float, float, dict]] = {}

    def total(name: str, seen=()) -> Tuple[float, float, float, dict]:
        if name in memo:
            return memo[name]
        if name not in stats or name in seen:
            return (0.0, 0.0, 0.0, {})
        st = stats[name]
        f, cf, cb = st["flops"], st["conv_flops"], st["coll_bytes"]
        ops = dict(st["coll_ops"])
        seen = seen + (name,)
        for cond, body in st["whiles"]:
            tf, tcf, tcb, tops = total(body, seen)
            t = trip.get(body, 1)
            f += tf * t
            cf += tcf * t
            cb += tcb * t
            for k, v in tops.items():
                ops[k] = ops.get(k, 0) + v * t
        for child in st["children"]:
            if child in (w[1] for w in st["whiles"]):
                continue
            tf, tcf, tcb, tops = total(child, seen)
            f += tf
            cf += tcf
            cb += tcb
            for k, v in tops.items():
                ops[k] = ops.get(k, 0) + v
        memo[name] = (f, cf, cb, ops)
        return memo[name]

    if entry is None:
        return {"error": "no ENTRY computation found"}
    f, cf, cb, ops = total(entry)
    return {"dot_flops": f, "conv_flops": cf, "collective_bytes": cb,
            "collective_ops": ops,
            "while_trip_counts": sorted(trip.values(), reverse=True)[:8]}
