"""Serving launcher: batched on-demand inference.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.configs.reduced import reduce_config
from repro.models import init_params
from repro.serving import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args(argv)

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = reduce_config(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.0f}M")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    engine.serve_batch(reqs)
    dt = time.time() - t0
    n = sum(len(r.tokens_out) for r in reqs)
    print(f"{n} tokens / {len(reqs)} requests in {dt:.2f}s "
          f"({n/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: ttfb={1e3*(r.first_token_at-r.submitted_at):.0f}ms "
              f"tokens={r.tokens_out[:8]}...")


if __name__ == "__main__":
    main()
