"""Hybrid-workload cluster scheduler CLI (trace-based).

    PYTHONPATH=src python -m repro.launch.cluster --mechanism CUA&SPAA \
        --jobs 600 --mix W5 --seed 0

Runs the paper's scheduler over a synthesized Theta-like trace and prints
the §IV-D metrics.  `--mechanism all` compares everything (Figure 6 row).
"""
from __future__ import annotations

import argparse
import json

from repro.core import (MECHANISMS, SimConfig, Simulator, WorkloadConfig,
                        collect, generate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mechanism", default="CUA&SPAA",
                    help="one of %s, BASE, or 'all'" % (MECHANISMS,))
    ap.add_argument("--nodes", type=int, default=4392)
    ap.add_argument("--jobs", type=int, default=600)
    ap.add_argument("--mix", default="W5")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load", type=float, default=1.15)
    ap.add_argument("--ckpt-factor", type=float, default=1.0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    wcfg = WorkloadConfig(n_nodes=args.nodes, n_jobs=args.jobs,
                          horizon_days=21.0, target_load=args.load,
                          notice_mix=args.mix, seed=args.seed,
                          ckpt_freq_factor=args.ckpt_factor)
    jobs = generate(wcfg)
    mechs = ("BASE",) + MECHANISMS if args.mechanism == "all" \
        else (args.mechanism,)
    for mech in mechs:
        sim = Simulator(SimConfig(n_nodes=args.nodes, mechanism=mech),
                        [j for j in jobs])
        sim.run()
        m = collect(sim)
        if args.json:
            print(json.dumps({"mechanism": mech, **m.as_dict()}))
        else:
            print(f"{mech:10s} turnaround={m.avg_turnaround_h:.1f}h "
                  f"util={m.system_utilization:.3f} "
                  f"instant={m.od_instant_start_rate:.2f} "
                  f"preempt(r/m)={m.preemption_ratio_rigid:.2f}/"
                  f"{m.preemption_ratio_malleable:.2f}")


if __name__ == "__main__":
    main()
