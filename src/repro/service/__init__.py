"""repro.service — the scheduler daemon over the policy engine.

A live, policy-driven control plane: the same registered Notice /
Arrival / Queue / Elasticity policies that drive offline simulations
(``repro.core.policy``) schedule real workloads here — on-demand jobs
are inference demand, malleable jobs are elastic training runs — with
decisions appended to a structured JSONL log whose digest must match an
offline Simulator run on the same trace (the shadow-mode contract;
docs/service.md).

Deliberately jax-free at import: shadow mode (ReplayClock +
DryrunLauncher) runs on CPU-only CI; only LiveClusterLauncher touches
the elastic runtime, and only through the cluster object handed to it.
"""
from .admission import (BACKPRESSURE_POLICIES, AdmissionQueue,
                        AdmissionRejected)
from .clock import ReplayClock
from .core import ServiceCore
from .daemon import (FidelityReport, RecoveryReport, SchedulerService,
                     ServiceConfig, ShadowReport, shadow_fidelity)
from .decisionlog import (DIGEST_EXEMPT_EVENTS, MEASUREMENT_KEYS,
                          DecisionLog, TornLogError, decision_digest,
                          log_segments, read_decision_log)
from .launchers import (DryrunLauncher, Launcher, LiveClusterLauncher,
                        NullLauncher, RetryPolicy, RetryingLauncher,
                        ShadowLaunchError, TransientLaunchError,
                        plan_requests)
from .slo import SloMonitor, SloPolicy, SloReport

__all__ = [
    "AdmissionQueue", "AdmissionRejected", "BACKPRESSURE_POLICIES",
    "ReplayClock", "ServiceCore",
    "FidelityReport", "RecoveryReport", "SchedulerService", "ServiceConfig",
    "ShadowReport", "shadow_fidelity",
    "DIGEST_EXEMPT_EVENTS", "MEASUREMENT_KEYS", "DecisionLog",
    "TornLogError", "decision_digest", "log_segments", "read_decision_log",
    "DryrunLauncher", "Launcher", "LiveClusterLauncher", "NullLauncher",
    "RetryPolicy", "RetryingLauncher", "ShadowLaunchError",
    "TransientLaunchError", "plan_requests",
    "SloMonitor", "SloPolicy", "SloReport",
]
