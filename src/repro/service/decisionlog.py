"""Structured JSONL decision log + the shadow-fidelity digest.

Every placement decision the service core makes is appended as one JSON
line.  Two kinds of field live in a row:

* **deterministic** fields — ``seq``, ``t_sim``, ``event``, ``jid`` and
  the per-event detail (size, victim, beneficiary, ...).  These are a
  pure function of (trace, mechanism) and feed the fidelity digest: a
  sha256 over the canonical rendering of every deterministic row, which
  must equal the digest of an offline :class:`repro.core.Simulator` run
  on the same trace + mechanism (the shadow-mode contract).
* **measurement** fields — ``wall`` (human-readable wall-clock ISO
  stamp), ``mono`` (monotonic seconds), ``latency_ms`` (wall latency of
  the event batch that produced the decision).  These vary run to run
  and are excluded from the digest.

Schema (see docs/service.md for the full table)::

    {"seq": 12, "t_sim": 5400.0, "event": "start", "jid": 7,
     "size": 128, "jtype": "malleable",
     "wall": "2026-08-08T12:00:01Z", "mono": 123.456, "latency_ms": 0.41}
"""
from __future__ import annotations

import hashlib
import json
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

#: row keys that are measurements, not decisions (digest-excluded)
MEASUREMENT_KEYS = ("wall", "mono", "latency_ms")


def _canonical(row: Dict) -> bytes:
    """Stable rendering of a row's deterministic fields."""
    det = {k: v for k, v in row.items() if k not in MEASUREMENT_KEYS}
    return json.dumps(det, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def decision_digest(rows: Iterable[Dict]) -> str:
    """Order-sensitive sha256 over the deterministic fields of every
    decision row — the fidelity fingerprint compared between the live
    service and the offline simulator."""
    h = hashlib.sha256()
    for row in rows:
        h.update(_canonical(row))
        h.update(b"\n")
    return h.hexdigest()


class DecisionLog:
    """Append-only JSONL writer with an incremental fidelity digest and
    an in-memory latency series for the SLO monitor.

    ``path=None`` keeps everything in memory (tests, fidelity reference
    runs); with a path each row is written and flushed as it is appended
    so a crashed daemon leaves a complete prefix on disk.
    """

    def __init__(self, path: Optional[str] = None, keep_rows: bool = True):
        self.path = path
        self.keep_rows = keep_rows
        self.rows: List[Dict] = []
        self.n_rows = 0
        self.latencies_ms: List[float] = []
        self._sha = hashlib.sha256()
        self._fh = open(path, "w") if path else None

    def append(self, decision: Dict, *, latency_ms: Optional[float] = None,
               mono: Optional[float] = None) -> Dict:
        """Append one decision; measurement fields are added here so the
        deterministic part stays exactly what the core emitted."""
        row = dict(decision)
        row["wall"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
        row["mono"] = time.monotonic() if mono is None else mono
        if latency_ms is not None:
            row["latency_ms"] = round(latency_ms, 4)
            self.latencies_ms.append(latency_ms)
        self._sha.update(_canonical(row))
        self._sha.update(b"\n")
        self.n_rows += 1
        if self.keep_rows:
            self.rows.append(row)
        if self._fh is not None:
            self._fh.write(json.dumps(row, sort_keys=True, default=str) + "\n")
            self._fh.flush()
        return row

    @property
    def digest(self) -> str:
        """Digest over every row appended so far (incremental — safe on
        logs too large to retain in memory)."""
        return self._sha.hexdigest()

    def latency_summary(self) -> Dict[str, float]:
        """Decision-latency distribution in milliseconds."""
        if not self.latencies_ms:
            return {"n": 0, "p50_ms": float("nan"), "p90_ms": float("nan"),
                    "p99_ms": float("nan"), "max_ms": float("nan")}
        lat = np.asarray(self.latencies_ms, dtype=np.float64)
        p50, p90, p99 = np.percentile(lat, (50, 90, 99))
        return {"n": int(lat.size), "p50_ms": float(p50), "p90_ms": float(p90),
                "p99_ms": float(p99), "max_ms": float(lat.max())}

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "DecisionLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_decision_log(path: str) -> List[Dict]:
    """Load a JSONL decision log back into row dicts."""
    rows = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
