"""Structured JSONL decision log + the shadow-fidelity digest.

Every placement decision the service core makes is appended as one JSON
line.  Two kinds of field live in a row:

* **deterministic** fields — ``seq``, ``t_sim``, ``event``, ``jid`` and
  the per-event detail (size, victim, beneficiary, ...).  These are a
  pure function of (trace, mechanism) and feed the fidelity digest: a
  sha256 over the canonical rendering of every deterministic row, which
  must equal the digest of an offline :class:`repro.core.Simulator` run
  on the same trace + mechanism (the shadow-mode contract).
* **measurement** fields — ``wall`` (human-readable wall-clock ISO
  stamp), ``mono`` (monotonic seconds), ``latency_ms`` (wall latency of
  the event batch that produced the decision).  These vary run to run
  and are excluded from the digest.

A third class of row exists only in the live service: **runtime rows**
(events in :data:`DIGEST_EXEMPT_EVENTS`, e.g. ``launch_failed`` /
``quarantine``).  They record backend failures, carry ``seq=-1``, and
are excluded from the digest entirely — launcher flakiness must never
perturb the fidelity fingerprint of the decision stream.

Crash tolerance (docs/faults.md):

* each row is written and flushed as it is appended, so a SIGKILL'd
  daemon leaves at worst one *torn* final line;
* ``rotate_bytes`` rotates the active file to ``<path>.<seq>`` on a
  line boundary, bounding any one file's size;
* :func:`read_decision_log` / :meth:`DecisionLog.recover` reassemble
  the rotated segments in order, tolerate a torn tail on the final
  segment (with a warning), and rebuild the incremental digest so a
  recovered log continues producing the exact suffix an uninterrupted
  run would have.

Schema (see docs/service.md for the full table)::

    {"seq": 12, "t_sim": 5400.0, "event": "start", "jid": 7,
     "size": 128, "jtype": "malleable",
     "wall": "2026-08-08T12:00:01Z", "mono": 123.456, "latency_ms": 0.41}
"""
from __future__ import annotations

import hashlib
import json
import os
import time
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

#: row keys that are measurements, not decisions (digest-excluded)
MEASUREMENT_KEYS = ("wall", "mono", "latency_ms")

#: events that are runtime observations, not scheduling decisions —
#: excluded from the fidelity digest so backend flakiness (launch
#: failures, quarantines) never perturbs the shadow-mode contract
DIGEST_EXEMPT_EVENTS = frozenset({"launch_failed", "quarantine"})


def _canonical(row: Dict) -> bytes:
    """Stable rendering of a row's deterministic fields."""
    det = {k: v for k, v in row.items() if k not in MEASUREMENT_KEYS}
    return json.dumps(det, sort_keys=True, separators=(",", ":"),
                      default=str).encode()


def decision_digest(rows: Iterable[Dict]) -> str:
    """Order-sensitive sha256 over the deterministic fields of every
    decision row — the fidelity fingerprint compared between the live
    service and the offline simulator.  Runtime rows
    (:data:`DIGEST_EXEMPT_EVENTS`) are skipped."""
    h = hashlib.sha256()
    for row in rows:
        if row.get("event") in DIGEST_EXEMPT_EVENTS:
            continue
        h.update(_canonical(row))
        h.update(b"\n")
    return h.hexdigest()


class TornLogError(ValueError):
    """A decision-log file is corrupt somewhere other than its final
    line — a torn tail is survivable, a torn middle is not."""


def log_segments(path: str) -> List[str]:
    """All on-disk files of a (possibly rotated) decision log, oldest
    first: ``<path>.1``, ``<path>.2``, ..., then the active ``<path>``."""
    d = os.path.dirname(path) or "."
    base = os.path.basename(path)
    rotated = []
    for name in os.listdir(d):
        if name.startswith(base + "."):
            suffix = name[len(base) + 1:]
            if suffix.isdigit():
                rotated.append((int(suffix), os.path.join(d, name)))
    out = [p for _, p in sorted(rotated)]
    if os.path.exists(path):
        out.append(path)
    return out


def _read_rows(path: str, tolerate_torn: bool) -> Tuple[List[Dict], int]:
    """Parse one JSONL segment; returns ``(rows, good_bytes)`` where
    ``good_bytes`` is the byte offset just past the last complete row.

    A malformed *final* line is a torn tail (crash mid-write): skipped
    with a warning when ``tolerate_torn``.  Malformed content anywhere
    else is real corruption and raises :class:`TornLogError`.
    """
    rows: List[Dict] = []
    good = 0
    with open(path, "rb") as fh:
        data = fh.read()
    offset = 0
    for raw in data.splitlines(keepends=True):
        line = raw.strip()
        complete = raw.endswith(b"\n")
        if line:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                if offset + len(raw) < len(data) or not tolerate_torn:
                    raise TornLogError(
                        f"{path}: corrupt row at byte {offset}") from None
                warnings.warn(f"{path}: torn final line "
                              f"({len(raw)} bytes) skipped", RuntimeWarning)
                return rows, good
            if not complete:      # valid JSON but crash before the newline
                if tolerate_torn:
                    warnings.warn(f"{path}: unterminated final line kept",
                                  RuntimeWarning)
                else:
                    raise TornLogError(f"{path}: unterminated final line")
        offset += len(raw)
        good = offset
    return rows, good


def read_decision_log(path: str) -> List[Dict]:
    """Load a JSONL decision log back into row dicts, reassembling
    rotated segments in order and tolerating a torn final line on the
    last segment (crash-consistency: every complete row survives)."""
    segments = log_segments(path)
    if not segments:
        raise FileNotFoundError(path)
    rows: List[Dict] = []
    for i, seg in enumerate(segments):
        seg_rows, _ = _read_rows(seg, tolerate_torn=(i == len(segments) - 1))
        rows.extend(seg_rows)
    return rows


class DecisionLog:
    """Append-only JSONL writer with an incremental fidelity digest and
    an in-memory latency series for the SLO monitor.

    ``path=None`` keeps everything in memory (tests, fidelity reference
    runs); with a path each row is written and flushed as it is appended
    so a crashed daemon leaves a complete prefix on disk.  With
    ``rotate_bytes`` the active file is rotated to ``<path>.<n>`` once
    it exceeds that size (always on a line boundary).
    """

    def __init__(self, path: Optional[str] = None, keep_rows: bool = True,
                 rotate_bytes: Optional[int] = None):
        self.path = path
        self.keep_rows = keep_rows
        self.rotate_bytes = rotate_bytes
        self.rows: List[Dict] = []
        self.n_rows = 0
        self.latencies_ms: List[float] = []
        self._sha = hashlib.sha256()
        self._active_bytes = 0
        self._rotations = 0
        self._fh = open(path, "w") if path else None

    # ------------------------------------------------------------- rotation
    def _rotate(self) -> None:
        """Rotate the active file to ``<path>.<n>`` and start a fresh
        one.  Called only between complete rows, so every segment is a
        well-formed JSONL file (modulo the final one after a crash)."""
        assert self._fh is not None and self.path is not None
        self._fh.close()
        self._rotations += 1
        os.replace(self.path, f"{self.path}.{self._rotations}")
        self._fh = open(self.path, "w")
        self._active_bytes = 0

    def append(self, decision: Dict, *, latency_ms: Optional[float] = None,
               mono: Optional[float] = None) -> Dict:
        """Append one decision; measurement fields are added here so the
        deterministic part stays exactly what the core emitted."""
        row = dict(decision)
        row["wall"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
        row["mono"] = time.monotonic() if mono is None else mono
        if latency_ms is not None:
            row["latency_ms"] = round(latency_ms, 4)
            self.latencies_ms.append(latency_ms)
        if row.get("event") not in DIGEST_EXEMPT_EVENTS:
            self._sha.update(_canonical(row))
            self._sha.update(b"\n")
        self.n_rows += 1
        if self.keep_rows:
            self.rows.append(row)
        if self._fh is not None:
            line = json.dumps(row, sort_keys=True, default=str) + "\n"
            self._fh.write(line)
            self._fh.flush()
            self._active_bytes += len(line)
            if self.rotate_bytes is not None and \
                    self._active_bytes >= self.rotate_bytes:
                self._rotate()
        return row

    # ------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, path: str, keep_rows: bool = True,
                rotate_bytes: Optional[int] = None
                ) -> Tuple["DecisionLog", List[Dict]]:
        """Reopen a crashed daemon's log for appending.

        Reads every complete row across the rotated segments (a torn
        final line is truncated away with a warning), rebuilds the
        incremental digest over the surviving rows, and returns
        ``(log, rows)`` with the log positioned to append — the digest
        of recovered-prefix + appended-suffix equals that of one
        uninterrupted run.
        """
        segments = log_segments(path)
        if not segments:
            raise FileNotFoundError(path)
        rows: List[Dict] = []
        for i, seg in enumerate(segments):
            last = i == len(segments) - 1
            seg_rows, good = _read_rows(seg, tolerate_torn=last)
            rows.extend(seg_rows)
            if last and seg == path and good < os.path.getsize(seg):
                with open(seg, "r+b") as fh:     # drop the torn tail
                    fh.truncate(good)
        log = cls.__new__(cls)
        log.path = path
        log.keep_rows = keep_rows
        log.rotate_bytes = rotate_bytes
        log.rows = list(rows) if keep_rows else []
        log.n_rows = len(rows)
        log.latencies_ms = [r["latency_ms"] for r in rows
                            if "latency_ms" in r]
        log._sha = hashlib.sha256()
        for row in rows:
            if row.get("event") not in DIGEST_EXEMPT_EVENTS:
                log._sha.update(_canonical(row))
                log._sha.update(b"\n")
        rotated = [s for s in segments if s != path]
        log._rotations = max(
            (int(s.rsplit(".", 1)[1]) for s in rotated), default=0)
        log._active_bytes = os.path.getsize(path) \
            if os.path.exists(path) else 0
        log._fh = open(path, "a")
        return log, rows

    @property
    def digest(self) -> str:
        """Digest over every row appended so far (incremental — safe on
        logs too large to retain in memory)."""
        return self._sha.hexdigest()

    def latency_summary(self) -> Dict[str, float]:
        """Decision-latency distribution in milliseconds."""
        if not self.latencies_ms:
            return {"n": 0, "p50_ms": float("nan"), "p90_ms": float("nan"),
                    "p99_ms": float("nan"), "max_ms": float("nan")}
        lat = np.asarray(self.latencies_ms, dtype=np.float64)
        p50, p90, p99 = np.percentile(lat, (50, 90, 99))
        return {"n": int(lat.size), "p50_ms": float(p50), "p90_ms": float(p90),
                "p99_ms": float(p99), "max_ms": float(lat.max())}

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "DecisionLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
