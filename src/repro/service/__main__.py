"""CLI: replay a workload scenario through the shadow scheduler service.

    python -m repro.service --scenario bursty-od --n-jobs 80 \
        --mechanism "CUA&SPAA" --speed inf --log decisions.jsonl --fidelity

Prints the ShadowReport (or FidelityReport) as JSON; exits non-zero when
an SLO or the fidelity contract is violated, so the same invocation
works as a CI gate.  ``--speed 60`` replays at one simulated minute per
wall second (watchable); the default ``inf`` never sleeps.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core.workloads import get_scenario, registered_scenarios

from .daemon import SchedulerService, ServiceConfig, shadow_fidelity
from .launchers import DryrunLauncher
from .slo import SloPolicy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Shadow-mode scheduler service replay.")
    ap.add_argument("--scenario", default="bursty-od",
                    help="workload preset (see --list-scenarios)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--n-jobs", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mechanism", default="CUA&SPAA")
    ap.add_argument("--queue-policy", default="EASY")
    ap.add_argument("--speed", default="inf",
                    help="sim-seconds per wall-second, or 'inf'")
    ap.add_argument("--log", default=None, metavar="PATH",
                    help="write the JSONL decision log here")
    ap.add_argument("--decision-p99-ms", type=float, default=10.0)
    ap.add_argument("--fidelity", action="store_true",
                    help="also run the offline reference and compare")
    args = ap.parse_args(argv)

    if args.list_scenarios:
        print("\n".join(registered_scenarios()))
        return 0

    scn = get_scenario(args.scenario, n_jobs=args.n_jobs)
    jobs, n_nodes = scn.realize(args.seed)
    cfg = ServiceConfig(
        n_nodes=n_nodes, mechanism=args.mechanism,
        queue_policy=args.queue_policy, speed=float(args.speed),
        decision_log_path=args.log,
        slo=SloPolicy(decision_p99_ms=args.decision_p99_ms))

    if args.fidelity:
        rep = shadow_fidelity(jobs, cfg)
        print(json.dumps(rep.as_dict(), indent=2, default=str))
        return 0 if (rep.ok and rep.service.ok) else 1

    svc = SchedulerService(cfg, jobs, launcher=DryrunLauncher(n_nodes))
    rep = svc.run_replay()
    print(json.dumps(rep.as_dict(), indent=2, default=str))
    return 0 if rep.ok else 1


if __name__ == "__main__":
    sys.exit(main())
