"""The scheduler service daemon: replay-paced and live event loops.

:class:`SchedulerService` wires the pieces together — a
:class:`~repro.service.core.ServiceCore` (the narrating simulator), a
:class:`~repro.service.clock.ReplayClock` (wall→sim pacing), a
:class:`~repro.service.decisionlog.DecisionLog` (JSONL + fidelity
digest), an :class:`~repro.service.slo.SloMonitor` (gates), and a
:class:`~repro.service.launchers.Launcher` (execution backend).

Two loops share the core:

* :meth:`SchedulerService.run_replay` — shadow mode.  A trace or
  Scenario's jobs arrive as live traffic at ``speed`` sim-seconds per
  wall-second (``inf`` = as fast as decisions can be made, the CI
  mode).  Each iteration sleeps until the next event's sim time, steps
  the core through exactly that event batch under a perf_counter, and
  appends the drained decisions with the batch latency attached.
* :meth:`SchedulerService.run_live` — jobs arrive through an
  :class:`~repro.service.admission.AdmissionQueue` instead of a trace;
  the loop polls admissions between batches and exits when the queue
  is closed and the core drains.

The pacing loop passes ``step_until`` a non-decreasing sequence of
limits, which the simulator guarantees processes the exact event
sequence one offline ``run()`` would — see docs/service.md for why that
makes shadow fidelity hold by construction rather than by testing luck.

Batch scheduling rounds (``SimConfig.batch_rounds``, via
``ServiceConfig.sim_overrides``) need no daemon changes:
``Simulator.next_event_time`` reports a pending deferred pass's round
boundary as the next event, so both loops sleep to round boundaries and
each ``step_until(next_event_time())`` call runs the deferred pass at
exactly its boundary.  Shadow fidelity still holds by construction —
the offline comparison run shares the same ``batch_rounds``.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.job import JobSpec
from repro.core.simulator import JobRecord, SimConfig, Simulator

from .admission import AdmissionQueue
from .clock import ReplayClock
from .core import ServiceCore
from .decisionlog import (DIGEST_EXEMPT_EVENTS, DecisionLog, decision_digest)
from .launchers import (DryrunLauncher, Launcher, NullLauncher,
                        RetryingLauncher)
from .slo import SloMonitor, SloPolicy


@dataclass
class ServiceConfig:
    """Service-level knobs; simulator mechanics ride in ``sim_overrides``."""

    n_nodes: int
    mechanism: str = "CUA&SPAA"
    queue_policy: str = "EASY"
    #: sim-seconds per wall-second; ``inf`` never sleeps (CI/benchmarks)
    speed: float = math.inf
    decision_log_path: Optional[str] = None
    keep_log_rows: bool = True
    #: rotate the decision log to ``<path>.<n>`` past this size (None = never)
    log_rotate_bytes: Optional[int] = None
    #: pull a node from service when a launch action fails persistently
    quarantine_on_launch_failure: bool = True
    slo: SloPolicy = field(default_factory=SloPolicy)
    sim_overrides: Dict[str, object] = field(default_factory=dict)

    def sim_config(self) -> SimConfig:
        return SimConfig(n_nodes=self.n_nodes, mechanism=self.mechanism,
                         queue_policy=self.queue_policy, **self.sim_overrides)


@dataclass
class ShadowReport:
    """What one service run produced, shaped for CI artifacts."""

    ok: bool                      # every SLO held
    digest: str                   # fidelity fingerprint of the decision log
    n_decisions: int
    n_jobs: int
    finish_time: float            # sim time of the last completion
    wall_s: float                 # wall clock the replay took
    latency: Dict[str, float]     # decision-latency summary (ms)
    slo: Dict                     # SloReport.as_dict()
    launcher_counts: Optional[Dict[str, int]] = None
    admission_counts: Optional[Dict[str, int]] = None   # live mode only

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class SchedulerService:
    """One service instance = one core + one decision log + one launcher.

    Without a ``record_sink`` the core retains every JobRecord (tests
    and fidelity checks read them back); with one, records retire
    streamingly through the monitor into the sink and the service holds
    O(active) state — the year-scale replay posture.
    """

    def __init__(self, cfg: ServiceConfig, jobs: Iterable[JobSpec] = (),
                 launcher: Optional[Launcher] = None,
                 record_sink: Optional[Callable[[JobRecord], None]] = None):
        self.cfg = cfg
        self.launcher = launcher or NullLauncher()
        self.monitor = SloMonitor(cfg.slo)
        self._streaming = record_sink is not None
        sink = None
        if record_sink is not None:
            def sink(rec, _user=record_sink):
                self.monitor.add_record(rec)
                _user(rec)
        jobs = jobs if not isinstance(jobs, tuple) else list(jobs)
        self.core = ServiceCore(cfg.sim_config(), jobs,
                                launcher=self.launcher, record_sink=sink)
        self.log = DecisionLog(cfg.decision_log_path,
                               keep_rows=cfg.keep_log_rows,
                               rotate_bytes=cfg.log_rotate_bytes)
        if isinstance(self.launcher, RetryingLauncher) and \
                self.launcher.on_give_up is None:
            self.launcher.on_give_up = self._on_launch_failed
        self.clock: Optional[ReplayClock] = None
        self._admission: Optional[AdmissionQueue] = None
        self.wall_s = 0.0

    def _on_launch_failed(self, action: str, subject, exc: Exception) -> None:
        """A backend action failed persistently (RetryingLauncher gave
        up).  Record it as a runtime row — ``seq=-1``, digest-exempt, so
        the fidelity fingerprint is untouched — and optionally pull a
        node out of service on the theory that repeated launch failures
        mean bad hardware."""
        jid = getattr(subject, "jid",
                      getattr(getattr(subject, "job", None), "jid", -1))
        self.log.append({"seq": -1, "t_sim": round(self.core.now, 6),
                         "event": "launch_failed", "jid": jid,
                         "action": action, "error": str(exc)})
        if self.cfg.quarantine_on_launch_failure:
            self.core.quarantine(1)

    # ------------------------------------------------------------ event loop
    def _step_batch(self, t_next: float) -> None:
        """Process one event batch under the latency meter and log the
        decisions it produced (log I/O stays outside the meter: the SLO
        bounds scheduling latency, not disk flushes)."""
        t0 = time.perf_counter()
        self.core.step_until(t_next)
        lat_ms = (time.perf_counter() - t0) * 1e3
        self.monitor.add_decision_latency(lat_ms)
        for d in self.core.drain_decisions():
            self.log.append(d, latency_ms=lat_ms)
        self.launcher.tick()

    def _wind_down(self, t0_wall: float) -> ShadowReport:
        self.core.finalize()
        self.launcher.close()
        self.log.close()
        if not self._streaming:           # harvest od waits post-hoc
            for rec in self.core.records.values():
                self.monitor.add_record(rec)
        self.wall_s = time.monotonic() - t0_wall
        return self.report()

    def run_replay(self) -> ShadowReport:
        """Shadow mode: replay the constructor's jobs as live arrivals."""
        t0_wall = time.monotonic()
        first = self.core.next_event_time()
        self.clock = ReplayClock(self.cfg.speed,
                                 origin=first if first is not None else 0.0)
        while True:
            t_next = self.core.next_event_time()
            if t_next is None:
                break
            self.clock.sleep_until(t_next)
            self._step_batch(t_next)
        return self._wind_down(t0_wall)

    def run_live(self, admission: AdmissionQueue,
                 poll_s: float = 0.02) -> ShadowReport:
        """Live mode: drain an admission queue between event batches;
        returns once the queue is closed and the core has drained.  The
        core must have been built with ``jobs=[]`` (see
        ``ServiceCore.admit``)."""
        self._admission = admission
        t0_wall = time.monotonic()
        self.clock = ReplayClock(self.cfg.speed, origin=self.core.now)
        while True:
            for spec in admission.drain():
                self.core.admit(spec)
            t_next = self.core.next_event_time()
            if t_next is None:
                if admission.closed and not len(admission):
                    break
                time.sleep(poll_s)
                continue
            now_sim = self.clock.now_sim()
            if t_next <= now_sim:
                self._step_batch(t_next)
                continue
            # next event is in the (scaled) future: nap, but wake early
            # enough to notice new admissions
            time.sleep(min(poll_s, (t_next - now_sim) / self.cfg.speed))
        return self._wind_down(t0_wall)

    # --------------------------------------------------------------- results
    def report(self) -> ShadowReport:
        slo = self.monitor.report()
        counts = getattr(self.launcher, "counts", None)
        adm = self._admission
        return ShadowReport(
            ok=slo.ok, digest=self.log.digest,
            n_decisions=self.log.n_rows, n_jobs=self.core.n_ingested,
            finish_time=self.core.finish_time(),
            wall_s=round(self.wall_s, 3),
            latency=self.log.latency_summary(), slo=slo.as_dict(),
            launcher_counts=dict(counts) if counts is not None else None,
            admission_counts=dict(adm.counts) if adm is not None else None)

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, cfg: ServiceConfig, jobs: Iterable[JobSpec],
                launcher: Optional[Launcher] = None,
                record_sink: Optional[Callable[[JobRecord], None]] = None
                ) -> Tuple["SchedulerService", "RecoveryReport"]:
        """Resume a killed daemon from its on-disk decision log.

        Because the decision stream is a pure function of
        (trace, mechanism), recovery is deterministic replay: read every
        complete row from the (possibly rotated, possibly torn) log at
        ``cfg.decision_log_path``, build a fresh core over the same jobs,
        and step it until it has re-made exactly the logged decisions.
        The replayed prefix's digest must equal the logged prefix's —
        proof the recovered core stands in the crashed daemon's exact
        state — then any overshoot (decisions the crashed daemon made
        but never flushed... impossible, or ones the replay batch made
        past the last logged row) is appended, and the service continues
        with the recovered log open for append.  The returned service's
        eventual digest is identical to an uninterrupted run's.

        Limitation: replay assumes the crashed run's *decision-affecting*
        state came only from (trace, mechanism).  Runtime quarantines
        (``launch_failed`` rows) shrink the free pool, so runs that
        quarantined nodes cannot be byte-faithfully replayed — recovery
        then reports ``digests_match=False`` rather than guessing.
        """
        if not cfg.decision_log_path:
            raise ValueError("recover() needs cfg.decision_log_path")
        log, rows = DecisionLog.recover(cfg.decision_log_path,
                                        keep_rows=cfg.keep_log_rows,
                                        rotate_bytes=cfg.log_rotate_bytes)
        logged = [r for r in rows
                  if r.get("event") not in DIGEST_EXEMPT_EVENTS]
        k = len(logged)

        bare = replace(cfg, decision_log_path=None)
        svc = cls(bare, list(jobs), launcher=launcher,
                  record_sink=record_sink)
        svc.cfg = cfg
        svc.log.close()
        svc.log = log                 # appends continue the on-disk stream

        replayed: List[Dict] = []
        while svc.core.n_decisions < k:
            t_next = svc.core.next_event_time()
            if t_next is None:
                break                 # log claims more decisions than trace
            svc.core.step_until(t_next)
            replayed.extend(svc.core.drain_decisions())
        dec = [r for r in replayed
               if r.get("event") not in DIGEST_EXEMPT_EVENTS]
        runtime = [r for r in replayed
                   if r.get("event") in DIGEST_EXEMPT_EVENTS]
        prefix_digest = decision_digest(dec[:k])
        digests_match = prefix_digest == decision_digest(logged)
        for d in dec[k:] + runtime:   # decisions past the last flushed row
            log.append(d)
        report = RecoveryReport(
            ok=digests_match, digests_match=digests_match,
            n_log_rows=len(rows), n_decisions_recovered=k,
            n_overshoot=max(0, len(dec) - k),
            digest_prefix=prefix_digest, resumed_at=svc.core.now)
        return svc, report


@dataclass
class RecoveryReport:
    """What :meth:`SchedulerService.recover` reconstructed."""

    ok: bool                      # replayed prefix digest == logged digest
    digests_match: bool
    n_log_rows: int               # complete rows read back (incl. runtime)
    n_decisions_recovered: int    # decision rows the replay had to re-make
    n_overshoot: int              # extra decisions the final batch produced
    digest_prefix: str
    resumed_at: float             # sim time the recovered core stands at

    def as_dict(self) -> dict:
        return dict(self.__dict__)


# ------------------------------------------------------------------ fidelity
@dataclass
class FidelityReport:
    """Shadow-mode contract check: the paced service vs the offline
    simulator on the identical trace + mechanism."""

    ok: bool                      # digests match AND records match
    digests_match: bool
    records_match: bool
    digest_service: str
    digest_reference: str
    n_jobs: int
    mismatched_jids: List[int]
    service: ShadowReport

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["service"] = self.service.as_dict()
        return d


def shadow_fidelity(jobs: Iterable[JobSpec], cfg: ServiceConfig,
                    launcher: Optional[Launcher] = None) -> FidelityReport:
    """Run the paced shadow service AND the offline reference on the
    same jobs, then compare:

    1. decision digests — the service's paced ``step_until`` stream vs
       one offline ``run()`` of an identical narrating core;
    2. job records — first_start / completion / killed / preemption and
       shrink counts per jid against a *plain* Simulator (no service
       code in the loop at all).

    Both must match exactly; this is the gate benchmarks/run.py and CI
    enforce.  JobSpecs are shared across the three runs (the simulator
    never mutates specs after construction).
    """
    jobs = list(jobs)
    svc = SchedulerService(cfg, list(jobs),
                           launcher=launcher
                           if launcher is not None
                           else DryrunLauncher(cfg.n_nodes))
    rep = svc.run_replay()

    ref = ServiceCore(cfg.sim_config(), list(jobs), launcher=NullLauncher())
    ref.run()
    ref_digest = decision_digest(ref.drain_decisions())

    sim = Simulator(cfg.sim_config(), list(jobs))
    sim_records = sim.run()
    mismatched = []
    for jid, r in sim_records.items():
        s = svc.core.records.get(jid)
        if s is None or (s.first_start, s.completion, s.killed,
                         s.n_preempted, s.n_shrunk) != \
                (r.first_start, r.completion, r.killed,
                 r.n_preempted, r.n_shrunk):
            mismatched.append(jid)
    if len(svc.core.records) != len(sim_records):
        mismatched.append(-1)     # sentinel: record sets differ in size

    digests_match = rep.digest == ref_digest
    records_match = not mismatched
    return FidelityReport(ok=digests_match and records_match,
                          digests_match=digests_match,
                          records_match=records_match,
                          digest_service=rep.digest,
                          digest_reference=ref_digest,
                          n_jobs=len(jobs),
                          mismatched_jids=mismatched,
                          service=rep)
