"""Launch backends: how the service's decisions become execution.

The decision core narrates placements (start / resize / preempt /
finish); a :class:`Launcher` turns them into work:

    DryrunLauncher       shadow mode (CPU-only CI): no model runs, but
                         the action stream is *validated* against a node
                         ledger — an illegal sequence (double start,
                         resize of a non-running job, capacity overflow)
                         raises ShadowLaunchError, in the spirit of
                         repro.launch.dryrun proving configs coherent
                         without hardware.  On-demand starts synthesize
                         the deterministic inference-request batch that
                         WOULD be admitted to ServeEngine.
    LiveClusterLauncher  decisions drive a real LiveCluster: batch jobs
                         become ElasticJob training runs, on-demand
                         starts vacate nodes through the cluster's own
                         registry-resolved arrival policy and serve an
                         inference batch, leases return on completion.

A launcher never makes decisions — it executes (or records) them, so a
shadow run and a live run see the identical decision sequence.
"""
from __future__ import annotations

import random
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.job import JobSpec, JobType
from repro.core.simulator import JobRecord


class ShadowLaunchError(RuntimeError):
    """The decision stream asked the launcher for an impossible action —
    a scheduler-core invariant was violated."""


class TransientLaunchError(RuntimeError):
    """A backend action failed in a way that retrying may fix (node
    momentarily unreachable, RPC timeout, ...).  RetryingLauncher
    retries these; anything else it treats as persistent."""


class Launcher:
    """No-op base; every hook receives already-made decisions."""

    def start_job(self, job: JobSpec, size: int) -> None:
        """Job placed on ``size`` nodes (on-demand included)."""

    def resize(self, job: JobSpec, new_size: int) -> None:
        """Running malleable shrunk/expanded to ``new_size`` nodes."""

    def preempt(self, job: JobSpec) -> None:
        """Running job vacated (will re-queue and start again later)."""

    def finish(self, rec: JobRecord) -> None:
        """Job reached its END event (record carries completion state)."""

    def tick(self) -> None:
        """Called once per daemon loop iteration — live backends use it
        to advance real work (training steps) between decisions."""

    def close(self) -> None:
        """Replay drained; release any live resources."""


class NullLauncher(Launcher):
    """Decisions logged, nothing executed (fidelity reference runs)."""


def plan_requests(job: JobSpec, max_batch: int = 8,
                  vocab: int = 1024) -> List[dict]:
    """The deterministic inference-request batch an on-demand job admits
    to the serving engine: one request per node up to ``max_batch``,
    prompt length and token budget derived from the jid so shadow and
    live runs plan the identical batch."""
    n = max(1, min(int(job.size), max_batch))
    return [{"rid": job.jid * max_batch + i,
             "prompt_len": 8 + (job.jid * 7 + i * 3) % 56,
             "max_new_tokens": 16,
             "vocab": vocab}
            for i in range(n)]


@dataclass
class _ShadowJob:
    size: int
    jtype: str
    n_starts: int = 1
    n_resizes: int = 0
    n_preempts: int = 0


class DryrunLauncher(Launcher):
    """Validating shadow backend.

    Keeps a node-count ledger mirroring what execution would occupy and
    checks every action against it; records a per-job action history and
    aggregate counters (the benchmark/CI artifact).  ``n_nodes=None``
    skips the capacity check (unknown machine size).
    """

    def __init__(self, n_nodes: Optional[int] = None):
        self.n_nodes = n_nodes
        self.active: Dict[int, _ShadowJob] = {}
        self.counts: Dict[str, int] = {
            "start": 0, "od_start": 0, "resize": 0, "preempt": 0,
            "finish": 0, "requests_planned": 0}
        self.request_plans: Dict[int, List[dict]] = {}

    # ------------------------------------------------------------- helpers
    def _occupied(self) -> int:
        return sum(j.size for j in self.active.values())

    def _check_capacity(self) -> None:
        if self.n_nodes is not None and self._occupied() > self.n_nodes:
            raise ShadowLaunchError(
                f"decision stream over-commits the machine: "
                f"{self._occupied()} > {self.n_nodes} nodes occupied")

    # --------------------------------------------------------------- hooks
    def start_job(self, job: JobSpec, size: int) -> None:
        if job.jid in self.active:
            raise ShadowLaunchError(f"job {job.jid} started while running")
        if size <= 0:
            raise ShadowLaunchError(f"job {job.jid} started on {size} nodes")
        self.active[job.jid] = _ShadowJob(size=size, jtype=job.jtype.value)
        self._check_capacity()
        self.counts["start"] += 1
        if job.jtype is JobType.ONDEMAND:
            self.counts["od_start"] += 1
            plan = plan_requests(job)
            self.request_plans[job.jid] = plan
            self.counts["requests_planned"] += len(plan)

    def resize(self, job: JobSpec, new_size: int) -> None:
        sj = self.active.get(job.jid)
        if sj is None:
            raise ShadowLaunchError(f"resize of non-running job {job.jid}")
        if not (0 < new_size <= job.n_max) or \
                (job.jtype is JobType.MALLEABLE and new_size < job.n_min):
            raise ShadowLaunchError(
                f"job {job.jid} resized to {new_size} outside "
                f"[{job.n_min}, {job.n_max}]")
        sj.size = new_size
        sj.n_resizes += 1
        self._check_capacity()
        self.counts["resize"] += 1

    def preempt(self, job: JobSpec) -> None:
        sj = self.active.pop(job.jid, None)
        if sj is None:
            raise ShadowLaunchError(f"preempt of non-running job {job.jid}")
        self.counts["preempt"] += 1

    def finish(self, rec: JobRecord) -> None:
        if self.active.pop(rec.job.jid, None) is None:
            raise ShadowLaunchError(
                f"finish of non-running job {rec.job.jid}")
        self.counts["finish"] += 1

    def close(self) -> None:
        if self.active:
            raise ShadowLaunchError(
                f"replay drained with jobs still marked running: "
                f"{sorted(self.active)}")


@dataclass
class RetryPolicy:
    """Exponential backoff with full jitter for flaky backend actions.

    Delay before attempt ``i`` (1-based retries) is drawn uniformly in
    ``[0, min(max_delay_s, base_delay_s * 2**(i-1))]`` — the classic
    full-jitter scheme that decorrelates thundering retries.  The jitter
    stream is its own seeded :class:`random.Random`, so retry timing
    never touches the simulator's RNGs (decision determinism is
    unaffected by how flaky the backend is).
    """

    retries: int = 3              # attempts AFTER the first try
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    timeout_s: Optional[float] = None   # per-attempt wall budget
    jitter: bool = True
    seed: int = 0


class RetryingLauncher(Launcher):
    """Wrap a launcher so transient backend failures do not kill the
    daemon.

    Each hook is tried up to ``1 + policy.retries`` times; only
    :class:`TransientLaunchError` (and, with ``timeout_s``, a transient
    attempt that overran its wall budget) is retried.
    :class:`ShadowLaunchError` is a *scheduler* invariant violation and
    is always re-raised immediately — retrying an illegal decision
    cannot make it legal.  When retries are exhausted (or the error is
    persistent and not a shadow error) the failure goes to
    ``on_give_up(action, job_or_rec, exc)`` if provided — the daemon
    uses this to log a ``launch_failed`` row and quarantine a node —
    else it is swallowed with a warning: the decision stream must keep
    flowing even when the backend cannot keep up.
    """

    def __init__(self, inner: Launcher, policy: Optional[RetryPolicy] = None,
                 on_give_up: Optional[Callable[[str, object, Exception],
                                               None]] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.inner = inner
        self.policy = policy or RetryPolicy()
        self.on_give_up = on_give_up
        self._sleep = sleep
        self._rng = random.Random(self.policy.seed)
        self.launch_retries = 0
        self.launch_failures = 0

    # ------------------------------------------------------------- plumbing
    def _delay(self, attempt: int) -> float:
        cap = min(self.policy.max_delay_s,
                  self.policy.base_delay_s * (2 ** attempt))
        return self._rng.uniform(0.0, cap) if self.policy.jitter else cap

    def _call(self, action: str, subject, fn, *args) -> None:
        p = self.policy
        for attempt in range(1 + p.retries):
            t0 = time.monotonic()
            try:
                fn(*args)
                return
            except ShadowLaunchError:
                raise                     # invariant violation — always fatal
            except TransientLaunchError as exc:
                if p.timeout_s is not None and \
                        time.monotonic() - t0 > p.timeout_s:
                    err: Exception = TimeoutError(
                        f"{action} attempt exceeded {p.timeout_s}s "
                        f"budget ({exc})")
                else:
                    err = exc
                if attempt < p.retries:
                    self.launch_retries += 1
                    self._sleep(self._delay(attempt))
                    continue
                return self._give_up(action, subject, err)
            except Exception as exc:      # persistent — no point retrying
                return self._give_up(action, subject, exc)

    def _give_up(self, action: str, subject, exc: Exception) -> None:
        self.launch_failures += 1
        if self.on_give_up is not None:
            self.on_give_up(action, subject, exc)
        else:
            warnings.warn(f"launcher {action} gave up after retries: {exc}",
                          RuntimeWarning)

    # --------------------------------------------------------------- hooks
    def start_job(self, job: JobSpec, size: int) -> None:
        self._call("start", job, self.inner.start_job, job, size)

    def resize(self, job: JobSpec, new_size: int) -> None:
        self._call("resize", job, self.inner.resize, job, new_size)

    def preempt(self, job: JobSpec) -> None:
        self._call("preempt", job, self.inner.preempt, job)

    def finish(self, rec: JobRecord) -> None:
        self._call("finish", rec, self.inner.finish, rec)

    def tick(self) -> None:
        self.inner.tick()

    def close(self) -> None:
        self.inner.close()

    @property
    def counts(self) -> Dict[str, int]:
        inner = getattr(self.inner, "counts", None)
        out = dict(inner) if inner is not None else {}
        out["launch_retries"] = self.launch_retries
        out["launch_failures"] = self.launch_failures
        return out


class LiveClusterLauncher(Launcher):
    """Execute decisions on a real :class:`repro.runtime.LiveCluster`.

    ``job_factory(job: JobSpec) -> ElasticJob`` builds the training
    payload for rigid/malleable jobs; ``serve_fn(job, node_ids)`` (if
    given) runs the inference batch for an on-demand start on the nodes
    the cluster vacated.  The *cluster's own* registry-resolved arrival
    policy picks shrink/preemption victims when on-demand demand arrives
    — the service's shadow ledger stays authoritative for WHAT starts
    WHEN, the cluster for WHICH physical nodes move (see
    docs/service.md).  Shrink/expand decisions for batch jobs are
    handled by the cluster's own lease mechanics, so :meth:`resize` and
    :meth:`preempt` only track counters here.
    """

    def __init__(self, cluster, job_factory: Callable[[JobSpec], object],
                 serve_fn: Optional[Callable[[JobSpec, List[int]], object]]
                 = None, steps_per_tick: int = 1,
                 target_steps: int = 20):
        self.cluster = cluster
        self.job_factory = job_factory
        self.serve_fn = serve_fn
        self.steps_per_tick = steps_per_tick
        self.target_steps = target_steps
        self.od_nodes: Dict[int, List[int]] = {}
        self.infos: Dict[int, object] = {}
        self.served: List[object] = []

    def start_job(self, job: JobSpec, size: int) -> None:
        if job.jtype is JobType.ONDEMAND:
            nodes = self.cluster.acquire_for_ondemand(size)
            self.od_nodes[job.jid] = nodes
            if self.serve_fn is not None:
                self.served.append(self.serve_fn(job, nodes))
            return
        if job.jid in self.infos:       # restart after preemption
            return                      # cluster resumes it on free nodes
        ej = self.job_factory(job)
        n_min = job.n_min if job.jtype is JobType.MALLEABLE else size
        self.infos[job.jid] = self.cluster.submit(
            ej, min_nodes=max(1, n_min), max_nodes=size,
            target_steps=self.target_steps)

    def finish(self, rec: JobRecord) -> None:
        nodes = self.od_nodes.pop(rec.job.jid, None)
        if nodes is not None:
            self.cluster.release_ondemand(nodes)

    def tick(self) -> None:
        self.cluster.step_all(self.steps_per_tick)

    def close(self) -> None:
        for jid, nodes in list(self.od_nodes.items()):
            self.cluster.release_ondemand(nodes)
            del self.od_nodes[jid]
