"""ServiceCore: the scheduler daemon's decision engine.

Fidelity by construction — ServiceCore IS the offline
:class:`repro.core.Simulator`, subclassed to narrate.  It overrides the
simulator's placement primitives (`_begin_run`, `_preempt`, `_shrink`,
`_expand`, `_on_end`, `_on_od_timeout`) to emit one structured decision
row per action and forward it to a :class:`~repro.service.launchers.
Launcher`; it adds no logic of its own, so the decision sequence a paced
replay produces (``step_until`` with the daemon's non-decreasing limits)
is bit-identical to what one offline ``run()`` on the same trace +
mechanism produces.  That identity, fingerprinted by
:func:`~repro.service.decisionlog.decision_digest`, is the shadow-mode
contract (docs/service.md).

Launcher hooks fire *before* the superclass mutates state: a preempt/end
frees nodes that the same event may immediately hand to an expand, and a
validating launcher's mirror ledger must see the release first or it
would report a phantom over-commit.  (`finish` therefore receives the
record before ``completion`` is stamped — backends key on ``rec.job``.)
"""
from __future__ import annotations

import itertools
from dataclasses import replace
from typing import Callable, Dict, Iterable, List, Optional

from repro.core.job import JobSpec, NoticeKind
from repro.core.simulator import JobRecord, SimConfig, Simulator

from .launchers import Launcher, NullLauncher


class ServiceCore(Simulator):
    """A Simulator that narrates every placement decision.

    Decisions accumulate in ``self._pending`` until the daemon drains
    them (:meth:`drain_decisions`) into the decision log — the core
    never blocks on I/O inside an event handler, so decision latency
    measures scheduling, not logging.
    """

    def __init__(self, cfg: SimConfig, jobs: Iterable[JobSpec],
                 launcher: Optional[Launcher] = None,
                 record_sink: Optional[Callable[[JobRecord], None]] = None):
        # narration state must exist before super().__init__ ingests jobs
        self.launcher = launcher or NullLauncher()
        self._pending: List[Dict] = []
        self._dseq = itertools.count()
        self.n_decisions = 0
        self._pending_quarantine = 0
        self.n_quarantined = 0
        super().__init__(cfg, jobs, record_sink=record_sink)

    # ------------------------------------------------------------- narration
    def _emit(self, event: str, jid: int, **detail) -> None:
        row = {"seq": next(self._dseq), "t_sim": round(self.now, 6),
               "event": event, "jid": jid}
        row.update(detail)
        self._pending.append(row)
        self.n_decisions += 1

    def _emit_runtime(self, event: str, jid: int, **detail) -> None:
        """Emit a runtime observation row (``seq=-1``).  These record
        backend incidents, not scheduling decisions: they consume no
        decision seq and do not count toward ``n_decisions``, so the
        deterministic decision stream — and its digest — is identical
        with or without them (see DIGEST_EXEMPT_EVENTS)."""
        row = {"seq": -1, "t_sim": round(self.now, 6),
               "event": event, "jid": jid}
        row.update(detail)
        self._pending.append(row)

    def drain_decisions(self) -> List[Dict]:
        """Hand off (and clear) the decisions emitted since the last
        drain — the daemon appends them to the DecisionLog."""
        out, self._pending = self._pending, []
        return out

    # ----------------------------------------------------- live-mode ingress
    def admit(self, job: JobSpec) -> JobSpec:
        """Admit a job submitted to the *live* service (not replayed from
        a trace).  Times are clamped to the current clock so an admission
        racing the event loop can never submit in the past; returns the
        (possibly adjusted) spec actually ingested.  Only valid on the
        materialized path (live cores are built with ``jobs=[]``)."""
        if self._arrivals is not None:
            raise RuntimeError("admit() on a trace-replaying core; live "
                               "admission needs a core built with jobs=[]")
        if job.jid in self.jobs or job.jid in self.records:
            raise ValueError(f"duplicate admission of jid {job.jid}")
        fix = {}
        if job.submit_time < self.now:
            fix["submit_time"] = self.now
        if job.notice_kind is not NoticeKind.NONE:
            if job.notice_time is None or job.notice_time < self.now:
                fix["notice_time"] = self.now
            if job.est_arrival is None or \
                    job.est_arrival < fix.get("submit_time", job.submit_time):
                fix["est_arrival"] = fix.get("submit_time", job.submit_time)
        if fix:
            job = replace(job, **fix)
        self._ingest(job)
        self._emit("admit", job.jid, jtype=job.jtype.value,
                   submit_time=round(job.submit_time, 6), size=job.size)
        return job

    # ----------------------------------------------- narrated sim primitives
    def _begin_run(self, jid: int, size: int) -> None:
        job = self.jobs[jid]
        restart = jid in self.progress   # carry-over => restart after preempt
        self.launcher.start_job(job, size)
        self._emit("start", jid, size=size, jtype=job.jtype.value,
                   restart=restart)
        super()._begin_run(jid, size)

    def _preempt(self, jid: int, beneficiary: Optional[int] = None,
                 lost: int = 0) -> None:
        rs = self.running[jid]
        self.launcher.preempt(rs.job)
        if lost:
            self._emit("preempt", jid, size=rs.cur_size,
                       beneficiary=beneficiary, lost=lost)
        else:   # legacy detail shape — keeps fault-free digests unchanged
            self._emit("preempt", jid, size=rs.cur_size,
                       beneficiary=beneficiary)
        super()._preempt(jid, beneficiary=beneficiary, lost=lost)

    def _shrink(self, jid: int, k: int, od: int) -> None:
        rs = self.running[jid]
        new_size = rs.cur_size - k
        self.launcher.resize(rs.job, new_size)
        self._emit("shrink", jid, k=k, new_size=new_size, od=od)
        super()._shrink(jid, k, od)

    def _expand(self, jid: int, k: int) -> None:
        rs = self.running[jid]
        grow = min(k, rs.job.n_max - rs.cur_size)
        if grow > 0:
            self.launcher.resize(rs.job, rs.cur_size + grow)
            self._emit("expand", jid, k=grow, new_size=rs.cur_size + grow)
        super()._expand(jid, k)

    def _on_end(self, jid: int, epoch: int) -> None:
        rs = self.running.get(jid)
        if rs is not None and rs.epoch == epoch:   # not a stale END event
            killed = rs.work_done(self.now) < rs.job.work - 1e-6
            self.launcher.finish(self.records[jid])
            self._emit("end", jid, size=rs.cur_size, killed=killed,
                       jtype=rs.job.jtype.value)
        super()._on_end(jid, epoch)

    def _on_od_timeout(self, jid: int) -> None:
        fired = self.od_status.get(jid) == "noticed"
        released = self.ledger.reserved_of(jid) if fired else 0
        super()._on_od_timeout(jid)
        if fired:
            self._emit("od_timeout", jid, released=released)

    # ------------------------------------------------- narrated fault events
    def _on_node_down(self, node: int) -> None:
        if node not in self._down_nodes:   # mirror the super's dedup guard
            self._emit("node_down", -1, node=node)
        super()._on_node_down(node)

    def _on_node_up(self, node: int) -> None:
        if node in self._down_nodes:
            self._emit("node_up", -1, node=node)
        super()._on_node_up(node)

    def _fault_shrink(self, jid: int) -> None:
        rs = self.running[jid]
        new_size = rs.cur_size - 1
        self.launcher.resize(rs.job, new_size)
        self._emit("fault_shrink", jid, new_size=new_size)
        super()._fault_shrink(jid)

    def _fault_evict_od(self, jid: int) -> None:
        rs = self.running[jid]
        self.launcher.preempt(rs.job)
        self._emit("fault_evict", jid, size=rs.cur_size)
        super()._fault_evict_od(jid)

    # ------------------------------------------------------------ quarantine
    def quarantine(self, k: int = 1) -> None:
        """Request that ``k`` nodes be pulled from service (a persistent
        launch failure suggests bad hardware).  Nodes move free→draining
        lazily, at the next scheduling pass, and only while the free
        pool has them to give — the base Simulator's hot path is never
        touched, and a busy cluster drains as nodes free up."""
        self._pending_quarantine += k

    def _apply_pending_quarantine(self) -> None:
        while self._pending_quarantine > 0 and self.ledger.free > 0:
            self.ledger.drain_free()
            self._pending_quarantine -= 1
            self.n_quarantined += 1
            self._emit_runtime("quarantine", -1, draining=self.ledger.draining)

    def _schedule(self) -> None:
        if self._pending_quarantine:
            self._apply_pending_quarantine()
        super()._schedule()
