"""Replay clock: the service's bridge between wall time and trace time.

A :class:`ReplayClock` maps the monotonic wall clock onto the simulated
timeline at a configurable ``speed`` (simulated seconds per wall second).
The scheduler daemon sleeps against it so replayed arrivals hit the
decision core at scaled real-time pace; ``speed=inf`` (the CI/benchmark
mode) never sleeps and replays as fast as the decision core can go —
the *decision sequence* is identical either way, only the wall-clock
spacing of the decisions changes.

All measurements use ``time.monotonic`` (never ``time.time``): the
mapping must survive wall-clock adjustments, and the per-decision
latencies derived from it feed the SLO gate.
"""
from __future__ import annotations

import math
import time


class ReplayClock:
    """Maps wall time onto simulated time at a fixed speed-up factor.

    ``origin`` is the simulated time at which the clock starts, so a
    trace whose first event is at t=86 400 does not force a day of (or
    even a scaled) dead wait.
    """

    def __init__(self, speed: float = math.inf, origin: float = 0.0):
        if not (speed > 0):
            raise ValueError(f"replay speed must be > 0, got {speed!r}")
        self.speed = speed
        self.origin = origin
        self._t0 = time.monotonic()

    @property
    def realtime(self) -> bool:
        """True when the clock actually paces (finite speed)."""
        return math.isfinite(self.speed)

    def wall_elapsed(self) -> float:
        """Wall seconds since the clock started."""
        return time.monotonic() - self._t0

    def now_sim(self) -> float:
        """Current position on the simulated timeline."""
        if not self.realtime:
            return math.inf
        return self.origin + self.wall_elapsed() * self.speed

    def sleep_until(self, t_sim: float, max_sleep: float = 0.25) -> float:
        """Sleep until the simulated clock reaches ``t_sim``; returns the
        wall seconds slept.  Sleeps in ``max_sleep`` chunks so a live
        daemon stays responsive to new admissions; ``speed=inf`` returns
        immediately."""
        if not self.realtime:
            return 0.0
        slept = 0.0
        while True:
            behind = (t_sim - self.now_sim()) / self.speed
            if behind <= 0:
                return slept
            dt = min(behind, max_sleep)
            time.sleep(dt)
            slept += dt
