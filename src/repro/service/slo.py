"""SLO monitor: the production-shaped gates the shadow service must hold.

Two service-level objectives, both from the paper's goals:

* **decision latency** — p99 of the wall time a scheduling event batch
  takes, bounded by Obs-10's 10 ms (the decision path must stay
  interactive under heavy traffic);
* **on-demand wait** — p99 of (first_start - submit) for on-demand jobs,
  optional bound (the paper's "minimal waiting" goal; scenario-dependent,
  so unbounded by default).

The monitor aggregates streamingly (counts + bounded series) so it works
as a record sink on year-scale replays, and renders an :class:`SloReport`
whose ``ok`` is the CI gate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.job import JobType
from repro.core.simulator import JobRecord


def _p99(xs: List[float]) -> float:
    return float(np.percentile(np.asarray(xs, dtype=np.float64), 99)) \
        if xs else float("nan")


@dataclass
class SloPolicy:
    """The bounds a service run is gated on."""

    decision_p99_ms: float = 10.0          # paper Obs 10
    od_wait_p99_s: Optional[float] = None  # None: report, don't gate


@dataclass
class SloReport:
    ok: bool
    decision_p99_ms: float
    decision_bound_ms: float
    od_wait_p99_s: float
    od_wait_bound_s: Optional[float]
    n_decisions: int
    n_od: int
    violations: List[str] = field(default_factory=list)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class SloMonitor:
    """Accumulates decision latencies and per-record waits; ``report()``
    evaluates them against an :class:`SloPolicy`."""

    def __init__(self, policy: Optional[SloPolicy] = None):
        self.policy = policy or SloPolicy()
        self.decision_ms: List[float] = []
        self.od_wait_s: List[float] = []
        self.n_records = 0

    def add_decision_latency(self, ms: float) -> None:
        self.decision_ms.append(ms)

    def add_record(self, rec: JobRecord) -> None:
        """Record sink hook: harvest the on-demand wait as records retire
        (works streamingly; on-demand counts are machine-bounded)."""
        self.n_records += 1
        if rec.job.jtype is JobType.ONDEMAND and rec.first_start is not None:
            self.od_wait_s.append(rec.first_start - rec.job.submit_time)

    def report(self) -> SloReport:
        pol = self.policy
        dec_p99 = _p99(self.decision_ms)
        od_p99 = _p99(self.od_wait_s)
        violations = []
        if self.decision_ms and dec_p99 > pol.decision_p99_ms:
            violations.append(
                f"decision p99 {dec_p99:.3f}ms > {pol.decision_p99_ms}ms "
                "bound (paper Obs 10)")
        if pol.od_wait_p99_s is not None and self.od_wait_s \
                and od_p99 > pol.od_wait_p99_s:
            violations.append(
                f"on-demand wait p99 {od_p99:.1f}s > {pol.od_wait_p99_s}s")
        return SloReport(ok=not violations,
                         decision_p99_ms=dec_p99,
                         decision_bound_ms=pol.decision_p99_ms,
                         od_wait_p99_s=od_p99,
                         od_wait_bound_s=pol.od_wait_p99_s,
                         n_decisions=len(self.decision_ms),
                         n_od=len(self.od_wait_s),
                         violations=violations)
