"""Admission queue: the live service's front door.

Producers (an API handler, an example script, a test) submit work as
:class:`~repro.core.job.JobSpec`-shaped requests; the daemon drains the
queue between event batches and feeds specs to ``ServiceCore.admit``.
Thread-safe and bounded-free — on-demand inference requests and
malleable training submissions go through the same door, mirroring the
paper's hybrid workload.

Convenience constructors map service-level requests onto the spec
fields the policy stack understands:

* :meth:`AdmissionQueue.submit_inference` — an ONDEMAND job (the node
  demand of a serving burst), with optional advance notice so
  notice-aware mechanisms (CUA/CUP) can pre-vacate;
* :meth:`AdmissionQueue.submit_training` — a MALLEABLE job (an elastic
  training run the cluster may shrink for on-demand traffic);
* :meth:`AdmissionQueue.submit_rigid` — a RIGID batch job.

Bounded capacity (``maxsize``) adds backpressure — what happens when a
producer outruns the daemon is a policy choice (``backpressure``):

* ``"block"`` — the producer waits until the daemon drains (classic
  bounded queue; a slow daemon slows its clients);
* ``"shed-oldest-inference"`` — drop the oldest queued ONDEMAND spec to
  make room (latency-sensitive serving traffic is stale the moment it
  waits; training submissions are never shed).  If nothing is sheddable
  the submission is rejected instead;
* ``"reject"`` — raise :class:`AdmissionRejected` at the producer.

Shed / rejected / blocked events are counted in :attr:`counts` and
surfaced in the ShadowReport for live runs.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from typing import Dict, List, Optional

from repro.core.job import JobSpec, JobType, NoticeKind

#: valid values for ``AdmissionQueue(backpressure=...)``
BACKPRESSURE_POLICIES = ("block", "shed-oldest-inference", "reject")


class AdmissionRejected(RuntimeError):
    """A submission was refused: the queue is at capacity and the
    backpressure policy could not make room."""


class AdmissionQueue:
    """Thread-safe FIFO of admitted :class:`JobSpec`.

    ``base_jid`` seeds the jid allocator; keep it above any replayed
    trace's jid range when mixing live admissions into a replay.
    ``maxsize=None`` (default) is unbounded — the legacy behavior.
    """

    def __init__(self, base_jid: int = 1_000_000,
                 maxsize: Optional[int] = None,
                 backpressure: str = "block"):
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(f"unknown backpressure policy "
                             f"{backpressure!r}; pick one of "
                             f"{BACKPRESSURE_POLICIES}")
        if maxsize is not None and maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self._q: deque = deque()
        self._cond = threading.Condition()
        self._jids = itertools.count(base_jid)
        self._closed = False
        self.maxsize = maxsize
        self.backpressure = backpressure
        self.n_submitted = 0
        self.counts: Dict[str, int] = {
            "submitted": 0, "shed": 0, "rejected": 0, "blocked": 0}

    # ------------------------------------------------------------- plumbing
    def _make_room(self) -> bool:
        """At-capacity handling under the non-blocking policies; returns
        True when the caller may enqueue.  Caller holds the lock."""
        if self.backpressure == "shed-oldest-inference":
            for i, spec in enumerate(self._q):
                if spec.jtype is JobType.ONDEMAND:
                    del self._q[i]
                    self.counts["shed"] += 1
                    return True
        self.counts["rejected"] += 1
        return False

    def put(self, spec: JobSpec, timeout: Optional[float] = None) -> JobSpec:
        """Enqueue one spec, honoring the backpressure policy when the
        queue is full.  Under ``"block"``, ``timeout`` bounds the wait
        (then :class:`AdmissionRejected` is raised)."""
        with self._cond:
            if self._closed:
                raise RuntimeError("admission queue is closed")
            if self.maxsize is not None and len(self._q) >= self.maxsize:
                if self.backpressure == "block":
                    self.counts["blocked"] += 1
                    ok = self._cond.wait_for(
                        lambda: self._closed or len(self._q) < self.maxsize,
                        timeout=timeout)
                    if self._closed:
                        raise RuntimeError("admission queue is closed")
                    if not ok:
                        self.counts["rejected"] += 1
                        raise AdmissionRejected(
                            f"queue full ({self.maxsize}) after "
                            f"{timeout}s wait")
                elif not self._make_room():
                    raise AdmissionRejected(
                        f"queue full ({self.maxsize}), policy "
                        f"{self.backpressure!r} could not make room")
            self._q.append(spec)
            self.n_submitted += 1
            self.counts["submitted"] += 1
        return spec

    def drain(self) -> List[JobSpec]:
        """Remove and return every pending spec (daemon-side)."""
        with self._cond:
            out = list(self._q)
            self._q.clear()
            self._cond.notify_all()       # wake blocked producers
        return out

    def close(self) -> None:
        """No further submissions; the daemon drains what remains and
        exits once the core is idle."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()       # unblock waiting producers

    @property
    def closed(self) -> bool:
        return self._closed

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)

    def _next_jid(self, jid: Optional[int]) -> int:
        return next(self._jids) if jid is None else jid

    # ----------------------------------------------------------- front door
    def submit_inference(self, nodes: int, hold_s: float,
                         submit_time: float = 0.0, *,
                         notice_lead_s: Optional[float] = None,
                         project: str = "serve",
                         jid: Optional[int] = None) -> JobSpec:
        """On-demand serving demand: ``nodes`` for ``hold_s`` seconds.
        ``notice_lead_s`` announces it that many seconds ahead (clamped
        by the core if the lead is already in the past)."""
        notice = NoticeKind.NONE if notice_lead_s is None else NoticeKind.ACCURATE
        return self.put(JobSpec(
            jid=self._next_jid(jid), jtype=JobType.ONDEMAND, project=project,
            submit_time=submit_time, size=nodes,
            t_estimate=hold_s, t_actual=hold_s,
            notice_kind=notice,
            notice_time=None if notice_lead_s is None
            else submit_time - notice_lead_s,
            est_arrival=None if notice_lead_s is None else submit_time))

    def submit_training(self, n_max: int, runtime_s: float,
                        submit_time: float = 0.0, *, n_min: int = 0,
                        estimate_s: Optional[float] = None,
                        setup_s: float = 0.0, project: str = "train",
                        jid: Optional[int] = None) -> JobSpec:
        """Elastic (malleable) training run: may run anywhere in
        [n_min, n_max] nodes; ``runtime_s`` is the full-size runtime."""
        return self.put(JobSpec(
            jid=self._next_jid(jid), jtype=JobType.MALLEABLE, project=project,
            submit_time=submit_time, size=n_max,
            t_estimate=estimate_s or runtime_s * 1.5, t_actual=runtime_s,
            t_setup=setup_s, n_min=n_min))

    def submit_rigid(self, nodes: int, runtime_s: float,
                     submit_time: float = 0.0, *,
                     estimate_s: Optional[float] = None,
                     setup_s: float = 0.0, project: str = "batch",
                     jid: Optional[int] = None) -> JobSpec:
        """Fixed-size batch job."""
        return self.put(JobSpec(
            jid=self._next_jid(jid), jtype=JobType.RIGID, project=project,
            submit_time=submit_time, size=nodes,
            t_estimate=estimate_s or runtime_s * 1.5, t_actual=runtime_s,
            t_setup=setup_s))
