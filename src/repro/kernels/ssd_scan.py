"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid (batch, heads, chunks) with the chunk dimension innermost-sequential:
the inter-chunk SSM state (d_head x d_state, fp32) lives in VMEM scratch
and is carried across the chunk iterations, so the HBM traffic is exactly
one read of (x, dt, B, C) and one write of y per token — the kernel is
bandwidth-optimal for the training/prefill pass.

Within a chunk the computation is the quadratic "attention form" of SSD:
  y[t] = C_t . (sum_{u<=t} dA(u->t) dt_u B_u x_u) + dA(0->t) . state_in
tiled to (chunk x chunk) gates on the VPU and (chunk x d_state) x
(d_state x d_head) matmuls on the MXU.

VMEM per step (chunk=256, p=64, n=64):
  x 256x64, B/C 256x64, gates 256x256 f32, state 64x64 f32  ~ 0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (c, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (c,)
    A = a_ref[0]                                     # scalar (per head)
    B = b_ref[0].astype(jnp.float32)                 # (c, n)
    C = c_ref[0].astype(jnp.float32)                 # (c, n)
    D = d_ref[0]

    la = dt * A                                      # log decay per step, <= 0
    cs = jnp.cumsum(la)                              # within-chunk cumulative
    # ---- intra-chunk attention form -----------------------------------------
    seg = cs[:, None] - cs[None, :]                  # decay u -> t
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.exp(jnp.where(cols <= rows, seg, -1e30))
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (c, c)
    w = cb * gate
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())))  # (c, p)
    # ---- inter-chunk contribution -------------------------------------------
    state = state_ref[...]                           # (n, p)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())))
    # ---- update carried state ----------------------------------------------
    total = cs[chunk - 1]
    decay_to_end = jnp.exp(total - cs)               # (c,)
    state_ref[...] = state * jnp.exp(total) + jax.lax.dot_general(
        B * (decay_to_end * dt)[:, None], x, (((0,), (0,)), ((), ())))
    o_ref[0, :, 0, :] = (y + x * D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256, interpret: bool = False):
    """Shapes as kernels.ref.naive_ssd: x (b,s,h,p), dt (b,s,h), A (h,),
    B/C (b,s,n), D (h,).  s must divide by chunk."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    grid = (b, h, nc)
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j, c: (i, c, j)),
            pl.BlockSpec((1,), lambda i, j, c: (j,)),
            pl.BlockSpec((1, chunk, n), lambda i, j, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, c: (i, c, 0)),
            pl.BlockSpec((1,), lambda i, j, c: (j,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda i, j, c: (i, c, j, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C, D)
