"""Flash-decode: single-token attention against a long KV cache (Pallas).

The decode cells' arithmetic intensity is ~2 flops/byte — the kernel's job
is to stream the cache through VMEM exactly once at full HBM bandwidth
while accumulating the online-softmax stats in scratch.  Grid
(batch, kv_heads, kv_blocks) with the kv dimension innermost-sequential;
all query heads of a kv group (GQA) are processed together so the cache
tile is read once per group, not once per head.

Valid-length masking (cache filled up to `pos+1`) is block-exact: blocks
beyond the valid prefix are skipped with pl.when (no HBM reads wasted on
the unfilled tail when the grid is sized to max_seq).

VMEM per step: k,v tiles 2 x block_kv x d + acc G x d f32 + stats G f32
(e.g. 2 x 1024 x 128 bf16 + 8 x 128 f32 ~ 0.5 MB).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import tpu_compiler_params, tpu_memory_space

NEG_INF = -1e30


def _kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_kv: int, n_groups: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    vlen = vlen_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(ki * block_kv < vlen)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (T, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (T, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, T)
        t_abs = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(t_abs < vlen, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        den = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_kv",
                                             "interpret"))
def flash_decode(q, k, v, kv_valid_len, *, scale=None, block_kv: int = 1024,
                 interpret: bool = False):
    """q: (B, 1, H, D); k/v: (B, S, K, Dk/Dv); kv_valid_len: () int32.
    Returns (B, 1, H, Dv)."""
    B, sq, H, D = q.shape
    assert sq == 1, "decode kernel is single-token"
    _, S, K, Dv = v.shape
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_kv = min(block_kv, S)
    assert S % block_kv == 0
    nk = S // block_kv
    qs = (q * scale).reshape(B, K, G, D)   # (b, kv_head, group, d)
    vlen = jnp.asarray(kv_valid_len, jnp.int32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_kernel, block_kv=block_kv, n_groups=G),
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec(memory_space=tpu_memory_space("SMEM")),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(vlen, qs, k, v)
    return out.reshape(B, 1, H, Dv)
