"""Pure-jnp oracles for every kernel (small shapes only; used by tests)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def naive_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    kv_valid_len=None) -> jax.Array:
    """Softmax attention, materializing full scores.

    q: (B, Sq, H, D); k/v: (B, Skv, K, D) with H % K == 0 (GQA broadcast).
    With kv_valid_len: mask positions t >= valid_len (decode against cache);
    query i is aligned so that position of q[i] = valid_len - Sq + i.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * scale
    Skv = k.shape[1]
    ti = jnp.arange(Skv)
    if kv_valid_len is not None:
        qpos = kv_valid_len - Sq + jnp.arange(Sq)
        mask = ti[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    elif causal:
        mask = ti[None, :] <= jnp.arange(Sq)[:, None] + (Skv - Sq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", a, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def naive_ssd(x, dt, A, B, C, D) -> jax.Array:
    """Mamba-2 SSD reference: sequential recurrence over time.

    x: (b, s, h, p)   input per head
    dt: (b, s, h)     positive step sizes
    A: (h,)           negative decay rate per head
    B, C: (b, s, n)   input/output projections (shared across heads)
    D: (h,)           skip
    Returns (b, s, h, p).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, None, :])            # (b,s,h)

    def step(state, t):
        st, = state
        # st: (b, h, p, n)
        db = dtf[:, t, :, None, None] * B[:, t, None, None, :]  # (b,h,1,n)
        st = st * decay[:, t, :, None, None] + xf[:, t, :, :, None] * db
        y = jnp.einsum("bhpn,bn->bhp", st, C[:, t].astype(jnp.float32))
        return (st,), y

    st0 = jnp.zeros((b, h, p, n), jnp.float32)
    (_,), ys = jax.lax.scan(step, (st0,), jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                          # (b,s,h,p)
    y = y + xf * D[None, None, :, None]
    return y.astype(x.dtype)


def naive_mlstm(q, k, v, i_gate, f_gate) -> jax.Array:
    """xLSTM mLSTM reference: sequential matrix-memory recurrence.

    q,k,v: (b, s, h, d); i_gate,f_gate: (b, s, h) pre-activation.
    Stabilized exponential gating per the xLSTM paper.
    """
    b, s, h, d = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (b,s,h)
    i_ = i_gate.astype(jnp.float32)

    def step(carry, t):
        Cm, nm, m = carry  # (b,h,d,d), (b,h,d), (b,h)
        m_new = jnp.maximum(logf[:, t] + m, i_[:, t])
        fd = jnp.exp(logf[:, t] + m - m_new)           # (b,h)
        id_ = jnp.exp(i_[:, t] - m_new)
        Cm = Cm * fd[..., None, None] + id_[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", kf[:, t], vf[:, t])
        nm = nm * fd[..., None] + id_[..., None] * kf[:, t]
        num = jnp.einsum("bhd,bhde->bhe", qf[:, t], Cm)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf[:, t], nm))
        y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (Cm, nm, m_new), y

    init = (jnp.zeros((b, h, d, d), jnp.float32),
            jnp.zeros((b, h, d), jnp.float32),
            jnp.full((b, h), -jnp.inf, jnp.float32))
    _, ys = jax.lax.scan(step, init, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype)
