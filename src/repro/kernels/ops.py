"""Jit-ready kernel wrappers.

`attention` / `ssd_scan` / `mlstm_scan` dispatch between:
  * the Pallas TPU kernels (pl.pallas_call, VMEM-tiled) on TPU, and
  * mathematically identical chunked-jnp implementations everywhere else
    (CPU dry-run + tests) so the lowered HLO has *exact* causal FLOPs —
    the roofline reads these numbers.

The causal path is "binary blocked": the S x S causal triangle is split
into log2(S/block) levels of equal-shape rectangles plus a batched
block-diagonal, every level one batched matmul.  Exact FLOPs (no masked
waste), O(S * block) live memory, O(log S) HLO size.
"""
from __future__ import annotations

import contextlib
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_BACKEND_OVERRIDE: Optional[str] = None  # "jnp" | "pallas" | None=auto


def tpu_compiler_params(**kwargs):
    """Version-compat constructor for the Pallas TPU compiler params.

    JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
    (and older releases only have the TPU-prefixed name), so resolve
    whichever the installed JAX exposes — the kwargs are identical.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def tpu_memory_space(name: str):
    """Same rename compat for ``pltpu.MemorySpace`` (nee
    ``TPUMemorySpace``): ``tpu_memory_space("SMEM")``."""
    from jax.experimental.pallas import tpu as pltpu
    enum = getattr(pltpu, "MemorySpace", None)
    if enum is None:
        enum = pltpu.TPUMemorySpace
    return getattr(enum, name)


def x64_enabled() -> bool:
    """Whether float64/int64 are live JAX types right now (global flag or
    an enclosing :func:`enable_x64` scope)."""
    return bool(jax.config.jax_enable_x64)


def enable_x64(enable: bool = True):
    """Version-compat scoped x64 switch.

    The scheduler decision kernels (repro.core.decision_jax) need exact
    float64 parity with their numpy references without flipping the
    global ``jax_enable_x64`` flag — the model/kernel suites in the same
    process rely on float32/bf16 canonicalization.  Prefers the
    thread-local ``jax.experimental.enable_x64`` context manager and
    falls back to saving/restoring the global flag on JAX versions
    without it.
    """
    ctx = getattr(jax.experimental, "enable_x64", None)
    if ctx is not None:
        return ctx(enable)

    @contextlib.contextmanager
    def _flag_scope():
        prev = bool(jax.config.jax_enable_x64)
        jax.config.update("jax_enable_x64", enable)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", prev)
    return _flag_scope()


def set_backend(name: Optional[str]) -> None:
    global _BACKEND_OVERRIDE
    _BACKEND_OVERRIDE = name


def _use_pallas() -> bool:
    if _BACKEND_OVERRIDE == "pallas":
        return True
    if _BACKEND_OVERRIDE == "jnp":
        return False
    return jax.default_backend() == "tpu"


# ============================================================== soft helpers
def _merge(o1, l1, o2, l2):
    """Combine two partial attentions via their logsumexps."""
    m = jnp.maximum(l1, l2)
    w1 = jnp.exp(l1 - m)
    w2 = jnp.exp(l2 - m)
    den = w1 + w2
    o = (o1 * (w1 / den)[..., None] + o2 * (w2 / den)[..., None])
    return o, m + jnp.log(den)


def _sdp(qg, k, v, scale, mask=None):
    """One dense block: qg (..., Sq, K, G, D) x k/v (..., T, K, D), GQA.
    Returns (out (..., Sq, K, G, Dv), lse (..., Sq, K, G))."""
    s = jnp.einsum("...skgd,...tkd->...kgst", qg, k) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    den = jnp.sum(p, axis=-1)                      # (..., K, G, Sq)
    o = jnp.einsum("...kgst,...tkd->...skgd", p, v)
    o = o / jnp.moveaxis(den, -1, -3)[..., None]
    lse = m[..., 0] + jnp.log(jnp.maximum(den, 1e-30))
    return o, jnp.moveaxis(lse, -1, -3)            # lse -> (..., Sq, K, G)


def _rect_chunked(qg, k, v, scale, block_kv: int, block_q: int = 0):
    """Non-causal attention of qg against full k/v, scanned over kv chunks
    (and q chunks when the rectangle is tall, bounding live scores to
    block_q x block_kv per head).  qg: (B, M, Sq, K, G, D); k/v:
    (B, M, T, K, D).  Returns (out, lse)."""
    Sq, T = qg.shape[2], k.shape[2]
    if block_q and Sq > block_q and Sq % block_q == 0:
        nq = Sq // block_q
        qb = jnp.moveaxis(
            qg.reshape(*qg.shape[:2], nq, block_q, *qg.shape[3:]), 2, 0)

        def qbody(qblk):
            return _rect_chunked(qblk, k, v, scale, block_kv)

        o, lse = jax.lax.map(qbody, qb)
        o = jnp.moveaxis(o, 0, 2).reshape(*qg.shape[:-1], v.shape[-1])
        lse = jnp.moveaxis(lse, 0, 2).reshape(qg.shape[:-1])
        return o, lse
    nk = max(1, math.ceil(T / block_kv))
    if T % nk != 0:  # fall back to single chunk when not divisible
        o, lse = _sdp(qg, k, v, scale)
        return o, lse
    ck = k.reshape(*k.shape[:2], nk, T // nk, *k.shape[3:])
    cv = v.reshape(*v.shape[:2], nk, T // nk, *v.shape[3:])

    def body(carry, xs):
        o_acc, l_acc = carry
        kb, vb = xs
        o, l = _sdp(qg, kb, vb, scale)
        return _merge(o_acc, l_acc, o, l), None

    o0 = jnp.zeros((*qg.shape[:-1], v.shape[-1]), qg.dtype)
    l0 = jnp.full(qg.shape[:-1], -jnp.inf, qg.dtype)
    (o, lse), _ = jax.lax.scan(body, (o0, l0),
                               (jnp.moveaxis(ck, 2, 0), jnp.moveaxis(cv, 2, 0)))
    return o, lse


def _causal_binary(qg, k, v, scale, block_q: int, block_kv: int):
    """Exact-FLOPs causal attention via binary block decomposition.

    qg: (B, S, K, G, D); k/v: (B, S, K, D).  S must be a power-of-two
    multiple of the leaf block (callers pad); returns (B, S, K, G, Dv).
    """
    B, S, K, G, D = qg.shape
    Dv = v.shape[-1]
    leaf = min(block_q, S)
    nb = S // leaf
    # ---- block-diagonal causal leaves (one batched op) ---------------------
    qb = qg.reshape(B, nb, leaf, K, G, D)
    kb = k.reshape(B, nb, leaf, K, D)
    vb = v.reshape(B, nb, leaf, K, Dv)
    ti = jnp.arange(leaf)
    mask = (ti[None, :] <= ti[:, None])[None, None, None, None]  # (1,1,1,1,s,t)
    out, lse = _sdp(qb, kb, vb, scale, mask=mask)
    out = out.astype(jnp.float32)
    # ---- levels of strictly-lower rectangles -------------------------------
    size = 1
    while size < nb:
        R = leaf * size                 # rectangle side
        m = nb // (2 * size)            # rectangles at this level
        q_r = qg.reshape(B, m, 2 * R, K, G, D)[:, :, R:]
        k_r = k.reshape(B, m, 2 * R, K, D)[:, :, :R]
        v_r = v.reshape(B, m, 2 * R, K, Dv)[:, :, :R]
        o_r, l_r = _rect_chunked(q_r, k_r, v_r, scale, block_kv,
                                 block_q=4 * leaf)
        # merge into the running accumulators for those query rows
        out_v = out.reshape(B, m, 2 * R, K, G, -1)
        lse_v = lse.reshape(B, m, 2 * R, K, G)
        o_hi, l_hi = _merge(out_v[:, :, R:], lse_v[:, :, R:],
                            o_r.astype(jnp.float32), l_r.astype(jnp.float32))
        out = jnp.concatenate([out_v[:, :, :R], o_hi], axis=2).reshape(out.shape)
        lse = jnp.concatenate([lse_v[:, :, :R], l_hi], axis=2).reshape(lse.shape)
        size *= 2
    return out


# ================================================================= attention
def attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
              kv_valid_len=None, block_q: int = 512, block_kv: int = 1024):
    """Multi-head attention with GQA.

    q: (B, Sq, H, D); k/v: (B, Skv, K, Dk/Dv), H % K == 0.
      * kv_valid_len set   -> decode against a cache (mask t > pos).
      * causal             -> exact binary-blocked causal attention.
      * else               -> full (cross/encoder) attention, kv-chunked.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    ct = q.dtype
    qg = q.reshape(B, Sq, K, G, D)

    if _use_pallas() and kv_valid_len is None and causal and Sq == k.shape[1]:
        from . import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=True, scale=scale,
                                  block_q=block_q, block_kv=block_kv)

    if _use_pallas() and kv_valid_len is not None and Sq == 1 \
            and k.shape[1] % min(block_kv, k.shape[1]) == 0:
        from . import flash_decode as fd
        return fd.flash_decode(q, k, v, kv_valid_len, scale=scale,
                               block_kv=block_kv)

    if kv_valid_len is None and causal and Sq == k.shape[1] and Sq > block_q \
            and Sq % block_q == 0 and _is_pow2(Sq // block_q):
        out = _causal_binary(qg.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), scale, block_q, block_kv)
        return out.reshape(B, Sq, H, -1).astype(ct)

    # ---- small / decode / cross path ---------------------------------------
    qf = qg.astype(jnp.float32)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    Skv = k.shape[1]
    ti = jnp.arange(Skv)
    mask = None
    if kv_valid_len is not None:
        qpos = kv_valid_len - Sq + jnp.arange(Sq)
        mask = (ti[None, :] <= qpos[:, None])[None, None, None]
    elif causal:
        mask = (ti[None, :] <= jnp.arange(Sq)[:, None] + (Skv - Sq))[None, None, None]
    o, _ = _sdp(qf[:, None], kf[:, None], vf[:, None], scale,
                mask=mask[:, None] if mask is not None else None)
    return o[:, 0].reshape(B, Sq, H, -1).astype(ct)


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ================================================================== SSD scan
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256,
             return_final_state: bool = False):
    """Mamba-2 SSD: chunked parallel scan (matches kernels.ref.naive_ssd).

    Shapes as in the reference.  Chunk-local quadratic attention-form +
    carried inter-chunk state; one lax.scan over chunks.  With
    return_final_state, also returns the (b,h,p,n) state after the last
    token (prefill -> decode handoff).
    """
    if _use_pallas() and not return_final_state:
        from . import ssd_scan as kern
        return kern.ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    return _ssd_jnp(x, dt, A, B, C, D, chunk, return_final_state)


def _ssd_jnp(x, dt, A, Bm, Cm, D, chunk: int, return_final_state: bool = False):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    c = min(chunk, s)
    nc = s // c
    assert s % c == 0, f"seq {s} not divisible by chunk {c}"
    xf = x.astype(jnp.float32).reshape(b, nc, c, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, c, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, c, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, c, n)
    la = dtf * A[None, None, None, :]            # log decay per step (<=0)
    cs = jnp.cumsum(la, axis=2)                  # within-chunk cumulative
    total = cs[:, :, -1, :]                      # (b,nc,h)

    # ---- intra-chunk (attention form): y_t = sum_{u<=t} C_t.B_u dA(u->t) x_u
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (b,nc,t,u,h)
    ti, ui = jnp.arange(c), jnp.arange(c)
    causal = (ui[None, :] <= ti[:, None])[None, None, :, :, None]
    # mask in log space: exp of a masked +big region would give inf * 0
    # = NaN in the backward pass
    gate = jnp.exp(jnp.where(causal, seg, -1e30))
    cb = jnp.einsum("bktn,bkun->bktu", Cf, Bf)
    w = cb[..., None] * gate                      # (b,nc,t,u,h)
    y_intra = jnp.einsum("bktuh,bkuhp->bkthp", w, xf * dtf[..., None])

    # ---- chunk states & inter-chunk scan -----------------------------------
    decay_to_end = jnp.exp(total[:, :, None, :] - cs)     # (b,nc,c,h)
    states = jnp.einsum("bkch,bkcn,bkchp->bkhpn",
                        decay_to_end * dtf, Bf, xf)

    def carry_fn(st, xs):
        st_k, tot_k = xs                          # (b,h,p,n), (b,h)
        new = st * jnp.exp(tot_k)[:, :, None, None] + st_k
        return new, st                            # emit state BEFORE chunk k

    st0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev = jax.lax.scan(carry_fn, st0,
                               (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)               # (b,nc,h,p,n) state entering k
    y_inter = jnp.einsum("bkcn,bkch,bkhpn->bkchp", Cf, jnp.exp(cs), prev)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    y = y.astype(x.dtype)
    return (y, final) if return_final_state else y


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D):
    """Single decode step of the SSD recurrence.  state: (b,h,p,n)."""
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])
    st = state * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xf * dtf[..., None], B_t.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", st, C_t.astype(jnp.float32))
    y = y + xf * D[None, :, None]
    return st, y.astype(x_t.dtype)


# ================================================================ mLSTM scan
def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk: int = 256,
               return_final_state: bool = False):
    """Chunked-parallel mLSTM (matches kernels.ref.naive_mlstm).  With
    return_final_state also returns the (C, n, m) matrix memory after the
    last token."""
    return _mlstm_jnp(q, k, v, i_gate, f_gate, min(chunk, q.shape[1]),
                      return_final_state)


def _mlstm_jnp(q, k, v, ig, fg, chunk: int, return_final_state: bool = False):
    b, s, h, d = q.shape
    c = chunk
    assert s % c == 0
    nc = s // c
    qf = q.astype(jnp.float32).reshape(b, nc, c, h, d)
    kf = k.astype(jnp.float32).reshape(b, nc, c, h, d)
    vf = v.astype(jnp.float32).reshape(b, nc, c, h, d)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32)).reshape(b, nc, c, h)
    ii = ig.astype(jnp.float32).reshape(b, nc, c, h)
    csf = jnp.cumsum(logf, axis=2)                 # (b,nc,c,h)
    total = csf[:, :, -1, :]

    # log-weights: within-chunk decay from u to t plus input gate at u
    seg = csf[:, :, :, None, :] - csf[:, :, None, :, :]   # (b,nc,t,u,h)
    lw = seg + ii[:, :, None, :, :]
    ti = jnp.arange(c)
    causal = (ti[None, :] <= ti[:, None])[None, None, :, :, None]
    lw = jnp.where(causal, lw, -jnp.inf)
    # stabilizer per (chunk, t): running max over available inputs
    m_intra = jnp.max(lw, axis=3)                  # (b,nc,t,h)

    def carry_fn(carry, xs):
        # inter-chunk stabilized matrix memory
        Cs, ns, m = carry                          # (b,h,d,d),(b,h,d),(b,h)
        kc, vc, ic, lfc, csfc, totc = xs
        m_loc = jnp.max(csfc[:, -1, None, :] - csfc + ic, axis=1)  # (b,h)
        m_new = jnp.maximum(m + totc, m_loc)
        w = jnp.exp(csfc[:, -1, None, :] - csfc + ic - m_new[:, None, :])
        Cc = jnp.einsum("bch,bchd,bche->bhde", w, kc, vc)
        nc_ = jnp.einsum("bch,bchd->bhd", w, kc)
        scale_old = jnp.exp(m + totc - m_new)
        C_out = Cs * scale_old[..., None, None] + Cc
        n_out = ns * scale_old[..., None] + nc_
        return (C_out, n_out, m_new), (Cs, ns, m)

    init = (jnp.zeros((b, h, d, d), jnp.float32),
            jnp.zeros((b, h, d), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    final, (Cprev, nprev, mprev) = jax.lax.scan(
        carry_fn, init,
        tuple(jnp.moveaxis(t, 1, 0) for t in
              (kf, vf, ii, logf, csf, total)))
    Cprev = jnp.moveaxis(Cprev, 0, 1)              # state entering chunk
    nprev = jnp.moveaxis(nprev, 0, 1)
    mprev = jnp.moveaxis(mprev, 0, 1)              # (b,nc,h)

    # combine intra + inter with shared stabilizer
    m_inter = mprev[:, :, None, :] + csf           # (b,nc,c,h)
    m_tot = jnp.maximum(m_intra, m_inter)
    w_intra = jnp.exp(lw - m_tot[:, :, :, None, :])
    s_qk = jnp.einsum("bkthd,bkuhd->bktuh", qf, kf)
    num = jnp.einsum("bktuh,bkuhe->bkthe", s_qk * w_intra, vf)
    den = jnp.einsum("bktuh,bkuhd->bkthd", w_intra, kf)
    den = jnp.einsum("bkthd,bkthd->bkth", qf, den)
    w_int = jnp.exp(m_inter - m_tot)
    num = num + jnp.einsum("bkth,bkthd,bkhde->bkthe", w_int, qf, Cprev)
    den = den + jnp.einsum("bkth,bkthd,bkhd->bkth", w_int, qf, nprev)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))
    y = (num / den[..., None]).reshape(b, s, h, d)
    y = y.astype(q.dtype)
    return (y, final) if return_final_state else y
