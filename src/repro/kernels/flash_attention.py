"""Flash attention as a Pallas TPU kernel.

Online-softmax tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost-sequential ("arbitrary"), carrying the running
(max, denom, acc) in VMEM scratch.  Block shapes are MXU-aligned
(block_q x d_head and block_kv x d_head tiles, multiples of 128 for the
full-size configs).  GQA is handled in the k/v index_map (h -> h*K//H), so
kv tiles are fetched once per query-head group without materializing the
head broadcast in HBM.

Causal masking is block-exact: fully-masked kv blocks are skipped with
pl.when (no MXU work), diagonal blocks apply the triangular mask.

VMEM working set per step:
    q tile  block_q x d          (bf16/f32)
    k,v     block_kv x d each
    scratch block_q x d f32 acc + 2 x block_q f32 stats
e.g. 512x128 q + 2 x 1024x128 kv + 512x128 acc ~ 1.1 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            seq_q: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + (seq_kv - seq_q)   # query absolute positions
    k_start = ki * block_kv
    # skip kv blocks strictly above the causal diagonal (no MXU work)
    if causal:
        run = k_start <= q_start + block_q - 1
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        den = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 512, block_kv: int = 1024,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D) with H % K == 0."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv)
    nq, nk = Sq // block_q, Skv // block_kv
    grid = (B, H, nq, nk)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_kv=block_kv,
                             seq_q=Sq, seq_kv=Skv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, i, j: (b, j, h * K // H, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, i, j: (b, j, h * K // H, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
