"""State-space and recurrent blocks: Mamba-2 (SSD) and xLSTM (mLSTM/sLSTM).

Train/prefill paths use the chunked-parallel scans from repro.kernels.ops;
decode paths carry O(1) recurrent state per layer — this is what makes the
`long_500k` shape tractable for the ssm/hybrid families (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from . import dist
from .config import ModelConfig
from .layers import _init, init_rmsnorm, rmsnorm

Params = Dict[str, jax.Array]


# ================================================================== Mamba-2
class MambaState(NamedTuple):
    conv_x: jax.Array   # (B, W-1, d_in)   channel-sharded over model
    conv_bc: jax.Array  # (B, W-1, 2*d_state)  replicated
    ssm: jax.Array      # (B, H, P, N)     head-sharded over model


def init_mamba2(key, cfg: ModelConfig) -> Params:
    """Projections are split (x / BC / dt / z) so every piece keeps a clean
    Megatron-style layout: channels+heads shard over `model` end-to-end,
    with a single psum at w_out (EXPERIMENTS.md zamba2 iterations)."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.d_head
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_x": _init(ks[0], (d, d_in), d ** -0.5, dt),
        "w_z": _init(ks[1], (d, d_in), d ** -0.5, dt),
        "w_bc": _init(ks[2], (d, 2 * s.d_state), d ** -0.5, dt),
        "w_dt": _init(ks[3], (d, nh), d ** -0.5, dt),
        "conv_x_w": _init(ks[4], (s.conv_width, d_in), 0.5, dt),
        "conv_x_b": jnp.zeros((d_in,), dt),
        "conv_bc_w": _init(ks[5], (s.conv_width, 2 * s.d_state), 0.5, dt),
        "conv_bc_b": jnp.zeros((2 * s.d_state,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(d_in, dt)["scale"],
        "w_out": _init(ks[0], (d_in, d), d_in ** -0.5, dt),
    }


def _causal_conv(x, w, b, state: Optional[jax.Array]):
    """Depthwise causal conv along time.  x: (B,S,C); w: (W,C).
    state (B,W-1,C) carries the tail for decode; returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    return y + b[None, None], xp[:, -(W - 1):]


def mamba2_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
               state: Optional[MambaState] = None,
               return_state: bool = False
               ) -> Tuple[jax.Array, Optional[MambaState]]:
    s = cfg.ssm
    ct = jnp.dtype(cfg.compute_dtype)
    d_in = s.expand * cfg.d_model
    nh = d_in // s.d_head
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(ct))
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(ct))
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"].astype(ct))
    dt = jnp.einsum("bsd,de->bse", x, p["w_dt"].astype(ct))
    conv_x, cx_state = _causal_conv(
        xi, p["conv_x_w"].astype(ct), p["conv_x_b"].astype(ct),
        state.conv_x if state is not None else None)
    conv_bc, cbc_state = _causal_conv(
        bc, p["conv_bc_w"].astype(ct), p["conv_bc_b"].astype(ct),
        state.conv_bc if state is not None else None)
    xs = jax.nn.silu(conv_x)
    B, C = jnp.split(jax.nn.silu(conv_bc), 2, axis=-1)
    # heads/channels shard over `model`: the SSD work distributes instead
    # of being redundantly replicated (EXPERIMENTS.md zamba2 iterations)
    xh = dist.constrain_heads(xs.reshape(*xs.shape[:2], nh, s.d_head))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    dt = dist.constrain_heads(dt)
    A = -jnp.exp(p["a_log"])
    if state is None:
        if return_state:
            y, ssm = kops.ssd_scan(xh, dt, A, B, C, p["d_skip"],
                                   chunk=s.chunk, return_final_state=True)
            new_state = MambaState(conv_x=cx_state, conv_bc=cbc_state,
                                   ssm=ssm)
        else:
            y = kops.ssd_scan(xh, dt, A, B, C, p["d_skip"], chunk=s.chunk)
            new_state = None
    else:
        ssm, y = kops.ssd_step(state.ssm, xh[:, 0], dt[:, 0], A,
                               B[:, 0], C[:, 0], p["d_skip"])
        y = y[:, None]
        new_state = MambaState(conv_x=cx_state, conv_bc=cbc_state, ssm=ssm)
    y = y.reshape(*y.shape[:2], d_in)
    y = rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(ct)), new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.d_head
    ct = jnp.dtype(cfg.compute_dtype)
    return MambaState(
        conv_x=jnp.zeros((batch, s.conv_width - 1, d_in), ct),
        conv_bc=jnp.zeros((batch, s.conv_width - 1, 2 * s.d_state), ct),
        ssm=jnp.zeros((batch, nh, s.d_head, s.d_state), jnp.float32))


# ==================================================================== mLSTM
class MLSTMState(NamedTuple):
    conv: jax.Array   # (B, W-1, f*d)
    C: jax.Array      # (B, H, Dh, Dh) matrix memory
    n: jax.Array      # (B, H, Dh)
    m: jax.Array      # (B, H) stabilizer


def init_mlstm(key, cfg: ModelConfig) -> Params:
    x = cfg.xlstm
    d = cfg.d_model
    f = int(x.proj_factor_m * d)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_up": _init(ks[0], (d, 2 * f), d ** -0.5, dt),
        "conv_w": _init(ks[1], (x.conv_width, f), 0.5, dt),
        "conv_b": jnp.zeros((f,), dt),
        "wq": _init(ks[2], (f, f), f ** -0.5, dt),
        "wk": _init(ks[3], (f, f), f ** -0.5, dt),
        "wv": _init(ks[4], (f, f), f ** -0.5, dt),
        "w_if": _init(ks[5], (f, 2 * cfg.n_heads), f ** -0.5, dt),
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,)),
                                 jnp.linspace(3.0, 6.0, cfg.n_heads)]).astype(dt),
        "norm": init_rmsnorm(f, dt)["scale"],
        "w_down": _init(ks[6], (f, d), f ** -0.5, dt),
    }


def mlstm_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
              state: Optional[MLSTMState] = None,
              return_state: bool = False):
    xc = cfg.xlstm
    ct = jnp.dtype(cfg.compute_dtype)
    d = cfg.d_model
    f = int(xc.proj_factor_m * d)
    H = cfg.n_heads
    dh = f // H
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(ct))
    xi, z = jnp.split(up, 2, axis=-1)
    conv_out, conv_state = _causal_conv(
        xi, p["conv_w"].astype(ct), p["conv_b"].astype(ct),
        state.conv if state is not None else None)
    xq = jax.nn.silu(conv_out)
    q = jnp.einsum("bsf,fe->bse", xq, p["wq"].astype(ct)) * dh ** -0.5
    k = jnp.einsum("bsf,fe->bse", xq, p["wk"].astype(ct)) * dh ** -0.5
    v = jnp.einsum("bsf,fe->bse", xi, p["wv"].astype(ct))
    gates = jnp.einsum("bsf,fg->bsg", xq, p["w_if"].astype(ct)) + \
        p["b_if"].astype(ct)[None, None]
    ig, fg = gates[..., :H], gates[..., H:]
    qh = q.reshape(*q.shape[:2], H, dh)
    kh = k.reshape(*k.shape[:2], H, dh)
    vh = v.reshape(*v.shape[:2], H, dh)
    if state is None:
        if return_state:
            y, (C2, n2, m2) = kops.mlstm_scan(qh, kh, vh, ig, fg, chunk=xc.chunk,
                                              return_final_state=True)
            new_state = MLSTMState(conv=conv_state, C=C2, n=n2, m=m2)
        else:
            y = kops.mlstm_scan(qh, kh, vh, ig, fg, chunk=xc.chunk)
            new_state = None
    else:
        y, C2, n2, m2 = _mlstm_step(state, qh[:, 0], kh[:, 0], vh[:, 0],
                                    ig[:, 0], fg[:, 0])
        y = y[:, None]
        new_state = MLSTMState(conv=conv_state, C=C2, n=n2, m=m2)
    y = y.reshape(*y.shape[:2], f)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bsf,fd->bsd", y, p["w_down"].astype(ct)), new_state


def _mlstm_step(st: MLSTMState, q, k, v, ig, fg):
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    i_ = ig.astype(jnp.float32)
    m_new = jnp.maximum(logf + st.m, i_)
    fd = jnp.exp(logf + st.m - m_new)
    id_ = jnp.exp(i_ - m_new)
    C = st.C * fd[..., None, None] + id_[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = st.n * fd[..., None] + id_[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return y.astype(q.dtype), C, n, m_new


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    x = cfg.xlstm
    f = int(x.proj_factor_m * cfg.d_model)
    H = cfg.n_heads
    dh = f // H
    ct = jnp.dtype(cfg.compute_dtype)
    return MLSTMState(conv=jnp.zeros((batch, x.conv_width - 1, f), ct),
                      C=jnp.zeros((batch, H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, H, dh), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


# ==================================================================== sLSTM
class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, Dh)
    n: jax.Array
    h: jax.Array
    m: jax.Array  # (B, H, Dh)


def init_slstm(key, cfg: ModelConfig) -> Params:
    x = cfg.xlstm
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    f = int(x.proj_factor_s * d)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        # input projections for 4 gates (i, f, z, o)
        "w_x": _init(ks[0], (d, 4 * d), d ** -0.5, dt),
        # block-diagonal recurrent weights per head
        "w_r": _init(ks[1], (4, H, dh, dh), dh ** -0.5, dt),
        "b": jnp.concatenate(
            [jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d), jnp.zeros((2 * d,))]
        ).astype(dt),
        "norm": init_rmsnorm(d, dt)["scale"],
        "w_ff1": _init(ks[2], (d, f), d ** -0.5, dt),
        "w_ff2": _init(ks[3], (f, d), f ** -0.5, dt),
    }


def _slstm_cell(p4r, carry: SLSTMState, gx):
    """One sLSTM step.  gx: (B, 4, H, Dh) input-gate preactivations."""
    c, n, h, m = carry
    r = jnp.einsum("bhd,ghde->bghe", h, p4r)            # recurrent part
    g = gx.astype(jnp.float32) + r.astype(jnp.float32)
    i_, f_, z_, o_ = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    logf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(logf + m, i_)
    c = c * jnp.exp(logf + m - m_new) + jnp.exp(i_ - m_new) * jnp.tanh(z_)
    n = n * jnp.exp(logf + m - m_new) + jnp.exp(i_ - m_new)
    h_new = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h_new.astype(h.dtype), m_new), h_new


def _slstm_scan(w_r, st: SLSTMState, gx):
    """Time scan over (B_local, S, 4, H, dh) gate preactivations."""
    st, ys = jax.lax.scan(lambda c, g: _slstm_cell(w_r, c, g),
                          st, jnp.moveaxis(gx, 1, 0))
    return st, jnp.moveaxis(ys, 0, 1)


def slstm_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
              state: Optional[SLSTMState] = None,
              return_state: bool = False):
    ct = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    gx = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(ct)) + \
        p["b"].astype(ct)[None, None]
    gx = gx.reshape(B, S, 4, H, dh)
    w_r = p["w_r"].astype(ct)
    st = state if state is not None else SLSTMState(
        c=jnp.zeros((B, H, dh), jnp.float32),
        n=jnp.zeros((B, H, dh), jnp.float32),
        h=jnp.zeros((B, H, dh), ct),
        m=jnp.full((B, H, dh), -1e30, jnp.float32))
    if S == 1:
        st, y = _slstm_cell(w_r, st, gx[:, 0])
        ys = y[:, None].astype(ct)
    else:
        from . import dist
        mesh = dist.get_mesh()
        ba = dist.batch_axes()
        nb = 1
        if mesh is not None:
            import numpy as _np
            nb = int(_np.prod([mesh.shape[a] for a in ba]))
        if mesh is not None and B % nb == 0 and nb > 1:
            # shard_map over batch: the recurrent-weight gradient psum
            # happens ONCE at the boundary instead of per scan step (XLA
            # otherwise emits an all-reduce of dW_r inside the 4096-step
            # time loop — see EXPERIMENTS.md §Perf xlstm iteration).
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            bspec = ba if len(ba) > 1 else ba[0]

            def body(w_r_, st_, gx_):
                return _slstm_scan(w_r_, st_, gx_)

            st_spec = SLSTMState(*([P(bspec)] * 4))
            st, ys = shard_map(
                body, mesh=mesh,
                in_specs=(P(), st_spec, P(bspec)),
                out_specs=(st_spec, P(bspec)),
                check_rep=False)(w_r, st, gx)
        else:
            st, ys = _slstm_scan(w_r, st, gx)
        ys = ys.astype(ct)
    y = ys.reshape(B, S, d)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps)
    ff = jnp.einsum("bsd,df->bsf", y, p["w_ff1"].astype(ct))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(ff), p["w_ff2"].astype(ct))
    return y, (st if state is not None or return_state else None)
