"""Mixture-of-Experts FFN with expert parallelism.

Experts are sharded over the mesh's `model` axis.  Because token
activations are replicated over `model` between blocks (TP layout), each
expert shard can gather the tokens routed to *its* experts locally and the
shard outputs combine with a single psum — the same collective cost as a
dense TP FFN, with no all-to-all and no dense dispatch einsum (whose
E x C FLOPs multiplier would swamp the roofline).

Dispatch is capacity-based (GShard-style token dropping) implemented with
sort-free scatter/gather so dispatch costs O(T k d) moves and ~0 FLOPs.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import _init

Params = Dict[str, jax.Array]

# mesh context lives in models.dist; re-exported here for callers
from .dist import get_mesh, set_mesh  # noqa: E402
from . import dist as _dist           # noqa: E402


def init_moe(key, cfg: ModelConfig) -> Params:
    m, d = cfg.moe, cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, m.n_experts), d ** -0.5, jnp.float32),
        "w1": _init(ks[1], (m.n_experts, d, m.d_expert), d ** -0.5, dt),
        "w3": _init(ks[2], (m.n_experts, d, m.d_expert), d ** -0.5, dt),
        "w2": _init(ks[3], (m.n_experts, m.d_expert, d), m.d_expert ** -0.5, dt),
    }
    if m.n_shared:
        f = m.n_shared * m.d_expert
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(k1, (d, f), d ** -0.5, dt),
            "w_up": _init(k2, (d, f), d ** -0.5, dt),
            "w_down": _init(k3, (f, d), f ** -0.5, dt),
        }
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _moe_local(x2d, router, w1, w3, w2, cfg: ModelConfig,
               e_start, n_local: int, capacity: int):
    """Per-shard MoE: route all local tokens, run the local expert slice.

    x2d: (T, d); w*: (E_loc, ...); e_start: first local expert id.
    Returns (partial y (T, d), partial aux-loss scalars).
    """
    m = cfg.moe
    T, d = x2d.shape
    ct = x2d.dtype
    logits = (x2d.astype(jnp.float32) @ router).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)                      # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # ---- flatten assignments, keep only local experts ----------------------
    A = T * m.top_k
    eid = top_e.reshape(A)
    gate = top_w.reshape(A)
    tok = jnp.repeat(jnp.arange(T), m.top_k)
    local = (eid >= e_start) & (eid < e_start + n_local)
    el = jnp.where(local, eid - e_start, 0)
    onehot = (el[:, None] == jnp.arange(n_local)[None]) & local[:, None]
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    pos = jnp.take_along_axis(pos, el[:, None], axis=1)[:, 0]
    keep = local & (pos < capacity)
    slot = jnp.where(keep, pos, capacity)          # overflow -> trash slot
    # ---- dispatch: (E_loc, C+1, d) buffer ----------------------------------
    buf = jnp.zeros((n_local, capacity + 1, d), ct)
    buf = buf.at[el, slot].add(jnp.where(keep[:, None], x2d[tok], 0))
    buf = buf[:, :capacity]
    # ---- expert FFN (batched over local experts) ---------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, w1.astype(ct))
    u = jnp.einsum("ecd,edf->ecf", buf, w3.astype(ct))
    h = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w2.astype(ct))
    # ---- combine back -------------------------------------------------------
    hp = jnp.concatenate([h, jnp.zeros((n_local, 1, d), ct)], axis=1)
    contrib = hp[el, slot] * (gate * keep).astype(ct)[:, None]
    y = jnp.zeros((T, d), ct).at[tok].add(contrib)
    # ---- load-balance aux (Switch-style), local partial sums ---------------
    frac_prob = jnp.mean(probs, axis=0)                    # (E,)
    assigned = jnp.zeros((m.n_experts,), jnp.float32).at[eid].add(
        jnp.ones((A,), jnp.float32))
    return y, frac_prob, assigned, jnp.asarray(T, jnp.float32)


def moe_fwd(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """Routed experts (+optional shared experts).  Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    mesh = _dist.get_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        ep = mesh.shape["model"]
        n_local = m.n_experts // ep
        cap = _capacity(B * S // _batch_shards(mesh), cfg)

        def shard_fn(xs, router, w1, w3, w2):
            T = xs.shape[0] * xs.shape[1]
            j = jax.lax.axis_index("model")
            tc = m.token_chunk
            if tc and T > tc and T % tc == 0:
                # chunked dispatch: capacity and the (T*k, d) gather/
                # scatter buffers scale with the chunk, not the batch
                cap_c = max(8, -(-cap * tc // T // 8) * 8)

                def chunk_fn(xc):
                    return _moe_local(xc, router, w1, w3, w2, cfg,
                                      j * n_local, n_local, cap_c)
                y, fp, asg, t = jax.lax.map(
                    chunk_fn, xs.reshape(T // tc, tc, d))
                y = y.reshape(T, d)
                fp = jnp.mean(fp, axis=0)
                asg = jnp.sum(asg, axis=0)
                t = jnp.sum(t)
            else:
                y, fp, asg, t = _moe_local(xs.reshape(T, d), router, w1, w3,
                                           w2, cfg, j * n_local, n_local, cap)
            y = jax.lax.psum(y, "model")
            ba = _dist.batch_axes()
            fp = jax.lax.pmean(fp, ba)
            asg = jax.lax.psum(asg, ba + ("model",))
            t = jax.lax.psum(t, ba + ("model",))
            return y.reshape(xs.shape), fp, asg, t

        y, fp, asg, t = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(_flat_batch_spec(), None, None),
                      P(None, None), P("model", None, None),
                      P("model", None, None), P("model", None, None)),
            out_specs=(P(_flat_batch_spec(), None, None), P(None), P(None), P()),
            check_rep=False,
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
    else:
        cap = _capacity(B * S, cfg)
        y, fp, asg, t = _moe_local(x.reshape(B * S, d), p["router"], p["w1"],
                                   p["w3"], p["w2"], cfg, 0, m.n_experts, cap)
        y = y.reshape(B, S, d)
    frac_tokens = asg / jnp.maximum(t * m.top_k, 1.0)
    aux = m.n_experts * jnp.sum(fp * frac_tokens)
    if m.n_shared:
        sh = p["shared"]
        ct = x.dtype
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"].astype(ct))
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"].astype(ct))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           sh["w_down"].astype(ct))
    return y, aux


def _flat_batch_spec():
    ba = _dist.batch_axes()
    return ba if len(ba) > 1 else ba[0]


def _batch_shards(mesh) -> int:
    n = 1
    for a in _dist.batch_axes():
        n *= mesh.shape[a]
    return n
