"""Model assembly: init / loss / prefill / decode for every family.

Families and their block structure:
  dense|moe|vlm : [GQA or MLA attention] + [SwiGLU or MoE FFN], scanned.
  ssm (xLSTM)   : mLSTM blocks with sLSTM every `slstm_every` (python loop —
                  small models, heterogeneous params).
  hybrid        : Mamba-2 stack, one *shared-weight* GQA+FFN block applied
                  every `attn_every` layers (Zamba-style), single scan with
                  an inlined conditional.
  audio         : enc-dec; encoder non-causal GQA blocks, decoder adds
                  cross-attention to the (stub) frame embeddings.

Caches: homogeneous families carry stacked (L, ...) cache arrays through
the layer scan; recurrent families carry O(1) states (see ssm.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import dist
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (_init, embed, gqa_fwd, init_embedding, init_gqa,
                     init_mla, init_rmsnorm, init_swiglu, mla_fwd, rmsnorm,
                     swiglu_fwd, unembed)

Params = Dict[str, Any]


# ------------------------------------------------------------------ utilities
def _remat(fn, cfg: ModelConfig, in_scan: bool = True):
    """Activation checkpointing.  prevent_cse=False is only sound inside a
    lax.scan body (the scan barrier already blocks CSE); for python-loop
    layer stacks XLA would CSE the recompute away and silently undo remat
    (caught by the xlstm memory probe, EXPERIMENTS.md §Perf)."""
    if cfg.remat == "full":
        return jax.checkpoint(fn, prevent_cse=not in_scan)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, prevent_cse=not in_scan,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ============================================================= dense/moe block
def _init_block(key, cfg: ModelConfig, moe_layer: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "ln2": init_rmsnorm(cfg.d_model, dt),
        "attn": init_mla(k1, cfg) if cfg.mla else init_gqa(k1, cfg),
    }
    if moe_layer:
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.moe and cfg.moe.first_dense:
            d_ff = cfg.moe.d_first_dense
        p["ffn"] = init_swiglu(k3, cfg.d_model, d_ff, dt)
    return p


def _block_fwd(p: Params, x, cfg: ModelConfig, *, positions, cache=None,
               cache_index=None, causal=True, moe_layer=False,
               return_kv=False):
    x = dist.constrain_batch(x)
    attn_fn = mla_fwd if cfg.mla else gqa_fwd
    h, new_cache = attn_fn(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                           positions=positions, cache=cache,
                           cache_index=cache_index, causal=causal,
                           return_kv=return_kv)
    x = x + h
    hn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe_layer:
        h, aux = moe_mod.moe_fwd(p["moe"], hn, cfg)
    else:
        h, aux = swiglu_fwd(p["ffn"], hn, cfg.compute_dtype), 0.0
    return dist.constrain_batch(x + h), new_cache, aux


# ================================================================== init
def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {"embed": init_embedding(keys[0], cfg),
                 "ln_f": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.param_dtype))}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        n_pre = cfg.moe.first_dense if cfg.moe else 0
        if n_pre:
            p["pre_layers"] = _stack_init(
                keys[1], n_pre, lambda k: _init_block(k, cfg, False))
        p["layers"] = _stack_init(
            keys[2], cfg.n_layers - n_pre,
            lambda k: _init_block(k, cfg, cfg.moe is not None))
    elif fam == "ssm":
        xl = cfg.xlstm
        assert cfg.n_layers % xl.slstm_every == 0, "xlstm group structure"
        n_groups = cfg.n_layers // xl.slstm_every
        n_m = xl.slstm_every - 1
        k1, k2 = jax.random.split(keys[1])
        p["slstm"] = _stack_init(k1, n_groups,
                                 lambda k: ssm_mod.init_slstm(k, cfg))
        m_flat = _stack_init(k2, n_groups * n_m,
                             lambda k: ssm_mod.init_mlstm(k, cfg))
        p["mlstm"] = jax.tree.map(
            lambda a: a.reshape(n_groups, n_m, *a.shape[1:]), m_flat)
    elif fam == "hybrid":
        p["layers"] = _stack_init(keys[1], cfg.n_layers,
                                  lambda k: ssm_mod.init_mamba2(k, cfg))
        p["shared_attn"] = _init_block(keys[2], cfg, False)
    elif fam == "audio":
        p["enc_layers"] = _stack_init(
            keys[1], cfg.n_enc_layers, lambda k: _init_block(k, cfg, False))
        p["layers"] = _stack_init(
            keys[2], cfg.n_layers, lambda k: _init_dec_block(k, cfg))
        p["ln_enc"] = init_rmsnorm(cfg.d_model, jnp.dtype(cfg.param_dtype))
    else:
        raise ValueError(fam)
    if fam == "vlm" and cfg.n_patches:
        p["patch_proj"] = _init(keys[3], (cfg.d_model, cfg.d_model),
                                cfg.d_model ** -0.5,
                                jnp.dtype(cfg.param_dtype))
    return p


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "ln_x": init_rmsnorm(cfg.d_model, dt),
        "ln2": init_rmsnorm(cfg.d_model, dt),
        "attn": init_gqa(k1, cfg),
        "xattn": init_gqa(k2, cfg),
        "ffn": init_swiglu(k3, cfg.d_model, cfg.d_ff, dt),
    }


def _dec_block_fwd(p, x, enc, cfg, *, positions, cache=None, cache_index=None,
                   return_kv=False):
    x = dist.constrain_batch(x)
    h, new_self = gqa_fwd(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                          positions=positions,
                          cache=None if cache is None else cache[:2],
                          cache_index=cache_index, causal=True,
                          return_kv=return_kv)
    x = x + h
    h, _ = gqa_fwd(p["xattn"], rmsnorm(p["ln_x"], x, cfg.norm_eps), cfg,
                   positions=positions, kv_source=enc, causal=False)
    x = x + h
    h = swiglu_fwd(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps),
                   cfg.compute_dtype)
    return x + h, new_self



# ---------------------------------------------------------------- hybrid util
def _hybrid_split(cfg: ModelConfig, stacked):
    """(L, ...) stacked mamba params/states -> ((G, k, ...), (tail, ...))."""
    k = cfg.attn_every
    g = cfg.n_layers // k
    body = jax.tree.map(lambda a: a[:g * k].reshape(g, k, *a.shape[1:]),
                        stacked)
    tail = jax.tree.map(lambda a: a[g * k:], stacked)
    return body, tail


def _hybrid_join(cfg: ModelConfig, body, tail):
    return jax.tree.map(
        lambda b, t: jnp.concatenate(
            [b.reshape(-1, *b.shape[2:]), t], axis=0), body, tail)


# ============================================================ forward (train)
class TrainBatch(NamedTuple):
    tokens: jax.Array                      # (B, S) inputs
    labels: jax.Array                      # (B, S) next-token targets
    extra: Optional[jax.Array] = None      # vlm patches / audio frames


def forward(params: Params, batch: TrainBatch, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V) or (B,S_text,V), aux_loss)."""
    fam = cfg.family
    x = embed(params["embed"], batch.tokens, cfg)
    aux_total = jnp.zeros((), jnp.float32)
    if fam == "vlm" and batch.extra is not None:
        ct = jnp.dtype(cfg.compute_dtype)
        patches = jnp.einsum("bpd,de->bpe", batch.extra.astype(ct),
                             params["patch_proj"].astype(ct))
        x = dist.constrain_batch(jnp.concatenate([patches, x], axis=1))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if fam in ("dense", "moe", "vlm"):
        moe_layer = cfg.moe is not None

        def body(carry, lp):
            h, aux = carry
            h2, _, a = _block_fwd(lp, h, cfg, positions=positions,
                                  moe_layer=moe_layer)
            return (h2, aux + a), None

        if "pre_layers" in params:
            def pre_body(carry, lp):
                h, aux = carry
                h2, _, a = _block_fwd(lp, h, cfg, positions=positions,
                                      moe_layer=False)
                return (h2, aux + a), None
            (x, aux_total), _ = jax.lax.scan(
                _remat(pre_body, cfg), (x, aux_total), params["pre_layers"])
        (x, aux_total), _ = jax.lax.scan(
            _remat(body, cfg), (x, aux_total), params["layers"])
    elif fam == "ssm":
        def m_body(h, lp):
            h = dist.constrain_batch(h)
            d, _ = ssm_mod.mlstm_fwd(lp, h, cfg)
            return dist.constrain_batch(h + d), None

        def group_body(h, gp):
            sp, mp = gp
            h = dist.constrain_batch(h)
            d, _ = ssm_mod.slstm_fwd(sp, h, cfg)
            h = dist.constrain_batch(h + d)
            h, _ = jax.lax.scan(_remat(m_body, cfg), h, mp)
            return h, None

        x, _ = jax.lax.scan(_remat(group_body, cfg), x,
                            (params["slstm"], params["mlstm"]))
    elif fam == "hybrid":
        shared = params["shared_attn"]
        gp, tail = _hybrid_split(cfg, params["layers"])

        def m_body(h, lp):
            h = dist.constrain_batch(h)
            d, _ = ssm_mod.mamba2_fwd(lp, h, cfg)
            return dist.constrain_batch(h + d), None

        def group_body(h, glp):
            h, _ = jax.lax.scan(_remat(m_body, cfg), h, glp)
            h, _, _ = _block_fwd(shared, h, cfg, positions=positions)
            return h, None

        x, _ = jax.lax.scan(_remat(group_body, cfg), x, gp)
        x, _ = jax.lax.scan(_remat(m_body, cfg), x, tail)
    elif fam == "audio":
        enc = batch.extra.astype(jnp.dtype(cfg.compute_dtype))
        e_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                 enc.shape[:2])

        def enc_body(h, lp):
            h2, _, _ = _block_fwd(lp, h, cfg, positions=e_pos, causal=False)
            return h2, None
        enc, _ = jax.lax.scan(_remat(enc_body, cfg), enc, params["enc_layers"])
        enc = rmsnorm(params["ln_enc"], enc, cfg.norm_eps)

        def dec_body(h, lp):
            h2, _ = _dec_block_fwd(lp, h, enc, cfg, positions=positions)
            return h2, None
        x, _ = jax.lax.scan(_remat(dec_body, cfg), x, params["layers"])

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if fam == "vlm" and batch.extra is not None:
        x = x[:, batch.extra.shape[1]:]
    logits = unembed(params["embed"], x, cfg)
    return logits, aux_total


def loss_fn(params: Params, batch: TrainBatch, cfg: ModelConfig,
            aux_coef: float = 0.01):
    logits, aux = forward(params, batch, cfg)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via masked reduction: take_along_axis over the
    # model-sharded vocab dim would all-gather the full logits
    # (EXPERIMENTS.md: seamless/internvl train memory iteration)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                      logits.ndim - 1)
    gold = jnp.sum(jnp.where(v_iota == batch.labels[..., None],
                             logits, 0.0), axis=-1)
    nll = (logz - gold).mean()
    zloss = 1e-4 * (logz ** 2).mean()
    loss = nll + zloss + aux_coef * aux
    return loss, {"nll": nll, "aux": aux, "zloss": zloss}


# ======================================================== caches + decode step
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Concrete zero-filled cache pytree for serving."""
    ct = jnp.dtype(cfg.compute_dtype)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        L = cfg.n_layers - (cfg.moe.first_dense if cfg.moe else 0)
        Lp = cfg.moe.first_dense if cfg.moe else 0
        if cfg.mla:
            m = cfg.mla
            mk = lambda n: (jnp.zeros((n, batch, max_seq, m.kv_lora), ct),
                            jnp.zeros((n, batch, max_seq, m.d_rope), ct))
        else:
            mk = lambda n: (jnp.zeros((n, batch, max_seq, cfg.n_kv, cfg.d_head), ct),
                            jnp.zeros((n, batch, max_seq, cfg.n_kv, cfg.d_head), ct))
        out = {"layers": mk(L)}
        if Lp:
            out["pre_layers"] = mk(Lp)
        return out
    if fam == "ssm":
        xl = cfg.xlstm
        n_groups = cfg.n_layers // xl.slstm_every
        n_m = xl.slstm_every - 1
        B, H, dh = batch, cfg.n_heads, cfg.d_model // cfg.n_heads
        s_state = ssm_mod.SLSTMState(
            c=jnp.zeros((B, H, dh), jnp.float32),
            n=jnp.zeros((B, H, dh), jnp.float32),
            h=jnp.zeros((B, H, dh), ct),
            m=jnp.full((B, H, dh), -1e30, jnp.float32))
        m_state = ssm_mod.init_mlstm_state(cfg, batch)
        stack = lambda st, n: jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), st)
        return {"slstm": stack(s_state, n_groups),
                "mlstm": jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None, None], (n_groups, n_m, *a.shape)).copy(),
                    m_state)}
    if fam == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        mamba = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)),
            ssm_mod.init_mamba_state(cfg, batch))
        attn = (jnp.zeros((n_apps, batch, max_seq, cfg.n_kv, cfg.d_head), ct),
                jnp.zeros((n_apps, batch, max_seq, cfg.n_kv, cfg.d_head), ct))
        return {"mamba": mamba, "attn": attn}
    if fam == "audio":
        return {
            "self": (jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.d_head), ct),
                     jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.d_head), ct)),
            "enc": jnp.zeros((batch, cfg.enc_len, cfg.d_model), ct),
        }
    raise ValueError(fam)


def decode_step(params: Params, cache, tokens, pos, cfg: ModelConfig):
    """One token for every sequence.  tokens: (B, 1); pos: scalar index.
    Returns (logits (B, V), new_cache)."""
    fam = cfg.family
    x = embed(params["embed"], tokens, cfg)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)

    if fam in ("dense", "moe", "vlm"):
        def body(h, xs):
            lp, ck = xs
            h2, new_ck, _ = _block_fwd(lp, h, cfg, positions=positions,
                                       cache=ck, cache_index=pos,
                                       moe_layer=cfg.moe is not None)
            return h2, new_ck
        new_cache = dict(cache)
        if "pre_layers" in params:
            def pre_body(h, xs):
                lp, ck = xs
                h2, new_ck, _ = _block_fwd(lp, h, cfg, positions=positions,
                                           cache=ck, cache_index=pos,
                                           moe_layer=False)
                return h2, new_ck
            x, new_cache["pre_layers"] = jax.lax.scan(
                pre_body, x, (params["pre_layers"], cache["pre_layers"]))
        x, new_cache["layers"] = jax.lax.scan(
            body, x, (params["layers"], cache["layers"]))
    elif fam == "ssm":
        def m_body(h, xs):
            lp, st = xs
            d, st2 = ssm_mod.mlstm_fwd(lp, h, cfg, state=st)
            return h + d, st2

        def group_body(h, xs):
            sp, mp, s_st, m_st = xs
            d, s_st2 = ssm_mod.slstm_fwd(sp, h, cfg, state=s_st)
            h = h + d
            h, m_st2 = jax.lax.scan(m_body, h, (mp, m_st))
            return h, (s_st2, m_st2)

        x, (s_new, m_new) = jax.lax.scan(
            group_body, x, (params["slstm"], params["mlstm"],
                            cache["slstm"], cache["mlstm"]))
        new_cache = {"slstm": s_new, "mlstm": m_new}
    elif fam == "hybrid":
        mamba_new, attn_new, x = _hybrid_decode(params, cache, x, positions,
                                                pos, cfg)
        new_cache = {"mamba": mamba_new, "attn": attn_new}
    elif fam == "audio":
        enc = cache["enc"]
        def body(h, xs):
            lp, ck = xs
            h2, new_self = _dec_block_fwd(lp, h, enc, cfg,
                                          positions=positions,
                                          cache=(ck[0], ck[1]),
                                          cache_index=pos)
            return h2, new_self
        x, new_self = jax.lax.scan(body, x, (params["layers"], cache["self"]))
        new_cache = {"self": new_self, "enc": enc}

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, 0], cfg)[..., :cfg.vocab]
    return logits, new_cache


def _hybrid_decode(params, cache, x, positions, pos, cfg: ModelConfig):
    """Hybrid decode: group scan [k mamba + shared attn], per-application
    attention caches consumed as scan xs (no dynamic indexing)."""
    shared = params["shared_attn"]
    gp, tail = _hybrid_split(cfg, params["layers"])
    gst, tail_st = _hybrid_split(cfg, cache["mamba"])

    def m_body(h, xs):
        lp, mst = xs
        d, mst2 = ssm_mod.mamba2_fwd(lp, h, cfg, state=mst)
        return h + d, mst2

    def group_body(h, xs):
        glp, gmst, ck, cv = xs
        h, mst2 = jax.lax.scan(m_body, h, (glp, gmst))
        h, new_c, _ = _block_fwd(shared, h, cfg, positions=positions,
                                 cache=(ck, cv), cache_index=pos)
        return h, (mst2, new_c[0], new_c[1])

    ck, cv = cache["attn"]
    x, (gst2, ck2, cv2) = jax.lax.scan(group_body, x, (gp, gst, ck, cv))
    x, tail_st2 = jax.lax.scan(m_body, x, (tail, tail_st))
    mamba_new = _hybrid_join(cfg, gst2, tail_st2)
    return mamba_new, (ck2, cv2), x


# ---------------------------------------------------------------- prefill
def prefill(params: Params, tokens, cfg: ModelConfig,
            extra: Optional[jax.Array] = None):
    """Process a full prompt; returns (last-token logits, cache).

    Implemented as forward + cache extraction for the attention families;
    recurrent families run their chunked scans and keep final states.
    """
    fam = cfg.family
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    if fam == "vlm" and extra is not None:
        ct = jnp.dtype(cfg.compute_dtype)
        patches = jnp.einsum("bpd,de->bpe", extra.astype(ct),
                             params["patch_proj"].astype(ct))
        x = dist.constrain_batch(jnp.concatenate([patches, x], axis=1))
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    max_seq = S

    if fam in ("dense", "moe", "vlm"):
        def body(h, lp):
            h2, kv, _ = _block_fwd(lp, h, cfg, positions=positions,
                                   moe_layer=cfg.moe is not None,
                                   return_kv=True)
            return h2, kv
        cache = {}
        if "pre_layers" in params:
            def pre_body(h, lp):
                h2, kv, _ = _block_fwd(lp, h, cfg, positions=positions,
                                       moe_layer=False, return_kv=True)
                return h2, kv
            x, cache["pre_layers"] = jax.lax.scan(
                pre_body, x, params["pre_layers"])
        x, cache["layers"] = jax.lax.scan(body, x, params["layers"])
    elif fam == "audio":
        enc = extra.astype(jnp.dtype(cfg.compute_dtype))
        e_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None], enc.shape[:2])

        def enc_body(h, lp):
            h2, _, _ = _block_fwd(lp, h, cfg, positions=e_pos, causal=False)
            return h2, None
        enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
        enc = rmsnorm(params["ln_enc"], enc, cfg.norm_eps)
        def dec_body(h, lp):
            h2, kv = _dec_block_fwd(lp, h, enc, cfg, positions=positions,
                                    return_kv=True)
            return h2, kv
        x, new_self = jax.lax.scan(dec_body, x, params["layers"])
        cache = {"self": new_self, "enc": enc}
    elif fam == "ssm":
        def m_body(h, lp):
            d, st = ssm_mod.mlstm_fwd(lp, h, cfg, return_state=True)
            return h + d, st

        def group_body(h, gp):
            sp, mp = gp
            d, s_st = ssm_mod.slstm_fwd(sp, h, cfg, return_state=True)
            h = h + d
            h, m_st = jax.lax.scan(m_body, h, mp)
            return h, (s_st, m_st)

        x, (s_st, m_st) = jax.lax.scan(
            group_body, x, (params["slstm"], params["mlstm"]))
        cache = {"slstm": s_st, "mlstm": m_st}
    elif fam == "hybrid":
        shared = params["shared_attn"]
        gp, tail = _hybrid_split(cfg, params["layers"])

        def m_body(h, lp):
            d, mst = ssm_mod.mamba2_fwd(lp, h, cfg, return_state=True)
            return h + d, mst

        def group_body(h, glp):
            h, mst = jax.lax.scan(m_body, h, glp)
            h, kv, _ = _block_fwd(shared, h, cfg, positions=positions,
                                  return_kv=True)
            return h, (mst, kv[0], kv[1])

        x, (gst, ck, cv) = jax.lax.scan(group_body, x, gp)
        x, tail_st = jax.lax.scan(m_body, x, tail)
        cache = {"mamba": _hybrid_join(cfg, gst, tail_st),
                 "attn": (ck, cv)}
    else:
        raise NotImplementedError(fam)

    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = unembed(params["embed"], x[:, 0], cfg)[..., :cfg.vocab]
    return logits, cache
