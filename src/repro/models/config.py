"""Model configuration for the 10 assigned architectures.

One ModelConfig describes any member of the supported families:
dense / moe / ssm (xLSTM) / hybrid (Mamba2+shared attn) / vlm / audio
(enc-dec).  Frontends for [vlm]/[audio] are stubs: `input_specs()` supplies
precomputed patch/frame embeddings per the assignment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    first_dense: int = 0          # first k layers use a dense FFN instead
    d_first_dense: int = 0
    token_chunk: int = 0          # process tokens in chunks of this size
                                  # (bounds the (T*k, d) dispatch buffers)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512            # compressed kv dim (cached at decode)
    q_lora: int = 1536
    d_nope: int = 128             # per-head non-rotary q/k dim
    d_rope: int = 64              # shared rotary key dim
    d_v: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    d_head: int = 64
    chunk: int = 256
    conv_width: int = 4


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 6          # layer i is sLSTM if i % slstm_every == 0
    chunk: int = 256              # mLSTM chunk length
    proj_factor_m: float = 2.0    # mLSTM up-projection
    proj_factor_s: float = 1.3334 # sLSTM FFN factor
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0               # defaults to d_model // n_heads
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # chatglm-style 2d rope: rotate this fraction
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 0           # hybrid: shared attn block every k layers
    # enc-dec (audio) --------------------------------------------------------
    n_enc_layers: int = 0
    enc_len: int = 1024           # frame embeddings from the stub frontend
    # vlm --------------------------------------------------------------------
    n_patches: int = 0            # patch embeddings from the stub frontend
    # numerics / performance -------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "none"           # none|dots|full  (activation checkpointing)
    fsdp: bool = False            # additionally shard weights over data axis
    train_microbatches: int = 1   # gradient-accumulation microbatches
    layout: str = "tp"            # "tp": model axis = TP/EP | "fsdp": model
                                  # axis joins data (pure ZeRO-3, no TP)
    attn_block_q: int = 512       # chunked-attention query block
    attn_block_kv: int = 1024
    logits_chunk: int = 0         # vocab-chunked loss (0 = off)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert not (self.moe and self.layout == "fsdp"), \
            "MoE archs need the model axis for expert parallelism"

    # -- family predicates ---------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a tile boundary so the vocab dim can
        shard over the model axis (151655 etc. are not divisible by 16;
        unsharded logits replicate ~20 GB/device — EXPERIMENTS.md)."""
        return -(-self.vocab // 128) * 128

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context?  (SSM/hybrid: yes.)"""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND roofline math) -----------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":        # xLSTM
            x = self.xlstm
            per_m = int(2 * d * d * x.proj_factor_m) + \
                int(3 * d * d * x.proj_factor_m / 2) + 8 * d
            per_s = 4 * d * d + int(2 * d * d * x.proj_factor_s) + 8 * d
            n_s = len([i for i in range(L) if i % x.slstm_every == 0])
            return emb + n_s * per_s + (L - n_s) * per_m
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            per = 2 * d * d_in + d_in * d + 2 * d_in * s.d_state  # approx
            attn = 4 * d * d + 3 * d * self.d_ff
            n_attn = L // max(self.attn_every, 1)
            return emb + L * per + attn + n_attn * 0  # shared block params once
        # attention side
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.d_nope + m.d_rope)
                    + d * (m.kv_lora + m.d_rope)
                    + m.kv_lora * self.n_heads * (m.d_nope + m.d_v)
                    + self.n_heads * m.d_v * d)
        else:
            attn = d * self.n_heads * self.d_head + \
                2 * d * self.n_kv * self.d_head + self.n_heads * self.d_head * d
        if self.moe:
            mo = self.moe
            n_routed = mo.top_k if active_only else mo.n_experts
            ffn = (n_routed + mo.n_shared) * 3 * d * mo.d_expert
            dense_ff = mo.first_dense * 3 * d * mo.d_first_dense
            ffn_total = (L - mo.first_dense) * ffn + dense_ff
        else:
            ffn_total = L * 3 * d * self.d_ff
        total = emb + L * attn + ffn_total
        if self.is_encdec:  # encoder layers: self-attn + ffn; decoder adds cross
            enc = self.n_enc_layers * (attn + 3 * d * self.d_ff)
            total += enc + L * attn  # cross-attention in each decoder layer
        return total


# ---------------------------------------------------------------- input shapes
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig):
    """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        yield s
