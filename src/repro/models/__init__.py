"""Model substrate: configs, layers, and the unified LM assembly."""
from .config import (SHAPES, SHAPES_BY_NAME, MLAConfig, ModelConfig,
                     MoEConfig, ShapeSpec, SSMConfig, XLSTMConfig,
                     applicable_shapes)
from .model import (TrainBatch, decode_step, forward, init_cache,
                    init_params, loss_fn, prefill)
from .moe import get_mesh, set_mesh

__all__ = [
    "SHAPES", "SHAPES_BY_NAME", "MLAConfig", "ModelConfig", "MoEConfig",
    "ShapeSpec", "SSMConfig", "XLSTMConfig", "applicable_shapes",
    "TrainBatch", "decode_step", "forward", "init_cache", "init_params",
    "loss_fn", "prefill", "get_mesh", "set_mesh",
]
