"""Shared neural layers: norms, rotary embeddings, GQA and MLA attention.

Everything is functional: `init_*` builds a param pytree, `*_fwd` applies
it.  Per-layer params are stacked on axis 0 by the model assembly and
consumed through `jax.lax.scan` (bounded compile time, production-sane).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from . import dist
from .config import ModelConfig

Params = Dict[str, jax.Array]


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------- rope
def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """Rotary embedding on the first `fraction` of head dims.

    x: (..., S, H, D); positions: (..., S) broadcastable.
    """
    d = x.shape[-1]
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                     # (d_rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d_rot/2)
    ang = ang[..., None, :]                               # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., 0::2].astype(jnp.float32), xr[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if d_rot < d else out


# ------------------------------------------------------------------ embedding
def init_embedding(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab
    p = {"tok": _init(k1, (v, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = _init(k2, (v, cfg.d_model), cfg.d_model ** -0.5, dt)
    return p


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tok"].astype(jnp.dtype(cfg.compute_dtype))[tokens]


def unembed(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p.get("unembed", p["tok"]).astype(jnp.dtype(cfg.compute_dtype))
    return jnp.einsum("...d,vd->...v", x, w)


# -------------------------------------------------------------- GQA attention
def init_gqa(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    H, K, Dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": _init(ks[0], (d, H, Dh), s, dt),
        "wk": _init(ks[1], (d, K, Dh), s, dt),
        "wv": _init(ks[2], (d, K, Dh), s, dt),
        "wo": _init(ks[3], (H, Dh, d), (H * Dh) ** -0.5, dt),
    }


def gqa_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
            positions: jax.Array,
            cache: Optional[Tuple[jax.Array, jax.Array]] = None,
            cache_index: Optional[jax.Array] = None,
            kv_source: Optional[jax.Array] = None,
            causal: bool = True, return_kv: bool = False):
    """GQA/MQA attention.  Modes:
       * train/prefill: cache is None, full self-attention over x.
       * decode:        cache=(k,v) with (B,S,K,Dh); writes at cache_index.
       * cross:         kv_source given (encoder memory), no rope on kv.
    Returns (out, new_cache).
    """
    ct = jnp.dtype(cfg.compute_dtype)
    q = dist.constrain_heads(
        jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct)))
    src = x if kv_source is None else kv_source
    k = dist.constrain_heads(
        jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(ct)))
    v = dist.constrain_heads(
        jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(ct)))
    if kv_source is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    new_cache = None
    if return_kv and cache is None:
        # prefill: emit the cache content directly (no zero buffer to
        # update — a full-size zeros+dynamic-update carry costs ~2x the
        # cache in live temps; see EXPERIMENTS.md deepseek iteration)
        out = kops.attention(q, k, v, causal=causal and kv_source is None,
                             block_q=cfg.attn_block_q,
                             block_kv=cfg.attn_block_kv)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(ct))
        return out, (k, v)
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        new_cache = (ck, cv)
        k, v = ck, cv
        kv_len = jnp.asarray(cache_index + x.shape[1], jnp.int32)
        out = kops.attention(q, k, v, causal=False, kv_valid_len=kv_len,
                             block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    else:
        out = kops.attention(q, k, v, causal=causal and kv_source is None,
                             block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(ct))
    return out, new_cache


# -------------------------------------------------------------- MLA attention
def init_mla(key, cfg: ModelConfig) -> Params:
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wq_a": _init(ks[0], (d, m.q_lora), d ** -0.5, dt),
        "wq_b": _init(ks[1], (m.q_lora, H, m.d_nope + m.d_rope),
                      m.q_lora ** -0.5, dt),
        "wkv_a": _init(ks[2], (d, m.kv_lora), d ** -0.5, dt),
        "wk_rope": _init(ks[3], (d, m.d_rope), d ** -0.5, dt),
        "wkv_b": _init(ks[4], (m.kv_lora, H, m.d_nope + m.d_v),
                       m.kv_lora ** -0.5, dt),
        "wo": _init(ks[5], (H, m.d_v, d), (H * m.d_v) ** -0.5, dt),
    }


def mla_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
            positions: jax.Array,
            cache: Optional[Tuple[jax.Array, jax.Array]] = None,
            cache_index: Optional[jax.Array] = None,
            causal: bool = True, return_kv: bool = False):
    """Multi-head latent attention (DeepSeek-V2).

    Cache stores only (c_kv, k_rope): (B,S,kv_lora) + (B,S,d_rope) — the
    compressed latents.  Decode uses the *absorbed* formulation (Wkv_b
    folded into the query/output) so per-step FLOPs scale with kv_lora,
    not H x (d_nope + d_v).
    """
    m = cfg.mla
    ct = jnp.dtype(cfg.compute_dtype)
    H = cfg.n_heads
    q = jnp.einsum("bsd,dq->bsq", x, p["wq_a"].astype(ct))
    q = dist.constrain_heads(
        jnp.einsum("bsq,qhk->bshk", q, p["wq_b"].astype(ct)))
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dc->bsc", x, p["wkv_a"].astype(ct))
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"].astype(ct))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    scale = 1.0 / math.sqrt(m.d_nope + m.d_rope)

    if cache is None:
        kv = dist.constrain_heads(
            jnp.einsum("bsc,chk->bshk", c_kv, p["wkv_b"].astype(ct)))
        k_nope, v = kv[..., :m.d_nope], kv[..., m.d_nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], m.d_rope))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        out = kops.attention(qf, k, v, causal=causal, scale=scale,
                             block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
        out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(ct))
        return out, ((c_kv, k_rope) if return_kv else None)

    # ---- decode: absorbed attention in compressed space -------------------
    cc, cr = cache
    cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv.astype(cc.dtype), cache_index, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(cr, k_rope.astype(cr.dtype), cache_index, axis=1)
    f32 = jnp.float32
    wb_k = p["wkv_b"].astype(f32)[..., :m.d_nope]        # (c, H, d_nope)
    wb_v = p["wkv_b"].astype(f32)[..., m.d_nope:]        # (c, H, d_v)
    # f32 score math: the latents stay bf16 in HBM (decode is bandwidth-
    # bound); casting after load costs ~nothing and keeps the absorbed
    # formulation numerically equal to the direct one.
    q_abs = jnp.einsum("bshk,chk->bshc", q_nope.astype(f32), wb_k)
    scores = (jnp.einsum("bshc,btc->bhst", q_abs, cc.astype(f32))
              + jnp.einsum("bshr,btr->bhst", q_rope.astype(f32),
                           cr.astype(f32))) * scale
    t = jnp.arange(cc.shape[1])
    qpos = cache_index + jnp.arange(x.shape[1])     # per-query causal mask
    mask = t[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btc->bshc", attn, cc.astype(f32))
    out = jnp.einsum("bshc,chv->bshv", ctx, wb_v).astype(ct)  # absorb o-proj
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(ct))
    return out, (cc, cr)


# ---------------------------------------------------------------- dense FFN
def init_swiglu(key, d: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d, d_ff), d ** -0.5, dtype),
        "w_up": _init(ks[1], (d, d_ff), d ** -0.5, dtype),
        "w_down": _init(ks[2], (d_ff, d), d_ff ** -0.5, dtype),
    }


def swiglu_fwd(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    ct = jnp.dtype(compute_dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(ct))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(ct))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"].astype(ct))
