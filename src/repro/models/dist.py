"""Mesh context + activation sharding constraints.

XLA's sharding propagation can pick pathological layouts for scan carries
(involuntary full rematerialization).  Pinning activations at block
boundaries to (batch over pod x data, replicated elsewhere) keeps the
layout stable; every constraint is a no-op when no mesh is set (CPU smoke
tests, single device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_MESH: Optional[Mesh] = None
_BATCH_AXES: Tuple[str, ...] = ("data",)


def set_mesh(mesh: Optional[Mesh], batch_axes=("data",)) -> None:
    global _MESH, _BATCH_AXES
    _MESH = mesh
    _BATCH_AXES = tuple(batch_axes)


def get_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes() -> Tuple[str, ...]:
    return _BATCH_AXES


def _flat(axes):
    return axes if len(axes) > 1 else axes[0]


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) if a mesh is active.
    Use "batch" as a placeholder for the flattened batch axes."""
    if _MESH is None or x is None:
        return x
    spec = tuple(_flat(_BATCH_AXES) if s == "batch" else s for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))


def constrain_batch(x):
    """Shard dim 0 over batch axes; replicate the rest (any rank)."""
    if _MESH is None or x is None:
        return x
    import numpy as np
    n = int(np.prod([_MESH.shape[a] for a in _BATCH_AXES]))
    if not x.shape or x.shape[0] % n:
        return x
    return constrain(x, "batch", *([None] * (x.ndim - 1)))


def constrain_tree(tree, shardings):
    if _MESH is None or shardings is None:
        return tree
    return jax.lax.with_sharding_constraint(tree, shardings)


def constrain_heads(x, head_axis: int = 2):
    """Pin (B, S, H, D)-like activations: batch on dp axes, heads on model
    (TP layout only, and only when H divides the axis)."""
    if _MESH is None or x is None or "model" in _BATCH_AXES:
        return x
    if "model" not in _MESH.axis_names:
        return x
    if x.shape[head_axis] % _MESH.shape["model"]:
        return x
    spec = [None] * x.ndim
    import numpy as np
    nb = int(np.prod([_MESH.shape[a] for a in _BATCH_AXES]))
    if x.shape[0] % nb == 0:
        spec[0] = _flat(_BATCH_AXES)
    spec[head_axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))
