"""Streaming O(1)-state accumulators (Welford mean/variance, P² quantile).

Split out of :mod:`repro.core.metrics` so the simulator itself can hold a
sketch (the streaming decision-latency p99) without importing the metrics
module — metrics imports the simulator, so the sketches must live below
both.  ``repro.core.metrics`` re-exports both classes; existing imports
keep working.

``P2Quantile.add`` is a named hot frame of the million-job replay profile
(benchmarks/bench_profile.py): a streaming sink feeds six sketches per
retired record, so the marker update below is unrolled and localized —
same arithmetic, same float operations, bit-identical estimates to the
straightforward transcription of Jain & Chlamtac (1985).
"""
from __future__ import annotations

from typing import List

import numpy as np


class Welford:
    """Numerically stable streaming mean/variance (Welford 1962)."""

    __slots__ = ("n", "mean", "_m2")

    def __init__(self) -> None:
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0

    def add(self, x: float) -> None:
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self._m2 += delta * (x - self.mean)

    @property
    def variance(self) -> float:
        return self._m2 / self.n if self.n else float("nan")

    def result(self) -> float:
        return self.mean if self.n else float("nan")


class P2Quantile:
    """The P² streaming quantile estimator (Jain & Chlamtac 1985).

    Five markers track the running ``p``-quantile in O(1) memory; exact
    below five observations, approximate after (parabolic marker
    adjustment).  Accuracy is excellent for the mid quantiles and
    degrades gracefully in the tails — the docs carry the caveat.

    Marker positions stay *strictly increasing*: an adjustment moves a
    marker by ±1 only when the gap on that side exceeds 1, so every
    denominator below is at least 1 in magnitude and the classic P²
    divide-by-zero (implementations that let adjacent markers collide on
    duplicate-heavy streams) cannot occur.  The linear fallback keeps a
    defensive gap guard anyway — it costs nothing and turns a violated
    invariant into a no-op adjustment instead of a crash.
    """

    __slots__ = ("p", "_q", "_n", "_np", "_dn", "count")

    def __init__(self, p: float):
        assert 0.0 < p < 1.0
        self.p = p
        self.count = 0
        self._q: List[float] = []           # marker heights
        self._n = [0, 1, 2, 3, 4]           # marker positions (0-based)
        self._np = [0.0, 2 * p, 4 * p, 2 + 2 * p, 4.0]  # desired positions
        self._dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]

    def add(self, x: float) -> None:
        self.count += 1
        q = self._q
        if self.count <= 5:
            q.append(x)
            q.sort()
            return
        n = self._n
        # locate cell k, clamp the extremes, and bump the marker
        # positions above the cell (the loop pair of the textbook
        # transcription, unrolled: one comparison chain per sample)
        if x < q[2]:
            if x < q[0]:
                q[0] = x
                n[1] += 1; n[2] += 1; n[3] += 1; n[4] += 1  # noqa: E702
            elif x < q[1]:
                n[1] += 1; n[2] += 1; n[3] += 1; n[4] += 1  # noqa: E702
            else:
                n[2] += 1; n[3] += 1; n[4] += 1             # noqa: E702
        elif x < q[3]:
            n[3] += 1; n[4] += 1                            # noqa: E702
        else:
            if x >= q[4]:
                q[4] = x
            n[4] += 1
        np_, dn = self._np, self._dn
        np_[1] += dn[1]; np_[2] += dn[2]; np_[3] += dn[3]   # noqa: E702
        np_[4] += 1.0
        # adjust the three middle markers toward their desired positions
        for i in (1, 2, 3):
            d = np_[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or \
                    (d <= -1 and n[i - 1] - n[i] < -1):
                d = 1 if d > 0 else -1
                # parabolic (P²) candidate, linear fallback
                qi = q[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (q[i + 1] - q[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1])
                    / (n[i] - n[i - 1]))
                if not q[i - 1] < qi < q[i + 1]:
                    gap = n[i + d] - n[i]
                    if gap == 0:  # unreachable per the invariant; defensive
                        continue
                    qi = q[i] + d * (q[i + d] - q[i]) / gap
                q[i] = qi
                n[i] += d

    def result(self) -> float:
        if self.count == 0:
            return float("nan")
        if self.count <= 5:
            return float(np.percentile(np.asarray(self._q), self.p * 100))
        return self._q[2]
