"""Theta-like workload synthesis (paper §IV-A, §IV-B).

The real one-year Theta trace is not redistributable, so we synthesize
traces that match its published characterization: 4392 nodes, job sizes
dominated by the 128-1024 range (Fig. 3), lognormal runtimes, overestimated
walltimes, project-grouped submissions, and *bursty* on-demand arrivals
(projects submit several on-demand jobs within a short window, Fig. 5).

Job types are assigned per-project (paper default: 10% of projects submit
on-demand jobs, 60% rigid, 30% malleable); on-demand jobs larger than half
the system are reassigned to rigid/malleable (paper §IV-A).

W1-W5 advance-notice mixes (paper Table III) control the split of
on-demand jobs across {no notice, accurate, early, late}.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .job import JobSpec, JobType, NoticeKind

# paper Table III
NOTICE_MIXES: Dict[str, List[float]] = {
    "W1": [0.70, 0.10, 0.10, 0.10],
    "W2": [0.10, 0.70, 0.10, 0.10],
    "W3": [0.10, 0.10, 0.70, 0.10],
    "W4": [0.10, 0.10, 0.10, 0.70],
    "W5": [0.25, 0.25, 0.25, 0.25],
}
NOTICE_KINDS = [NoticeKind.NONE, NoticeKind.ACCURATE,
                NoticeKind.EARLY, NoticeKind.LATE]

# Theta/ALCF-flavored size mix (paper Fig. 3): most jobs 128-1024 nodes.
SIZE_BUCKETS = [(128, 256), (257, 512), (513, 1024), (1025, 2048), (2049, 4096)]
SIZE_WEIGHTS = [0.46, 0.26, 0.16, 0.08, 0.04]


@dataclass
class WorkloadConfig:
    n_nodes: int = 4392
    n_jobs: int = 1500
    horizon_days: float = 14.0
    target_load: float = 1.05          # offered load vs capacity
    n_projects: int = 60
    frac_od_projects: float = 0.10     # paper §IV-B
    frac_rigid_projects: float = 0.60
    notice_mix: str = "W5"
    # on-demand burstiness (paper Fig. 5)
    od_burst_size: tuple = (2, 8)
    od_burst_window: float = 1800.0
    # runtime model
    runtime_median_s: float = 7200.0
    runtime_sigma: float = 1.1
    runtime_max_s: float = 86400.0
    runtime_min_s: float = 600.0
    estimate_factor: tuple = (1.0, 3.0)
    # overheads (paper §IV-B)
    rigid_setup_frac: tuple = (0.05, 0.10)
    malleable_setup_frac: tuple = (0.0, 0.05)
    malleable_min_frac: float = 0.20
    ckpt_overhead_small: float = 600.0   # < 1K nodes
    ckpt_overhead_large: float = 1200.0  # >= 1K nodes
    ckpt_freq_factor: float = 1.0        # 0.5 = twice as frequent as Daly
    node_mtbf_hours: float = 20000.0     # per-node MTBF for the Daly interval
    notice_lead: tuple = (900.0, 1800.0)  # 15-30 min
    late_window: float = 1800.0
    seed: int = 0


def daly_interval(delta: float, mtbf_job: float) -> float:
    """Daly's first-order optimal checkpoint interval."""
    if not math.isfinite(mtbf_job):
        return math.inf
    return max(math.sqrt(2.0 * delta * mtbf_job) - delta, delta)


def generate(cfg: WorkloadConfig) -> List[JobSpec]:
    rng = np.random.default_rng(cfg.seed)
    horizon = cfg.horizon_days * 86400.0

    # ---- project pool with Zipf-ish activity ------------------------------
    n_proj = cfg.n_projects
    proj_w = 1.0 / np.arange(1, n_proj + 1) ** 0.8
    proj_w /= proj_w.sum()
    proj_type = np.array([JobType.ONDEMAND] * round(n_proj * cfg.frac_od_projects)
                         + [JobType.RIGID] * round(n_proj * cfg.frac_rigid_projects),
                         dtype=object)
    proj_type = np.concatenate(
        [proj_type, np.array([JobType.MALLEABLE] * (n_proj - len(proj_type)),
                             dtype=object)])
    rng.shuffle(proj_type)

    # ---- raw jobs ----------------------------------------------------------
    projects = rng.choice(n_proj, size=cfg.n_jobs, p=proj_w)
    buckets = rng.choice(len(SIZE_BUCKETS), size=cfg.n_jobs, p=SIZE_WEIGHTS)
    lo = np.array([SIZE_BUCKETS[b][0] for b in buckets])
    hi = np.array([SIZE_BUCKETS[b][1] for b in buckets])
    sizes = np.exp(rng.uniform(np.log(lo), np.log(hi))).astype(int)
    sizes = np.clip(sizes, 1, cfg.n_nodes)
    runtimes = np.exp(rng.normal(np.log(cfg.runtime_median_s), cfg.runtime_sigma,
                                 cfg.n_jobs))
    runtimes = np.clip(runtimes, cfg.runtime_min_s, cfg.runtime_max_s)

    # scale arrivals so offered load ~= target_load of capacity
    total_work = float((sizes * runtimes).sum())
    span = total_work / (cfg.n_nodes * cfg.target_load)
    span = min(span, horizon)
    arrivals = np.sort(rng.uniform(0.0, span, cfg.n_jobs))

    jobs: List[JobSpec] = []
    mix = NOTICE_MIXES[cfg.notice_mix]
    od_members: Dict[int, List[int]] = {}
    for i in range(cfg.n_jobs):
        p = int(projects[i])
        jt: JobType = proj_type[p]
        size, t_act = int(sizes[i]), float(runtimes[i])
        if jt is JobType.ONDEMAND and size > cfg.n_nodes // 2:
            jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
        t_est = float(t_act * rng.uniform(*cfg.estimate_factor))
        t_est = min(t_est, cfg.runtime_max_s * 3)
        if jt is JobType.RIGID:
            setup = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
            delta = (cfg.ckpt_overhead_small if size < 1000
                     else cfg.ckpt_overhead_large)
            mtbf_job = cfg.node_mtbf_hours * 3600.0 / size
            tau = daly_interval(delta, mtbf_job) * cfg.ckpt_freq_factor
            jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                t_est, t_act, t_setup=setup,
                                ckpt_overhead=delta, ckpt_interval=tau))
        elif jt is JobType.MALLEABLE:
            setup = float(t_act * rng.uniform(*cfg.malleable_setup_frac))
            jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                t_est, t_act, t_setup=setup,
                                n_min=max(1, math.ceil(cfg.malleable_min_frac * size))))
        else:
            setup = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
            jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                t_est, t_act, t_setup=setup))
            od_members.setdefault(p, []).append(len(jobs) - 1)

    # ---- bursty on-demand arrivals + notice kinds (Table III) --------------
    for p, idxs in od_members.items():
        k = 0
        while k < len(idxs):
            burst = int(rng.integers(*cfg.od_burst_size))
            anchor = jobs[idxs[k]].submit_time
            for j in idxs[k:k + burst]:
                jobs[j].submit_time = float(
                    anchor + rng.uniform(0.0, cfg.od_burst_window))
            k += burst
    od_jobs = [j for j in jobs if j.jtype is JobType.ONDEMAND]
    kinds = rng.choice(4, size=len(od_jobs), p=mix)
    for j, kidx in zip(od_jobs, kinds):
        kind = NOTICE_KINDS[int(kidx)]
        j.notice_kind = kind
        if kind is NoticeKind.NONE:
            continue
        lead = float(rng.uniform(*cfg.notice_lead))
        arrival = j.submit_time
        if kind is NoticeKind.ACCURATE:
            j.notice_time = arrival - lead
            j.est_arrival = arrival
        elif kind is NoticeKind.EARLY:
            # actual arrival uniform in (notice, est_arrival)
            j.notice_time = arrival - float(rng.uniform(0.0, lead))
            j.est_arrival = j.notice_time + lead
        else:  # LATE: arrival within 30 min after estimate
            j.est_arrival = arrival - float(rng.uniform(0.0, cfg.late_window))
            j.notice_time = j.est_arrival - lead
        j.notice_time = max(j.notice_time, 0.0)

    jobs.sort(key=lambda j: j.submit_time)
    for new_id, j in enumerate(jobs):
        j.jid = new_id
    return jobs
