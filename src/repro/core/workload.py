"""Backward-compat shim: the workload layer is now the
``repro.core.workloads`` package (sources, transforms, scenarios behind a
registry — see docs/workloads.md).  Every name that used to live here
re-exports unchanged; ``generate(cfg)`` still reproduces the pre-split
traces bit-for-bit (golden-tested)."""
from .workloads.synthetic import (NOTICE_KINDS, NOTICE_MIXES, SIZE_BUCKETS,
                                  SIZE_WEIGHTS, ThetaGenerator,
                                  WorkloadConfig, daly_interval, generate,
                                  notice_mix)

__all__ = [
    "NOTICE_KINDS", "NOTICE_MIXES", "SIZE_BUCKETS", "SIZE_WEIGHTS",
    "ThetaGenerator", "WorkloadConfig", "daly_interval", "generate",
    "notice_mix",
]
