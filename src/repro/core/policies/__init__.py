"""Builtin + bundled third-party scheduling policies.

Importing this package registers every policy and mechanism shipped with
the repo; `repro.core.policy.resolve_mechanism` imports it lazily so any
`Simulator(...)` construction sees the full registry.
"""
from . import builtin  # noqa: F401  (registration side effects)
from . import wagomu   # noqa: F401

__all__ = ["builtin", "wagomu"]
