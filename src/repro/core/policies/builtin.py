"""The paper's mechanisms as registry entries (paper §III-B).

Notice axis:   N (nothing) | CUA (collect-until-actual-arrival)
               | CUP (collect-until-predicted-arrival, planned preemption)
Arrival axis:  PAA (preempt ascending overhead) | SPAA (shrink-then-PAA)
Queue:         EASY (FCFS + EASY backfilling) | FCFS (no backfill)
Elasticity:    NONE (lease-repay expansion only — the seed behavior)

Each class is a verbatim port of the corresponding pre-refactor
`Simulator` method; legacy mechanism strings must reproduce seed metrics
bit-for-bit (tests/test_policy_api.py::test_golden_seed_metrics).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import numpy as np

from ..decision import (apportion_shrink, backfill_prefilter,
                        backfill_shadow_filter, easy_shadow,
                        expected_releases_before, select_preemption_victims)
from ..job import JobType
from ..policy import (ArrivalPolicy, ElasticityPolicy, NoticePolicy,
                      PolicyBundle, QueuePolicy, SchedulerOps, SchedulerView,
                      register_mechanism, register_policy)


# ------------------------------------------------------------------- notice
@register_policy("notice", "N")
class NoNotice(NoticePolicy):
    """Ignore advance notice; the job competes only at actual arrival."""

    def on_notice(self, ops: SchedulerOps, jid: int) -> None:
        pass


@register_policy("notice", "CUA")
class CollectUntilArrival(NoticePolicy):
    """Reserve idle nodes at notice; collect releases until arrival."""

    def on_notice(self, ops: SchedulerOps, jid: int) -> None:
        job = ops.jobs[jid]
        got = ops.reserve_from_free(jid, job.size)
        if got < job.size:
            ops.collect(jid)
            self.plan(ops, jid)

    def plan(self, ops: SchedulerOps, jid: int) -> None:
        """CUA never plans preemptions; CUP overrides."""


@register_policy("notice", "CUP")
class CollectUntilPredicted(CollectUntilArrival):
    """CUA + planned preemptions so demand is met by est_arrival."""

    def plan(self, ops: SchedulerOps, jid: int) -> None:
        job = ops.jobs[jid]
        need = job.size - ops.reserved_of(jid)
        ends, sizes = [], []
        for rs in ops.running.values():
            ends.append(ops.est_end(rs))
            sizes.append(rs.cur_size)
        need -= expected_releases_before(ends, sizes, job.est_arrival)
        if need <= 0:
            return
        # candidates: rigid right after an upcoming checkpoint (cheap), then
        # malleables at est_arrival - warning, then any rigid at est_arrival.
        cand: List[Tuple[float, float, int]] = []  # (overhead, preempt_t, jid)
        for rid, rs in ops.running.items():
            j = rs.job
            if j.jtype is JobType.ONDEMAND:
                continue
            if j.jtype is JobType.MALLEABLE:
                t_p = max(ops.now, job.est_arrival - ops.cfg.malleable_warning)
                cand.append((j.t_setup * j.size, t_p, rid))
            else:
                nc = rs.next_ckpt_completion(ops.now)
                if nc is not None and nc <= job.est_arrival:
                    cand.append((j.t_setup * j.size, nc, rid))
                else:
                    t_p = max(ops.now, job.est_arrival - 1.0)
                    lost = rs.work_done(t_p) - rs.checkpointed_work(t_p)
                    cand.append((j.t_setup * j.size + max(lost, 0.0), t_p, rid))
        cand.sort()
        for overhead, t_p, rid in cand:
            if need <= 0:
                break
            rs = ops.running.get(rid)
            if rs is None:
                continue
            ops.push_event(t_p, "planned_preempt", (jid, rid, rs.epoch))
            need -= rs.cur_size


# ------------------------------------------------------------------ arrival
@register_policy("arrival", "PAA")
class PreemptAscendingOverhead(ArrivalPolicy):
    """PAA: preempt running jobs in ascending preemption-overhead order."""

    def acquire(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        return self._paa(ops, jid, need)

    def _paa(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        cand = [(rid, rs) for rid, rs in ops.running.items()
                if rs.job.jtype is not JobType.ONDEMAND]
        # nodes borrowed from other reservations return to their owners, not
        # to this job: only the un-borrowed remainder counts as supply.
        supply = [rs.cur_size - sum(rs.borrowed.values()) for _, rs in cand]
        victims, _ = select_preemption_victims(
            supply, [rs.preemption_overhead(ops.now) for _, rs in cand], need)
        if not victims and need > 0:
            return False
        for i in victims:
            ops.preempt(cand[i][0], beneficiary=jid)
        job = ops.jobs[jid]
        if ops.reserved_of(jid) + ops.free < job.size:
            return False  # borrowed-node routing starved us; wait in queue
        ops.start_od(jid)
        return True


@register_policy("arrival", "SPAA")
class ShrinkThenPreempt(PreemptAscendingOverhead):
    """SPAA: shrink running malleables evenly; fall back to PAA."""

    def acquire(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        if self._try_shrink(ops, jid, need):
            return True
        return self._paa(ops, jid, need)

    def _try_shrink(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        mall = [(rid, rs) for rid, rs in ops.running.items()
                if rs.job.jtype is JobType.MALLEABLE
                and rs.cur_size > rs.job.n_min]
        if not mall:
            return False
        sheds = apportion_shrink([rs.cur_size for _, rs in mall],
                                 [rs.job.n_min for _, rs in mall], need)
        if not sheds:
            return False
        for (rid, _), k in zip(mall, sheds):
            if k > 0:
                ops.shrink(rid, k, jid)
        ops.start_od(jid)
        return True


# -------------------------------------------------------------------- queue
def _fcfs_key(front_get, jobs, jid: int):
    """FCFS with arrived on-demand jobs pinned to the queue front; the one
    definition behind both order_key and the specialized closure."""
    return (0 if front_get(jid) else 1, jobs[jid].submit_time, jid)


@register_policy("queue", "EASY")
class FcfsEasyBackfill(QueuePolicy):
    """FCFS order (arrived on-demand jobs pinned to the front) with EASY
    backfilling behind a blocked head, optionally onto idle reservations."""

    def order_key(self, view: SchedulerView, jid: int):
        return _fcfs_key(view.od_front_map.get, view.jobs, jid)

    def make_order_key(self, view: SchedulerView):
        if type(self).order_key is not FcfsEasyBackfill.order_key:
            # subclass customized the ordering: use the generic wrapper so
            # the override actually takes effect
            return super().make_order_key(view)
        front_get, jobs = view.od_front_map.get, view.jobs
        return lambda jid: _fcfs_key(front_get, jobs, jid)

    def _shadow(self, view: SchedulerView, head: int) -> Tuple[float, int]:
        """EASY reservation for the queue head over estimated releases
        (the vectorized kernel over the incrementally maintained est-end
        arrays — see decision.easy_shadow)."""
        job = view.jobs[head]
        need = job.n_min if job.jtype is JobType.MALLEABLE else job.size
        avail = view.avail_for(head)
        if avail >= need:
            return view.now, avail - need
        bases, sizes = view.est_end_arrays()
        return easy_shadow(avail, need, bases, sizes, view.now)

    def backfill(self, ops: SchedulerOps, head: int) -> None:
        queue = ops.queue
        qlen = len(queue)
        if qlen <= 1:
            return
        allow_borrow = ops.cfg.allow_reserved_backfill
        pool, deadline = ops.borrow_pool() if allow_borrow else (0, math.inf)
        ledger, now = ops.ledger, ops.now
        lo, hi = 1, min(qlen, 1 + ops.cfg.backfill_depth)
        needs_l, ests_l = queue.meta_window(lo, hi)
        bound = ledger.free + pool
        needs = np.asarray(needs_l, dtype=np.float64)
        stage1 = backfill_prefilter(needs, bound)
        hold_book = ledger.job_hold
        if stage1.size == 0 and not hold_book:
            return  # nothing can start: skip the shadow computation too
        t_shadow, extra = self._shadow(ops, head)
        if pool > 0:
            keep = set(map(int, stage1))
        else:
            ests = np.asarray(ests_l, dtype=np.float64)
            keep = set(map(int, backfill_shadow_filter(
                needs, ests, stage1, extra, now, t_shadow)))
        # returned-lease holders see more supply than either bound
        for jid, hold in hold_book.items():
            if jid in queue:
                i = queue.position(jid) - lo
                if 0 <= i < hi - lo and i not in keep \
                        and needs_l[i] <= bound + hold:
                    keep.add(i)
        if not keep:
            return
        cand = [queue[lo + i] for i in sorted(keep)]
        jobs, hold_of = ops.jobs, ops.hold_of
        est_remaining = ops.est_remaining
        for jid in cand:
            job = jobs[jid]
            if job.jtype is JobType.ONDEMAND:
                continue  # arrived ods start only via their own path
            need_min = job.n_min if job.jtype is JobType.MALLEABLE else job.size
            est_run = est_remaining[jid]
            # == borrowable(jid) with the pool scan hoisted out of the loop
            idle_reserved = pool if pool > 0 \
                and ops.borrow_eligible(jid, deadline) else 0
            plain = ledger.free + hold_of(jid)
            total = plain + idle_reserved
            if total < need_min:
                continue
            size = job.size if job.jtype is not JobType.MALLEABLE else \
                min(job.n_max, total)
            from_plain = min(size, plain)
            borrow = size - from_plain
            if job.jtype is JobType.MALLEABLE:
                est_run = job.t_setup + (est_run - job.t_setup) * job.n_max / size
            fits_hole = now + est_run <= t_shadow
            uses_free = max(0, from_plain - hold_of(jid))
            if not fits_hole and uses_free > extra:
                continue
            if not fits_hole:
                extra -= uses_free
            ops.start_backfilled(jid, size, borrow)
            if borrow > 0:  # reservations shrank; re-derive the pool view
                pool, deadline = ops.borrow_pool()


@register_policy("queue", "FCFS")
class FcfsNoBackfill(FcfsEasyBackfill):
    """Strict FCFS: nothing jumps a blocked queue head."""

    def backfill(self, ops: SchedulerOps, head: int) -> None:
        pass


@register_policy("queue", "XFACTOR")
class XFactorEasyBackfill(FcfsEasyBackfill):
    """Expansion-factor aging priority (Maui/Moab XFactor) with EASY
    backfill: rank by (wait + estimate) / estimate, largest first, so
    short jobs age fast and nothing starves.  Arrived on-demand jobs
    stay pinned to the front exactly as under FCFS.

    The key reads the clock, so keys are declared unstable and the
    queue re-sorts with fresh keys every scheduling pass — the
    documented O(n log n)-per-pass regime (docs/performance.md) that
    batched scheduling rounds (``SimConfig.batch_rounds``) exist to
    amortize."""

    order_keys_stable = False

    def order_key(self, view: SchedulerView, jid: int):
        job = view.jobs[jid]
        est = max(job.t_estimate, 1.0)
        xfactor = (view.now - job.submit_time + est) / est
        return (0 if view.od_front_map.get(jid) else 1,
                -xfactor, job.submit_time, jid)


# --------------------------------------------------------------- elasticity
@register_policy("elasticity", "NONE")
class LeaseRepayOnly(ElasticityPolicy):
    """Seed behavior: malleables expand only when a lease is repaid."""


# --------------------------------------------------------------- mechanisms
def _base_bundle(queue: QueuePolicy) -> PolicyBundle:
    """BASE (paper Table II): every job is a plain batch job; the notice
    and arrival policies are inert placeholders."""
    return PolicyBundle(notice=NoNotice(), arrival=PreemptAscendingOverhead(),
                        queue=queue, elasticity=LeaseRepayOnly(),
                        od_aware=False)


register_mechanism("BASE", _base_bundle)
