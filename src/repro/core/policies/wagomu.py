"""Malleable-scheduling algorithms ported from the Wagomu project
(projectwagomu/MalleableJobScheduling, ElastiSim algorithms; EPL-2.0),
adapted to this repo's event-driven hybrid-workload simulator.

Both are :class:`ArrivalPolicy` alternatives to the paper's SPAA: they
decide *which* running malleables shed nodes for an arrived on-demand
job, and pair with the BALANCE elasticity policy so shrunk jobs expand
back into idle nodes — completing the malleability incentive loop.

    STEAL   average-steal agreement: shed one node at a time from the
            malleable with the highest fractional allocation
            (cur - n_min) / (n_max - n_min), driving all malleables
            toward the same average fill level.
    POOL    common-pool preference: each malleable has a preferred size
            halfway between n_min and n_max; jobs furthest above their
            preference shed first, down to pref, then down to n_min.

Unmeetable demand falls back to PAA preemption so on-demand jobs keep
their instant-start guarantee (paper Obs 9).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

from ..job import JobSpec, JobType, RunState
from ..policy import (ElasticityPolicy, SchedulerOps, register_policy)
from .builtin import PreemptAscendingOverhead


def fill_fraction(rs: RunState, delta: int = 0) -> float:
    """Fractional allocation of a malleable in [0, 1] after `delta` nodes."""
    span = rs.job.n_max - rs.job.n_min
    if span <= 0:
        return 1.0
    return (rs.cur_size + delta - rs.job.n_min) / span


def preferred_size(job: JobSpec) -> int:
    """POOL's per-job preference: halfway between n_min and n_max."""
    return min(job.n_max, max(job.n_min, (job.n_min + job.n_max + 1) // 2))


def _running_malleables(ops: SchedulerOps) -> List[Tuple[int, RunState]]:
    return [(rid, rs) for rid, rs in ops.running.items()
            if rs.job.jtype is JobType.MALLEABLE]


# ------------------------------------------------------------------ arrival
@register_policy("arrival", "STEAL")
class AverageStealAgreement(PreemptAscendingOverhead):
    """Wagomu average_steal_agreement: steal from the fullest malleable."""

    preferred_elasticity = "BALANCE"

    def acquire(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        sheds = self._select_sheds(ops, need)
        if sheds is None:
            return self._paa(ops, jid, need)
        for rid, k in sheds:
            ops.shrink(rid, k, jid)
        ops.start_od(jid)
        return True

    def _select_sheds(self, ops: SchedulerOps,
                      need: int) -> Optional[List[Tuple[int, int]]]:
        """One node per round from the malleable with the highest fill
        fraction; None if the combined slack cannot cover `need`.

        Heap keyed on (-fill, arrival order) so each round is O(log m)
        with the same winner (ties to the first malleable) as a full
        max() scan."""
        mall = [(rid, rs) for rid, rs in _running_malleables(ops)
                if rs.cur_size > rs.job.n_min]
        if sum(rs.cur_size - rs.job.n_min for _, rs in mall) < need:
            return None
        shed: Dict[int, int] = {rid: 0 for rid, _ in mall}
        heap = [(-fill_fraction(rs), i) for i, (_, rs) in enumerate(mall)]
        heapq.heapify(heap)
        for _ in range(need):
            _, i = heapq.heappop(heap)
            rid, rs = mall[i]
            shed[rid] += 1
            if rs.cur_size - shed[rid] > rs.job.n_min:
                heapq.heappush(heap, (-fill_fraction(rs, -shed[rid]), i))
        return [(rid, k) for rid, k in shed.items() if k > 0]


@register_policy("arrival", "POOL")
class CommonPoolPreference(PreemptAscendingOverhead):
    """Wagomu pref_common_pool: shed above-preference allocations first."""

    preferred_elasticity = "BALANCE"

    def acquire(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        sheds = (self._select_sheds(ops, need,
                                    lambda j: preferred_size(j))
                 or self._select_sheds(ops, need, lambda j: j.n_min))
        if not sheds:
            return self._paa(ops, jid, need)
        for rid, k in sheds:
            ops.shrink(rid, k, jid)
        ops.start_od(jid)
        return True

    def _select_sheds(self, ops: SchedulerOps, need: int,
                      floor) -> Optional[List[Tuple[int, int]]]:
        """Take nodes above `floor(job)` from the jobs furthest above
        their preferred size; None unless `need` is covered exactly."""
        mall = _running_malleables(ops)
        mall.sort(key=lambda it: it[1].cur_size - preferred_size(it[1].job),
                  reverse=True)
        sheds: List[Tuple[int, int]] = []
        left = need
        for rid, rs in mall:
            if left <= 0:
                break
            k = min(left, rs.cur_size - floor(rs.job))
            if k > 0:
                sheds.append((rid, k))
                left -= k
        return sheds if left <= 0 else None


# --------------------------------------------------------------- elasticity
@register_policy("elasticity", "BALANCE")
class AverageBalance(ElasticityPolicy):
    """Expand the emptiest malleables back toward n_max whenever nodes go
    spare and nothing is waiting (Wagomu expand_running_malleable_jobs)."""

    def absorb_release(self, ops: SchedulerOps, k: int) -> int:
        if ops.queue:  # never hoard nodes while jobs wait
            return k
        for rid, grow in self._apportion(ops, k):
            ops.expand_occupied(rid, grow)
            k -= grow
        return k

    def on_idle(self, ops: SchedulerOps) -> None:
        if ops.queue or ops.free <= 0:
            return
        for rid, grow in self._apportion(ops, ops.free):
            ops.expand_from_free(rid, grow)

    def _apportion(self, ops: SchedulerOps,
                   k: int) -> List[Tuple[int, int]]:
        """Hand nodes one at a time to the malleable with the lowest fill
        fraction until supply or expandability runs out.

        Heap keyed on (fill, arrival order): O(log m) per node with the
        same winner (ties to the first malleable) as a full min() scan."""
        mall = [(rid, rs) for rid, rs in _running_malleables(ops)
                if rs.cur_size < rs.job.n_max]
        grow: Dict[int, int] = {rid: 0 for rid, _ in mall}
        heap = [(fill_fraction(rs), i) for i, (_, rs) in enumerate(mall)]
        heapq.heapify(heap)
        while k > 0 and heap:
            _, i = heapq.heappop(heap)
            rid, rs = mall[i]
            grow[rid] += 1
            k -= 1
            if rs.cur_size + grow[rid] < rs.job.n_max:
                heapq.heappush(heap, (fill_fraction(rs, grow[rid]), i))
        return [(rid, g) for rid, g in grow.items() if g > 0]
