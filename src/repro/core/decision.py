"""Vectorized decision kernels (paper §II-C "quick decision making").

The hot decisions — PAA victim selection, SPAA shrink apportionment, the
EASY shadow-window computation, and the backfill candidate prefilter —
are O(running jobs) / O(queue window) numpy operations so a full-system
decision stays well under the paper's 10 ms bound (Obs. 10) even on
month-scale traces; benchmarked in bench_decision.py.

These numpy kernels are the *bit-for-bit references* for the jitted JAX
ports in :mod:`repro.core.decision_jax` (sweeps-on-device; see
docs/performance.md).  The :func:`capture` context manager records every
kernel call's raw inputs and outputs into a :class:`DecisionTrace` so a
whole sweep cell's decision stream can be replayed — and parity-checked
— as one batched device program.  Capture is a single module-global
``None`` check per call when inactive (the hot path pays nothing).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


# ------------------------------------------------------- decision capture
class DecisionTrace:
    """Bounded per-kernel capture of decision calls (inputs + outputs).

    One trace records one simulation's decision stream: for each kernel a
    list of ``(inputs..., output)`` tuples, truncated at ``limit`` calls
    per kernel (a deterministic prefix — the device replay and its parity
    gate cover exactly the captured prefix).  Arrays are copied at record
    time so later caller-side mutation cannot corrupt the trace; traces
    are plain numpy + scalars, hence picklable across process fan-out.
    """

    KERNELS = ("easy_shadow", "select_preemption_victims",
               "apportion_shrink", "backfill_prefilter",
               "backfill_shadow_filter")

    def __init__(self, limit: int = 256):
        self.limit = limit
        self.calls: Dict[str, list] = {k: [] for k in self.KERNELS}
        self.n_dropped: Dict[str, int] = {k: 0 for k in self.KERNELS}

    def record(self, kernel: str, inputs: tuple, output) -> None:
        lst = self.calls[kernel]
        if len(lst) < self.limit:
            lst.append((inputs, output))
        else:
            self.n_dropped[kernel] += 1

    def n_calls(self) -> int:
        return sum(len(v) for v in self.calls.values())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        per = {k: len(v) for k, v in self.calls.items() if v}
        return f"<DecisionTrace {self.n_calls()} calls {per}>"


_ACTIVE_TRACE: Optional[DecisionTrace] = None


@contextmanager
def capture(limit: int = 256) -> Iterator[DecisionTrace]:
    """Record every decision-kernel call made inside the block.

    Nestable (the inner capture wins, the outer resumes after); used by
    ``Experiment(device=...)`` workers to ship each cell's decision
    stream back for batched on-device replay.
    """
    global _ACTIVE_TRACE
    prev, trace = _ACTIVE_TRACE, DecisionTrace(limit)
    _ACTIVE_TRACE = trace
    try:
        yield trace
    finally:
        _ACTIVE_TRACE = prev


def select_preemption_victims(
    sizes: Sequence[int],
    overheads: Sequence[float],
    need: int,
) -> Tuple[List[int], int]:
    """PAA victim selection.

    Sort candidates by ascending preemption overhead (node-seconds wasted)
    and take a prefix until the freed nodes cover `need`.

    Returns (victim indices in preemption order, surplus nodes beyond need).
    If the total supply cannot cover `need`, returns ([], 0) — the paper
    then queues the on-demand job at the front instead of preempting.
    """
    sizes_a = np.asarray(sizes, dtype=np.int64)
    over_a = np.asarray(overheads, dtype=np.float64)
    if sizes_a.sum() < need or need <= 0:
        out: Tuple[List[int], int] = ([], 0)
    else:
        order = np.argsort(over_a, kind="stable")
        csum = np.cumsum(sizes_a[order])
        cut = int(np.searchsorted(csum, need)) + 1
        victims = order[:cut]
        surplus = int(csum[cut - 1]) - need
        out = ([int(i) for i in victims], surplus)
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.record("select_preemption_victims",
                             (sizes_a.copy(), over_a.copy(), int(need)), out)
    return out


def apportion_shrink(
    cur_sizes: Sequence[int],
    min_sizes: Sequence[int],
    need: int,
) -> List[int]:
    """SPAA: shrink running malleables "evenly" to free `need` nodes.

    Each job contributes proportionally to its shrinkable slack
    (cur - min), integerized by largest remainder so that the total equals
    `need` exactly.  Returns per-job nodes to shed; empty list if the slack
    cannot cover `need` (caller falls back to PAA, paper §III-B2).

    The largest-remainder pass is hardened two ways.  First, the
    historical quota expression ``need * slack / supply`` overflows the
    int64 product once ``need * max(slack)`` exceeds 2**63-1, wrapping
    into garbage quotas whose clamped floors leave a shortfall far
    larger than the number of jobs with remaining fractional slack —
    the old single top-`short` pass then promoted ``-inf`` entries past
    their per-job slack cap and tripped the sum assert.  The product is
    now guarded: the exact-product expression is kept bit-for-bit
    whenever it cannot overflow (every realistic node count), else the
    overflow-safe ``need * (slack / supply)`` is used.  Second, the
    rounding pass is iterative in both directions: each round hands one
    node to (or retracts one from) the ``min(|short|, eligible)``
    extreme remainders; supply >= need guarantees an eligible job
    exists while any shortfall remains, so the loops terminate with the
    sum exact.  For the common case (short <= eligible, no overflow)
    round one is bit-identical to the historical single pass.
    """
    cur = np.asarray(cur_sizes, dtype=np.int64)
    mn = np.asarray(min_sizes, dtype=np.int64)
    slack = np.maximum(cur - mn, 0)
    supply = int(slack.sum())
    if supply < need or need <= 0:
        out: List[int] = [] if need > 0 else [0] * len(cur)
        if _ACTIVE_TRACE is not None:
            _ACTIVE_TRACE.record("apportion_shrink",
                                 (cur.copy(), mn.copy(), int(need)), out)
        return out
    max_slack = int(slack.max())
    if max_slack > 0 and need > (2**63 - 1) // max_slack:
        quota = need * (slack / supply)
    else:
        quota = need * slack / supply
    base = np.clip(np.floor(quota).astype(np.int64), 0, slack)
    short = need - int(base.sum())
    while short > 0:
        eligible = slack > base
        frac = np.where(eligible, quota - base, -np.inf)
        # largest remainders get the leftover node each
        take = min(short, int(eligible.sum()))
        top = np.argsort(-frac, kind="stable")[:take]
        base[top] += 1
        short -= take
    while short < 0:
        # floats >= 2**53: floored quotas can overshoot need; retract
        # from the most over-granted jobs
        granted = base > 0
        frac = np.where(granted, quota - base, np.inf)
        take = min(-short, int(granted.sum()))
        bottom = np.argsort(frac, kind="stable")[:take]
        base[bottom] -= 1
        short += take
    assert int(base.sum()) == need and np.all(base <= slack)
    out = [int(x) for x in base]
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.record("apportion_shrink",
                             (cur.copy(), mn.copy(), int(need)), out)
    return out


def easy_shadow(
    avail: int,
    need: int,
    est_end_bases: Sequence[float],
    sizes: Sequence[int],
    now: float,
) -> Tuple[float, int]:
    """EASY shadow window: when can the blocked queue head start?

    ``est_end_bases`` / ``sizes`` are the incrementally maintained
    per-running-job estimated-end bases (clamped to ``now`` here, exactly
    like ``Simulator._est_end``) and current sizes.  Accumulates releases
    in ascending (est_end, size) order — the order the legacy Python
    ``sorted()`` loop used — until ``avail`` covers ``need``.

    Returns ``(t_shadow, extra)``: the head's reservation start and the
    spare nodes at that moment.  ``(now, avail - need)`` when the
    already-free supply covers ``need`` with no release at all — in
    particular when the running set is empty, where the cumsum is empty
    and a bare ``searchsorted`` would walk off the end and misreport an
    immediately-startable head as ``(inf, 0)``.  ``(inf, 0)`` when the
    running set cannot ever cover the head (its kill-time estimates are
    finite, so this only happens for a head larger than the machine's
    usable pool).
    """
    if avail >= need:
        out = (float(now), int(avail) - int(need))
        if _ACTIVE_TRACE is not None:
            _ACTIVE_TRACE.record(
                "easy_shadow",
                (int(avail), int(need),
                 np.asarray(est_end_bases, dtype=np.float64).copy(),
                 np.asarray(sizes, dtype=np.int64).copy(), float(now)), out)
        return out
    ends = np.maximum(np.asarray(est_end_bases, dtype=np.float64), now)
    szs = np.asarray(sizes, dtype=np.int64)
    order = np.lexsort((szs, ends))
    csum = avail + np.cumsum(szs[order])
    i = int(np.searchsorted(csum, need))
    if i >= len(csum):
        out = (math.inf, 0)
    else:
        out = (float(ends[order[i]]), int(csum[i]) - need)
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.record(
            "easy_shadow",
            (int(avail), int(need),
             np.asarray(est_end_bases, dtype=np.float64).copy(),
             np.asarray(sizes, dtype=np.int64).copy(), float(now)), out)
    return out


def backfill_prefilter(
    need_mins: Sequence[float],
    supply_bound: float,
) -> np.ndarray:
    """Stage-1 backfill prefilter: supply-feasible candidate indices.

    ``need_mins`` is the queue window's cached minimum start sizes
    (``inf`` for on-demand jobs, which never backfill); ``supply_bound``
    is an upper bound on any candidate's visible supply (free pool +
    every idle noticed reservation).  Supply only shrinks while the
    backfill loop starts jobs, so every index dropped here is one the
    legacy per-candidate scan would have ``continue``-d over.  An empty
    result lets the caller skip the shadow-window computation entirely.

    Candidates holding returned-lease nodes see more supply than the
    bound; the caller re-adds those few by hand (the hold book is
    per-job and tiny).
    """
    needs = np.asarray(need_mins, dtype=np.float64)
    out = np.flatnonzero(needs <= supply_bound)
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.record("backfill_prefilter",
                             (needs.copy(), float(supply_bound)), out.copy())
    return out


def backfill_shadow_filter(
    need_mins: np.ndarray,
    est_remainings: np.ndarray,
    candidates: np.ndarray,
    spare_budget: int,
    now: float,
    t_shadow: float,
) -> np.ndarray:
    """Stage-2 backfill prefilter against the EASY shadow window.

    Applies only when there are no reservations to borrow from and only
    to candidates without returned-lease holds: such a candidate starts
    entirely from the free pool, so it must either fit the shadow hole
    at its fastest (full-size) estimate — ``est_remaining`` exactly, for
    rigid and malleable alike — or fit its minimum size inside the
    head's spare budget (``extra``); both bounds only tighten as the
    loop starts jobs, so dropped candidates are exactly legacy
    ``continue``-s.  Survivors then run the exact legacy checks.
    """
    needs = need_mins[candidates]
    ests = est_remainings[candidates]
    out = candidates[(needs <= spare_budget) | (now + ests <= t_shadow)]
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.record(
            "backfill_shadow_filter",
            (np.asarray(needs, dtype=np.float64).copy(),
             np.asarray(ests, dtype=np.float64).copy(),
             np.asarray(candidates).copy(), int(spare_budget), float(now),
             float(t_shadow)), np.asarray(out).copy())
    return out


def expected_releases_before(
    est_ends: Sequence[float],
    sizes: Sequence[int],
    horizon: float,
) -> int:
    """CUP planning: nodes expected to free up before `horizon`."""
    ends = np.asarray(est_ends, dtype=np.float64)
    szs = np.asarray(sizes, dtype=np.int64)
    return int(szs[ends <= horizon].sum())
