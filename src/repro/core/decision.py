"""Vectorized decision kernels (paper §II-C "quick decision making").

The two hot decisions — PAA victim selection and SPAA shrink apportionment —
are O(running jobs) numpy operations so a full-system decision stays well
under the paper's 10 ms bound (Obs. 10); benchmarked in bench_decision.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def select_preemption_victims(
    sizes: Sequence[int],
    overheads: Sequence[float],
    need: int,
) -> Tuple[List[int], int]:
    """PAA victim selection.

    Sort candidates by ascending preemption overhead (node-seconds wasted)
    and take a prefix until the freed nodes cover `need`.

    Returns (victim indices in preemption order, surplus nodes beyond need).
    If the total supply cannot cover `need`, returns ([], 0) — the paper
    then queues the on-demand job at the front instead of preempting.
    """
    sizes_a = np.asarray(sizes, dtype=np.int64)
    over_a = np.asarray(overheads, dtype=np.float64)
    if sizes_a.sum() < need:
        return [], 0
    if need <= 0:
        return [], 0
    order = np.argsort(over_a, kind="stable")
    csum = np.cumsum(sizes_a[order])
    cut = int(np.searchsorted(csum, need)) + 1
    victims = order[:cut]
    surplus = int(csum[cut - 1]) - need
    return [int(i) for i in victims], surplus


def apportion_shrink(
    cur_sizes: Sequence[int],
    min_sizes: Sequence[int],
    need: int,
) -> List[int]:
    """SPAA: shrink running malleables "evenly" to free `need` nodes.

    Each job contributes proportionally to its shrinkable slack
    (cur - min), integerized by largest remainder so that the total equals
    `need` exactly.  Returns per-job nodes to shed; empty list if the slack
    cannot cover `need` (caller falls back to PAA, paper §III-B2).
    """
    cur = np.asarray(cur_sizes, dtype=np.int64)
    mn = np.asarray(min_sizes, dtype=np.int64)
    slack = np.maximum(cur - mn, 0)
    supply = int(slack.sum())
    if supply < need or need <= 0:
        return [] if need > 0 else [0] * len(cur)
    quota = need * slack / supply
    base = np.floor(quota).astype(np.int64)
    base = np.minimum(base, slack)
    short = need - int(base.sum())
    if short > 0:
        frac = np.where(slack - base > 0, quota - base, -np.inf)
        # largest remainders get the leftover node each
        top = np.argsort(-frac, kind="stable")[:short]
        base[top] += 1
    assert int(base.sum()) == need and np.all(base <= slack)
    return [int(x) for x in base]


def expected_releases_before(
    est_ends: Sequence[float],
    sizes: Sequence[int],
    horizon: float,
) -> int:
    """CUP planning: nodes expected to free up before `horizon`."""
    ends = np.asarray(est_ends, dtype=np.float64)
    szs = np.asarray(sizes, dtype=np.int64)
    return int(szs[ends <= horizon].sum())
