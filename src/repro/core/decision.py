"""Vectorized decision kernels (paper §II-C "quick decision making").

The hot decisions — PAA victim selection, SPAA shrink apportionment, the
EASY shadow-window computation, and the backfill candidate prefilter —
are O(running jobs) / O(queue window) numpy operations so a full-system
decision stays well under the paper's 10 ms bound (Obs. 10) even on
month-scale traces; benchmarked in bench_decision.py.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np


def select_preemption_victims(
    sizes: Sequence[int],
    overheads: Sequence[float],
    need: int,
) -> Tuple[List[int], int]:
    """PAA victim selection.

    Sort candidates by ascending preemption overhead (node-seconds wasted)
    and take a prefix until the freed nodes cover `need`.

    Returns (victim indices in preemption order, surplus nodes beyond need).
    If the total supply cannot cover `need`, returns ([], 0) — the paper
    then queues the on-demand job at the front instead of preempting.
    """
    sizes_a = np.asarray(sizes, dtype=np.int64)
    over_a = np.asarray(overheads, dtype=np.float64)
    if sizes_a.sum() < need:
        return [], 0
    if need <= 0:
        return [], 0
    order = np.argsort(over_a, kind="stable")
    csum = np.cumsum(sizes_a[order])
    cut = int(np.searchsorted(csum, need)) + 1
    victims = order[:cut]
    surplus = int(csum[cut - 1]) - need
    return [int(i) for i in victims], surplus


def apportion_shrink(
    cur_sizes: Sequence[int],
    min_sizes: Sequence[int],
    need: int,
) -> List[int]:
    """SPAA: shrink running malleables "evenly" to free `need` nodes.

    Each job contributes proportionally to its shrinkable slack
    (cur - min), integerized by largest remainder so that the total equals
    `need` exactly.  Returns per-job nodes to shed; empty list if the slack
    cannot cover `need` (caller falls back to PAA, paper §III-B2).
    """
    cur = np.asarray(cur_sizes, dtype=np.int64)
    mn = np.asarray(min_sizes, dtype=np.int64)
    slack = np.maximum(cur - mn, 0)
    supply = int(slack.sum())
    if supply < need or need <= 0:
        return [] if need > 0 else [0] * len(cur)
    quota = need * slack / supply
    base = np.floor(quota).astype(np.int64)
    base = np.minimum(base, slack)
    short = need - int(base.sum())
    if short > 0:
        frac = np.where(slack - base > 0, quota - base, -np.inf)
        # largest remainders get the leftover node each
        top = np.argsort(-frac, kind="stable")[:short]
        base[top] += 1
    assert int(base.sum()) == need and np.all(base <= slack)
    return [int(x) for x in base]


def easy_shadow(
    avail: int,
    need: int,
    est_end_bases: Sequence[float],
    sizes: Sequence[int],
    now: float,
) -> Tuple[float, int]:
    """EASY shadow window: when can the blocked queue head start?

    ``est_end_bases`` / ``sizes`` are the incrementally maintained
    per-running-job estimated-end bases (clamped to ``now`` here, exactly
    like ``Simulator._est_end``) and current sizes.  Accumulates releases
    in ascending (est_end, size) order — the order the legacy Python
    ``sorted()`` loop used — until ``avail`` covers ``need``.

    Returns ``(t_shadow, extra)``: the head's reservation start and the
    spare nodes at that moment.  ``(inf, 0)`` when the running set cannot
    ever cover the head (its kill-time estimates are finite, so this only
    happens for a head larger than the machine's usable pool).
    """
    ends = np.maximum(np.asarray(est_end_bases, dtype=np.float64), now)
    szs = np.asarray(sizes, dtype=np.int64)
    order = np.lexsort((szs, ends))
    csum = avail + np.cumsum(szs[order])
    i = int(np.searchsorted(csum, need))
    if i >= len(csum):
        return math.inf, 0
    return float(ends[order[i]]), int(csum[i]) - need


def backfill_prefilter(
    need_mins: Sequence[float],
    supply_bound: float,
) -> np.ndarray:
    """Stage-1 backfill prefilter: supply-feasible candidate indices.

    ``need_mins`` is the queue window's cached minimum start sizes
    (``inf`` for on-demand jobs, which never backfill); ``supply_bound``
    is an upper bound on any candidate's visible supply (free pool +
    every idle noticed reservation).  Supply only shrinks while the
    backfill loop starts jobs, so every index dropped here is one the
    legacy per-candidate scan would have ``continue``-d over.  An empty
    result lets the caller skip the shadow-window computation entirely.

    Candidates holding returned-lease nodes see more supply than the
    bound; the caller re-adds those few by hand (the hold book is
    per-job and tiny).
    """
    needs = np.asarray(need_mins, dtype=np.float64)
    return np.flatnonzero(needs <= supply_bound)


def backfill_shadow_filter(
    need_mins: np.ndarray,
    est_remainings: np.ndarray,
    candidates: np.ndarray,
    spare_budget: int,
    now: float,
    t_shadow: float,
) -> np.ndarray:
    """Stage-2 backfill prefilter against the EASY shadow window.

    Applies only when there are no reservations to borrow from and only
    to candidates without returned-lease holds: such a candidate starts
    entirely from the free pool, so it must either fit the shadow hole
    at its fastest (full-size) estimate — ``est_remaining`` exactly, for
    rigid and malleable alike — or fit its minimum size inside the
    head's spare budget (``extra``); both bounds only tighten as the
    loop starts jobs, so dropped candidates are exactly legacy
    ``continue``-s.  Survivors then run the exact legacy checks.
    """
    needs = need_mins[candidates]
    ests = est_remainings[candidates]
    return candidates[(needs <= spare_budget) | (now + ests <= t_shadow)]


def expected_releases_before(
    est_ends: Sequence[float],
    sizes: Sequence[int],
    horizon: float,
) -> int:
    """CUP planning: nodes expected to free up before `horizon`."""
    ends = np.asarray(est_ends, dtype=np.float64)
    szs = np.asarray(sizes, dtype=np.int64)
    return int(szs[ends <= horizon].sum())
