"""Theta-like workload synthesis (paper §IV-A, §IV-B), decomposed.

The real one-year Theta trace is not redistributable, so we synthesize
traces that match its published characterization: 4392 nodes, job sizes
dominated by the 128-1024 range (Fig. 3), lognormal runtimes, overestimated
walltimes, project-grouped submissions, and *bursty* on-demand arrivals
(projects submit several on-demand jobs within a short window, Fig. 5).

Job types are assigned per-project (paper default: 10% of projects submit
on-demand jobs, 60% rigid, 30% malleable); on-demand jobs larger than half
the system are reassigned to rigid/malleable (paper §IV-A).

W1-W5 advance-notice mixes (paper Table III) control the split of
on-demand jobs across {no notice, accurate, early, late}.

The monolithic ``generate`` of PR 0/1 is now :class:`ThetaGenerator`, a
registered :class:`~repro.core.workloads.base.WorkloadSource` ("theta")
assembled from five pluggable models — ProjectModel (Zipf activity +
per-project types), SizeModel (Fig. 3 buckets), RuntimeModel (lognormal +
estimate inflation), ArrivalModel (load-scaled uniform + od bursts), and
NoticeModel (Table III kinds and lead geometry).  Swapping a model is a
constructor argument; the default models reproduce the pre-split
``generate`` **bit-for-bit** (same RNG, same draw order — golden-tested),
and ``generate(cfg)`` remains the one-call legacy entry point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..job import JobSpec, JobType, NoticeKind
from .base import UnknownWorkloadError, WorkloadSource, canonicalize, \
    register_source

# paper Table III
NOTICE_MIXES: Dict[str, List[float]] = {
    "W1": [0.70, 0.10, 0.10, 0.10],
    "W2": [0.10, 0.70, 0.10, 0.10],
    "W3": [0.10, 0.10, 0.70, 0.10],
    "W4": [0.10, 0.10, 0.10, 0.70],
    "W5": [0.25, 0.25, 0.25, 0.25],
}
NOTICE_KINDS = [NoticeKind.NONE, NoticeKind.ACCURATE,
                NoticeKind.EARLY, NoticeKind.LATE]

# Theta/ALCF-flavored size mix (paper Fig. 3): most jobs 128-1024 nodes.
SIZE_BUCKETS = [(128, 256), (257, 512), (513, 1024), (1025, 2048), (2049, 4096)]
SIZE_WEIGHTS = [0.46, 0.26, 0.16, 0.08, 0.04]


def notice_mix(name: str) -> List[float]:
    """Look up a Table III notice mix; unknown names raise
    :class:`UnknownWorkloadError` listing the valid mixes."""
    try:
        return NOTICE_MIXES[name]
    except KeyError:
        raise UnknownWorkloadError(
            f"unknown notice mix {name!r}; valid mixes: "
            f"{', '.join(sorted(NOTICE_MIXES))}") from None


@dataclass
class WorkloadConfig:
    n_nodes: int = 4392
    n_jobs: int = 1500
    horizon_days: float = 14.0
    target_load: float = 1.05          # offered load vs capacity
    n_projects: int = 60
    frac_od_projects: float = 0.10     # paper §IV-B
    frac_rigid_projects: float = 0.60
    notice_mix: str = "W5"
    # on-demand burstiness (paper Fig. 5)
    od_burst_size: tuple = (2, 8)
    od_burst_window: float = 1800.0
    # runtime model
    runtime_median_s: float = 7200.0
    runtime_sigma: float = 1.1
    runtime_max_s: float = 86400.0
    runtime_min_s: float = 600.0
    estimate_factor: tuple = (1.0, 3.0)
    # overheads (paper §IV-B)
    rigid_setup_frac: tuple = (0.05, 0.10)
    malleable_setup_frac: tuple = (0.0, 0.05)
    malleable_min_frac: float = 0.20
    ckpt_overhead_small: float = 600.0   # < 1K nodes
    ckpt_overhead_large: float = 1200.0  # >= 1K nodes
    ckpt_freq_factor: float = 1.0        # 0.5 = twice as frequent as Daly
    node_mtbf_hours: float = 20000.0     # per-node MTBF for the Daly interval
    notice_lead: tuple = (900.0, 1800.0)  # 15-30 min
    late_window: float = 1800.0
    seed: int = 0


def daly_interval(delta: float, mtbf_job: float) -> float:
    """Daly's first-order optimal checkpoint interval."""
    if not math.isfinite(mtbf_job):
        return math.inf
    return max(math.sqrt(2.0 * delta * mtbf_job) - delta, delta)


def rigid_ckpt_params(size: int, overhead_small: float = 600.0,
                      overhead_large: float = 1200.0,
                      node_mtbf_hours: float = 20000.0,
                      freq_factor: float = 1.0) -> tuple:
    """``(delta, tau)`` of the rigid Daly checkpoint model (§IV-B).

    The single copy of the parameterization — the generator, the SWF
    annotator, and the type_mix transform all derive through it."""
    delta = overhead_small if size < 1000 else overhead_large
    mtbf_job = node_mtbf_hours * 3600.0 / size
    return delta, daly_interval(delta, mtbf_job) * freq_factor


# -------------------------------------------------------------------- models
def assign_project_types(rng: np.random.Generator, n_projects: int,
                         frac_od: float, frac_rigid: float) -> np.ndarray:
    """Shuffled per-project job types at the paper's §IV-A fractions.

    The single copy of the assignment rule — the generator, the SWF
    annotator, and the type_mix transform all draw through it."""
    proj_type = np.array(
        [JobType.ONDEMAND] * round(n_projects * frac_od)
        + [JobType.RIGID] * round(n_projects * frac_rigid),
        dtype=object)
    proj_type = np.concatenate(
        [proj_type,
         np.array([JobType.MALLEABLE] * (n_projects - len(proj_type)),
                  dtype=object)])
    rng.shuffle(proj_type)
    return proj_type


class ProjectModel:
    """Zipf-ish project activity and per-project job-type assignment."""

    def weights(self, cfg: WorkloadConfig) -> np.ndarray:
        w = 1.0 / np.arange(1, cfg.n_projects + 1) ** 0.8
        return w / w.sum()

    def types(self, rng: np.random.Generator,
              cfg: WorkloadConfig) -> np.ndarray:
        return assign_project_types(rng, cfg.n_projects,
                                    cfg.frac_od_projects,
                                    cfg.frac_rigid_projects)


class SizeModel:
    """Fig. 3 size buckets with log-uniform spread inside each bucket."""

    buckets: Sequence = SIZE_BUCKETS
    bucket_weights: Sequence = SIZE_WEIGHTS

    def sample(self, rng: np.random.Generator, cfg: WorkloadConfig,
               n: int) -> np.ndarray:
        picks = rng.choice(len(self.buckets), size=n, p=self.bucket_weights)
        lo = np.array([self.buckets[b][0] for b in picks])
        hi = np.array([self.buckets[b][1] for b in picks])
        sizes = np.exp(rng.uniform(np.log(lo), np.log(hi))).astype(int)
        return np.clip(sizes, 1, cfg.n_nodes)


class RuntimeModel:
    """Lognormal runtimes plus the user walltime-estimate inflation."""

    def sample(self, rng: np.random.Generator, cfg: WorkloadConfig,
               n: int) -> np.ndarray:
        runtimes = np.exp(rng.normal(np.log(cfg.runtime_median_s),
                                     cfg.runtime_sigma, n))
        return np.clip(runtimes, cfg.runtime_min_s, cfg.runtime_max_s)

    def estimate(self, rng: np.random.Generator, cfg: WorkloadConfig,
                 t_actual: float) -> float:
        t_est = float(t_actual * rng.uniform(*cfg.estimate_factor))
        return min(t_est, cfg.runtime_max_s * 3)


class _SubmitView:
    """Adapter exposing ``jobs[i].submit_time`` through the indexable
    get/set interface :meth:`ArrivalModel.burstify_times` rewrites, so
    the one burst algorithm serves both the materialized JobSpec list
    and the streaming path's numpy submit column."""

    __slots__ = ("jobs",)

    def __init__(self, jobs: List[JobSpec]):
        self.jobs = jobs

    def __getitem__(self, i: int) -> float:
        return self.jobs[i].submit_time

    def __setitem__(self, i: int, t: float) -> None:
        self.jobs[i].submit_time = t


class ArrivalModel:
    """Load-scaled uniform arrivals + bursty on-demand windows (Fig. 5)."""

    def sample(self, rng: np.random.Generator, cfg: WorkloadConfig,
               sizes: np.ndarray, runtimes: np.ndarray) -> np.ndarray:
        # scale arrivals so offered load ~= target_load of capacity
        total_work = float((sizes * runtimes).sum())
        span = total_work / (cfg.n_nodes * cfg.target_load)
        span = min(span, cfg.horizon_days * 86400.0)
        return np.sort(rng.uniform(0.0, span, len(sizes)))

    def burstify(self, rng: np.random.Generator, cfg: WorkloadConfig,
                 jobs: List[JobSpec],
                 od_members: Dict[int, List[int]]) -> None:
        """Cluster each project's on-demand jobs into short windows."""
        self.burstify_times(rng, cfg, _SubmitView(jobs), od_members)

    def burstify_times(self, rng: np.random.Generator, cfg: WorkloadConfig,
                       times, od_members: Dict[int, List[int]]) -> None:
        """The burst algorithm over an indexable submit-time container
        (``times[i]`` get/set) — the single copy both the materialized
        and the streaming (columnar) generator paths draw through."""
        for _p, idxs in od_members.items():
            k = 0
            while k < len(idxs):
                burst = int(rng.integers(*cfg.od_burst_size))
                anchor = times[idxs[k]]
                for j in idxs[k:k + burst]:
                    times[j] = float(
                        anchor + rng.uniform(0.0, cfg.od_burst_window))
                k += burst


class NoticeModel:
    """Table III notice kinds and lead/early/late time geometry.

    Source-agnostic: the SWF annotator and the notice-mix scenario
    transform reuse it on any list of on-demand jobs.  The draws are
    split from the arithmetic (``draw`` / ``apply_one``) because the
    draw *count* depends only on the kind, never on the job — which is
    what lets the streaming paths pre-draw the whole notice share of an
    RNG stream and attach it to jobs as they flow past later.
    ``assign`` is defined in terms of both, so subclasses override
    ``draw``/``apply_one`` (not ``assign``) to stay stream-consistent.
    """

    def draw(self, rng: np.random.Generator, n_od: int,
             mix: Sequence[float], lead: tuple = (900.0, 1800.0),
             late_window: float = 1800.0) -> List[tuple]:
        """All RNG for ``n_od`` on-demand jobs, in assign order:
        one ``(kind, lead_s, extra)`` tuple per job."""
        kinds = rng.choice(4, size=n_od, p=list(mix))
        out = []
        for kidx in kinds:
            kind = NOTICE_KINDS[int(kidx)]
            if kind is NoticeKind.NONE:
                out.append((kind, 0.0, 0.0))
                continue
            lead_s = float(rng.uniform(*lead))
            if kind is NoticeKind.ACCURATE:
                extra = 0.0
            elif kind is NoticeKind.EARLY:
                extra = float(rng.uniform(0.0, lead_s))
            else:  # LATE
                extra = float(rng.uniform(0.0, late_window))
            out.append((kind, lead_s, extra))
        return out

    @staticmethod
    def apply_one(j: JobSpec, drawn: tuple) -> None:
        """Set one job's notice fields from its pre-drawn tuple (pure
        arithmetic on the job's current submit time — no RNG)."""
        kind, lead_s, extra = drawn
        j.notice_kind = kind
        if kind is NoticeKind.NONE:
            j.notice_time = None
            j.est_arrival = None
            return
        arrival = j.submit_time
        if kind is NoticeKind.ACCURATE:
            j.notice_time = arrival - lead_s
            j.est_arrival = arrival
        elif kind is NoticeKind.EARLY:
            # actual arrival uniform in (notice, est_arrival)
            j.notice_time = arrival - extra
            j.est_arrival = j.notice_time + lead_s
        else:  # LATE: arrival within `late_window` after estimate
            j.est_arrival = arrival - extra
            j.notice_time = j.est_arrival - lead_s
        j.notice_time = max(j.notice_time, 0.0)

    def assign(self, rng: np.random.Generator, od_jobs: List[JobSpec],
               mix: Sequence[float], lead: tuple = (900.0, 1800.0),
               late_window: float = 1800.0) -> None:
        for j, drawn in zip(od_jobs, self.draw(rng, len(od_jobs), mix,
                                               lead, late_window)):
            self.apply_one(j, drawn)


# ----------------------------------------------------------------- generator
@register_source("theta")
class ThetaGenerator(WorkloadSource):
    """The synthetic Theta-like source, assembled from pluggable models.

    Registry params are WorkloadConfig fields (``get_source("theta",
    n_jobs=600, notice_mix="W2", seed=1)``); model instances are
    constructor-only (they are code, not data).  The default models
    replay the legacy ``generate`` draw-for-draw.
    """

    def __init__(self, cfg: Optional[WorkloadConfig] = None, *,
                 project_model: Optional[ProjectModel] = None,
                 size_model: Optional[SizeModel] = None,
                 runtime_model: Optional[RuntimeModel] = None,
                 arrival_model: Optional[ArrivalModel] = None,
                 notice_model: Optional[NoticeModel] = None,
                 **cfg_kw):
        if cfg is None:
            cfg = WorkloadConfig(**cfg_kw)
        elif cfg_kw:
            cfg = replace(cfg, **cfg_kw)
        self.cfg = cfg
        self.project_model = project_model or ProjectModel()
        self.size_model = size_model or SizeModel()
        self.runtime_model = runtime_model or RuntimeModel()
        self.arrival_model = arrival_model or ArrivalModel()
        self.notice_model = notice_model or NoticeModel()

    @property
    def n_nodes(self) -> int:
        return self.cfg.n_nodes

    def jobs(self) -> List[JobSpec]:
        cfg = self.cfg
        mix = notice_mix(cfg.notice_mix)  # fail fast, before any sampling
        rng = np.random.default_rng(cfg.seed)

        # ---- project pool with Zipf-ish activity --------------------------
        proj_w = self.project_model.weights(cfg)
        proj_type = self.project_model.types(rng, cfg)

        # ---- raw jobs ------------------------------------------------------
        projects = rng.choice(cfg.n_projects, size=cfg.n_jobs, p=proj_w)
        sizes = self.size_model.sample(rng, cfg, cfg.n_jobs)
        runtimes = self.runtime_model.sample(rng, cfg, cfg.n_jobs)
        arrivals = self.arrival_model.sample(rng, cfg, sizes, runtimes)

        jobs: List[JobSpec] = []
        od_members: Dict[int, List[int]] = {}
        for i in range(cfg.n_jobs):
            p = int(projects[i])
            jt: JobType = proj_type[p]
            size, t_act = int(sizes[i]), float(runtimes[i])
            if jt is JobType.ONDEMAND and size > cfg.n_nodes // 2:
                jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
            t_est = self.runtime_model.estimate(rng, cfg, t_act)
            if jt is JobType.RIGID:
                setup = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
                delta, tau = rigid_ckpt_params(
                    size, cfg.ckpt_overhead_small, cfg.ckpt_overhead_large,
                    cfg.node_mtbf_hours, cfg.ckpt_freq_factor)
                jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                    t_est, t_act, t_setup=setup,
                                    ckpt_overhead=delta, ckpt_interval=tau))
            elif jt is JobType.MALLEABLE:
                setup = float(t_act * rng.uniform(*cfg.malleable_setup_frac))
                jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                    t_est, t_act, t_setup=setup,
                                    n_min=max(1, math.ceil(
                                        cfg.malleable_min_frac * size))))
            else:
                setup = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
                jobs.append(JobSpec(i, jt, f"proj{p}", float(arrivals[i]), size,
                                    t_est, t_act, t_setup=setup))
                od_members.setdefault(p, []).append(len(jobs) - 1)

        # ---- bursty on-demand arrivals + notice kinds (Table III) ----------
        self.arrival_model.burstify(rng, cfg, jobs, od_members)
        od_jobs = [j for j in jobs if j.jtype is JobType.ONDEMAND]
        self.notice_model.assign(rng, od_jobs, mix, lead=cfg.notice_lead,
                                 late_window=cfg.late_window)

        return canonicalize(jobs)

    # ------------------------------------------------------------- streaming
    # _columns() MUST stay draw-for-draw in sync with jobs() above — it is
    # the same algorithm with numeric columns in place of JobSpec objects
    # (tests/test_streaming.py pins the two paths sha256-identical).
    def _columns(self) -> dict:
        """Sample the whole trace into compact per-job columns (~100 B/job
        instead of a JobSpec object), deferring JobSpec construction to
        :meth:`iter_jobs` — the bounded-memory half of the generator.
        Memoized: trace_stats() and iter_jobs() share one sampling."""
        cached = getattr(self, "_columns_cache", None)
        if cached is not None:
            return cached
        cfg = self.cfg
        mix = notice_mix(cfg.notice_mix)  # fail fast, before any sampling
        rng = np.random.default_rng(cfg.seed)

        proj_w = self.project_model.weights(cfg)
        proj_type = self.project_model.types(rng, cfg)
        projects = rng.choice(cfg.n_projects, size=cfg.n_jobs, p=proj_w)
        sizes = self.size_model.sample(rng, cfg, cfg.n_jobs)
        runtimes = self.runtime_model.sample(rng, cfg, cfg.n_jobs)
        arrivals = self.arrival_model.sample(rng, cfg, sizes, runtimes)

        n = cfg.n_jobs
        jtype = np.empty(n, dtype=object)       # JobType per job
        submit = np.empty(n, dtype=np.float64)
        t_est = np.empty(n, dtype=np.float64)
        setup = np.empty(n, dtype=np.float64)
        od_members: Dict[int, List[int]] = {}
        od_order: List[int] = []
        for i in range(n):
            p = int(projects[i])
            jt: JobType = proj_type[p]
            size, t_act = int(sizes[i]), float(runtimes[i])
            if jt is JobType.ONDEMAND and size > cfg.n_nodes // 2:
                jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
            t_est[i] = self.runtime_model.estimate(rng, cfg, t_act)
            if jt is JobType.RIGID:
                setup[i] = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
            elif jt is JobType.MALLEABLE:
                setup[i] = float(t_act * rng.uniform(*cfg.malleable_setup_frac))
            else:
                setup[i] = float(t_act * rng.uniform(*cfg.rigid_setup_frac))
                od_members.setdefault(p, []).append(i)
                od_order.append(i)
            jtype[i] = jt
            submit[i] = float(arrivals[i])

        self.arrival_model.burstify_times(rng, cfg, submit, od_members)
        # od_order is jid order == the order jobs() collects od_jobs in
        notice = dict(zip(od_order,
                          self.notice_model.draw(rng, len(od_order), mix,
                                                 lead=cfg.notice_lead,
                                                 late_window=cfg.late_window)))
        order = np.argsort(submit, kind="stable")  # == canonicalize's sort
        self._columns_cache = {
            "jtype": jtype, "submit": submit, "t_est": t_est,
            "setup": setup, "sizes": sizes, "runtimes": runtimes,
            "projects": projects, "notice": notice, "order": order}
        return self._columns_cache

    def iter_jobs(self):
        """Yield the canonical trace lazily — job-for-job identical to
        ``jobs()`` (same RNG stream, same stable submit sort), but only
        one JobSpec is alive per step beyond the numeric columns."""
        cfg = self.cfg
        c = self._columns()
        jtype, submit, t_est, setup = (c["jtype"], c["submit"], c["t_est"],
                                       c["setup"])
        for new_id, i in enumerate(c["order"]):
            i = int(i)
            jt: JobType = jtype[i]
            size = int(c["sizes"][i])
            kw = {}
            if jt is JobType.RIGID:
                kw["ckpt_overhead"], kw["ckpt_interval"] = rigid_ckpt_params(
                    size, cfg.ckpt_overhead_small, cfg.ckpt_overhead_large,
                    cfg.node_mtbf_hours, cfg.ckpt_freq_factor)
            elif jt is JobType.MALLEABLE:
                kw["n_min"] = max(1, math.ceil(cfg.malleable_min_frac * size))
            j = JobSpec(new_id, jt, f"proj{int(c['projects'][i])}",
                        float(submit[i]), size, float(t_est[i]),
                        float(c["runtimes"][i]), t_setup=float(setup[i]),
                        **kw)
            if jt is JobType.ONDEMAND:
                self.notice_model.apply_one(j, c["notice"][i])
            yield j

    def trace_stats(self):
        from .base import TraceStats
        c = self._columns()
        if not len(c["order"]):
            return TraceStats(0, 0, 0.0, 0.0)
        return TraceStats(
            len(c["order"]),
            sum(jt is JobType.ONDEMAND for jt in c["jtype"]),
            float(c["submit"][c["order"][0]]),
            float(c["submit"][c["order"][-1]]))


def generate(cfg: WorkloadConfig) -> List[JobSpec]:
    """Legacy one-call entry point: the default-model "theta" source."""
    return ThetaGenerator(cfg).jobs()
