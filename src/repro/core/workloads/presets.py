"""Named scenario presets: registry-keyed Scenario factories.

A preset name is accepted anywhere Experiment accepts a workload
(``Experiment(workloads=("W1", "bursty-od", ...))``); ``get_scenario``
builds the Scenario, with keyword overrides merged into the source
params::

    get_scenario("W2", n_jobs=600, target_load=1.15)
    get_scenario("trace-replay", trace="tests/data/sample.swf")

Shipped presets:

    W1..W5        paper Table III notice mixes on the synthetic Theta
                  source (the Figure 6 evaluation grid)
    bursty-od     on-demand stress: 2.5x od projects plus injected
                  no-notice od bursts (§III-B arrival-path stress)
    diurnal       day/night arrival modulation on the Theta source
    trace-replay  SWF trace replay (requires ``trace=`` or ``path=``)

Custom presets register a factory taking keyword overrides and returning
a Scenario::

    @register_scenario("my-stress")
    def _my_stress(**over):
        return Scenario("theta", params={"target_load": 1.4, **over},
                        name="my-stress")
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from .base import Scenario, UnknownWorkloadError
from .synthetic import NOTICE_MIXES

_PRESETS: Dict[str, Callable[..., Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register a ``(**overrides) -> Scenario`` factory."""
    def deco(factory: Callable[..., Scenario]):
        _PRESETS[name] = factory
        return factory
    return deco


def get_scenario(name: str, **overrides) -> Scenario:
    """Build a preset Scenario by name, merging keyword overrides."""
    try:
        factory = _PRESETS[name]
    except KeyError:
        raise UnknownWorkloadError(
            f"unknown scenario {name!r}; registered: "
            f"{', '.join(sorted(_PRESETS))}") from None
    return factory(**overrides)


def registered_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_PRESETS))


# ------------------------------------------------------------ paper W1-W5
def _paper_mix(mix: str) -> Callable[..., Scenario]:
    def factory(**over) -> Scenario:
        return Scenario("theta", params={"notice_mix": mix, **over}, name=mix)
    return factory


for _mix in NOTICE_MIXES:
    register_scenario(_mix)(_paper_mix(_mix))


# ------------------------------------------------------------- stress/replay
@register_scenario("bursty-od")
def _bursty_od(**over) -> Scenario:
    """On-demand arrival-path stress: more od projects, injected bursts."""
    params = {"frac_od_projects": 0.25, "notice_mix": "W1"}
    params.update(over)
    return Scenario(
        "theta", params=params,
        transforms=(("burst_inject",
                     {"n_bursts": 4, "burst_size": (4, 8),
                      "size": (64, 256), "mix": "W1"}),),
        name="bursty-od")


@register_scenario("diurnal")
def _diurnal(**over) -> Scenario:
    amplitude = over.pop("amplitude", 0.6)
    return Scenario("theta", params=over,
                    transforms=(("diurnal", {"amplitude": amplitude}),),
                    name="diurnal")


@register_scenario("trace-replay")
def _trace_replay(**over) -> Scenario:
    params = dict(over)
    if "trace" in params:
        params["path"] = params.pop("trace")
    if "path" not in params:
        raise UnknownWorkloadError(
            "scenario 'trace-replay' needs an SWF file: "
            "get_scenario('trace-replay', trace='path/to/trace.swf')")
    return Scenario("swf", params=params, name="trace-replay")
