"""Composable workload/scenario API (mirrors the policy architecture).

    base         WorkloadSource / ScenarioTransform protocols, Scenario,
                 string-keyed source+transform registries
    synthetic    the decomposed Theta-like generator ("theta" source)
    swf          Standard Workload Format trace replay ("swf" source)
    transforms   load_scale / burst_inject / diurnal / notice_mix / type_mix
    presets      named Scenario presets (W1-W5, bursty-od, trace-replay)

See docs/workloads.md for the source/transform contract and a 10-line
custom-source example.
"""
from .base import (Scenario, ScenarioTransform, TraceStats,
                   UnknownWorkloadError, WorkloadDataError, WorkloadSource,
                   canonicalize, get_source, get_transform, register_source,
                   register_transform, registered_sources,
                   registered_transforms, trace_sha256, trace_stats_of)
from .synthetic import (NOTICE_KINDS, NOTICE_MIXES, SIZE_BUCKETS,
                        SIZE_WEIGHTS, ArrivalModel, NoticeModel,
                        ProjectModel, RuntimeModel, SizeModel,
                        ThetaGenerator, WorkloadConfig,
                        assign_project_types, daly_interval, generate,
                        notice_mix, rigid_ckpt_params)
from .swf import SWF_FIELDS, SwfTrace, iter_swf, parse_swf
from .transforms import (BurstInject, DiurnalModulation, LoadScale,
                         NoticeMixOverride, TypeMixReassign)
from .presets import get_scenario, register_scenario, registered_scenarios

__all__ = [
    "Scenario", "ScenarioTransform", "TraceStats", "WorkloadSource",
    "UnknownWorkloadError", "WorkloadDataError",
    "canonicalize", "get_source", "get_transform", "register_source",
    "register_transform", "registered_sources", "registered_transforms",
    "trace_sha256", "trace_stats_of", "iter_swf",
    "NOTICE_KINDS", "NOTICE_MIXES", "SIZE_BUCKETS", "SIZE_WEIGHTS",
    "ArrivalModel", "NoticeModel", "ProjectModel", "RuntimeModel",
    "SizeModel", "ThetaGenerator", "WorkloadConfig",
    "assign_project_types", "daly_interval", "generate", "notice_mix",
    "rigid_ckpt_params",
    "SWF_FIELDS", "SwfTrace", "parse_swf",
    "BurstInject", "DiurnalModulation", "LoadScale", "NoticeMixOverride",
    "TypeMixReassign",
    "get_scenario", "register_scenario", "registered_scenarios",
]
