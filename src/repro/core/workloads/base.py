"""Composable workload API: sources, transforms, scenarios (paper §IV-A).

PR 1 made scheduling *policies* pluggable; this package does the same for
the other evaluation axis — workload composition.  Three small protocols
mirror the policy architecture (`repro.core.policy`):

    WorkloadSource     produces a job trace (a list of JobSpec).  Built-in
                       sources: "theta" (the decomposed synthetic Theta-like
                       generator, repro.core.workloads.synthetic) and "swf"
                       (Standard Workload Format trace replay with
                       job-type/malleability annotation,
                       repro.core.workloads.swf).
    ScenarioTransform  rewrites a trace: load scaling, burst injection,
                       diurnal modulation, notice-mix override, type-mix
                       reassignment (repro.core.workloads.transforms).
                       Transforms stack on any source.
    Scenario           a picklable recipe: source name + params + a stack
                       of (transform name, params) — the unit Experiment
                       sweeps alongside mechanisms and seeds.

Both sources and transforms live in string-keyed registries so new
workloads are *data* (registry entries) rather than forks of the
generator, exactly like scheduling policies::

    from repro.core.workloads import WorkloadSource, register_source

    @register_source("replay_csv")
    class CsvReplay(WorkloadSource):
        def __init__(self, path, n_nodes=4392, seed=0):
            self.path, self.n_nodes, self.seed = path, n_nodes, seed

        def jobs(self):
            return [make_jobspec(row) for row in read_csv(self.path)]

    # Scenario("replay_csv", params={"path": "trace.csv"}) now works
    # everywhere — Experiment, benchmarks, examples.

Named presets (paper W1-W5, bursty-OD stress, trace replay) are plain
Scenario factories registered in repro.core.workloads.presets; Experiment
accepts the preset name string directly.
"""
from __future__ import annotations

import hashlib
import logging
import os
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence, Tuple

import numpy as np

from ..job import JobSpec

log = logging.getLogger(__name__)

#: scenario labels already warned about losing the bounded-memory
#: guarantee (one structured warning per scenario per process, so a
#: thousand-cell sweep does not emit a thousand copies)
_WARNED_MATERIALIZED: set = set()


class UnknownWorkloadError(ValueError):
    """A workload source, transform, scenario, or notice-mix name that is
    not in its registry.  ValueError subclass for backward compatibility,
    in the style of :class:`repro.core.policy.UnknownPolicyError`;
    Experiment relies on the distinct type to tell registry misses in
    spawn-start workers apart from genuine simulation errors."""


class WorkloadDataError(ValueError):
    """A workload source's input data is unusable (corrupt trace line, no
    usable jobs, ...).  Deliberately NOT an UnknownWorkloadError: registry
    misses make Experiment retry the sweep serially (spawn-start workers
    may lack parent-registered classes), while data errors are
    deterministic and must propagate immediately."""


# ------------------------------------------------------------------ protocols
@dataclass(frozen=True)
class TraceStats:
    """Cheap global aggregates of a canonical trace, computable without
    materializing it: job/on-demand counts and the submit-time span.
    Streaming transforms pre-draw their RNG from these (a transform's
    randomness may depend on trace *shape*, never on trace *contents*),
    and each transform republishes the stats it hands downstream via
    :meth:`ScenarioTransform.stream_stats`."""

    n_jobs: int
    n_od: int
    t0: float
    t1: float
    #: on-demand job counts per stream-merge rank: rank 0 is the base
    #: trace, rank r >= 1 the jobs a trace-restructuring transform (the
    #: r-th ``burst_inject`` in the stack) merged in.  The *materialized*
    #: pipeline orders od jobs base-first-then-appended when a later
    #: transform assigns per-od draws (NoticeModel.assign walks the list
    #: in that order); a streaming merge interleaves them by submit time,
    #: so downstream per-od transforms recover the materialized
    #: assignment order from each job's rank (:func:`stream_rank`) plus
    #: these per-rank offsets.  Empty means "all rank 0" (n_od jobs).
    od_rank_counts: Tuple[int, ...] = ()

    def od_rank_offsets(self) -> Tuple[int, ...]:
        """Start index of each rank's od block in materialized order."""
        counts = self.od_rank_counts or (self.n_od,)
        offsets = [0]
        for c in counts[:-1]:
            offsets.append(offsets[-1] + c)
        return tuple(offsets)


#: attribute a stream-merging transform sets on the JobSpecs it injects
#: (absent == rank 0, the base trace): a ``(rank, index)`` pair, where
#: index is the job's position within its rank in *materialized*
#: (generation/appended) order — the merge re-orders injected jobs by
#: submit time, so encounter order no longer carries it.  See
#: TraceStats.od_rank_counts.
_STREAM_TAG_ATTR = "_stream_tag"


def stream_rank(j: JobSpec) -> int:
    """The stream-merge rank of a job (0 for base-trace jobs)."""
    return getattr(j, _STREAM_TAG_ATTR, (0, 0))[0]


def stream_index(j: JobSpec) -> int:
    """A tagged job's position within its rank, in materialized order."""
    return getattr(j, _STREAM_TAG_ATTR, (0, 0))[1]


def tag_stream_rank(j: JobSpec, rank: int, index: int) -> None:
    setattr(j, _STREAM_TAG_ATTR, (rank, index))


class WorkloadSource:
    """Produces one job trace.

    Contract:
      * the constructor accepts registry params as keyword arguments and
        MUST accept a ``seed`` keyword (Experiment re-seeds each run);
      * ``jobs()`` returns a canonical trace — submit-time sorted with
        contiguous jids starting at 0 (use :func:`canonicalize`);
      * ``iter_jobs()`` yields the *same* canonical trace lazily — the
        streaming entry point (year-scale replays).  The default
        materializes through ``jobs()``; sources that can stream
        (builtin "theta" and "swf" stage compact numeric columns
        instead of JobSpec objects) override it, and must be
        job-for-job identical to ``jobs()``;
      * ``trace_stats()`` returns the :class:`TraceStats` of the
        canonical trace without yielding it (streaming transforms
        pre-draw from these).  The default materializes; streaming
        sources override it to stay bounded;
      * ``n_nodes`` is the system size the trace targets (SimConfig uses
        it when a Scenario does not override it).
    """

    name: str = "?"
    n_nodes: int = 0

    def jobs(self) -> List[JobSpec]:
        raise NotImplementedError

    def iter_jobs(self) -> Iterator[JobSpec]:
        return iter(self.jobs())

    def trace_stats(self) -> TraceStats:
        return trace_stats_of(self.jobs())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} source:{self.name}>"


def trace_stats_of(jobs: Sequence[JobSpec]) -> TraceStats:
    """TraceStats of a materialized (not necessarily sorted) trace."""
    from ..job import JobType
    if not jobs:
        return TraceStats(0, 0, 0.0, 0.0)
    subs = [j.submit_time for j in jobs]
    return TraceStats(len(jobs),
                      sum(j.jtype is JobType.ONDEMAND for j in jobs),
                      min(subs), max(subs))


class ScenarioTransform:
    """Rewrites a job trace; stateless apart from constructor params.

    ``apply`` receives the trace, a numpy Generator (seeded per run by
    :meth:`Scenario.realize`), and the system size the trace targets —
    so transforms can honor size invariants like the paper's half-system
    on-demand cap — and returns the transformed trace; it may mutate and
    return the input list.  Scenario.realize re-canonicalizes after the
    whole stack, so transforms may leave arrivals unsorted or jids stale
    (new jobs use ``jid=-1``).

    Transforms that can rewrite a trace *one job at a time* additionally
    set ``streamable = True`` and implement ``stream``, which lets
    :meth:`Scenario.iter_realize` run the whole stack in bounded memory.
    The streaming contract (bit-identity with ``apply``):

      * ``stream(jobs, rng, n_nodes, stats)`` is called **eagerly** in
        stack order and must consume ALL the RNG draws ``apply`` would
        make *before returning* its generator (pre-draw from ``stats``
        — a draw may depend on trace shape, never on job contents), so
        the shared per-run stream is consumed in exactly the
        materialized order;
      * the returned iterator must preserve submit-time order (monotone
        arrival maps).  A transform that *adds* jobs (``burst_inject``)
        streams by drawing its bounded injected set eagerly and merging
        it into the flow in submit order with base-first tie-breaks —
        reproducing exactly what ``canonicalize``'s stable sort does to
        the appended materialized list — and tags the injected jobs
        with a stream rank (:func:`tag_stream_rank`) so downstream
        per-od transforms can recover the materialized assignment
        order (see :attr:`TraceStats.od_rank_counts`).  Rewrites that
        reassign *existing* jobs' draws content-dependently
        (``type_mix``) stay ``streamable = False`` and force
        ``iter_realize`` to fall back to the materialized path;
      * ``stream_stats`` republishes the stats the transform hands the
        next stage (e.g. a compressed arrival span, or counts grown by
        injected jobs).  ``iter_realize`` calls it *after* ``stream``,
        so a merging transform may publish exact stats of the set it
        just drew."""

    name: str = "?"
    streamable: bool = False

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        raise NotImplementedError

    def stream(self, jobs: Iterator[JobSpec], rng: np.random.Generator,
               n_nodes: int, stats: TraceStats) -> Iterator[JobSpec]:
        raise NotImplementedError(
            f"transform {self.name!r} is not streamable")

    def stream_stats(self, stats: TraceStats) -> TraceStats:
        return stats

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} transform:{self.name}>"


def canonicalize(jobs: List[JobSpec]) -> List[JobSpec]:
    """Sort by submit time and renumber jids contiguously from 0 (the
    trace invariant every source and Scenario.realize guarantee)."""
    jobs.sort(key=lambda j: j.submit_time)
    for new_id, j in enumerate(jobs):
        j.jid = new_id
    return jobs


# ------------------------------------------------------------------ registries
_SOURCES: Dict[str, type] = {}
_TRANSFORMS: Dict[str, type] = {}


def register_source(name: str) -> Callable[[type], type]:
    """Class decorator: ``@register_source("swf")``."""
    def deco(cls: type) -> type:
        cls.name = name
        _SOURCES[name] = cls
        return cls
    return deco


def register_transform(name: str) -> Callable[[type], type]:
    """Class decorator: ``@register_transform("load_scale")``."""
    def deco(cls: type) -> type:
        cls.name = name
        _TRANSFORMS[name] = cls
        return cls
    return deco


def get_source(name: str, **params) -> WorkloadSource:
    """Instantiate a registered workload source by name."""
    _ensure_builtins()
    try:
        cls = _SOURCES[name]
    except KeyError:
        raise UnknownWorkloadError(
            f"unknown workload source {name!r}; registered: "
            f"{', '.join(sorted(_SOURCES))}") from None
    return cls(**params)


def get_transform(name: str, **params) -> ScenarioTransform:
    """Instantiate a registered scenario transform by name."""
    _ensure_builtins()
    try:
        cls = _TRANSFORMS[name]
    except KeyError:
        raise UnknownWorkloadError(
            f"unknown scenario transform {name!r}; registered: "
            f"{', '.join(sorted(_TRANSFORMS))}") from None
    return cls(**params)


def registered_sources() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_SOURCES))


def registered_transforms() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_TRANSFORMS))


# ------------------------------------------------------------------- scenario
@dataclass
class Scenario:
    """A picklable workload recipe: source + params + transform stack.

    Experiment treats a Scenario exactly like a legacy WorkloadConfig cell:
    one Scenario x mechanism x seed per run, with ``seed`` replaced by the
    RunSpec seed (the template seed is a default for direct use).

        Scenario("swf", params={"path": "theta.swf"},
                 transforms=[("load_scale", {"factor": 1.3})])
    """

    source: str
    params: Dict[str, object] = field(default_factory=dict)
    transforms: Sequence[Tuple[str, Dict[str, object]]] = ()
    #: preset label for reporting (ExperimentResult.rows "scenario" column)
    name: Optional[str] = None
    seed: int = 0
    #: system-size override: forwarded to the source as its ``n_nodes``
    #: param (winning over ``params``) so trace clipping and the
    #: on-demand size cap match the simulated machine
    n_nodes: Optional[int] = None
    #: fault-model spec (repro.faults): None/"none" for the legacy
    #: perfect machine, else a compact string ("exp-mtbf:mtbf_h=168")
    #: or a {"model": ...} dict.  Experiment threads it into
    #: ``SimConfig.faults`` for every run of this scenario (explicit
    #: ``sim_kw["faults"]`` overrides win).
    faults: object = None
    #: batch scheduling-round interval in seconds (see
    #: ``SimConfig.batch_rounds``): None/0 for the per-event engine,
    #: > 0 for one deferred scheduling pass per round.  Experiment
    #: threads it into ``SimConfig.batch_rounds`` for every run of this
    #: scenario (explicit ``sim_kw["batch_rounds"]`` overrides win).
    batch_rounds: Optional[float] = None

    @property
    def label(self) -> str:
        return self.name or self.source

    def with_seed(self, seed: int) -> "Scenario":
        return replace(self, seed=seed)

    def validate(self) -> None:
        """Fail fast — without building the trace — on errors that would
        otherwise surface in pool workers, where Experiment either
        misreads them as spawn registry misses or pays a full serial
        re-run before they propagate: unregistered source/transform
        names (UnknownWorkloadError), unknown notice mixes, and missing
        trace files (WorkloadDataError)."""
        _ensure_builtins()
        if self.source not in _SOURCES:
            get_source(self.source)  # raises with the registry listing
        for tname, _ in self.transforms:
            if tname not in _TRANSFORMS:
                get_transform(tname)  # raises with the registry listing
        from .synthetic import notice_mix
        param_sets = [self.params] + [p for _, p in self.transforms]
        for params in param_sets:
            for key in ("notice_mix", "mix"):
                if params.get(key) is not None:
                    notice_mix(params[key])
            path = params.get("path")
            if path is not None and not os.path.exists(path):
                raise WorkloadDataError(
                    f"scenario {self.label!r}: trace file not found: {path}")
        if self.faults not in (None, "none"):
            from ...faults import resolve_faults
            resolve_faults(self.faults)  # raises on unknown model / bad params
        if self.batch_rounds is not None and (
                not isinstance(self.batch_rounds, (int, float))
                or isinstance(self.batch_rounds, bool)
                or self.batch_rounds < 0
                or not np.isfinite(self.batch_rounds)):
            raise ValueError(
                f"scenario {self.label!r}: batch_rounds must be a finite "
                f"number >= 0, got {self.batch_rounds!r}")

    def realize(self, seed: Optional[int] = None
                ) -> Tuple[List[JobSpec], int]:
        """Build the trace: instantiate the source (re-seeded), run the
        transform stack, canonicalize.  Returns ``(jobs, n_nodes)``."""
        if seed is None:
            seed = self.seed
        params = {k: v for k, v in self.params.items() if k != "seed"}
        if self.n_nodes is not None:
            params["n_nodes"] = self.n_nodes
        src = get_source(self.source, seed=seed, **params)
        jobs = src.jobs()
        n_nodes = src.n_nodes
        # one transform-stack stream, decorrelated from the source's seed
        rng = np.random.default_rng([seed, 0x5CEA])
        for tname, tparams in self.transforms:
            jobs = get_transform(tname, **tparams).apply(jobs, rng, n_nodes)
        return canonicalize(jobs), n_nodes

    @property
    def streamable(self) -> bool:
        """True when the whole transform stack can run lazily (every
        transform is streamable); the source itself always can, via the
        materializing ``iter_jobs`` default at worst."""
        _ensure_builtins()
        return all(getattr(_TRANSFORMS.get(t, ScenarioTransform),
                           "streamable", False)
                   for t, _ in self.transforms)

    def iter_realize(self, seed: Optional[int] = None
                     ) -> Tuple[Iterator[JobSpec], int]:
        """Streaming :meth:`realize`: returns ``(job_iterator, n_nodes)``.

        Job-for-job identical to ``realize`` (same draws from the same
        per-run stream, same canonical order) but lazy: the source
        yields jobs one at a time and streamable transforms rewrite
        them in flight (``burst_inject`` merges its bounded injected
        set in tagged submit order).  A stack containing a
        non-streamable transform (``type_mix`` — it redraws existing
        jobs' assignments content-dependently) falls back to
        materializing internally; the call still returns an iterator,
        just not a bounded-memory one.
        """
        if seed is None:
            seed = self.seed
        if not self.streamable:
            _ensure_builtins()
            blocking = [t for t, _ in self.transforms
                        if not getattr(_TRANSFORMS.get(t, ScenarioTransform),
                                       "streamable", False)]
            key = (self.label, tuple(blocking))
            if key not in _WARNED_MATERIALIZED:
                _WARNED_MATERIALIZED.add(key)
                log.warning(
                    "Scenario %r: transform(s) %s are not streamable; "
                    "iter_realize falls back to materializing the full "
                    "trace internally — this run does NOT have the "
                    "bounded-memory streaming guarantee (see "
                    "docs/workloads.md#streaming-and-the-type_mix-fallback)",
                    self.label, ", ".join(repr(t) for t in blocking))
            jobs, n_nodes = self.realize(seed)
            return iter(jobs), n_nodes
        params = {k: v for k, v in self.params.items() if k != "seed"}
        if self.n_nodes is not None:
            params["n_nodes"] = self.n_nodes
        src = get_source(self.source, seed=seed, **params)
        n_nodes = src.n_nodes
        rng = np.random.default_rng([seed, 0x5CEA])
        stream = src.iter_jobs()
        if self.transforms:
            stats = src.trace_stats()
            for tname, tparams in self.transforms:
                tf = get_transform(tname, **tparams)
                # stream() consumes tf's whole RNG share eagerly, so the
                # shared stream is drawn in materialized stack order
                stream = tf.stream(stream, rng, n_nodes, stats)
                stats = tf.stream_stats(stats)
        return _renumber(stream), n_nodes


def _renumber(stream: Iterator[JobSpec]) -> Iterator[JobSpec]:
    """The streaming half of :func:`canonicalize`: sources yield in
    submit order and streamable transforms preserve it, so only the
    contiguous-jid invariant needs re-asserting."""
    for new_id, job in enumerate(stream):
        job.jid = new_id
        yield job


def trace_sha256(jobs: Iterable[JobSpec]) -> str:
    """Order-sensitive sha256 over every field of every job — the
    job-for-job identity fingerprint the streaming tests and benchmarks
    compare between ``iter_realize`` and ``realize``.  Consumes the
    iterable incrementally (safe on year-scale streams)."""
    h = hashlib.sha256()
    for j in jobs:
        h.update(repr((j.jid, j.jtype.value, j.project, j.submit_time,
                       j.size, j.t_estimate, j.t_actual, j.t_setup,
                       j.n_min, j.notice_kind.value, j.notice_time,
                       j.est_arrival, j.ckpt_overhead,
                       j.ckpt_interval)).encode())
    return h.hexdigest()


def _ensure_builtins() -> None:
    """Import the builtin source/transform modules exactly once
    (registration side effect); deferred to avoid a circular import at
    module load, mirroring repro.core.policy._ensure_builtins."""
    from . import swf, synthetic, transforms  # noqa: F401
