"""Standard Workload Format trace replay with hybrid-workload annotation.

SWF (Feitelson's Parallel Workloads Archive format, the one accasim and
most HPC simulators ingest) is one job per line, 18 whitespace-separated
integer/float fields, with ``;`` comment lines; header comments carry
directives like ``; MaxNodes: 4392``.  Missing fields are ``-1``.
Archive traces ship gzip-compressed (``.swf.gz``); the reader
decompresses transparently by magic bytes, so the trace-zoo cache
(repro.campaign.zoo) never has to unpack them on disk.

Real traces carry no job-type, malleability, or advance-notice labels —
the paper's evaluation axes — so :class:`SwfTrace` annotates them with
the same rules the synthetic generator uses (paper §IV-A):

  * "projects" are the trace's user_id (or group_id) values; a seeded
    shuffle assigns ``frac_od_projects`` of them ONDEMAND,
    ``frac_rigid_projects`` RIGID, the rest MALLEABLE;
  * on-demand jobs larger than half the system are reassigned to
    rigid/malleable with a fair coin;
  * malleable jobs get ``n_min = ceil(malleable_min_frac * size)``;
  * rigid jobs get the generator's Daly checkpoint model (§IV-B) — an
    infinite interval would forfeit all work on preemption, skewing
    mechanism comparisons vs synthetic traces;
  * on-demand jobs draw a Table III notice mix via the shared
    :class:`~repro.core.workloads.synthetic.NoticeModel`.

Registered as workload source ``"swf"``::

    Scenario("swf", params={"path": "tests/data/sample.swf",
                            "notice_mix": "W2"})
"""
from __future__ import annotations

import gzip
import io
import itertools
import math
import os
import re
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..job import JobSpec, JobType
from .base import WorkloadDataError, WorkloadSource, canonicalize, \
    register_source
from .synthetic import NoticeModel, assign_project_types, notice_mix, \
    rigid_ckpt_params

#: the 18 SWF fields, in file order (Parallel Workloads Archive v2.2)
SWF_FIELDS: Tuple[str, ...] = (
    "job_number", "submit_time", "wait_time", "run_time",
    "allocated_procs", "avg_cpu_time", "used_memory", "req_procs",
    "req_time", "req_memory", "status", "user_id", "group_id",
    "executable", "queue", "partition", "preceding_job", "think_time",
)

_HEADER_RE = re.compile(r";\s*([A-Za-z][A-Za-z0-9_ ]*?)\s*:\s*(.+?)\s*$")


#: (abspath, max_jobs, mtime_ns, size) -> (records, header).  A sweep
#: realizes one Scenario per (mechanism, seed) cell, each constructing a
#: fresh SwfTrace; the cache makes a large archive trace parse once per
#: process instead of once per cell.  Consumers treat records read-only.
_PARSE_CACHE: Dict[tuple, tuple] = {}
_PARSE_CACHE_MAX = 8


#: lines parsed per chunk by the streaming reader (amortizes the file
#: iteration without holding more than one chunk of raw text)
DEFAULT_CHUNK_LINES = 4096


def open_swf(path: str) -> io.TextIOBase:
    """Open an SWF file for text reading, decompressing transparently.

    gzip is detected by magic bytes (``\\x1f\\x8b``), not by extension,
    so both ``trace.swf.gz`` archives straight from the Parallel
    Workloads Archive and renamed copies work.  Decode errors are
    mapped to :class:`WorkloadDataError` lazily (the returned reader
    raises them as the corrupt bytes are reached)."""
    raw = open(path, "rb")
    try:
        magic = raw.read(2)
        raw.seek(0)
    except OSError:
        raw.close()
        raise
    if magic == b"\x1f\x8b":
        return io.TextIOWrapper(gzip.GzipFile(fileobj=raw),
                                encoding="utf-8", errors="strict")
    return io.TextIOWrapper(raw, encoding="utf-8", errors="strict")


def iter_swf(path: str, max_jobs: Optional[int] = None,
             chunk_lines: int = DEFAULT_CHUNK_LINES,
             header: Optional[Dict[str, str]] = None
             ) -> Iterator[Dict[str, float]]:
    """Stream an SWF file's records without materializing the file.

    The chunked twin of :func:`parse_swf` (which delegates here):
    reads ``chunk_lines`` raw lines at a time and yields one record
    dict per job line — identical records for every chunk size
    (hypothesis-tested in tests/test_properties.py).  Header
    directives are accumulated into the caller-supplied ``header``
    dict as they are encountered; since directives may technically
    appear anywhere, the dict is only complete once the iterator is
    exhausted (the streaming SwfTrace scan always runs it dry).

    gzip-compressed traces (``.swf.gz``) are read transparently
    (:func:`open_swf`); truncated/corrupt compressed streams and
    binary junk raise :class:`WorkloadDataError` with the path, never
    a bare codec/zlib traceback.  Short job lines are padded with the
    SWF ``-1`` "unknown" marker; lines with extra trailing fields are
    truncated to the 18 standard fields (both occur in public archive
    traces).
    """
    if chunk_lines <= 0:
        raise ValueError(f"chunk_lines must be >= 1, got {chunk_lines}")
    n_records = 0
    lineno = 0
    with open_swf(path) as f:
        while True:
            try:
                chunk = list(itertools.islice(f, chunk_lines))
            except (EOFError, zlib.error, gzip.BadGzipFile) as e:
                raise WorkloadDataError(
                    f"{path}: corrupt gzip stream near line {lineno}: {e}"
                ) from None
            except UnicodeDecodeError as e:
                raise WorkloadDataError(
                    f"{path}: not a text SWF trace (undecodable bytes "
                    f"near line {lineno}: {e})") from None
            if not chunk:
                return
            for line in chunk:
                lineno += 1
                line = line.strip()
                if not line:
                    continue
                if line.startswith(";"):
                    m = _HEADER_RE.match(line)
                    if m and header is not None:
                        header[m.group(1)] = m.group(2)
                    continue
                parts = line.split()
                try:
                    vals = [float(x) for x in parts[:len(SWF_FIELDS)]]
                except ValueError as e:
                    raise WorkloadDataError(
                        f"{path}:{lineno}: unparseable SWF line: {e}"
                    ) from None
                vals += [-1.0] * (len(SWF_FIELDS) - len(vals))
                yield dict(zip(SWF_FIELDS, vals))
                n_records += 1
                if max_jobs is not None and n_records >= max_jobs:
                    return


def parse_swf(path: str, max_jobs: Optional[int] = None
              ) -> Tuple[List[Dict[str, float]], Dict[str, str]]:
    """Parse an SWF file into (records, header directives).

    Each record maps every :data:`SWF_FIELDS` name to a float (ints
    included — SWF semantics are numeric); short lines are padded with
    ``-1`` (the SWF "unknown" marker).  Header directives are the
    ``; Key: value`` comment lines.  Results are cached per
    (path, max_jobs, mtime): callers must not mutate them.
    """
    try:
        st = os.stat(path)
        cache_key = (os.path.abspath(path), max_jobs, st.st_mtime_ns,
                     st.st_size)
    except OSError:
        cache_key = None
    if cache_key is not None and cache_key in _PARSE_CACHE:
        return _PARSE_CACHE[cache_key]
    header: Dict[str, str] = {}
    records = list(iter_swf(path, max_jobs, header=header))
    if cache_key is not None:
        if len(_PARSE_CACHE) >= _PARSE_CACHE_MAX:
            _PARSE_CACHE.pop(next(iter(_PARSE_CACHE)))
        _PARSE_CACHE[cache_key] = (records, header)
    return records, header


@register_source("swf")
class SwfTrace(WorkloadSource):
    """Replay an SWF trace as an annotated hybrid workload.

    Two ingestion modes:

    * ``stream=False`` (default): the whole file is parsed into record
      dicts once (cached per path+mtime) and ``jobs()`` materializes the
      annotated trace — the legacy path, bit-for-bit stable.
    * ``stream=True``: the constructor makes ONE bounded-memory pass
      (:func:`iter_swf`) that keeps only compact numeric columns
      (~50 B/job vs ~1 KB/job of record dicts), and ``iter_jobs()``
      yields annotated JobSpecs lazily in canonical order —
      job-for-job identical to the materialized path (pinned by
      tests/test_streaming.py).  ``jobs()`` still works (it drains the
      iterator), and construction still fails fast on corrupt lines.
    """

    def __init__(self, path: str, n_nodes: Optional[int] = None,
                 max_jobs: Optional[int] = None, seed: int = 0,
                 stream: bool = False,
                 frac_od_projects: float = 0.10,
                 frac_rigid_projects: float = 0.60,
                 notice_mix: str = "W5",
                 notice_lead: tuple = (900.0, 1800.0),
                 late_window: float = 1800.0,
                 malleable_min_frac: float = 0.20,
                 project_field: str = "user_id",
                 drop_cancelled: bool = True,
                 ckpt_overhead_small: float = 600.0,
                 ckpt_overhead_large: float = 1200.0,
                 ckpt_freq_factor: float = 1.0,
                 node_mtbf_hours: float = 20000.0):
        if project_field not in SWF_FIELDS:
            raise WorkloadDataError(
                f"unknown SWF project_field {project_field!r}; "
                f"one of: {', '.join(SWF_FIELDS)}")
        self.path = path
        self.max_jobs = max_jobs
        self.seed = seed
        self.frac_od_projects = frac_od_projects
        self.frac_rigid_projects = frac_rigid_projects
        self.notice_mix = notice_mix
        self.notice_lead = notice_lead
        self.late_window = late_window
        self.malleable_min_frac = malleable_min_frac
        self.project_field = project_field
        self.drop_cancelled = drop_cancelled
        self.ckpt_overhead_small = ckpt_overhead_small
        self.ckpt_overhead_large = ckpt_overhead_large
        self.ckpt_freq_factor = ckpt_freq_factor
        self.node_mtbf_hours = node_mtbf_hours
        self.stream = stream
        self._annot_cache = None
        if stream:
            self._records = None
            self._cols, self._header, largest = self._scan()
        else:
            self._records, self._header = parse_swf(path, max_jobs)
            self._cols = None
            largest = None  # computed only if the header cannot answer
        self.n_nodes = n_nodes if n_nodes is not None \
            else self._system_size(largest)

    @property
    def header(self) -> Dict[str, str]:
        return dict(self._header)

    def _system_size(self, largest_job: Optional[int]) -> int:
        for key in ("MaxNodes", "MaxProcs"):
            raw = self._header.get(key)
            if raw:
                m = re.match(r"\d+", raw.replace(",", ""))
                if m:
                    return int(m.group())
        if largest_job is None:  # header had no answer: scan the records
            largest_job = max((s for s in map(self._size, self._records)
                               if s > 0), default=0)
        if largest_job <= 0:
            raise WorkloadDataError(
                f"{self.path}: cannot infer system size (no MaxNodes/"
                "MaxProcs header and no sized jobs); pass n_nodes=")
        return largest_job

    def _usable(self, rec: Dict[str, float]) -> Optional[int]:
        """The job size when `rec` should be simulated, else None —
        the one copy of the cancelled/unsized filter both ingestion
        modes apply."""
        if self.drop_cancelled and rec["status"] == 5:
            return None
        size = self._size(rec)
        if size <= 0 or rec["run_time"] <= 0:
            return None
        return size

    def _scan(self) -> Tuple[dict, Dict[str, str], int]:
        """One streaming pass over the file: compact numeric columns of
        the usable records (submit/size/run/req/project), the header
        directives, and the largest raw job size (system-size
        fallback).  Never holds record dicts."""
        header: Dict[str, str] = {}
        submit: List[float] = []
        size_c: List[int] = []
        run_c: List[float] = []
        req_c: List[float] = []
        proj_c: List[int] = []
        largest = 0
        for rec in iter_swf(self.path, self.max_jobs, header=header):
            largest = max(largest, self._size(rec))
            size = self._usable(rec)
            if size is None:
                continue
            submit.append(rec["submit_time"])
            size_c.append(size)
            run_c.append(rec["run_time"])
            req_c.append(rec["req_time"])
            proj_c.append(int(rec[self.project_field]))
        cols = {"submit": np.asarray(submit, np.float64),
                "size": np.asarray(size_c, np.int64),
                "run": np.asarray(run_c, np.float64),
                "req": np.asarray(req_c, np.float64),
                "proj": np.asarray(proj_c, np.int64)}
        return cols, header, largest

    @staticmethod
    def _size(rec: Dict[str, float]) -> int:
        n = int(rec["allocated_procs"])
        return n if n > 0 else int(rec["req_procs"])

    def jobs(self) -> List[JobSpec]:
        if self.stream:
            return list(self.iter_jobs())
        mix = notice_mix(self.notice_mix)  # fail fast on bad mixes
        rng = np.random.default_rng(self.seed)

        usable = []
        for rec in self._records:
            if self.drop_cancelled and rec["status"] == 5:
                continue
            size = self._size(rec)
            if size <= 0 or rec["run_time"] <= 0:
                continue  # SWF "unknown" markers: nothing to simulate
            usable.append((rec, size))
        if not usable:
            raise WorkloadDataError(
                f"{self.path}: no usable jobs (need positive size and "
                "run_time)")

        # per-project type assignment, same proportions as the generator
        projects = sorted({int(rec[self.project_field]) for rec, _ in usable})
        ptypes = assign_project_types(rng, len(projects),
                                      self.frac_od_projects,
                                      self.frac_rigid_projects)
        type_of = dict(zip(projects, ptypes))

        t0 = min(rec["submit_time"] for rec, _ in usable)
        proj_tag = self.project_field.replace("_id", "")
        jobs: List[JobSpec] = []
        for rec, size in usable:
            size = min(size, self.n_nodes)
            p = int(rec[self.project_field])
            jt: JobType = type_of[p]
            if jt is JobType.ONDEMAND and size > self.n_nodes // 2:
                jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
            t_act = float(rec["run_time"])
            t_est = float(rec["req_time"]) if rec["req_time"] > 0 else t_act
            t_est = max(t_est, t_act)  # a kill limit below the trace runtime
            #                            would truncate the replayed job
            kw = {}
            if jt is JobType.MALLEABLE:
                kw["n_min"] = max(1, math.ceil(self.malleable_min_frac * size))
            elif jt is JobType.RIGID:
                # same Daly model as the generator (paper §IV-B): trace
                # runtimes already include regular checkpoints
                delta, tau = rigid_ckpt_params(
                    size, self.ckpt_overhead_small, self.ckpt_overhead_large,
                    self.node_mtbf_hours, self.ckpt_freq_factor)
                kw["ckpt_overhead"] = delta
                kw["ckpt_interval"] = tau
            jobs.append(JobSpec(len(jobs), jt, f"{proj_tag}{p}",
                                float(rec["submit_time"] - t0), size,
                                t_est, t_act, **kw))

        od_jobs = [j for j in jobs if j.jtype is JobType.ONDEMAND]
        NoticeModel().assign(rng, od_jobs, mix, lead=self.notice_lead,
                             late_window=self.late_window)
        return canonicalize(jobs)

    # ------------------------------------------------------------- streaming
    # _annotate() MUST stay draw-for-draw in sync with jobs() above — same
    # algorithm over the compact columns (tests/test_streaming.py pins the
    # two paths sha256-identical).
    def _annotate(self) -> dict:
        """Run the §IV-A annotation draws over the columns: final job
        types, pre-drawn notice tuples for the on-demand set, and the
        canonical (stable submit-sort) order.  Memoized so iter_jobs()
        and trace_stats() share one pass."""
        if self._annot_cache is not None:
            return self._annot_cache
        mix = notice_mix(self.notice_mix)  # fail fast on bad mixes
        rng = np.random.default_rng(self.seed)
        cols = self._cols
        if cols is None:
            cols, _header, _largest = self._scan()
            self._cols = cols
        n = len(cols["submit"])
        if n == 0:
            raise WorkloadDataError(
                f"{self.path}: no usable jobs (need positive size and "
                "run_time)")
        # per-project type assignment, same proportions as the generator
        projects = sorted({int(p) for p in cols["proj"]})
        ptypes = assign_project_types(rng, len(projects),
                                      self.frac_od_projects,
                                      self.frac_rigid_projects)
        type_of = dict(zip(projects, ptypes))
        t0 = float(cols["submit"].min())
        half = self.n_nodes // 2
        jtype = np.empty(n, dtype=object)
        od_idx: List[int] = []
        for i in range(n):
            jt: JobType = type_of[int(cols["proj"][i])]
            if jt is JobType.ONDEMAND \
                    and min(int(cols["size"][i]), self.n_nodes) > half:
                jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
            jtype[i] = jt
            if jt is JobType.ONDEMAND:
                od_idx.append(i)
        notice = dict(zip(od_idx,
                          NoticeModel().draw(rng, len(od_idx), mix,
                                             lead=self.notice_lead,
                                             late_window=self.late_window)))
        submit_rel = cols["submit"] - t0
        order = np.argsort(submit_rel, kind="stable")  # == canonicalize sort
        self._annot_cache = {"jtype": jtype, "notice": notice,
                             "submit_rel": submit_rel, "order": order}
        return self._annot_cache

    def iter_jobs(self):
        """Yield the annotated canonical trace lazily — job-for-job
        identical to the materialized ``jobs()`` path, holding only the
        numeric columns plus one JobSpec at a time."""
        ann = self._annotate()
        cols = self._cols
        proj_tag = self.project_field.replace("_id", "")
        for new_id, i in enumerate(ann["order"]):
            i = int(i)
            jt: JobType = ann["jtype"][i]
            size = min(int(cols["size"][i]), self.n_nodes)
            t_act = float(cols["run"][i])
            req = float(cols["req"][i])
            t_est = req if req > 0 else t_act
            t_est = max(t_est, t_act)  # a kill limit below the trace
            #                            runtime would truncate the job
            kw = {}
            if jt is JobType.MALLEABLE:
                kw["n_min"] = max(1, math.ceil(self.malleable_min_frac * size))
            elif jt is JobType.RIGID:
                delta, tau = rigid_ckpt_params(
                    size, self.ckpt_overhead_small, self.ckpt_overhead_large,
                    self.node_mtbf_hours, self.ckpt_freq_factor)
                kw["ckpt_overhead"] = delta
                kw["ckpt_interval"] = tau
            j = JobSpec(new_id, jt, f"{proj_tag}{int(cols['proj'][i])}",
                        float(ann["submit_rel"][i]), size, t_est, t_act,
                        **kw)
            if jt is JobType.ONDEMAND:
                NoticeModel.apply_one(j, ann["notice"][i])
            yield j

    def trace_stats(self):
        from .base import TraceStats
        ann = self._annotate()
        order = ann["order"]
        return TraceStats(len(order), len(ann["notice"]),
                          float(ann["submit_rel"][order[0]]),
                          float(ann["submit_rel"][order[-1]]))
