"""Composable scenario transforms: trace rewrites that stack on any source.

Each transform is a registered :class:`ScenarioTransform` — pure trace
surgery, source-agnostic, applied by :meth:`Scenario.realize` in stack
order with one shared per-run RNG stream:

    load_scale     compress/stretch inter-arrival gaps (offered load x k)
    burst_inject   add synthetic on-demand bursts (§III-B stress)
    diurnal        warp arrivals onto a day/night intensity profile
    notice_mix     re-draw Table III notice kinds for on-demand jobs
    type_mix       reassign job types per project to new fractions

Transforms may mutate the input list and may leave it unsorted or with
stale/placeholder jids (new jobs use ``jid=-1``): Scenario.realize
re-canonicalizes (sort + renumber) after the whole stack.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Iterator, List, Optional

import numpy as np

from ..job import JobSpec, JobType, NoticeKind
from .base import ScenarioTransform, TraceStats, register_transform, \
    stream_index, stream_rank, tag_stream_rank
from .synthetic import NoticeModel, assign_project_types, notice_mix, \
    rigid_ckpt_params


def _shift_notice(j: JobSpec, delta: float) -> None:
    """Translate a job's notice geometry with its arrival (preserves the
    lead/early/late windows instead of scaling them)."""
    if j.notice_time is not None:
        j.notice_time = max(0.0, j.notice_time + delta)
    if j.est_arrival is not None:
        j.est_arrival = j.est_arrival + delta


@register_transform("load_scale")
class LoadScale(ScenarioTransform):
    """Scale offered load by ``factor`` by compressing the arrival span.

    factor > 1 packs the same work into a shorter span (heavier load);
    factor < 1 stretches it.  Runtimes and sizes are untouched; notice
    windows translate with their jobs.  Streamable: the arrival map is
    monotone and draws no RNG, so jobs rewrite one at a time.
    """

    streamable = True

    def __init__(self, factor: float = 1.0):
        if factor <= 0:
            raise ValueError(f"load_scale factor must be > 0, got {factor}")
        self.factor = factor

    def _move(self, j: JobSpec, t0: float) -> None:
        new_t = t0 + (j.submit_time - t0) / self.factor
        _shift_notice(j, new_t - j.submit_time)
        j.submit_time = new_t

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        if not jobs or self.factor == 1.0:
            return jobs
        t0 = min(j.submit_time for j in jobs)
        for j in jobs:
            self._move(j, t0)
        return jobs

    def stream(self, jobs: Iterator[JobSpec], rng: np.random.Generator,
               n_nodes: int, stats: TraceStats) -> Iterator[JobSpec]:
        if stats.n_jobs == 0 or self.factor == 1.0:
            return jobs

        def gen():
            for j in jobs:
                self._move(j, stats.t0)
                yield j
        return gen()

    def stream_stats(self, stats: TraceStats) -> TraceStats:
        if stats.n_jobs == 0 or self.factor == 1.0:
            return stats
        # same float expression _move applies to the last arrival
        return replace(stats,
                       t1=stats.t0 + (stats.t1 - stats.t0) / self.factor)


@register_transform("burst_inject")
class BurstInject(ScenarioTransform):
    """Inject synthetic on-demand bursts into an existing trace.

    Emulates the paper's Fig. 5 behavior at adversarial intensity: a
    project fires ``burst_size`` on-demand jobs inside ``window`` seconds
    at ``n_bursts`` random anchors across the trace span.  Injected jobs
    draw sizes log-uniform in ``size`` — clipped to the half-system
    on-demand cap (paper §IV-A) — and runtimes log-uniform in
    ``runtime``; a ``mix`` (Table III name) gives them advance notice.

    Streamable via a *tagged merge stage*: every draw depends only on
    the span endpoints and system size, so ``stream`` draws the whole
    injected set eagerly (bounded: at most ``n_bursts x burst_size[1]``
    jobs), tags each injected job with the next stream rank, and merges
    them into the flow in submit order with base-first tie-breaks —
    bit-identical to what ``canonicalize``'s stable sort does to the
    appended materialized list, while the base trace itself never
    materializes.  ``stream_stats`` then republishes exact counts/span
    of the drawn set (it runs after ``stream``, per the contract).
    """

    streamable = True

    def __init__(self, n_bursts: int = 3, burst_size: tuple = (2, 8),
                 window: float = 1800.0, size: tuple = (64, 256),
                 runtime: tuple = (600.0, 7200.0),
                 estimate_factor: tuple = (1.0, 3.0),
                 mix: Optional[str] = None,
                 notice_lead: tuple = (900.0, 1800.0),
                 late_window: float = 1800.0):
        self.n_bursts = n_bursts
        self.burst_size = burst_size
        self.window = window
        self.size = size
        self.runtime = runtime
        self.estimate_factor = estimate_factor
        self.mix = mix
        self.notice_lead = notice_lead
        self.late_window = late_window

    def _draw_injected(self, rng: np.random.Generator, n_nodes: int,
                       t0: float, t1: float) -> List[JobSpec]:
        """The single copy of the injection draw sequence, shared by the
        materialized and streaming paths (same RNG consumption order)."""
        od_cap = max(1, n_nodes // 2)
        injected: List[JobSpec] = []
        for b in range(self.n_bursts):
            anchor = float(rng.uniform(t0, max(t0, t1 - self.window)))
            count = int(rng.integers(self.burst_size[0],
                                     self.burst_size[1] + 1))
            for _ in range(count):
                size = int(np.exp(rng.uniform(math.log(self.size[0]),
                                              math.log(self.size[1]))))
                size = min(max(size, 1), od_cap)
                t_act = float(np.exp(rng.uniform(math.log(self.runtime[0]),
                                                 math.log(self.runtime[1]))))
                t_est = float(t_act * rng.uniform(*self.estimate_factor))
                injected.append(JobSpec(
                    -1, JobType.ONDEMAND, f"odburst{b}",
                    anchor + float(rng.uniform(0.0, self.window)),
                    size, t_est, t_act))
        if self.mix is not None:
            NoticeModel().assign(rng, injected, notice_mix(self.mix),
                                 lead=self.notice_lead,
                                 late_window=self.late_window)
        return injected

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        if not jobs:
            return jobs
        t0 = min(j.submit_time for j in jobs)
        t1 = max(j.submit_time for j in jobs)
        jobs.extend(self._draw_injected(rng, n_nodes, t0, t1))
        return jobs

    def stream(self, jobs: Iterator[JobSpec], rng: np.random.Generator,
               n_nodes: int, stats: TraceStats) -> Iterator[JobSpec]:
        self._injected: List[JobSpec] = []
        if stats.n_jobs == 0:
            return jobs
        injected = self._draw_injected(rng, n_nodes, stats.t0, stats.t1)
        # injected jobs sort AFTER every incoming job on submit-time ties
        # (stable sort over the appended list); their rank lets
        # downstream per-od transforms reconstruct that appended order
        rank = len(stats.od_rank_counts or (stats.n_od,))
        for i, j in enumerate(injected):
            tag_stream_rank(j, rank, i)
        self._injected = injected
        merged = sorted(injected, key=lambda j: j.submit_time)

        def gen():
            it = iter(merged)
            nxt = next(it, None)
            for j in jobs:
                while nxt is not None and nxt.submit_time < j.submit_time:
                    yield nxt
                    nxt = next(it, None)
                yield j
            while nxt is not None:
                yield nxt
                nxt = next(it, None)
        return gen()

    def stream_stats(self, stats: TraceStats) -> TraceStats:
        injected = getattr(self, "_injected", [])
        if not injected:
            return stats
        subs = [j.submit_time for j in injected]
        counts = stats.od_rank_counts or (stats.n_od,)
        return replace(stats,
                       n_jobs=stats.n_jobs + len(injected),
                       n_od=stats.n_od + len(injected),
                       t0=min(stats.t0, min(subs)),
                       t1=max(stats.t1, max(subs)),
                       od_rank_counts=counts + (len(injected),))


@register_transform("diurnal")
class DiurnalModulation(ScenarioTransform):
    """Warp arrival times onto a diurnal intensity profile.

    Remaps the trace span through the inverse cumulative intensity of
    ``lambda(t) = 1 + amplitude * cos(2*pi*(t - peak)/period)``, so
    arrival density concentrates around ``peak`` each ``period`` while
    the span endpoints and the job count are preserved.  ``amplitude``
    must stay below 1 (intensity must remain positive for the warp to be
    monotone).  Streamable: the warp is a monotone per-job map built
    from the span endpoints alone, with no RNG.
    """

    streamable = True

    def __init__(self, amplitude: float = 0.6, period: float = 86400.0,
                 peak: float = 14 * 3600.0, grid: int = 4096):
        if not 0.0 <= amplitude < 1.0:
            raise ValueError(
                f"diurnal amplitude must be in [0, 1), got {amplitude}")
        self.amplitude = amplitude
        self.period = period
        self.peak = peak
        self.grid = grid

    def _cumulative(self, t: np.ndarray, t0: float) -> np.ndarray:
        w = 2.0 * math.pi / self.period
        return ((t - t0)
                + self.amplitude / w * (np.sin(w * (t - self.peak))
                                        - math.sin(w * (t0 - self.peak))))

    def _warp(self, j: JobSpec, t0: float, t1: float, grid: np.ndarray,
              cum: np.ndarray, total: float) -> None:
        # uniform position along the span -> inverse-CDF of lambda
        target = (j.submit_time - t0) / (t1 - t0) * total
        new_t = float(np.interp(target, cum, grid))
        _shift_notice(j, new_t - j.submit_time)
        j.submit_time = new_t

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        if len(jobs) < 2 or self.amplitude == 0.0:
            return jobs
        t0 = min(j.submit_time for j in jobs)
        t1 = max(j.submit_time for j in jobs)
        if t1 <= t0:
            return jobs
        grid = np.linspace(t0, t1, self.grid)
        cum = self._cumulative(grid, t0)  # monotone since amplitude < 1
        total = cum[-1]
        for j in jobs:
            self._warp(j, t0, t1, grid, cum, total)
        return jobs

    def stream(self, jobs: Iterator[JobSpec], rng: np.random.Generator,
               n_nodes: int, stats: TraceStats) -> Iterator[JobSpec]:
        t0, t1 = stats.t0, stats.t1
        if stats.n_jobs < 2 or self.amplitude == 0.0 or t1 <= t0:
            return jobs
        grid = np.linspace(t0, t1, self.grid)
        cum = self._cumulative(grid, t0)
        total = cum[-1]

        def gen():
            for j in jobs:
                self._warp(j, t0, t1, grid, cum, total)
                yield j
        return gen()
        # span endpoints are fixed points of the warp: stats unchanged


@register_transform("notice_mix")
class NoticeMixOverride(ScenarioTransform):
    """Re-draw every on-demand job's notice kind from a Table III mix.

    Turns any source/scenario into its W1-W5 variants without touching
    arrival or size structure — the knob behind the paper-mix presets.
    Streamable: the draw count per on-demand job depends only on its
    drawn kind, so the whole notice share of the RNG stream is
    pre-drawn from ``stats.n_od`` (:meth:`NoticeModel.draw`) and
    attached to on-demand jobs as they flow past, in stream order —
    exactly the order ``apply`` walks the materialized list.
    """

    streamable = True

    def __init__(self, mix: str = "W5", notice_lead: tuple = (900.0, 1800.0),
                 late_window: float = 1800.0):
        self.mix = mix
        self.notice_lead = notice_lead
        self.late_window = late_window

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
        NoticeModel().assign(rng, od, notice_mix(self.mix),
                             lead=self.notice_lead,
                             late_window=self.late_window)
        return jobs

    def stream(self, jobs: Iterator[JobSpec], rng: np.random.Generator,
               n_nodes: int, stats: TraceStats) -> Iterator[JobSpec]:
        # all RNG consumed here, before the first job flows (stack order)
        drawn = NoticeModel().draw(rng, stats.n_od, notice_mix(self.mix),
                                   lead=self.notice_lead,
                                   late_window=self.late_window)
        # materialized assign order is base-od-then-injected (the
        # appended list), while a merged stream interleaves by submit
        # time: each od job's drawn tuple is indexed by its rank's
        # offset plus its position within the rank.  Base (rank-0) jobs
        # keep encounter order (monotone stages preserve it); injected
        # jobs carry their materialized position in their stream tag.
        offsets = stats.od_rank_offsets()

        def gen():
            base_seen = 0
            for j in jobs:
                if j.jtype is JobType.ONDEMAND:
                    r = stream_rank(j)
                    if r == 0:
                        idx, base_seen = base_seen, base_seen + 1
                    else:
                        idx = stream_index(j)
                    NoticeModel.apply_one(j, drawn[offsets[r] + idx])
                yield j
        return gen()


@register_transform("type_mix")
class TypeMixReassign(ScenarioTransform):
    """Reassign job types per project to new od/rigid/malleable fractions.

    Projects are re-labelled wholesale (the paper's per-project rule), so
    submission locality survives; demoted jobs lose their on-demand
    fields, promoted malleables gain ``n_min``, promoted rigids gain a
    Daly checkpoint interval (same §IV-B parameters as the generator),
    and newly on-demand jobs larger than ``od_max_size`` (default: half
    the system, the generator's rule) are bounced back to
    rigid/malleable.  ``mix`` (a Table III name) re-draws notice for the
    resulting on-demand set.
    """

    def __init__(self, frac_od: float = 0.10, frac_rigid: float = 0.60,
                 malleable_min_frac: float = 0.20,
                 od_max_size: Optional[int] = None, mix: str = "W5",
                 notice_lead: tuple = (900.0, 1800.0),
                 late_window: float = 1800.0,
                 ckpt_overhead_small: float = 600.0,
                 ckpt_overhead_large: float = 1200.0,
                 ckpt_freq_factor: float = 1.0,
                 node_mtbf_hours: float = 20000.0):
        if frac_od < 0 or frac_rigid < 0 or frac_od + frac_rigid > 1:
            raise ValueError("type_mix fractions must be >= 0 and sum <= 1")
        self.frac_od = frac_od
        self.frac_rigid = frac_rigid
        self.malleable_min_frac = malleable_min_frac
        self.od_max_size = od_max_size
        self.mix = mix
        self.notice_lead = notice_lead
        self.late_window = late_window
        self.ckpt_overhead_small = ckpt_overhead_small
        self.ckpt_overhead_large = ckpt_overhead_large
        self.ckpt_freq_factor = ckpt_freq_factor
        self.node_mtbf_hours = node_mtbf_hours

    def apply(self, jobs: List[JobSpec], rng: np.random.Generator,
              n_nodes: int) -> List[JobSpec]:
        if not jobs:
            return jobs
        od_cap = (self.od_max_size if self.od_max_size is not None
                  else n_nodes // 2)
        projects = sorted({j.project for j in jobs})
        ptypes = assign_project_types(rng, len(projects), self.frac_od,
                                      self.frac_rigid)
        type_of = dict(zip(projects, ptypes))
        for j in jobs:
            jt: JobType = type_of[j.project]
            if jt is JobType.ONDEMAND and j.size > od_cap:
                jt = JobType.RIGID if rng.random() < 0.5 else JobType.MALLEABLE
            j.jtype = jt
            j.notice_kind = NoticeKind.NONE
            j.notice_time = None
            j.est_arrival = None
            if jt is JobType.MALLEABLE:
                j.n_min = max(1, math.ceil(self.malleable_min_frac * j.size))
            else:
                j.n_min = 0
            if jt is JobType.RIGID:
                if j.ckpt_interval >= math.inf:
                    # promoted rigid: same Daly model the generator applies
                    j.ckpt_overhead, j.ckpt_interval = rigid_ckpt_params(
                        j.size, self.ckpt_overhead_small,
                        self.ckpt_overhead_large, self.node_mtbf_hours,
                        self.ckpt_freq_factor)
            else:
                j.ckpt_overhead = 0.0
                j.ckpt_interval = math.inf
        od = [j for j in jobs if j.jtype is JobType.ONDEMAND]
        NoticeModel().assign(rng, od, notice_mix(self.mix),
                             lead=self.notice_lead,
                             late_window=self.late_window)
        return jobs
