"""Incremental scheduling containers (the O(log n) engine hot path).

The legacy simulator kept the wait queue as a plain list: every event
re-sorted it with a Python key function (O(n log n) with n key calls)
and removed members by linear scan.  At month-scale traces (50k jobs,
thousands waiting under offered load > 1) those two costs dominate the
whole simulation.  This module provides the replacements:

    WaitQueue    the wait queue, kept permanently sorted by a cached
                 order key: O(log n) search + a C-level memmove per
                 append/remove, O(1) membership, O(1) head peek.  Policies
                 whose keys are not stable between events opt out via
                 ``QueuePolicy.order_keys_stable = False`` and get the
                 legacy re-sort-every-pass behavior back (docs/performance.md).
    OrderedSet   insertion-ordered set with O(1) append/remove/contains;
                 replaces the list-based ``collecting`` roster whose
                 ``remove`` was a linear scan per on-demand completion.

Both expose the exact surface the legacy lists exposed (indexing,
slicing, iteration, ``in``, ``len``), so policies written against
``SchedulerView.queue`` / ``.collecting`` keep working unchanged.

Tie-breaking contract: equal order keys rank by append order (a stable
sort of the legacy list preserved exactly that order as long as keys did
not change between passes).  ``invalidate`` keeps a job's original
append rank so re-keying cannot reshuffle its ties.  Built-in policies
sidestep ties entirely by ending their keys with the jid.
"""
from __future__ import annotations

import itertools
from bisect import bisect_left, insort
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union


class WaitQueue:
    """Order-key-sorted wait queue with cached keys and O(1) membership.

    In *incremental* mode (the default) the queue is sorted at all times:
    ``append`` computes the order key once and bisects it in, ``remove``
    bisects the cached key out, and ``refresh`` is a no-op.  A job's key
    is recomputed only by ``invalidate`` — the simulator calls it at the
    few events that can change a key, and custom policies may call
    ``ops.invalidate_order_key`` for their own key-changing events.

    In *legacy* mode (``incremental=False``) the queue behaves exactly
    like the old list: appends go to the back unsorted and ``refresh``
    re-sorts stably with freshly computed keys — for policies whose keys
    read clock- or load-dependent state.

    ``meta_fn`` optionally attaches a pair of floats per member (the
    simulator uses the minimum nodes the job needs to start — ``inf``
    for on-demand jobs — and its remaining-runtime estimate);
    ``meta_window`` hands contiguous slices of those floats to the
    vectorized backfill prefilter without per-job dict lookups.
    """

    __slots__ = ("_entries", "_meta0", "_meta1", "_index", "_seq", "_key_fn",
                 "_meta_fn", "incremental")

    def __init__(self,
                 key_fn: Optional[Callable[[int], tuple]] = None,
                 incremental: bool = True,
                 meta_fn: Optional[Callable[[int], Tuple[float, float]]] = None):
        self._entries: List[Tuple] = []      # (key, seq, jid), sorted when incremental
        self._meta0: List[float] = []        # parallel to _entries
        self._meta1: List[float] = []
        self._index: Dict[int, Tuple] = {}   # jid -> (key, seq, meta0, meta1)
        self._seq = itertools.count()
        self._key_fn = key_fn
        self._meta_fn = meta_fn
        self.incremental = incremental

    def configure(self, key_fn: Callable[[int], tuple],
                  incremental: bool = True,
                  meta_fn: Optional[Callable[[int], Tuple[float, float]]] = None
                  ) -> None:
        """Install the order key (and mode) before any member is added."""
        assert not self._entries, "configure() before the first append"
        self._key_fn = key_fn
        self._meta_fn = meta_fn
        self.incremental = incremental

    # ------------------------------------------------------------ mutation
    def append(self, jid: int) -> None:
        if jid in self._index:
            raise ValueError(f"job {jid} is already queued")
        seq = next(self._seq)
        m0, m1 = self._meta_fn(jid) if self._meta_fn is not None else (0.0, 0.0)
        if self.incremental:
            key = self._key_fn(jid)
            entry = (key, seq, jid)
            i = bisect_left(self._entries, entry)
            self._entries.insert(i, entry)
            self._meta0.insert(i, m0)
            self._meta1.insert(i, m1)
        else:
            key = None                       # computed at refresh() time
            self._entries.append((key, seq, jid))
            self._meta0.append(m0)
            self._meta1.append(m1)
        self._index[jid] = (key, seq, m0, m1)

    def remove(self, jid: int) -> None:
        key, seq, _m0, _m1 = self._index.pop(jid)
        i = self._locate(jid, key, seq)
        del self._entries[i]
        del self._meta0[i]
        del self._meta1[i]

    def invalidate(self, jid: int) -> None:
        """Recompute a member's order key after an event changed it; a
        non-member jid is a no-op.  The original append rank is kept so
        ties stay deterministic."""
        if not self.incremental or jid not in self._index:
            return
        key, seq, m0, m1 = self._index[jid]
        i = self._locate(jid, key, seq)
        del self._entries[i]
        del self._meta0[i]
        del self._meta1[i]
        new_key = self._key_fn(jid)
        entry = (new_key, seq, jid)
        j = bisect_left(self._entries, entry)
        self._entries.insert(j, entry)
        self._meta0.insert(j, m0)
        self._meta1.insert(j, m1)
        self._index[jid] = (new_key, seq, m0, m1)

    def refresh(self) -> None:
        """Bring the queue into key order.  Incremental mode: already
        sorted, O(1).  Legacy mode: stable re-sort with fresh keys — the
        exact semantics of the old per-pass ``queue.sort(key=...)``."""
        if self.incremental:
            return
        key_fn = self._key_fn
        self._entries.sort(key=lambda e: key_fn(e[2]))
        index = self._index
        self._meta0 = [index[e[2]][2] for e in self._entries]
        self._meta1 = [index[e[2]][3] for e in self._entries]

    # ------------------------------------------------------------- queries
    def position(self, jid: int) -> int:
        """Current rank of a member (0 = head).  O(log n) incremental."""
        key, seq, _m0, _m1 = self._index[jid]
        return self._locate(jid, key, seq)

    def meta_window(self, lo: int, hi: int
                    ) -> Tuple[List[float], List[float]]:
        """The cached per-member float pairs for ranks [lo, hi) — two
        snapshot lists aligned with ``self[lo:hi]``."""
        return self._meta0[lo:hi], self._meta1[lo:hi]

    def _locate(self, jid: int, key, seq: int) -> int:
        if self.incremental:
            i = bisect_left(self._entries, (key, seq, jid))
            if i < len(self._entries) and self._entries[i][2] == jid:
                return i
        else:
            for i, e in enumerate(self._entries):
                if e[2] == jid:
                    return i
        raise KeyError(jid)  # pragma: no cover - index/entries desync guard

    def __contains__(self, jid: object) -> bool:
        return jid in self._index

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __iter__(self) -> Iterator[int]:
        return (e[2] for e in self._entries)

    def __getitem__(self, i: Union[int, slice]):
        if isinstance(i, slice):
            return [e[2] for e in self._entries[i]]
        return self._entries[i][2]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        mode = "incremental" if self.incremental else "legacy"
        return f"<WaitQueue {mode} {list(self)!r}>"


class OrderedSet:
    """Insertion-ordered set with O(1) append/remove/contains.

    Drop-in for the list-based ``collecting`` roster: ``append`` keeps
    the first insertion's position (every call site guards membership
    anyway), ``remove`` raises on a missing member like ``list.remove``.
    """

    __slots__ = ("_d",)

    def __init__(self, items=()):
        self._d: Dict = dict.fromkeys(items)

    def append(self, x) -> None:
        self._d.setdefault(x, None)

    add = append

    def remove(self, x) -> None:
        try:
            del self._d[x]
        except KeyError:
            raise ValueError(f"{x!r} not in OrderedSet") from None

    def discard(self, x) -> None:
        self._d.pop(x, None)

    def __contains__(self, x: object) -> bool:
        return x in self._d

    def __iter__(self) -> Iterator:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    def __bool__(self) -> bool:
        return bool(self._d)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OrderedSet({list(self._d)!r})"
