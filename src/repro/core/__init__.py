"""Hybrid workload scheduling on HPC systems (Fan et al., 2021) — core.

Layered architecture::

    job / cluster / decision     job model, node ledger, vectorized kernels
    policy + policies/           pluggable scheduling policies + registry
    simulator                    event loop + mechanics (leases, lifecycle)
    workloads/                   pluggable workload sources, SWF replay,
                                 scenario transforms + registry
    metrics                      evaluation metrics
    experiment                   mechanisms x scenarios x seeds sweeps

Public API:
    JobSpec / JobType / NoticeKind   job model (paper §III-A)
    SimConfig / Simulator            event-driven scheduler (§III-B)
    MECHANISMS                       the six legacy mechanisms N/CUA/CUP x PAA/SPAA
    NoticePolicy / ArrivalPolicy / QueuePolicy / ElasticityPolicy
                                     policy protocols (repro.core.policy)
    register_policy / resolve_mechanism / registered_mechanisms
                                     the string-keyed policy registry
    Experiment / ExperimentResult    sweep runner with process fan-out
    WorkloadConfig / generate        Theta-like trace synthesis (§IV-A)
    WorkloadSource / ScenarioTransform / Scenario
                                     workload protocols (repro.core.workloads)
    register_source / register_transform / get_scenario
                                     the string-keyed workload registry
    SwfTrace                         SWF trace replay with annotation
    Metrics / collect                evaluation metrics (§IV-D)
    run_mechanism                    one-call simulation entry point

A mechanism string is "<notice>&<arrival>" over registered policy names
("CUA&SPAA", "CUA&STEAL", ...) or an explicitly registered composite
("BASE").  See docs/policies.md for writing and registering custom
policies — new strategies plug in without touching the simulator.

A workload cell is a WorkloadConfig, a Scenario (registered source +
params + transform stack), or a preset name ("W1".."W5", "bursty-od",
"diurnal", "trace-replay").  See docs/workloads.md for writing and
registering custom sources — new workloads plug in without touching the
generator.
"""
from .job import JobSpec, JobType, NoticeKind, RunState
from .cluster import Lease, NodeLedger
from .decision import (DecisionTrace, apportion_shrink,
                       backfill_prefilter, backfill_shadow_filter,
                       capture, easy_shadow, expected_releases_before,
                       select_preemption_victims)
from .structures import OrderedSet, WaitQueue
from .policy import (ARRIVAL_POLICIES, MECHANISMS, NOTICE_POLICIES,
                     ArrivalPolicy, ElasticityPolicy, NoticePolicy,
                     PolicyBundle, QueuePolicy, SchedulerOps, SchedulerView,
                     UnknownPolicyError, get_policy, register_policy,
                     register_mechanism, registered_mechanisms,
                     registered_policies, resolve_mechanism)
from .simulator import JobRecord, SimConfig, Simulator
from .workloads import (NOTICE_MIXES, Scenario, ScenarioTransform,
                        SwfTrace, ThetaGenerator, TraceStats,
                        UnknownWorkloadError, WorkloadConfig,
                        WorkloadDataError, WorkloadSource, daly_interval,
                        generate, get_scenario, get_source, get_transform,
                        notice_mix, register_scenario, register_source,
                        register_transform, registered_scenarios,
                        registered_sources, registered_transforms,
                        trace_sha256)
from .metrics import (Metrics, StreamingMetrics, collect,
                      summarize_records)
from .experiment import Experiment, ExperimentResult, RunResult, RunSpec


def run_mechanism(mechanism: str, jobs, n_nodes: int, **cfg_kw) -> "Metrics":
    """Simulate `jobs` under one mechanism and return its metrics."""
    sim = Simulator(SimConfig(n_nodes=n_nodes, mechanism=mechanism, **cfg_kw),
                    [j for j in jobs])
    sim.run()
    return collect(sim)


__all__ = [
    "JobSpec", "JobType", "NoticeKind", "RunState", "Lease", "NodeLedger",
    "DecisionTrace", "apportion_shrink", "backfill_prefilter",
    "backfill_shadow_filter", "capture", "easy_shadow",
    "expected_releases_before", "select_preemption_victims",
    "OrderedSet", "WaitQueue",
    "MECHANISMS", "NOTICE_POLICIES", "ARRIVAL_POLICIES",
    "NoticePolicy", "ArrivalPolicy", "QueuePolicy", "ElasticityPolicy",
    "PolicyBundle", "SchedulerView", "SchedulerOps",
    "get_policy", "register_policy", "register_mechanism",
    "registered_policies", "registered_mechanisms", "resolve_mechanism",
    "UnknownPolicyError",
    "JobRecord", "SimConfig", "Simulator",
    "NOTICE_MIXES", "WorkloadConfig", "daly_interval", "generate",
    "notice_mix",
    "WorkloadSource", "ScenarioTransform", "Scenario", "SwfTrace",
    "ThetaGenerator", "TraceStats", "UnknownWorkloadError",
    "WorkloadDataError", "trace_sha256",
    "get_source", "get_transform", "get_scenario",
    "register_source", "register_transform", "register_scenario",
    "registered_sources", "registered_transforms", "registered_scenarios",
    "Metrics", "StreamingMetrics", "collect", "summarize_records",
    "run_mechanism",
    "Experiment", "ExperimentResult", "RunResult", "RunSpec",
]
