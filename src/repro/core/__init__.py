"""Hybrid workload scheduling on HPC systems (Fan et al., 2021) — core.

Public API:
    JobSpec / JobType / NoticeKind   job model (paper §III-A)
    SimConfig / Simulator            event-driven scheduler (§III-B)
    MECHANISMS                       the six mechanisms N/CUA/CUP x PAA/SPAA
    WorkloadConfig / generate        Theta-like trace synthesis (§IV-A)
    Metrics / collect                evaluation metrics (§IV-D)
    run_mechanism                    one-call simulation entry point
"""
from .job import JobSpec, JobType, NoticeKind, RunState
from .cluster import Lease, NodeLedger
from .decision import (apportion_shrink, expected_releases_before,
                       select_preemption_victims)
from .simulator import MECHANISMS, JobRecord, SimConfig, Simulator
from .workload import NOTICE_MIXES, WorkloadConfig, daly_interval, generate
from .metrics import Metrics, collect


def run_mechanism(mechanism: str, jobs, n_nodes: int, **cfg_kw) -> "Metrics":
    """Simulate `jobs` under one mechanism and return its metrics."""
    sim = Simulator(SimConfig(n_nodes=n_nodes, mechanism=mechanism, **cfg_kw),
                    [j for j in jobs])
    sim.run()
    return collect(sim)


__all__ = [
    "JobSpec", "JobType", "NoticeKind", "RunState", "Lease", "NodeLedger",
    "apportion_shrink", "expected_releases_before", "select_preemption_victims",
    "MECHANISMS", "JobRecord", "SimConfig", "Simulator",
    "NOTICE_MIXES", "WorkloadConfig", "daly_interval", "generate",
    "Metrics", "collect", "run_mechanism",
]
