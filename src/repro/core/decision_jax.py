"""Sweeps-on-device: jitted JAX ports of the decision kernels.

The numpy kernels in :mod:`repro.core.decision` are the bit-for-bit
references; this module recasts each of them as a **fixed-shape padded**
JAX kernel (mask-padded est-end/size arrays, ``jnp.where`` sentinels
instead of ragged inputs) so they jit cleanly and `vmap` across
(mechanism x scenario x seed) sweep cells.  :func:`run_device_sweep`
replays every decision a whole `Experiment` grid captured (see
:func:`repro.core.decision.capture`) as **one device program** — a
single jitted call evaluating every captured decision of every cell —
and parity-checks the device outputs against the recorded numpy
results.  Process fan-out stays the identity baseline: the numbers the
sweep reports come from the numpy engine, the device program must
reproduce its decisions job for job.

Numerical contract (documented in docs/performance.md):

* ``dtype="float64"`` (the default, and the parity gate): inputs are
  float64/int64, traced inside :func:`repro.kernels.ops.enable_x64`, and
  every kernel is **exactly** equal to its numpy reference — the same
  IEEE expressions over the same operands, including stable sort order.
* ``dtype="float32"``: inputs round to float32/int32.  Continuous
  outputs (``t_shadow``) agree within ``FLOAT32_RTOL``; discrete
  outputs (victim sets, sheds, filter masks) may legitimately differ
  where rounding crosses a comparison or reorders a sort, but the
  structural invariants still hold (sheds sum exactly to ``need`` and
  respect per-job slack; victim prefixes cover ``need``).

Padding contract: valid entries occupy a prefix of each row, the mask
marks them, and padded lanes carry identity sentinels (size 0,
est-end/overhead/need ``+inf``) that cannot alter a cumsum, win a sort
tie against a valid lane, or pass a filter.  Est-end bases and
overheads must be finite for valid lanes (the simulator's always are);
``+inf`` need_mins (on-demand jobs) are fine — they are compared, never
summed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .decision import DecisionTrace

#: documented float32 tolerance for continuous outputs (t_shadow): the
#: selected release time is one of the float32-rounded inputs, so it can
#: differ from the float64 pick by at most ~1 ulp of the input scale —
#: unless two releases are closer than that, in which case either is a
#: correct answer and the parity suite only checks feasibility.
FLOAT32_RTOL = 1e-6


def _dtypes(dtype: str):
    if dtype == "float64":
        return jnp.float64, jnp.int64
    if dtype == "float32":
        return jnp.float32, jnp.int32
    raise ValueError(f"dtype must be 'float64' or 'float32', got {dtype!r}")


# ---------------------------------------------------------------- kernels
# Fixed-shape, jit-compatible, vmappable.  Each mirrors the numpy
# reference expression-for-expression; comments call out only where the
# padding changes the derivation.

def _easy_shadow_kernel(avail, need, bases, sizes, valid, now):
    P = bases.shape[0]
    inf = jnp.asarray(jnp.inf, bases.dtype)
    ends = jnp.where(valid, jnp.maximum(bases, now), inf)
    szs = jnp.where(valid, sizes, 0)
    order = jnp.lexsort((szs, ends))
    ends_s = ends[order]
    csum = avail + jnp.cumsum(szs[order])
    i = jnp.searchsorted(csum, need)
    # padded lanes keep csum at the total supply, so a crossing (if any)
    # happens at a valid lane: i < n_valid <=> the numpy i < len(csum)
    found = i < jnp.sum(valid)
    ic = jnp.clip(i, 0, P - 1)
    covered_now = avail >= need
    t = jnp.where(covered_now, jnp.asarray(now, bases.dtype),
                  jnp.where(found, ends_s[ic], inf))
    extra = jnp.where(covered_now, avail - need,
                      jnp.where(found, csum[ic] - need, 0))
    return t, extra


def _victims_kernel(sizes, overheads, valid, need):
    P = sizes.shape[0]
    szs = jnp.where(valid, sizes, 0)
    over = jnp.where(valid, overheads, jnp.asarray(jnp.inf, overheads.dtype))
    order = jnp.argsort(over, stable=True)
    csum = jnp.cumsum(szs[order])
    supply = csum[P - 1]
    cut = jnp.searchsorted(csum, need) + 1
    ok = (need > 0) & (supply >= need)
    k = jnp.where(ok, cut, 0)
    surplus = jnp.where(ok, csum[jnp.clip(cut - 1, 0, P - 1)] - need, 0)
    return order, k, surplus


def _apportion_kernel(cur, mn, valid, need):
    P = cur.shape[0]
    slack = jnp.where(valid, jnp.maximum(cur - mn, 0), 0)
    supply = jnp.sum(slack)
    ok = (supply >= need) & (need > 0)
    supply_s = jnp.where(supply > 0, supply, 1)
    # mirror the numpy overflow guard: the exact-product expression is
    # bit-identical whenever need * max(slack) fits the int dtype; the
    # wrapped product computed on the overflow branch is discarded
    max_slack = jnp.maximum(jnp.max(slack, initial=0), 1)
    imax = jnp.iinfo(slack.dtype).max
    overflow = (jnp.max(slack, initial=0) > 0) & (need > imax // max_slack)
    quota = jnp.where(overflow, need * (slack / supply_s),
                      (need * slack) / supply_s)
    base = jnp.clip(jnp.floor(quota).astype(slack.dtype), 0, slack)
    base = jnp.where(ok, base, 0)
    short0 = jnp.where(ok, need - jnp.sum(base), 0)
    neg_inf = jnp.asarray(-jnp.inf, quota.dtype)

    # largest-remainder rounds, one node per eligible job per round —
    # the same iteration the hardened numpy reference runs
    def grow(carry):
        base, short = carry
        eligible = slack > base
        frac = jnp.where(eligible, quota - base, neg_inf)
        order = jnp.argsort(-frac, stable=True)
        take = jnp.minimum(short, jnp.sum(eligible).astype(short.dtype))
        inc = (jnp.arange(P) < take).astype(base.dtype)
        return base.at[order].add(inc), short - take

    base, _ = jax.lax.while_loop(lambda c: c[1] > 0, grow, (base, short0))

    # float32 only: rounded-up quotas can overshoot (floor lands above
    # the exact float64 floor), leaving short0 < 0; retract from the
    # most over-granted jobs so the sum is exact in every dtype
    def shrink(carry):
        base, short = carry
        granted = base > 0
        frac = jnp.where(granted, quota - base, -neg_inf)
        order = jnp.argsort(frac, stable=True)
        take = jnp.minimum(-short, jnp.sum(granted).astype(short.dtype))
        dec = (jnp.arange(P) < take).astype(base.dtype)
        return base.at[order].add(-dec), short + take

    base, _ = jax.lax.while_loop(lambda c: c[1] < 0, shrink, (base, short0))
    return ok, base


def _prefilter_kernel(needs, valid, bound):
    return valid & (needs <= bound)


def _shadow_filter_kernel(needs_c, ests_c, valid, budget, now, t_shadow):
    return valid & ((needs_c <= budget) | (now + ests_c <= t_shadow))


def _sweep_program(batches):
    """The whole grid's decisions in one jitted call.

    ``batches`` is a dict keyed by kernel name whose presence/shapes are
    static (part of the pytree structure), so one call compiles to one
    XLA program evaluating every captured decision of every cell."""
    out = {}
    if "easy_shadow" in batches:
        b = batches["easy_shadow"]
        out["easy_shadow"] = jax.vmap(_easy_shadow_kernel)(
            b["avail"], b["need"], b["bases"], b["sizes"], b["valid"],
            b["now"])
    if "select_preemption_victims" in batches:
        b = batches["select_preemption_victims"]
        out["select_preemption_victims"] = jax.vmap(_victims_kernel)(
            b["sizes"], b["overheads"], b["valid"], b["need"])
    if "apportion_shrink" in batches:
        b = batches["apportion_shrink"]
        out["apportion_shrink"] = jax.vmap(_apportion_kernel)(
            b["cur"], b["mn"], b["valid"], b["need"])
    if "backfill_prefilter" in batches:
        b = batches["backfill_prefilter"]
        out["backfill_prefilter"] = jax.vmap(_prefilter_kernel)(
            b["needs"], b["valid"], b["bound"])
    if "backfill_shadow_filter" in batches:
        b = batches["backfill_shadow_filter"]
        out["backfill_shadow_filter"] = jax.vmap(_shadow_filter_kernel)(
            b["needs"], b["ests"], b["valid"], b["budget"], b["now"],
            b["t_shadow"])
    return out


_sweep_program_jit = jax.jit(_sweep_program)

# module-level jitted single-call variants: the jit cache is keyed on the
# wrapper object, so these must be created once (a fresh jax.jit per call
# would retrace every time)
_easy_shadow_jit = jax.jit(_easy_shadow_kernel)
_victims_jit = jax.jit(_victims_kernel)
_apportion_jit = jax.jit(_apportion_kernel)
_prefilter_jit = jax.jit(_prefilter_kernel)
_shadow_filter_jit = jax.jit(_shadow_filter_kernel)


# ------------------------------------------------- single-call wrappers
# Same signatures and return conventions as the numpy kernels — these
# are what the parity suite drives directly.

def _pad(arr, P, fill, fdt):
    a = np.asarray(arr, dtype=fdt)
    out = np.full(P, fill, dtype=fdt)
    out[:a.size] = a
    return out


def easy_shadow_jax(avail: int, need: int, est_end_bases, sizes, now: float,
                    dtype: str = "float64") -> Tuple[float, int]:
    fdt, idt = _dtypes(dtype)
    n = len(est_end_bases)
    P = max(n, 1)
    with kops.enable_x64(dtype == "float64"):
        t, extra = _easy_shadow_jit(
            jnp.asarray(avail, idt), jnp.asarray(need, idt),
            jnp.asarray(_pad(est_end_bases, P, np.inf, fdt)),
            jnp.asarray(_pad(sizes, P, 0, idt)),
            jnp.arange(P) < n, jnp.asarray(now, fdt))
        return float(t), int(extra)


def select_preemption_victims_jax(sizes, overheads, need: int,
                                  dtype: str = "float64"
                                  ) -> Tuple[List[int], int]:
    fdt, idt = _dtypes(dtype)
    n = len(sizes)
    P = max(n, 1)
    with kops.enable_x64(dtype == "float64"):
        order, k, surplus = _victims_jit(
            jnp.asarray(_pad(sizes, P, 0, idt)),
            jnp.asarray(_pad(overheads, P, np.inf, fdt)),
            jnp.arange(P) < n, jnp.asarray(need, idt))
        return [int(i) for i in np.asarray(order)[:int(k)]], int(surplus)


def apportion_shrink_jax(cur_sizes, min_sizes, need: int,
                         dtype: str = "float64") -> List[int]:
    fdt, idt = _dtypes(dtype)
    n = len(cur_sizes)
    P = max(n, 1)
    if need <= 0:
        return [0] * n
    with kops.enable_x64(dtype == "float64"):
        ok, base = _apportion_jit(
            jnp.asarray(_pad(cur_sizes, P, 0, idt)),
            jnp.asarray(_pad(min_sizes, P, 0, idt)),
            jnp.arange(P) < n, jnp.asarray(need, idt))
        if not bool(ok):
            return []
        return [int(x) for x in np.asarray(base)[:n]]


def backfill_prefilter_jax(need_mins, supply_bound: float,
                           dtype: str = "float64") -> np.ndarray:
    fdt, _idt = _dtypes(dtype)
    n = len(need_mins)
    P = max(n, 1)
    with kops.enable_x64(dtype == "float64"):
        mask = _prefilter_jit(
            jnp.asarray(_pad(need_mins, P, np.inf, fdt)),
            jnp.arange(P) < n, jnp.asarray(supply_bound, fdt))
        return np.flatnonzero(np.asarray(mask)[:n])


def backfill_shadow_filter_jax(need_mins, est_remainings, candidates,
                               spare_budget: int, now: float,
                               t_shadow: float,
                               dtype: str = "float64") -> np.ndarray:
    fdt, idt = _dtypes(dtype)
    cand = np.asarray(candidates)
    needs_c = np.asarray(need_mins, dtype=np.float64)[cand]
    ests_c = np.asarray(est_remainings, dtype=np.float64)[cand]
    n = cand.size
    P = max(n, 1)
    with kops.enable_x64(dtype == "float64"):
        mask = _shadow_filter_jit(
            jnp.asarray(_pad(needs_c, P, np.inf, fdt)),
            jnp.asarray(_pad(ests_c, P, np.inf, fdt)),
            jnp.arange(P) < n, jnp.asarray(spare_budget, idt),
            jnp.asarray(now, fdt), jnp.asarray(t_shadow, fdt))
        return cand[np.asarray(mask)[:n]]


# --------------------------------------------- batched grid evaluation
@dataclass
class DeviceSweepReport:
    """What one batched device replay of a sweep grid proved."""

    n_cells: int
    n_calls: int
    calls_per_kernel: Dict[str, int]
    pad_per_kernel: Dict[str, int]
    n_dropped: int                      # calls beyond each cell's capture cap
    dtype: str
    parity_ok: bool
    #: (cell label, kernel, call index, expected, got) — first N only
    mismatches: List[tuple] = field(default_factory=list)
    n_mismatches: int = 0
    build_s: float = 0.0                # host-side padding/stacking
    compile_s: float = 0.0              # first program call (trace+compile)
    device_s: float = 0.0               # steady-state program execution
    n_programs: int = 1                 # always 1: the whole grid is one call

    @property
    def device_us_per_call(self) -> float:
        return 1e6 * self.device_s / max(self.n_calls, 1)

    def summary(self) -> dict:
        return {"n_cells": self.n_cells, "n_calls": self.n_calls,
                "calls_per_kernel": dict(self.calls_per_kernel),
                "pad_per_kernel": dict(self.pad_per_kernel),
                "n_dropped": self.n_dropped, "dtype": self.dtype,
                "parity_ok": self.parity_ok,
                "n_mismatches": self.n_mismatches,
                "n_programs": self.n_programs,
                "build_s": round(self.build_s, 4),
                "compile_s": round(self.compile_s, 4),
                "device_s": round(self.device_s, 6),
                "device_us_per_call": round(self.device_us_per_call, 3)}


def _build_batches(cells: Sequence[Tuple[object, DecisionTrace]],
                   dtype: str):
    """Stack every captured call of every cell into per-kernel padded
    batches.  Returns (numpy batches, per-kernel index lists of
    (cell_label, call_idx, inputs, expected_output))."""
    fdt_np = np.float64 if dtype == "float64" else np.float32
    idt_np = np.int64 if dtype == "float64" else np.int32
    index: Dict[str, list] = {k: [] for k in DecisionTrace.KERNELS}
    for label, trace in cells:
        for kernel, calls in trace.calls.items():
            for ci, (inputs, output) in enumerate(calls):
                index[kernel].append((label, ci, inputs, output))
    batches: Dict[str, Dict[str, np.ndarray]] = {}
    pads: Dict[str, int] = {}

    def stack(rows, P, fill, dt):
        out = np.full((len(rows), P), fill, dtype=dt)
        for i, r in enumerate(rows):
            a = np.asarray(r, dtype=dt)
            out[i, :a.size] = a
        return out

    def masks(lens, P):
        return np.arange(P)[None, :] < np.asarray(lens)[:, None]

    rows = index["easy_shadow"]
    if rows:
        P = max(max(len(inp[2]) for _, _, inp, _ in rows), 1)
        pads["easy_shadow"] = P
        batches["easy_shadow"] = {
            "avail": np.asarray([inp[0] for _, _, inp, _ in rows], idt_np),
            "need": np.asarray([inp[1] for _, _, inp, _ in rows], idt_np),
            "bases": stack([inp[2] for _, _, inp, _ in rows], P, np.inf,
                           fdt_np),
            "sizes": stack([inp[3] for _, _, inp, _ in rows], P, 0, idt_np),
            "valid": masks([len(inp[2]) for _, _, inp, _ in rows], P),
            "now": np.asarray([inp[4] for _, _, inp, _ in rows], fdt_np)}
    rows = index["select_preemption_victims"]
    if rows:
        P = max(max(len(inp[0]) for _, _, inp, _ in rows), 1)
        pads["select_preemption_victims"] = P
        batches["select_preemption_victims"] = {
            "sizes": stack([inp[0] for _, _, inp, _ in rows], P, 0, idt_np),
            "overheads": stack([inp[1] for _, _, inp, _ in rows], P, np.inf,
                               fdt_np),
            "valid": masks([len(inp[0]) for _, _, inp, _ in rows], P),
            "need": np.asarray([inp[2] for _, _, inp, _ in rows], idt_np)}
    rows = index["apportion_shrink"]
    if rows:
        P = max(max(len(inp[0]) for _, _, inp, _ in rows), 1)
        pads["apportion_shrink"] = P
        batches["apportion_shrink"] = {
            "cur": stack([inp[0] for _, _, inp, _ in rows], P, 0, idt_np),
            "mn": stack([inp[1] for _, _, inp, _ in rows], P, 0, idt_np),
            "valid": masks([len(inp[0]) for _, _, inp, _ in rows], P),
            "need": np.asarray([inp[2] for _, _, inp, _ in rows], idt_np)}
    rows = index["backfill_prefilter"]
    if rows:
        P = max(max(len(inp[0]) for _, _, inp, _ in rows), 1)
        pads["backfill_prefilter"] = P
        batches["backfill_prefilter"] = {
            "needs": stack([inp[0] for _, _, inp, _ in rows], P, np.inf,
                           fdt_np),
            "valid": masks([len(inp[0]) for _, _, inp, _ in rows], P),
            "bound": np.asarray([inp[1] for _, _, inp, _ in rows], fdt_np)}
    rows = index["backfill_shadow_filter"]
    if rows:
        P = max(max(len(inp[0]) for _, _, inp, _ in rows), 1)
        pads["backfill_shadow_filter"] = P
        batches["backfill_shadow_filter"] = {
            "needs": stack([inp[0] for _, _, inp, _ in rows], P, np.inf,
                           fdt_np),
            "ests": stack([inp[1] for _, _, inp, _ in rows], P, np.inf,
                          fdt_np),
            "valid": masks([len(inp[0]) for _, _, inp, _ in rows], P),
            "budget": np.asarray([inp[3] for _, _, inp, _ in rows], idt_np),
            "now": np.asarray([inp[4] for _, _, inp, _ in rows], fdt_np),
            "t_shadow": np.asarray([inp[5] for _, _, inp, _ in rows],
                                   fdt_np)}
    return batches, index, pads


def _check_parity(kernel: str, rows, outs, exact: bool) -> List[tuple]:
    """Compare one kernel's device outputs to the recorded numpy outputs.
    ``exact`` (float64) demands equality; float32 checks the documented
    tolerance/invariants instead."""
    bad = []
    if kernel == "easy_shadow":
        t_b, extra_b = (np.asarray(o) for o in outs)
        for i, (label, ci, inp, expected) in enumerate(rows):
            t, extra = float(t_b[i]), int(extra_b[i])
            et, eextra = expected
            if exact:
                ok = (t == et or (np.isinf(t) and np.isinf(et))) \
                    and extra == eextra
            else:
                ok = (np.isinf(t) and np.isinf(et)) or \
                    (np.isfinite(t) and np.isfinite(et)
                     and abs(t - et) <= FLOAT32_RTOL * max(abs(et), 1.0))
            if not ok:
                bad.append((label, kernel, ci, expected, (t, extra)))
    elif kernel == "select_preemption_victims":
        order_b, k_b, surplus_b = (np.asarray(o) for o in outs)
        for i, (label, ci, inp, expected) in enumerate(rows):
            victims = [int(x) for x in order_b[i, :int(k_b[i])]]
            got = (victims, int(surplus_b[i]))
            if exact:
                ok = got == expected
            else:
                sizes, _over, need = inp
                covered = sum(int(sizes[v]) for v in victims) - got[1]
                ok = (not victims and not expected[0]) or \
                    (bool(victims) and covered == need)
            if not ok:
                bad.append((label, kernel, ci, expected, got))
    elif kernel == "apportion_shrink":
        ok_b, base_b = (np.asarray(o) for o in outs)
        for i, (label, ci, inp, expected) in enumerate(rows):
            cur, mn, need = inp
            n = len(cur)
            if need <= 0:
                got: List[int] = [0] * n
            elif not bool(ok_b[i]):
                got = []
            else:
                got = [int(x) for x in base_b[i, :n]]
            if exact:
                ok = got == expected
            else:
                slack = np.maximum(np.asarray(cur) - np.asarray(mn), 0)
                ok = (got == [] and expected == []) or \
                    (sum(got) == (need if need > 0 else 0)
                     and all(0 <= g <= s for g, s in zip(got, slack)))
            if not ok:
                bad.append((label, kernel, ci, expected, got))
    elif kernel == "backfill_prefilter":
        mask_b = np.asarray(outs)
        for i, (label, ci, inp, expected) in enumerate(rows):
            n = len(inp[0])
            got = np.flatnonzero(mask_b[i, :n])
            if not np.array_equal(got, expected):
                bad.append((label, kernel, ci, expected.tolist(),
                            got.tolist()))
    elif kernel == "backfill_shadow_filter":
        mask_b = np.asarray(outs)
        for i, (label, ci, inp, expected) in enumerate(rows):
            cand = inp[2]
            got = np.asarray(cand)[mask_b[i, :len(cand)]]
            if not np.array_equal(got, expected):
                bad.append((label, kernel, ci, expected.tolist(),
                            got.tolist()))
    return bad


def run_device_sweep(cells: Sequence[Tuple[object, DecisionTrace]],
                     dtype: str = "float64",
                     max_mismatches: int = 20,
                     repeats: int = 3) -> DeviceSweepReport:
    """Replay every cell's captured decision stream as ONE device program
    and parity-check it against the recorded numpy outputs.

    ``cells`` is a sequence of (label, DecisionTrace); the float64 mode
    demands exact equality (the sweep gate), float32 checks the
    documented tolerance.  ``repeats`` re-runs the compiled program and
    keeps the fastest execution for ``device_s``.
    """
    _dtypes(dtype)  # validate early
    t0 = time.perf_counter()
    batches_np, index, pads = _build_batches(cells, dtype)
    n_calls = sum(len(v) for v in index.values())
    calls_per_kernel = {k: len(v) for k, v in index.items() if v}
    n_dropped = sum(sum(t.n_dropped.values()) for _, t in cells)
    build_s = time.perf_counter() - t0
    if not batches_np:
        return DeviceSweepReport(
            n_cells=len(cells), n_calls=0, calls_per_kernel={},
            pad_per_kernel={}, n_dropped=n_dropped, dtype=dtype,
            parity_ok=True, build_s=build_s, compile_s=0.0, device_s=0.0)
    with kops.enable_x64(dtype == "float64"):
        batches = jax.tree_util.tree_map(jnp.asarray, batches_np)
        t0 = time.perf_counter()
        outs = _sweep_program_jit(batches)
        jax.block_until_ready(outs)
        compile_s = time.perf_counter() - t0
        device_s = compile_s
        for _ in range(max(repeats - 1, 0)):
            t0 = time.perf_counter()
            outs = _sweep_program_jit(batches)
            jax.block_until_ready(outs)
            device_s = min(device_s, time.perf_counter() - t0)
        outs = jax.device_get(outs)
    mismatches: List[tuple] = []
    for kernel, rows in index.items():
        if rows:
            mismatches += _check_parity(kernel, rows, outs[kernel],
                                        exact=dtype == "float64")
    return DeviceSweepReport(
        n_cells=len(cells), n_calls=n_calls,
        calls_per_kernel=calls_per_kernel, pad_per_kernel=pads,
        n_dropped=n_dropped, dtype=dtype, parity_ok=not mismatches,
        mismatches=mismatches[:max_mismatches],
        n_mismatches=len(mismatches), build_s=build_s,
        compile_s=compile_s, device_s=device_s)
