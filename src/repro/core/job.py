"""Job model for hybrid workloads (paper §III-A).

Three job classes share one JobSpec; class-specific fields are optional.
All times are seconds (simulation clock), sizes are node counts.

Work accounting:
  * rigid:     size fixed; trace runtime t_actual already includes setup and
               regular checkpoints (the uninterrupted wall time).  Compute
               structure: [setup][tau work][delta ckpt][tau work]... so an
               uninterrupted run completes at start + t_actual, exactly as
               in the trace (baseline-faithful).
  * malleable: work = (t_actual - setup) * n_max node-seconds; runtime at
               size n is work/n + setup (linear speedup, paper §III-A).
  * on-demand: behaves like rigid w.r.t. execution, but is never preempted
               and must start instantly; may send advance notice.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Optional


class JobType(enum.Enum):
    RIGID = "rigid"
    ONDEMAND = "ondemand"
    MALLEABLE = "malleable"


class NoticeKind(enum.Enum):
    """Four on-demand categories (paper Fig. 1)."""

    NONE = "no_notice"
    ACCURATE = "accurate"
    EARLY = "arrive_early"
    LATE = "arrive_late"


@dataclass
class JobSpec:
    jid: int
    jtype: JobType
    project: str
    submit_time: float          # actual arrival on the system
    size: int                   # rigid/od: fixed n; malleable: n_max
    t_estimate: float           # user walltime estimate (kill limit)
    t_actual: float             # trace runtime at full size (incl. setup)
    t_setup: float = 0.0
    # --- malleable only ---
    n_min: int = 0
    # --- on-demand only ---
    notice_kind: NoticeKind = NoticeKind.NONE
    notice_time: Optional[float] = None      # when advance notice is received
    est_arrival: Optional[float] = None      # arrival estimate in the notice
    # --- rigid only: checkpointing ---
    ckpt_overhead: float = 0.0               # delta, s per checkpoint
    ckpt_interval: float = math.inf          # tau, s of compute per segment

    def __post_init__(self) -> None:
        if self.jtype is JobType.MALLEABLE and self.n_min <= 0:
            self.n_min = max(1, math.ceil(0.2 * self.size))
        self.t_actual = min(self.t_actual, self.t_estimate)

    # -- derived quantities ------------------------------------------------
    @property
    def n_max(self) -> int:
        return self.size

    @property
    def compute_time(self) -> float:
        """Pure compute wall time at full size (excl. setup and ckpts)."""
        t = self.t_actual - self.t_setup
        if self.jtype is JobType.RIGID and self.ckpt_interval < math.inf:
            # t = k segments of (tau + delta) + partial tau  =>  remove deltas
            full, tail = divmod(t, self.ckpt_interval + self.ckpt_overhead)
            t = full * self.ckpt_interval + min(tail, self.ckpt_interval)
        return max(t, 0.0)

    @property
    def work(self) -> float:
        """Total useful work in node-seconds."""
        return self.compute_time * self.size


@dataclass
class RunState:
    """Mutable per-execution state of a running job."""

    job: JobSpec
    start_time: float           # start of *this* execution (after resume)
    cur_size: int
    done_work: float = 0.0      # node-seconds completed before this start
    ckpt_work: float = 0.0      # node-seconds safely checkpointed (rigid)
    epoch: int = 0              # invalidates stale END events
    borrowed: dict = field(default_factory=dict)  # od_jid -> nodes borrowed
    last_resize: float = 0.0    # time of last size change (= start initially)
    work_at_resize: float = 0.0 # done_work as of last_resize
    n_starts: int = 1           # setups paid so far
    shrunk_by: dict = field(default_factory=dict)  # od_jid -> nodes lent

    def __post_init__(self) -> None:
        self.last_resize = self.start_time + self.job.t_setup
        self.work_at_resize = self.done_work

    # -- progress ----------------------------------------------------------
    def compute_elapsed(self, now: float) -> float:
        """Seconds of compute progress in the current execution at `now`."""
        return max(0.0, now - self.last_resize)

    def work_done(self, now: float) -> float:
        """Total useful node-seconds completed by `now` (this run incl.)."""
        j = self.job
        elapsed = self.compute_elapsed(now)
        if j.jtype is JobType.RIGID and j.ckpt_interval < math.inf:
            # subtract checkpoint overheads interleaved with compute
            seg = j.ckpt_interval + j.ckpt_overhead
            full, tail = divmod(elapsed, seg)
            elapsed = full * j.ckpt_interval + min(tail, j.ckpt_interval)
        return min(self.work_at_resize + elapsed * self.cur_size, j.work)

    def remaining_work(self, now: float) -> float:
        return max(0.0, self.job.work - self.work_done(now))

    def natural_end(self, now: float) -> float:
        """Wall time at which remaining work completes at current size."""
        j = self.job
        rem_compute = self.remaining_work(now) / max(self.cur_size, 1)
        if j.jtype is JobType.RIGID and j.ckpt_interval < math.inf:
            # re-add future checkpoint overheads
            done_compute = self.work_done(now) / j.size
            k_before = math.floor(done_compute / j.ckpt_interval)
            k_after = math.floor((done_compute + rem_compute) / j.ckpt_interval)
            # no checkpoint right at completion
            if (done_compute + rem_compute) % j.ckpt_interval == 0 and k_after > 0:
                k_after -= 1
            rem_compute += (k_after - k_before) * j.ckpt_overhead
        setup_left = max(0.0, self.last_resize - now)
        return now + setup_left + rem_compute

    # -- checkpoint bookkeeping (rigid) --------------------------------------
    def checkpointed_work(self, now: float) -> float:
        """Node-seconds protected by the latest completed checkpoint."""
        j = self.job
        if j.jtype is not JobType.RIGID or j.ckpt_interval >= math.inf:
            return self.ckpt_work
        elapsed = self.compute_elapsed(now)
        seg = j.ckpt_interval + j.ckpt_overhead
        k = math.floor(elapsed / seg)
        partial = elapsed - k * seg
        if partial >= j.ckpt_interval + j.ckpt_overhead:  # pragma: no cover
            k += 1
        elif partial >= j.ckpt_interval:
            pass  # checkpoint in progress, not yet complete
        run_ckpt = k * j.ckpt_interval * self.cur_size
        return max(self.ckpt_work, self.work_at_resize + run_ckpt)

    def next_ckpt_completion(self, now: float) -> Optional[float]:
        """Wall time when the next checkpoint finishes (rigid), else None."""
        j = self.job
        if j.jtype is not JobType.RIGID or j.ckpt_interval >= math.inf:
            return None
        base = self.last_resize
        elapsed = max(0.0, now - base)
        seg = j.ckpt_interval + j.ckpt_overhead
        k = math.floor(elapsed / seg)
        t_next = base + k * seg + j.ckpt_interval + j.ckpt_overhead
        if t_next <= now:
            t_next += seg
        # never past natural completion
        if t_next >= self.natural_end(now):
            return None
        return t_next

    # -- preemption cost (paper: ascending preemption overhead) -------------
    def preemption_overhead(self, now: float) -> float:
        """Node-seconds wasted if preempted at `now`.

        malleable: 2-min-warning checkpoint => only a future setup is lost.
        rigid:     future setup + work since the last completed checkpoint.
        """
        j = self.job
        setup_cost = j.t_setup * j.size
        if j.jtype is JobType.MALLEABLE:
            return setup_cost
        lost = self.work_done(now) - self.checkpointed_work(now)
        return setup_cost + max(0.0, lost)
