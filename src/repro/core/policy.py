"""Pluggable scheduling-policy API (paper §III-B, generalized).

The paper's six mechanisms are {notice} x {arrival} strategy pairs; this
module turns each axis into a small protocol class so new strategies are
*data* (registry entries) rather than forks of the event loop:

    NoticePolicy      what happens when an on-demand job sends advance
                      notice: reserve idle nodes, collect releases, plan
                      preemptions against the estimated arrival (N/CUA/CUP)
    ArrivalPolicy     how an *arrived* on-demand job acquires the nodes it
                      is short of: preemption orderings, shrink apportioning
                      (PAA/SPAA, plus third-party algorithms such as
                      STEAL/POOL from the Wagomu malleable-scheduling work)
    QueuePolicy       ordering of the wait queue and the backfill pass
                      (FCFS + EASY backfilling by default)
    ElasticityPolicy  when running malleable jobs absorb vacated or idle
                      nodes and expand back toward n_max (the paper's
                      malleability incentive); the seed behavior expands
                      only via lease repayment

Policies act through two layered handles:

    SchedulerView     read-only window onto simulator state (clock, ledger
                      pools, queue, running set, estimates)
    SchedulerOps      the view plus the small set of mutation primitives a
                      policy may invoke (preempt, shrink, expand, start,
                      reserve, push_event)

A string-keyed registry maps policy names and mechanism strings to policy
objects.  Legacy strings ("BASE", "CUA&SPAA", ...) resolve to bundles that
reproduce the pre-refactor simulator bit-for-bit; any "<notice>&<arrival>"
combination of registered policies (e.g. "CUA&STEAL") resolves without
touching the core.

Registering a custom policy::

    from repro.core.job import JobType
    from repro.core.policy import ArrivalPolicy, register_policy

    @register_policy("arrival", "GREEDY")
    class GreedyArrival(ArrivalPolicy):
        def acquire(self, ops, jid, need):
            for rid, rs in list(ops.running.items()):
                if need <= 0:
                    break
                if rs.job.jtype is JobType.ONDEMAND:
                    continue        # on-demand jobs are never preempted
                need -= rs.cur_size
                ops.preempt(rid, beneficiary=jid)
            if ops.reserved_of(jid) + ops.free < ops.jobs[jid].size:
                return False        # demand unmet: job queues at the front
            ops.start_od(jid)
            return True

    # SimConfig(mechanism="CUA&GREEDY") now works everywhere.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Dict, Optional, Tuple

import numpy as np

from .job import JobType

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .structures import OrderedSet, WaitQueue

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from .job import JobSpec, RunState
    from .simulator import Simulator

# Legacy mechanism axes (paper §III-B); kept as public constants.
NOTICE_POLICIES = ("N", "CUA", "CUP")
ARRIVAL_POLICIES = ("PAA", "SPAA")
MECHANISMS = tuple(f"{n}&{a}" for n in NOTICE_POLICIES for a in ARRIVAL_POLICIES)


# --------------------------------------------------------------- state views
class SchedulerView:
    """Read-only window onto a running :class:`Simulator`.

    Exposes exactly the state a scheduling decision may consult; mutating
    the returned containers is not supported.  Stable containers and query
    methods are bound once at construction (the simulator mutates them in
    place), so policy hot loops pay no delegation frames:

        jobs           jid -> JobSpec for every job in the trace
        running        jid -> RunState of running jobs
        queue          waiting jids (a WaitQueue, kept in order-key order;
                       supports the legacy list surface: indexing, slices,
                       iteration, ``in``, ``len``)
        collecting     od jids collecting node releases, notice order
        od_status      od jid -> "noticed"|"arrived"|"timeout"|"done"
        est_remaining  jid -> current user-estimate of remaining runtime
        od_front_map   od jid -> True while pinned to the queue front
        ledger         the NodeLedger (read-only: never call its mutators)
        cfg            the SimConfig
        reserved_of(od) / hold_of(jid)    idle-pool sizes per job
        avail_for(jid)    nodes the job could start on now (free+hold+own)
        borrowable(jid)   idle reserved nodes the job may borrow (§III-B1)
        borrow_pool()     the borrow supply as (pool, earliest owner
                          arrival); borrow_eligible(jid, deadline) is the
                          per-job §III-B1 rule — together they are
                          borrowable(), hoistable to once per pass
        est_end(rs)       estimated end used by EASY/CUP (user estimate)
        est_end_arrays()  (est-end bases, sizes) numpy arrays over the
                          running set, maintained incrementally — feed
                          them to decision.easy_shadow

    `now` and `free` change every event and are properties, as are the
    fault-axis counters `down` / `draining` and the active `fault_model`
    name (repro.faults; all zero/"none" on a perfect machine).

    **Round-awareness contract** (``SimConfig.batch_rounds``, exposed as
    :attr:`batch_rounds`): with batch scheduling rounds enabled the
    simulator calls queue/elasticity hooks once per round boundary, not
    once per event — everything that arrived, completed, or sent notice
    since the previous pass is visible *at once*.  Policies that honor
    the contract need no changes; concretely they must

    * read the clock from ``view.now`` at the pass (it is the round
      boundary, by construction >= every batched event's time), never
      cache it across passes;
    * treat the queue as a set that may have grown by many jobs since
      the last pass (the builtin EASY backfill already scans a
      ``backfill_depth`` window per pass, so its per-pass cost was
      always O(window), not O(events));
    * accept that ``order_keys_stable`` caching spans rounds exactly as
      it spans events — invalidation points are unchanged;
    * never assume a pass follows each arrival: only *on-demand*
      arrivals force an immediate pass (the Obs-10 path); batch-job
      starts may be up to one round stale.  Notice/arrival policies are
      NOT round-deferred — ``on_notice`` and ``acquire`` stay per-event.
    """

    def __init__(self, sim: "Simulator"):
        self._sim = sim
        self.cfg = sim.cfg
        self.jobs: Dict[int, "JobSpec"] = sim.jobs
        self.running: Dict[int, "RunState"] = sim.running
        self.queue: "WaitQueue" = sim.queue
        self.collecting: "OrderedSet" = sim.collecting
        self.od_status: Dict[int, str] = sim.od_status
        self.est_remaining: Dict[int, float] = sim.est_remaining
        self.od_front_map: Dict[int, bool] = sim.od_front
        self.ledger = sim.ledger             # read-only by convention
        self.reserved_of = sim.ledger.reserved_of
        self.hold_of = sim.ledger.hold_of
        self.avail_for = sim._avail_for
        self.borrowable = sim._borrowable
        self.borrow_pool = sim._borrow_pool
        self.borrow_eligible = sim._borrow_eligible
        self.est_end = sim._est_end

    @property
    def now(self) -> float:
        return self._sim.now

    @property
    def free(self) -> int:
        return self._sim.ledger.free

    @property
    def down(self) -> int:
        """Failed nodes awaiting repair (repro.faults)."""
        return self._sim.ledger.down

    @property
    def draining(self) -> int:
        """Quarantined nodes (service launch-failure handling)."""
        return self._sim.ledger.draining

    @property
    def fault_model(self) -> str:
        """Active fault-model name; "none" on a perfect machine."""
        return self._sim.fault_model_name

    @property
    def batch_rounds(self) -> float:
        """Scheduling-round interval in seconds; 0 on the per-event
        engine.  A policy may use it as its staleness bound: queue state
        observed during a pass is at most this many sim-seconds old
        (see the round-awareness contract in the class docstring)."""
        return self._sim.cfg.batch_rounds

    def od_front(self, jid: int) -> bool:
        return bool(self.od_front_map.get(jid))

    def est_end_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-running-job (est-end base, cur_size) arrays for the EASY
        shadow kernel.  The bases are the un-clamped ``est_end`` values
        (clamp to ``now`` is part of :func:`~repro.core.decision.easy_shadow`),
        cached by the simulator at the END-reschedule events where they
        can change, so no per-job ``est_end()`` recomputation happens
        here — only an O(running) materialization of the cache into the
        two arrays (the running set is machine-bounded and small)."""
        cache = self._sim._estend_cache
        if not cache:
            return (np.empty(0, dtype=np.float64),
                    np.empty(0, dtype=np.int64))
        bases, sizes = zip(*cache.values())
        return (np.asarray(bases, dtype=np.float64),
                np.asarray(sizes, dtype=np.int64))


class SchedulerOps(SchedulerView):
    """A :class:`SchedulerView` plus the mutation primitives policies use.

    Every mutator is a simulator primitive that keeps the node ledger,
    lease book, and event heap consistent — policies decide *what* to do,
    never touch accounting directly:

        push_event(t, kind, data)      schedule a simulator event
        invalidate_order_key(jid)      recompute a queued job's cached
                                       order key (incremental queues;
                                       no-op for non-members)
        reserve_from_free(od, want)    move free nodes into od's reservation
        collect(od)                    enroll od to collect future releases
        preempt(jid, beneficiary=od)   vacate a running job; nodes route to
                                       the beneficiary's reservation
        shrink(jid, k, od)             shed k malleable nodes into od's
                                       reservation (creates a lease)
        expand_occupied(jid, k)        grow a malleable by k vacated nodes
        expand_from_free(jid, k)       grow a malleable from the free pool
        start_od(jid)                  launch an arrived on-demand job
        start_backfilled(jid, size, borrow)
                                       launch a batch job out of FCFS order,
                                       `borrow` of it on idle reservations
    """

    def __init__(self, sim: "Simulator"):
        super().__init__(sim)
        self.push_event = sim._push
        self.invalidate_order_key = sim.queue.invalidate
        self.reserve_from_free = sim.ledger.reserve_from_free
        self.expand_occupied = sim._expand
        self.expand_from_free = sim._expand_from_free
        self.start_od = sim._start_od
        self.start_backfilled = sim._start_backfilled

    def collect(self, od: int) -> None:
        """Enroll an on-demand job to collect future node releases."""
        if od not in self.collecting:
            self.collecting.append(od)

    def preempt(self, jid: int, beneficiary: Optional[int] = None) -> None:
        """Vacate a running batch job.  On-demand jobs are never preempted
        (paper §III-B): the ledger mechanics assume an od restarts from its
        reservation + free pool only, so this guard turns a policy bug that
        would corrupt accounting much later into an immediate error."""
        if self.jobs[jid].jtype is JobType.ONDEMAND:
            raise ValueError(f"policy tried to preempt on-demand job {jid}; "
                             "on-demand jobs are never preempted")
        self._sim._preempt(jid, beneficiary=beneficiary)

    def shrink(self, jid: int, k: int, od: int) -> None:
        """Shed k nodes from a running *malleable* into od's reservation."""
        if self.jobs[jid].jtype is not JobType.MALLEABLE:
            raise ValueError(f"policy tried to shrink non-malleable job {jid}")
        self._sim._shrink(jid, k, od)


# ------------------------------------------------------------ policy protocols
class Policy:
    """Base for all policy kinds; `kind`/`name` are set by the registry."""

    kind: str = "?"
    name: str = "?"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.kind}:{self.name}>"


class NoticePolicy(Policy):
    """Reaction to an on-demand job's advance notice (paper §III-B2)."""

    kind = "notice"

    def on_notice(self, ops: SchedulerOps, jid: int) -> None:
        raise NotImplementedError


class ArrivalPolicy(Policy):
    """Node acquisition for an arrived on-demand job that is `need` short.

    `acquire` must either start the job (via `ops.start_od`) and return
    True, or return False — the simulator then queues the job at the front
    where it collects every release until its demand is met.
    """

    kind = "arrival"
    #: elasticity policy a "<notice>&<arrival>" mechanism string pairs with
    preferred_elasticity: str = "NONE"

    def acquire(self, ops: SchedulerOps, jid: int, need: int) -> bool:
        raise NotImplementedError


class QueuePolicy(Policy):
    """Wait-queue ordering and the backfill pass behind a blocked head."""

    kind = "queue"

    #: Incremental-queue contract (docs/performance.md): True promises a
    #: queued job's order key is constant except at requeue and at the
    #: explicitly announced invalidation points (the simulator's od-front
    #: pinning; a custom policy's ``ops.invalidate_order_key`` calls), so
    #: the simulator may cache keys and keep the queue sorted in O(log n)
    #: per operation.  Set False for keys that read clock- or load-
    #: dependent state — the queue then re-sorts with fresh keys every
    #: scheduling pass (the legacy O(n log n) behavior).
    order_keys_stable: bool = True

    def order_key(self, view: SchedulerView, jid: int):
        raise NotImplementedError

    def make_order_key(self, view: SchedulerView) -> Callable[[int], tuple]:
        """Build the order-key callable the wait queue caches per member
        (or, for ``order_keys_stable = False`` policies, calls afresh on
        every pass).

        The default wraps :meth:`order_key`; hot-path policies may return
        a specialized closure instead.
        """
        return lambda jid: self.order_key(view, jid)

    def backfill(self, ops: SchedulerOps, head: int) -> None:
        raise NotImplementedError


class ElasticityPolicy(Policy):
    """When running malleable jobs expand back toward n_max.

    Lease repayment (a shrunk lender reclaiming its nodes when the
    on-demand borrower completes, paper §III-B3) is core mechanics and
    always happens; these hooks add *extra* expansion opportunities.
    """

    kind = "elasticity"

    def absorb_release(self, ops: SchedulerOps, k: int) -> int:
        """Offered k vacated nodes nobody is waiting for; expand running
        malleables into them and return the leftover count."""
        return k

    def on_idle(self, ops: SchedulerOps) -> None:
        """Called after a scheduling pass; may expand malleables into the
        free pool when no job is waiting."""


# ------------------------------------------------------------------ registry
class UnknownPolicyError(ValueError):
    """A mechanism or policy name that is not in the registry.

    ValueError subclass for backward compatibility; Experiment relies on
    the distinct type to tell registry misses in spawn-start workers apart
    from genuine simulation errors."""


_REGISTRY: Dict[str, Dict[str, type]] = {
    "notice": {}, "arrival": {}, "queue": {}, "elasticity": {},
}
_MECHANISM_FACTORIES: Dict[str, Callable[[QueuePolicy], "PolicyBundle"]] = {}


def register_policy(kind: str, name: str):
    """Class decorator: `@register_policy("arrival", "STEAL")`."""
    if kind not in _REGISTRY:
        raise ValueError(f"unknown policy kind {kind!r}; "
                         f"one of {sorted(_REGISTRY)}")

    def deco(cls):
        cls.kind, cls.name = kind, name
        _REGISTRY[kind][name] = cls
        return cls
    return deco


def get_policy(kind: str, name: str) -> Policy:
    """Instantiate a registered policy by kind and name."""
    _ensure_builtins()
    try:
        return _REGISTRY[kind][name]()
    except KeyError:
        raise UnknownPolicyError(
            f"unknown {kind} policy {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY[kind]))}") from None


def registered_policies(kind: str) -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY[kind]))


def register_mechanism(name: str,
                       factory: Callable[[QueuePolicy], "PolicyBundle"]):
    """Map a mechanism string to a bundle factory (takes the queue policy)."""
    _MECHANISM_FACTORIES[name] = factory
    return factory


def registered_mechanisms() -> Tuple[str, ...]:
    """Every resolvable mechanism string: explicit registrations plus all
    <notice>&<arrival> combinations of registered policies."""
    _ensure_builtins()
    combos = {f"{n}&{a}" for n in _REGISTRY["notice"]
              for a in _REGISTRY["arrival"]}
    return tuple(sorted(combos | set(_MECHANISM_FACTORIES)))


@dataclass
class PolicyBundle:
    """The four policies one simulation runs with."""

    notice: NoticePolicy
    arrival: ArrivalPolicy
    queue: QueuePolicy
    elasticity: ElasticityPolicy
    #: False for "BASE": on-demand jobs are plain batch jobs (no notice
    #: handling, no instant-start arrival path).
    od_aware: bool = True


def resolve_mechanism(name: str, queue_policy: str = "EASY") -> PolicyBundle:
    """Resolve a mechanism string to a :class:`PolicyBundle`.

    Explicit registrations ("BASE") win; otherwise "<notice>&<arrival>"
    is parsed against the policy registry, pairing the arrival policy's
    preferred elasticity.  Raises ValueError naming every registered
    mechanism when the string resolves to nothing.
    """
    _ensure_builtins()
    queue = get_policy("queue", queue_policy)
    factory = _MECHANISM_FACTORIES.get(name)
    if factory is not None:
        return factory(queue)
    if "&" in name:
        n_name, a_name = name.split("&", 1)
        if n_name in _REGISTRY["notice"] and a_name in _REGISTRY["arrival"]:
            arrival = _REGISTRY["arrival"][a_name]()
            elasticity = get_policy("elasticity", arrival.preferred_elasticity)
            return PolicyBundle(notice=_REGISTRY["notice"][n_name](),
                                arrival=arrival, queue=queue,
                                elasticity=elasticity)
    raise UnknownPolicyError(
        f"unknown mechanism {name!r}; registered mechanisms: "
        f"{', '.join(registered_mechanisms())}")


def _ensure_builtins() -> None:
    """Import the builtin policy package exactly once (registration side
    effect); deferred to avoid a circular import at module load."""
    from . import policies  # noqa: F401
