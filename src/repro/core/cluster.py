"""Node accounting for the hybrid-workload cluster.

The ledger tracks six disjoint pools whose sizes always sum to N:

  free                 idle, unreserved
  od_reserved[od]      idle, reserved for a noticed on-demand job (CUA/CUP)
  job_hold[jid]        idle, returned-lease nodes held for a preempted job
  running occupancy    sum of cur_size over running jobs
  down                 failed, awaiting repair (fault injection, repro.faults)
  draining             quarantined by the service after persistent launch
                       failures; never scheduled until an operator undrains

Reserved nodes may be *borrowed* by backfilled jobs (paper §III-B1): the
borrowed count moves from od_reserved into running occupancy and is tracked
on the borrower so it can be preempted "immediately" at od arrival.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass
class NodeLedger:
    total: int
    free: int = -1
    od_reserved: Dict[int, int] = field(default_factory=dict)
    job_hold: Dict[int, int] = field(default_factory=dict)
    occupied: int = 0
    down: int = 0
    draining: int = 0

    def __post_init__(self) -> None:
        if self.free < 0:
            self.free = self.total

    # -- invariant ----------------------------------------------------------
    def check(self) -> None:
        s = (self.free + sum(self.od_reserved.values())
             + sum(self.job_hold.values()) + self.occupied
             + self.down + self.draining)
        assert s == self.total, (
            f"node leak: free={self.free} od_res={self.od_reserved} "
            f"hold={self.job_hold} occ={self.occupied} down={self.down} "
            f"draining={self.draining} != {self.total}")
        assert self.free >= 0
        assert self.down >= 0 and self.draining >= 0
        assert all(v >= 0 for v in self.od_reserved.values())
        assert all(v >= 0 for v in self.job_hold.values())

    @property
    def up(self) -> int:
        """Nodes currently part of the schedulable machine."""
        return self.total - self.down - self.draining

    # -- reservations ---------------------------------------------------------
    def reserve_from_free(self, od: int, want: int) -> int:
        """Move up to `want` free nodes into od's reservation."""
        k = min(want, self.free)
        if k > 0:
            self.free -= k
            self.od_reserved[od] = self.od_reserved.get(od, 0) + k
        return k

    def release_reservation(self, od: int) -> int:
        """Return od's idle reserved nodes to the free pool."""
        k = self.od_reserved.pop(od, 0)
        self.free += k
        return k

    def reserved_of(self, od: int) -> int:
        return self.od_reserved.get(od, 0)

    # -- job holds (returned leases for preempted jobs) ----------------------
    def add_hold(self, jid: int, k: int) -> None:
        if k > 0:
            self.job_hold[jid] = self.job_hold.get(jid, 0) + k

    def take_hold(self, jid: int) -> int:
        return self.job_hold.pop(jid, 0)

    def hold_of(self, jid: int) -> int:
        return self.job_hold.get(jid, 0)

    def hold_to_free(self, jid: int, k: int) -> None:
        """Move k of jid's held nodes into the free pool (the queue-head
        hold steal, paper deadlock resolution)."""
        have = self.job_hold[jid]
        assert 0 < k <= have
        if k == have:
            del self.job_hold[jid]
        else:
            self.job_hold[jid] = have - k
        self.free += k

    # -- allocation ----------------------------------------------------------
    def allocate(self, size: int, *, from_free: int = 0, od: int = None,
                 from_reserved: int = 0, from_hold: int = 0,
                 hold_jid: int = None) -> None:
        """Move nodes into running occupancy from the stated pools."""
        assert from_free + from_reserved + from_hold == size
        assert from_free <= self.free
        self.free -= from_free
        if from_reserved:
            assert od is not None and self.od_reserved.get(od, 0) >= from_reserved
            self.od_reserved[od] -= from_reserved
            if self.od_reserved[od] == 0:
                del self.od_reserved[od]
        if from_hold:
            assert hold_jid is not None
            have = self.job_hold.get(hold_jid, 0)
            assert have >= from_hold
            self.job_hold[hold_jid] = have - from_hold
            if self.job_hold[hold_jid] == 0:
                del self.job_hold[hold_jid]
        self.occupied += size

    def free_nodes(self, k: int) -> None:
        """Running job returns k nodes to the free pool."""
        assert k <= self.occupied
        self.occupied -= k
        self.free += k

    def occupied_to_reserved(self, od: int, k: int) -> None:
        """Nodes vacated by preemption/shrink go straight to od's reservation."""
        assert k <= self.occupied
        self.occupied -= k
        self.od_reserved[od] = self.od_reserved.get(od, 0) + k

    def occupied_to_hold(self, jid: int, k: int) -> None:
        assert k <= self.occupied
        self.occupied -= k
        self.add_hold(jid, k)

    # -- failure / repair / quarantine (repro.faults, service hardening) -----
    def fail_free(self) -> None:
        assert self.free > 0
        self.free -= 1
        self.down += 1

    def fail_reserved(self, od: int) -> None:
        have = self.od_reserved[od]
        assert have > 0
        if have == 1:
            del self.od_reserved[od]
        else:
            self.od_reserved[od] = have - 1
        self.down += 1

    def fail_hold(self, jid: int) -> None:
        have = self.job_hold[jid]
        assert have > 0
        if have == 1:
            del self.job_hold[jid]
        else:
            self.job_hold[jid] = have - 1
        self.down += 1

    def fail_occupied(self) -> None:
        assert self.occupied > 0
        self.occupied -= 1
        self.down += 1

    def repair(self) -> None:
        """A downed node comes back; it re-enters the free pool (the
        simulator routes it onward like any release)."""
        assert self.down > 0
        self.down -= 1
        self.free += 1

    def drain_free(self) -> None:
        """Quarantine one idle node (service launch-failure handling)."""
        assert self.free > 0
        self.free -= 1
        self.draining += 1

    def undrain(self) -> None:
        assert self.draining > 0
        self.draining -= 1
        self.free += 1


@dataclass
class Lease:
    """Nodes an on-demand job borrowed from a lender (paper §III-B3)."""

    lender: int
    nodes: int
    kind: str  # "preempt" | "shrink"


LeaseBook = Dict[int, List[Lease]]


def utilization_integral() -> Tuple[float, float]:  # pragma: no cover
    raise NotImplementedError("tracked by the simulator's metrics module")
