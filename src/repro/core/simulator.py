"""Event-driven hybrid-workload scheduling simulator (CQSim-equivalent).

The simulator owns *mechanics* — the event heap, the node ledger, lease
bookkeeping, run/end lifecycle — and delegates every scheduling *decision*
to a :class:`~repro.core.policy.PolicyBundle` resolved from
``SimConfig.mechanism``:

    notice      what to do at advance notice   (N / CUA / CUP, ...)
    arrival     node acquisition at od arrival (PAA / SPAA / STEAL / POOL, ...)
    queue       wait-queue order + backfill    (EASY / FCFS, ...)
    elasticity  malleable expand-back          (NONE / BALANCE, ...)

Legacy strings ("BASE", "CUA&SPAA", ...) reproduce the paper's six
mechanisms bit-for-bit; any registered "<notice>&<arrival>" combination
(e.g. "CUA&STEAL") runs without touching this file.  The lifecycle rules
are the paper's: lease return at on-demand completion and reservation
release 10 min after a no-show's estimated arrival; waiting jobs are
FCFS + EASY backfilled, and reserved nodes may host backfilled jobs that
are preempted the instant the on-demand job arrives (paper §III-B1).

With mechanism="BASE" every job is a plain batch job under FCFS/EASY
(paper Table II).
"""
from __future__ import annotations

import heapq
import itertools
import math
import time as _walltime
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .cluster import Lease, NodeLedger
from .job import JobSpec, JobType, NoticeKind, RunState
from .policy import (ARRIVAL_POLICIES, MECHANISMS, NOTICE_POLICIES,
                     PolicyBundle, SchedulerOps, resolve_mechanism)
from .sketches import P2Quantile
from .structures import OrderedSet, WaitQueue


@dataclass
class SimConfig:
    n_nodes: int
    mechanism: str = "CUA&SPAA"          # "BASE" or any registered mechanism
    release_threshold: float = 600.0      # release reservation 10 min past est
    malleable_warning: float = 120.0      # Amazon-style 2-min warning
    backfill_depth: int = 100
    allow_reserved_backfill: bool = True
    instant_eps: float = 1.0              # wait <= eps counts as instant start
    track_decision_time: bool = False
    queue_policy: str = "EASY"            # registered QueuePolicy name
    #: streaming ingestion horizon (s): when jobs arrive as an iterator,
    #: every job with submit_time within this window of the next event is
    #: ingested before the event runs, so advance-notice events (which
    #: precede their job's arrival by the notice lead) are never pushed
    #: into the past.  Must exceed the workload's largest notice lead +
    #: late window; the default covers the paper's minutes-scale leads
    #: with a wide margin while keeping the ingested-ahead set small.
    arrival_lookahead: float = 14400.0
    #: fault-model spec (repro.faults): "none" (default, bit-for-bit
    #: legacy), a compact string like "exp-mtbf:mtbf_h=168,mttr_h=2",
    #: or a {"model": ..., ...params} dict.  Resolved once at
    #: construction; the failure/repair stream is materialized into the
    #: event heap up front so injection is deterministic per spec.
    faults: object = "none"
    #: batch scheduling rounds (Firmament-style): interval in seconds
    #: between scheduling passes.  0 (default) is the per-event engine —
    #: bit-for-bit the golden-tested behavior, one epilogue ``_schedule``
    #: pass per event.  > 0 accumulates events between fixed round
    #: boundaries (heap pops still advance state and fire per-type
    #: semantics — notices reserve, releases route to collectors, ENDs
    #: retire) and runs ONE deferred pass at the next multiple of
    #: ``batch_rounds``; on-demand arrivals stay immediate-path (their
    #: acquire plus an epilogue pass run at arrival, Obs-10).  Queued
    #: batch jobs trade up to one round of start staleness for
    #: order-of-magnitude fewer passes (docs/performance.md carries the
    #: measured fidelity-vs-speed curve).
    batch_rounds: float = 0.0
    #: run the node-ledger invariant scan (``NodeLedger.check``) after
    #: every event.  Formerly unconditional; the scan was ~4% of the
    #: per-event hot loop (benchmarks/bench_profile.py), so it is now a
    #: debugging aid — property/chaos tests switch it on.
    check_invariants: bool = False

    # legacy introspection helpers; composite mechanisms ("BASE") have no
    # "&" and report themselves on both axes.
    @property
    def notice_policy(self) -> str:
        return self.mechanism.split("&", 1)[0] if "&" in self.mechanism \
            else self.mechanism

    @property
    def arrival_policy(self) -> str:
        return self.mechanism.split("&", 1)[1] if "&" in self.mechanism \
            else self.mechanism


@dataclass
class JobRecord:
    job: JobSpec
    first_start: Optional[float] = None
    completion: Optional[float] = None
    killed: bool = False
    n_preempted: int = 0
    n_shrunk: int = 0
    instant: bool = False

    @property
    def turnaround(self) -> Optional[float]:
        if self.completion is None:
            return None
        return self.completion - self.job.submit_time


class Simulator:
    """One simulation run over a job trace.

    ``jobs`` is either a materialized list (the legacy path: every
    event is pushed up front, bit-for-bit the golden-tested behavior)
    or any other iterable/iterator, which is consumed *lazily*: jobs
    are ingested as the clock approaches their submit time
    (``SimConfig.arrival_lookahead``), so a year-scale trace never
    holds more than the active window of JobSpecs.

    ``record_sink`` (optional) makes completed-job state *retire*: the
    sink callable receives each finished :class:`JobRecord` exactly
    once, after which the simulator drops every per-job structure for
    that jid — ``records``/``jobs`` then hold O(active jobs), not
    O(total), and metrics must be aggregated incrementally by the sink
    (see :class:`repro.core.metrics.StreamingMetrics`).  Without a
    sink, ``records`` accumulates every job as before and
    :func:`repro.core.metrics.collect` works unchanged.
    """

    def __init__(self, cfg: SimConfig, jobs: Iterable[JobSpec],
                 record_sink: Optional[Callable[[JobRecord], None]] = None):
        self.policies: PolicyBundle = resolve_mechanism(cfg.mechanism,
                                                        cfg.queue_policy)
        self.cfg = cfg
        self.record_sink = record_sink
        self.jobs: Dict[int, JobSpec] = {}
        self.ledger = NodeLedger(cfg.n_nodes)
        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self.queue = WaitQueue()             # waiting jids, order-key sorted
        self.running: Dict[int, RunState] = {}
        self.records: Dict[int, JobRecord] = {}
        self.od_status: Dict[int, str] = {}  # noticed|arrived|timeout|done
        self.collecting = OrderedSet()       # od jids collecting releases (notice order)
        self.od_front: Dict[int, bool] = {}  # arrived ods waiting at queue front
        self.leases: Dict[int, List[Lease]] = {}
        self.progress: Dict[int, dict] = {}  # preempted-job carry-over state
        self.est_remaining: Dict[int, float] = {}
        self._epochs: Dict[int, int] = {}    # monotonic per-jid END epoch
        self._estend_cache: Dict[int, Tuple[float, int]] = {}  # jid -> (est-end base, cur_size)
        # ---- fault injection (repro.faults) -------------------------------
        # The failure/repair stream is materialized up front in its own
        # seq namespace (trace < fault < dynamic at equal times), so the
        # event order is a pure function of the spec — independent of
        # feed timing, step_until partitioning, and the job trace.
        self.fault_model_name = "none"
        self._faults_on = False
        self._down_nodes: set = set()
        self._fault_shrunk: Dict[int, int] = {}  # jid -> nodes owed back
        self.fault_downs = 0                 # node_down events applied
        self.fault_ups = 0                   # node_up events applied
        self.n_interruptions = 0             # running jobs hit by a failure
        self.fault_lost_node_s = 0.0         # node-seconds of work + setup lost
        self.avail_integral = 0.0            # ∫ up-node count dt
        # snapshot at the latest completion: the goodput denominator is
        # the up-capacity over [0, finish_time], not over the (possibly
        # much longer) fault-event horizon
        self.avail_at_completion = 0.0
        if cfg.faults not in (None, "none"):
            from ..faults import resolve_faults
            model = resolve_faults(cfg.faults)
            if model.name != "none":
                import numpy as np
                self._faults_on = True
                self.fault_model_name = model.name
                self.fault_model = model
                self._fault_rng = np.random.default_rng([model.seed, 0xD01D])
                for i, ev in enumerate(model.events(cfg.n_nodes)):
                    heapq.heappush(
                        self._heap,
                        (ev.t, self._FAULT_SEQ_BASE + i,
                         "node_" + ev.kind, (ev.node,)))
        self.ops = SchedulerOps(self)        # the handle policies act through
        self._queue_key = self.policies.queue.make_order_key(self.ops)
        self.queue.configure(self._queue_key,
                             incremental=self.policies.queue.order_keys_stable,
                             meta_fn=self._queue_meta)
        # metrics accumulators
        self.occupied_integral = 0.0
        self.waste_node_seconds = 0.0
        self._last_t = 0.0
        #: materialized decision-latency samples (legacy path).  On
        #: streaming runs (a ``record_sink`` is installed) the list
        #: would grow one float per od arrival + scheduling pass over a
        #: million-job replay, so latencies fold into a P² p99 sketch
        #: instead (the p99 is the only statistic ever consumed there —
        #: see metrics.decision_p99_ms); the list then stays empty.
        self.decision_times: List[float] = []
        self._decision_sketch: Optional[P2Quantile] = \
            P2Quantile(0.99) if (record_sink is not None
                                 and cfg.track_decision_time) else None
        self._in_schedule = False
        self._sched_pending = False
        self._sched_now = False              # od arrival: pass runs this event
        self._round_next = math.inf          # pending deferred-pass boundary
        #: per-kind bound handlers, filled lazily on first dispatch — a
        #: dict hit replaces the per-event ``getattr(self, f"_on_{kind}")``
        #: string-build + attribute walk (a profiled hot-loop frame);
        #: subclass overrides still win because binding goes through self.
        self._handlers: Dict[str, Callable[..., None]] = {}
        self.n_ingested = 0                  # jobs pulled from the trace
        self.n_retired = 0                   # records handed to the sink
        self._last_completion = 0.0

        if isinstance(jobs, list):           # legacy: all events up front
            self._arrivals = None
            self._next_arrival: Optional[JobSpec] = None
            for j in jobs:
                self._ingest(j)
        else:                                # streaming: ingest lazily
            self._arrivals = iter(jobs)
            self._next_arrival = next(self._arrivals, None)

    # ------------------------------------------------------------------ events
    # Heap ties break on a sequence number.  Trace events (submit/notice/
    # od_timeout) take (jid, slot)-derived seqs BELOW this base and
    # dynamically scheduled events (end, planned_preempt) counter-derived
    # seqs above it — the exact order the legacy constructor produced by
    # pushing every trace event up front — so lazy ingestion cannot
    # reorder simultaneous events (integer-second SWF traces collide
    # constantly) and streaming stays tie-for-tie identical to the list
    # path.  Fault events (node_down/node_up, repro.faults) sit between
    # the two: at equal times a failure lands after the trace event but
    # before any dynamically scheduled END — and their seq is the index
    # into the materialized fault stream, so it never interacts with
    # either counter.
    _DYN_SEQ_BASE = 1 << 60
    _FAULT_SEQ_BASE = 1 << 59

    def _push(self, t: float, kind: str, data: tuple) -> None:
        heapq.heappush(self._heap,
                       (t, self._DYN_SEQ_BASE + next(self._seq), kind, data))

    def _push_trace(self, t: float, jid: int, slot: int, kind: str,
                    data: tuple) -> None:
        heapq.heappush(self._heap, (t, 4 * jid + slot, kind, data))

    def _ingest(self, j: JobSpec) -> None:
        """Admit one job to the simulation: per-job state + its events."""
        if self._arrivals is not None and j.submit_time < self.now - 1e-9:
            raise ValueError(
                f"streaming arrival out of order: job {j.jid} submits at "
                f"{j.submit_time} but the clock is already at {self.now} "
                "(the arrival iterator must be submit-time sorted)")
        self.jobs[j.jid] = j
        self.records[j.jid] = JobRecord(j)
        self.est_remaining[j.jid] = j.t_estimate
        self.n_ingested += 1
        self._push_trace(j.submit_time, j.jid, 0, "submit", (j.jid,))
        if (j.jtype is JobType.ONDEMAND and j.notice_kind is not NoticeKind.NONE
                and self.policies.od_aware):
            if self._arrivals is not None \
                    and j.notice_time < self.now - 1e-9:
                raise ValueError(
                    f"job {j.jid}'s advance notice at {j.notice_time} is "
                    f"already behind the clock ({self.now}): "
                    "SimConfig.arrival_lookahead "
                    f"({self.cfg.arrival_lookahead}s) must exceed the "
                    "workload's largest notice lead + late window")
            self._push_trace(j.notice_time, j.jid, 1, "notice", (j.jid,))
            # A LATE notice drawn near t=0 can place est_arrival (and so
            # the timeout) before the simulation start, which would pop a
            # negative-time event and break clock monotonicity.  The
            # reservation cannot expire before the notice that creates it,
            # so floor the timeout there — a no-op for every trace whose
            # timeouts already fall after their notices.
            self._push_trace(max(j.est_arrival + self.cfg.release_threshold,
                                 j.notice_time),
                             j.jid, 2, "od_timeout", (j.jid,))

    def _feed(self) -> None:
        """Pull pending arrivals whose submit time falls within
        ``arrival_lookahead`` of the next event, so notice/timeout
        events that *precede* an arrival are heaped before the clock
        can pass them.  No-op on the legacy list path."""
        nxt = self._next_arrival
        if nxt is None:
            return
        # anchor on the *earlier* of next event and next arrival: a
        # far-future heap event (a fault stream's next repair during a
        # quiet spell) must not drag the whole remaining trace into
        # memory.  The very next arrival is always within lookahead of
        # itself, so due arrivals are never missed.
        base = nxt.submit_time if not self._heap \
            else min(self._heap[0][0], nxt.submit_time)
        horizon = base + self.cfg.arrival_lookahead
        while nxt is not None and nxt.submit_time <= horizon:
            self._ingest(nxt)
            nxt = next(self._arrivals, None)
        self._next_arrival = nxt

    def _advance(self, t: float) -> None:
        assert t >= self.now - 1e-9
        dt = max(0.0, t - self._last_t)
        self.occupied_integral += self.ledger.occupied * dt
        if self._faults_on:
            self.avail_integral += (self.ledger.total - self.ledger.down
                                    - self.ledger.draining) * dt
        self._last_t = t
        self.now = max(self.now, t)

    def run(self) -> Dict[int, JobRecord]:
        """Drain the event heap (``step_until(inf)`` + :meth:`finalize`).

        Handlers do not re-enter ``_schedule`` per sub-event; they raise
        ``_sched_pending`` and the loop epilogue runs one scheduling pass
        per event (handlers invoked it as their final statement, so the
        hoisted call is behaviorally identical).  With
        ``SimConfig.batch_rounds > 0`` the epilogue pass is instead
        deferred to the next round boundary (od arrivals excepted); the
        drain naturally flushes a trailing deferred pass, which may
        start queued jobs and extend the run.

        On the streaming path, each iteration first tops the heap up
        with every arrival inside the lookahead window of the next
        event; a newly ingested event earlier than the current top is
        simply popped first.
        """
        self.step_until(math.inf)
        self.finalize()
        return self.records

    def next_event_time(self) -> Optional[float]:
        """Earliest pending event time — including a deferred batch-round
        scheduling pass (``SimConfig.batch_rounds``), which is an event
        for pacing purposes — or None when the simulation is drained.
        Ingests from a streaming arrival iterator as needed to answer
        (ingestion order is the same the run loop would use, so peeking
        never perturbs the event sequence).  This is the pacing signal
        external drivers (``repro.service``) sleep against: in batch
        mode the daemon therefore sleeps to round boundaries and its
        ``step_until(next_event_time())`` cadence runs each deferred
        pass at exactly its boundary."""
        if self._next_arrival is not None:
            self._feed()
        t = self._heap[0][0] if self._heap else None
        rn = self._round_next
        if rn != math.inf:
            return rn if t is None or rn < t else t
        return t

    def step_until(self, t_limit: float) -> Optional[float]:
        """Process every event with time <= ``t_limit`` and stop.

        The incremental face of :meth:`run`: calling ``step_until`` with
        any non-decreasing sequence of limits processes the exact event
        sequence one ``run()`` would (each loop iteration depends only on
        heap state, never on how the limits partition it), which is what
        makes an external replay driver decision-for-decision identical
        to the offline simulator.  A deferred batch-round pass behaves
        like an event here: it runs only once its boundary is <= the
        limit (ties go to heap events — a pass at a boundary runs after
        every event at that time), and a still-pending boundary is
        carried to the next call, so the partitioning property holds in
        batch mode too.  Returns the next pending event (or pending
        round-pass) time > ``t_limit``, or None when drained; callers
        that passed a finite limit must eventually call :meth:`finalize`
        (or :meth:`run`) to flush retained records into a
        ``record_sink``.
        """
        heap = self._heap
        handlers = self._handlers
        batch = self.cfg.batch_rounds
        track = self.cfg.track_decision_time
        check = self.ledger.check if self.cfg.check_invariants else None
        while True:
            if self._next_arrival is not None:
                self._feed()
            if batch:
                rn = self._round_next
                if rn < (heap[0][0] if heap else math.inf):
                    # the deferred pass is due before the next event
                    if rn > t_limit:
                        break
                    self._advance(rn)
                    self._round_next = math.inf
                    if track:
                        t0 = _walltime.perf_counter()
                        self._schedule()
                        self._record_decision(_walltime.perf_counter() - t0)
                    else:
                        self._schedule()
                    if check is not None:
                        check()
                    continue
            if not heap or heap[0][0] > t_limit:
                break
            t, _, kind, data = heapq.heappop(heap)
            self._advance(t)
            h = handlers.get(kind)
            if h is None:
                h = handlers[kind] = getattr(self, f"_on_{kind}")
            h(*data)
            if self._sched_pending:
                self._sched_pending = False
                if batch and not self._sched_now:
                    # defer to the next round boundary (>= now; equal
                    # when the event lands exactly on one).  An earlier
                    # boundary may already be pending — keep it.
                    if self._round_next == math.inf:
                        self._round_next = batch * math.ceil(self.now / batch)
                elif track:
                    self._sched_now = False
                    self._round_next = math.inf  # this pass supersedes it
                    t0 = _walltime.perf_counter()
                    self._schedule()
                    self._record_decision(_walltime.perf_counter() - t0)
                else:
                    self._sched_now = False
                    self._round_next = math.inf  # this pass supersedes it
                    self._schedule()
            if check is not None:
                check()
        nxt = heap[0][0] if heap else math.inf
        if self._round_next < nxt:
            nxt = self._round_next
        return None if nxt == math.inf else nxt

    def finalize(self) -> None:
        """Flush post-run record retention; idempotent."""
        if self.record_sink is not None and self.records:
            # jobs that never reached an END (e.g. unstartable size):
            # the sink must still see every record or its n_jobs and
            # ratio denominators would diverge from collect()'s
            for jid in list(self.records):
                self._retire(jid, self.records[jid])

    # ------------------------------------------------------------- submission
    def _on_submit(self, jid: int) -> None:
        job = self.jobs[jid]
        if job.jtype is JobType.ONDEMAND and self.policies.od_aware:
            self._od_arrival(jid)
        else:
            self.queue.append(jid)
            self._sched_pending = True

    # ---------------------------------------------------------- advance notice
    def _on_notice(self, jid: int) -> None:
        if self.od_status.get(jid) is not None:
            return  # already arrived (defensive)
        self.od_status[jid] = "noticed"
        self.policies.notice.on_notice(self.ops, jid)

    def _on_planned_preempt(self, od_jid: int, victim: int, epoch: int) -> None:
        if self.od_status.get(od_jid) != "noticed":
            return  # arrived or timed out; plan void
        rs = self.running.get(victim)
        if rs is None or rs.epoch != epoch:
            return
        od = self.jobs[od_jid]
        if self.ledger.reserved_of(od_jid) >= od.size:
            return  # demand already met by collected releases
        self._preempt(victim, beneficiary=od_jid)
        self._sched_pending = True

    def _on_od_timeout(self, jid: int) -> None:
        if self.od_status.get(jid) != "noticed":
            return
        self.od_status[jid] = "timeout"
        if jid in self.collecting:
            self.collecting.remove(jid)
        self.ledger.release_reservation(jid)
        self._sched_pending = True

    # ------------------------------------------------------------- od arrival
    def _od_arrival(self, jid: int) -> None:
        job = self.jobs[jid]
        self.od_status[jid] = "arrived"
        if jid in self.collecting:
            self.collecting.remove(jid)
        t0 = _walltime.perf_counter()
        # 1. evict backfilled borrowers of this reservation immediately.
        for rid in [r for r, rs in self.running.items() if rs.borrowed.get(jid)]:
            self._preempt(rid, beneficiary=jid)
        need = job.size - self.ledger.reserved_of(jid) - self.ledger.free
        if need <= 0:
            self._start_od(jid)
            started = True
        else:
            started = self.policies.arrival.acquire(self.ops, jid, need)
        if self.cfg.track_decision_time:
            self._record_decision(_walltime.perf_counter() - t0)
        if started:
            rec = self.records[jid]
            rec.instant = (rec.first_start - job.submit_time) <= self.cfg.instant_eps
        else:
            # cannot start instantly: head of queue + collect every release.
            self.od_front[jid] = True
            self.queue.append(jid)
            if jid not in self.collecting:
                self.collecting.append(jid)
        self._sched_pending = True
        # batch mode: the od arrival's epilogue pass is never deferred to
        # the round boundary — Obs-10 responsiveness survives any round
        # length (no-op flag on the per-event engine).
        self._sched_now = True

    def _record_decision(self, dt: float) -> None:
        """One decision-latency sample: the materialized list, or the P²
        p99 sketch on streaming runs (see ``decision_times``)."""
        sketch = self._decision_sketch
        if sketch is not None:
            sketch.add(dt)
        else:
            self.decision_times.append(dt)

    def _start_od(self, jid: int) -> None:
        job = self.jobs[jid]
        res = self.ledger.reserved_of(jid)
        take_res = min(res, job.size)
        from_free = job.size - take_res
        assert from_free <= self.ledger.free
        self.ledger.allocate(job.size, from_free=from_free,
                             od=jid if take_res else None, from_reserved=take_res)
        self.ledger.release_reservation(jid)  # return any surplus reservation
        if jid in self.collecting:
            self.collecting.remove(jid)
        self._begin_run(jid, job.size)
        self.od_front.pop(jid, None)
        # front-pinning is the one builtin event that changes an order key;
        # callers dequeue before starting, so this is a documented no-op
        # kept as the pattern custom key-changing events must follow
        self.queue.invalidate(jid)

    # -------------------------------------------------- preempt / shrink / expand
    def _preempt(self, jid: int, beneficiary: Optional[int] = None,
                 lost: int = 0) -> None:
        """Vacate a running job; nodes go to `beneficiary`'s reservation.

        ``lost`` nodes (a fault killed them under the job) are not
        routed anywhere — the caller already moved them to the ledger's
        down pool, so only ``cur_size - lost`` nodes are released."""
        rs = self.running.pop(jid)
        self._estend_cache.pop(jid, None)
        if self._fault_shrunk:
            self._fault_shrunk.pop(jid, None)
        job = rs.job
        rec = self.records[jid]
        rec.n_preempted += 1
        if job.jtype is JobType.MALLEABLE:
            done = rs.work_done(self.now)   # 2-min warning checkpoint
            ckpt = done
            self.waste_node_seconds += job.t_setup * job.size
        else:
            done = rs.work_done(self.now)
            ckpt = rs.checkpointed_work(self.now)
            self.waste_node_seconds += (done - ckpt) + job.t_setup * job.size
            done = ckpt                     # recompute from last checkpoint
        self.progress[jid] = {"done_work": done, "ckpt_work": ckpt,
                              "n_starts": rs.n_starts}
        # paper: updated runtime estimate, original submit time kept.
        slack = max(1.0, job.t_estimate / max(job.t_actual, 1.0))
        rem = max(job.work - done, 0.0) / job.size
        if job.jtype is JobType.RIGID and math.isfinite(job.ckpt_interval):
            rem += math.floor(rem / job.ckpt_interval) * job.ckpt_overhead
        self.est_remaining[jid] = job.t_setup + rem * slack + 60.0
        # ---- node routing: borrowed -> owners, rest -> beneficiary/releases
        freed = rs.cur_size - lost
        for od, k in rs.borrowed.items():
            k = min(k, freed)
            if self.od_status.get(od) == "noticed":
                self.ledger.occupied_to_reserved(od, k)
            else:
                self.ledger.free_nodes(k)
            freed -= k
        if beneficiary is not None and freed > 0:
            bj = self.jobs[beneficiary]
            want = max(0, bj.size - self.ledger.reserved_of(beneficiary))
            k = min(want, freed)
            if k > 0:
                self.ledger.occupied_to_reserved(beneficiary, k)
                self._lease(beneficiary, jid, k, "preempt")
                freed -= k
        self._epochs[jid] = self._epochs.get(jid, 0) + 1  # invalidate pending END
        # re-queue before routing: the elasticity policy must see the victim
        # waiting, or absorb_release would hand its nodes to running
        # malleables (FCFS key keeps the original submit time).
        self.queue.append(jid)
        if freed > 0:
            self._route_release(freed)

    def _shrink(self, jid: int, k: int, od: int) -> None:
        rs = self.running[jid]
        assert rs.cur_size - k >= rs.job.n_min
        rs.work_at_resize = rs.work_done(self.now)
        rs.last_resize = max(self.now, rs.last_resize)
        rs.cur_size -= k
        rs.shrunk_by[od] = rs.shrunk_by.get(od, 0) + k
        self.records[jid].n_shrunk += 1
        self.ledger.occupied_to_reserved(od, k)
        self._lease(od, jid, k, "shrink")
        self._reschedule_end(jid)

    def _expand(self, jid: int, k: int) -> None:
        """Give k already-accounted (occupied) nodes back to a shrunk job."""
        rs = self.running[jid]
        grow = min(k, rs.job.n_max - rs.cur_size)
        if grow < k:  # cannot absorb everything; spill to free pool
            self.ledger.free_nodes(k - grow)
        if grow <= 0:
            return
        rs.work_at_resize = rs.work_done(self.now)
        rs.last_resize = max(self.now, rs.last_resize)
        rs.cur_size += grow
        self._reschedule_end(jid)

    def _expand_from_free(self, jid: int, k: int) -> int:
        """Grow a running malleable by up to k free-pool nodes; returns the
        number actually granted (ElasticityPolicy.on_idle uses this)."""
        rs = self.running[jid]
        k = min(k, self.ledger.free, rs.job.n_max - rs.cur_size)
        if k <= 0:
            return 0
        self.ledger.allocate(k, from_free=k)
        self._expand(jid, k)
        return k

    def _lease(self, od: int, lender: int, k: int, kind: str) -> None:
        self.leases.setdefault(od, []).append(Lease(lender, k, kind))

    # ------------------------------------------------------------ node faults
    def _on_node_down(self, node: int) -> None:
        """A node fails (repro.faults).  The count-based ledger has no
        per-node identity, so "which node died" maps to "which pool was
        hit" at the moment of failure: one draw from the fault rng,
        uniform over all in-play nodes, walked through the pools in a
        fixed order (free, od reservations, holds, running occupancy in
        insertion order).  Draws are consumed in event order, so the
        whole run is deterministic per fault spec."""
        if node in self._down_nodes:
            return  # node already out (overlapping trace entries)
        led = self.ledger
        in_play = (led.free + sum(led.od_reserved.values())
                   + sum(led.job_hold.values()) + led.occupied)
        if in_play <= 0:
            return  # machine already fully down/draining
        self._down_nodes.add(node)
        self.fault_downs += 1
        r = int(self._fault_rng.integers(in_play))
        if r < led.free:
            led.fail_free()
        else:
            r -= led.free
            hit_od = None
            for od, k in led.od_reserved.items():
                if r < k:
                    hit_od = od
                    break
                r -= k
            if hit_od is not None:
                # the reservation shrinks; its owner re-collects the
                # shortfall from later releases/repairs
                led.fail_reserved(hit_od)
            else:
                hit_hold = None
                for jid, k in led.job_hold.items():
                    if r < k:
                        hit_hold = jid
                        break
                    r -= k
                if hit_hold is not None:
                    led.fail_hold(hit_hold)
                else:
                    victim = None
                    for jid, rs in self.running.items():
                        if r < rs.cur_size:
                            victim = jid
                            break
                        r -= rs.cur_size
                    assert victim is not None, "pool walk exhausted in-play nodes"
                    self._fault_hit_running(victim)
        self._sched_pending = True

    def _fault_hit_running(self, victim: int) -> None:
        """Apply the paper's per-type semantics to the job that owned the
        failed node: malleable jobs shed it and keep running, rigid jobs
        restart from their last Daly checkpoint (§IV), on-demand jobs are
        re-dispatched with the wait clock still running."""
        rs = self.running[victim]
        job = rs.job
        self.n_interruptions += 1
        if job.jtype is JobType.MALLEABLE and rs.cur_size > max(job.n_min, 1):
            self._fault_shrink(victim)
            return
        # the job dies with the node: account the lost slice, move the
        # node out of occupancy, then route through the normal restart
        # machinery with the downed node excluded from release routing.
        done = rs.work_done(self.now)
        self.ledger.fail_occupied()
        if job.jtype is JobType.ONDEMAND and self.policies.od_aware:
            self._fault_evict_od(victim)
            return
        if job.jtype is JobType.MALLEABLE:
            ckpt = done                     # 2-min-warning checkpoint model
        else:
            ckpt = rs.checkpointed_work(self.now)
        self.fault_lost_node_s += (done - ckpt) + job.t_setup * job.size
        self._preempt(victim, lost=1)

    def _fault_shrink(self, jid: int) -> None:
        """A malleable job sheds the failed node and keeps running; the
        repair hands the node back (expand-back) ahead of the free pool."""
        rs = self.running[jid]
        rs.work_at_resize = rs.work_done(self.now)
        rs.last_resize = max(self.now, rs.last_resize)
        rs.cur_size -= 1
        self.records[jid].n_shrunk += 1
        self.ledger.fail_occupied()
        self._fault_shrunk[jid] = self._fault_shrunk.get(jid, 0) + 1
        self._reschedule_end(jid)

    def _fault_evict_od(self, jid: int) -> None:
        """Re-dispatch a fault-killed on-demand job.  On-demand jobs have
        no checkpoints, so all progress is lost; ``submit_time`` is kept
        so Obs-style responsiveness is measured *through* the failure.
        The surviving nodes become the job's own reservation and the
        arrival policy re-acquires the shortfall exactly as at a fresh
        arrival (caller already moved the downed node out of occupancy)."""
        rs = self.running.pop(jid)
        self._estend_cache.pop(jid, None)
        job = rs.job
        rec = self.records[jid]
        rec.n_preempted += 1
        done = rs.work_done(self.now)
        waste = done + job.t_setup * job.size
        self.waste_node_seconds += waste
        self.fault_lost_node_s += waste
        self.progress[jid] = {"done_work": 0.0, "ckpt_work": 0.0,
                              "n_starts": rs.n_starts}
        slack = max(1.0, job.t_estimate / max(job.t_actual, 1.0))
        self.est_remaining[jid] = job.t_setup + (job.work / job.size) * slack + 60.0
        self._epochs[jid] = self._epochs.get(jid, 0) + 1  # void pending END
        assert not rs.borrowed, "on-demand jobs never borrow"
        freed = rs.cur_size - 1
        if freed > 0:
            self.ledger.occupied_to_reserved(jid, freed)
        need = job.size - self.ledger.reserved_of(jid) - self.ledger.free
        if need <= 0:
            self._start_od(jid)
        elif not self.policies.arrival.acquire(self.ops, jid, need):
            self.od_front[jid] = True
            self.queue.append(jid)
            if jid not in self.collecting:
                self.collecting.append(jid)

    def _on_node_up(self, node: int) -> None:
        """A failed node is repaired: it re-enters service and is routed
        like a release — collecting on-demand reservations first (paper
        od priority), then expand-back for fault-shrunk malleables when
        no queued job could claim it, else the free pool for the
        scheduling pass."""
        if node not in self._down_nodes:
            return  # repair for a node that never went down (trace noise)
        self._down_nodes.remove(node)
        self.fault_ups += 1
        self.ledger.repair()
        for od in list(self.collecting):
            if self.ledger.free == 0:
                break
            job = self.jobs[od]
            want = job.size - self.ledger.reserved_of(od)
            if want > 0:
                self.ledger.reserve_from_free(od, want)
            if self.ledger.reserved_of(od) >= job.size:
                self.collecting.remove(od)
                if self.od_status.get(od) == "arrived":
                    self.queue.remove(od)
                    self._start_od(od)
        if self.ledger.free > 0 and not self.queue and self._fault_shrunk:
            for jid in list(self._fault_shrunk):
                if jid not in self.running:
                    del self._fault_shrunk[jid]
                    continue
                got = self._expand_from_free(jid, self._fault_shrunk[jid])
                if got >= self._fault_shrunk[jid]:
                    del self._fault_shrunk[jid]
                else:
                    self._fault_shrunk[jid] -= got
                if self.ledger.free == 0:
                    break
        self._sched_pending = True

    # --------------------------------------------------------------- run / end
    def _begin_run(self, jid: int, size: int) -> None:
        job = self.jobs[jid]
        carry = self.progress.pop(jid, None)
        rs = RunState(job=job, start_time=self.now, cur_size=size)
        if carry:
            rs.done_work = carry["done_work"]
            rs.ckpt_work = carry["ckpt_work"]
            rs.n_starts = carry["n_starts"] + 1
            rs.work_at_resize = rs.done_work
        self.running[jid] = rs
        rec = self.records[jid]
        if rec.first_start is None:
            rec.first_start = self.now
        self._reschedule_end(jid)

    def _est_end_base(self, rs: RunState) -> float:
        """The un-clamped estimated end; constant between _reschedule_end
        calls (est_remaining, last_resize, and cur_size only change at
        events that reschedule the END), so it is cached per running job
        for the vectorized EASY shadow window."""
        start = rs.last_resize - rs.job.t_setup
        est = self.est_remaining[rs.job.jid]
        if rs.job.jtype is JobType.MALLEABLE:
            est = rs.job.t_setup + (est - rs.job.t_setup) * rs.job.n_max / max(rs.cur_size, 1)
        return start + est

    def _est_end(self, rs: RunState) -> float:
        """Estimated end used by EASY/CUP (user estimate, not actual)."""
        return max(self._est_end_base(rs), self.now)

    def _queue_meta(self, jid: int) -> Tuple[float, float]:
        """The WaitQueue metas the vectorized backfill prefilter scans:
        (minimum nodes to start — inf for on-demand jobs, which never
        backfill —, remaining-runtime estimate).  Both are constant while
        the job waits: est_remaining changes only on preemption, which
        requeues the job and recomputes its metas."""
        job = self.jobs[jid]
        if job.jtype is JobType.ONDEMAND:
            return math.inf, self.est_remaining[jid]
        need = float(job.n_min if job.jtype is JobType.MALLEABLE else job.size)
        return need, self.est_remaining[jid]

    def _reschedule_end(self, jid: int) -> None:
        rs = self.running[jid]
        self._epochs[jid] = self._epochs.get(jid, 0) + 1
        rs.epoch = self._epochs[jid]
        base = self._est_end_base(rs)
        self._estend_cache[jid] = (base, rs.cur_size)
        natural = rs.natural_end(self.now)
        kill = max(base, self.now)
        self._push(min(natural, max(kill, self.now)), "end", (jid, rs.epoch))

    def _on_end(self, jid: int, epoch: int) -> None:
        rs = self.running.get(jid)
        if rs is None or rs.epoch != epoch:
            return
        job = rs.job
        done = rs.work_done(self.now)
        killed = done < job.work - 1e-6
        del self.running[jid]
        self._estend_cache.pop(jid, None)
        if self._fault_shrunk:
            self._fault_shrunk.pop(jid, None)
        rec = self.records[jid]
        rec.completion = self.now
        rec.killed = killed
        # vacate: borrowed -> owners, rest routed to collectors/free
        freed = rs.cur_size
        for od, k in rs.borrowed.items():
            k = min(k, freed)
            if self.od_status.get(od) == "noticed":
                self.ledger.occupied_to_reserved(od, k)
            else:
                self.ledger.free_nodes(k)
            freed -= k
        if job.jtype is JobType.ONDEMAND:
            self.od_status[jid] = "done"
            freed = self._repay_leases(jid, freed)
        if freed > 0:
            self._route_release(freed)
        self._last_completion = max(self._last_completion, self.now)
        if self._faults_on:
            self.avail_at_completion = self.avail_integral
        if self.record_sink is not None:
            self._retire(jid, rec)
        self._sched_pending = True

    def _retire(self, jid: int, rec: JobRecord) -> None:
        """Hand a finished record to the sink and drop every per-job
        structure: with a sink installed the simulator holds O(active)
        job state, not O(total).  Only reached from ``_on_end`` —
        completed jobs are never rescheduled, stale heap events for the
        jid are epoch/status-guarded, and a done/timed-out on-demand
        status reads the same as an absent one everywhere it is
        checked."""
        self.record_sink(rec)
        self.n_retired += 1
        del self.records[jid]
        del self.jobs[jid]
        del self.est_remaining[jid]
        self._epochs.pop(jid, None)
        self.progress.pop(jid, None)
        if rec.job.jtype is JobType.ONDEMAND:
            self.od_status.pop(jid, None)

    def _repay_leases(self, od: int, avail: int) -> int:
        """Return leased nodes to lenders (paper §III-B3)."""
        for lease in self.leases.pop(od, []):
            k = min(lease.nodes, avail)
            if k <= 0:
                break
            lender = lease.lender
            rs = self.running.get(lender)
            if rs is not None and lease.kind == "shrink" and rs.shrunk_by.get(od):
                give = min(k, rs.shrunk_by[od])
                rs.shrunk_by[od] -= give
                self._expand(lender, give)   # stays "occupied"
                avail -= give
                k -= give
            if k > 0 and lender in self.queue:
                self.ledger.occupied_to_hold(lender, k)
                avail -= k
            # lender finished or not expandable: nodes stay in `avail`
        return avail

    def _route_release(self, k: int) -> None:
        """Vacated occupied nodes -> collecting reservations, then the
        elasticity policy, then the free pool."""
        assert k >= 0
        for od in list(self.collecting):
            if k == 0:
                break
            job = self.jobs[od]
            want = job.size - self.ledger.reserved_of(od)
            take = min(want, k)
            if take > 0:
                self.ledger.occupied_to_reserved(od, take)
                k -= take
            if self.ledger.reserved_of(od) >= job.size:
                self.collecting.remove(od)
                if self.od_status.get(od) == "arrived":
                    # arrived od waiting at queue front: launch now
                    self.queue.remove(od)
                    self._start_od(od)
        if k > 0:
            k = self.policies.elasticity.absorb_release(self.ops, k)
        if k > 0:
            self.ledger.free_nodes(k)

    # ------------------------------------------------------------- scheduling
    def _schedule(self) -> None:
        if self._in_schedule:
            return
        self._in_schedule = True
        try:
            changed = True
            while changed:
                changed = False
                self.queue.refresh()   # incremental queues are always sorted
                if not self.queue:
                    break
                head = self.queue[0]
                if self._try_start(head):
                    changed = True
                    continue
                if self._steal_holds(head) and self._try_start(head):
                    changed = True
                    continue
                if (self.cfg.allow_reserved_backfill
                        and self.jobs[head].jtype is not JobType.ONDEMAND
                        and self._try_start_borrowed(head)):
                    changed = True
                    continue
                self.policies.queue.backfill(self.ops, head)
                break
            self.policies.elasticity.on_idle(self.ops)
        finally:
            self._in_schedule = False

    def _avail_for(self, jid: int) -> int:
        job = self.jobs[jid]
        avail = self.ledger.free + self.ledger.hold_of(jid)
        if job.jtype is JobType.ONDEMAND:
            avail += self.ledger.reserved_of(jid)
        return avail

    def _steal_holds(self, head: int) -> int:
        """Deadlock resolution: the queue head outranks returned-lease holds
        of jobs *behind* it.  Transfers just enough held nodes (youngest
        holder first) into the free pool.

        Only the hold book's few entries can contribute, so the legacy
        reversed full-queue walk reduces to sorting the queued holders by
        rank — same nodes moved in the same order, without the O(queue)
        scan per blocked head.  Returns the nodes transferred when they
        cover the shortfall, else 0: an insufficient steal cannot make
        ``_try_start`` succeed, so the caller skips that doomed retry
        (the transfers themselves stand either way, exactly as before).
        """
        job = self.jobs[head]
        need_min = job.n_min if job.jtype is JobType.MALLEABLE else job.size
        short = need_min - self._avail_for(head)
        if short <= 0:
            return 0
        hold_book = self.ledger.job_hold
        if not hold_book:
            return 0
        holders = sorted((self.queue.position(jid), jid) for jid in hold_book
                         if jid != head and jid in self.queue)
        moved = 0
        for _rank, jid in reversed(holders):
            if moved >= short:
                break
            k = min(hold_book[jid], short - moved)
            self.ledger.hold_to_free(jid, k)
            moved += k
        return moved if moved >= short else 0

    def _try_start(self, jid: int) -> bool:
        job = self.jobs[jid]
        need_min = job.n_min if job.jtype is JobType.MALLEABLE else job.size
        if self._avail_for(jid) < need_min:
            return False
        self.queue.remove(jid)
        if job.jtype is JobType.ONDEMAND:
            self._start_od(jid)
            return True
        size = job.size if job.jtype is not JobType.MALLEABLE else \
            min(job.n_max, self._avail_for(jid))
        hold = self.ledger.take_hold(jid)
        from_hold = min(hold, size)
        if from_hold:  # re-insert then consume precisely
            self.ledger.add_hold(jid, from_hold)
        if hold > from_hold:  # excess hold returns to the pool
            self.ledger.free += hold - from_hold
        self.ledger.allocate(size, from_free=size - from_hold,
                             from_hold=from_hold, hold_jid=jid if from_hold else None)
        self._begin_run(jid, size)
        return True

    def _borrow_pool(self) -> Tuple[int, float]:
        """The §III-B1 borrow supply: (idle nodes reserved for
        *not-yet-arrived* on-demand jobs, earliest estimated owner
        arrival).  The backfill pass hoists this to once per pass."""
        pool, deadline = 0, math.inf
        for od, k in self.ledger.od_reserved.items():
            if self.od_status.get(od) == "noticed":
                pool += k
                deadline = min(deadline, self.jobs[od].est_arrival or math.inf)
        return pool, deadline

    def _borrow_eligible(self, jid: int, deadline: float) -> bool:
        """Paper §III-B1 borrower rule: malleable borrowers may run past
        the owner's arrival (the 2-minute-warning preemption only costs
        setup); rigid borrowers must be estimated to finish before it
        (their preemption is expensive)."""
        job = self.jobs[jid]
        return (job.jtype is JobType.MALLEABLE
                or self.now + self.est_remaining[jid] <= deadline)

    def _borrowable(self, jid: int) -> int:
        """Idle reserved nodes this waiting job may borrow (§III-B1)."""
        pool, deadline = self._borrow_pool()
        if pool == 0:
            return 0
        return pool if self._borrow_eligible(jid, deadline) else 0

    def _try_start_borrowed(self, jid: int) -> bool:
        """Start the queue head on idle *reserved* nodes (paper §III-B1):
        such a job is a backfill in the paper's sense and is preempted the
        moment the reservation's on-demand job arrives."""
        job = self.jobs[jid]
        idle_reserved = self._borrowable(jid)
        plain = self.ledger.free + self.ledger.hold_of(jid)
        need_min = job.n_min if job.jtype is JobType.MALLEABLE else job.size
        if idle_reserved == 0 or plain + idle_reserved < need_min:
            return False
        size = job.size if job.jtype is not JobType.MALLEABLE else \
            min(job.n_max, plain + idle_reserved)
        borrow = max(0, size - plain)
        self._start_backfilled(jid, size, borrow)
        return True

    def _start_backfilled(self, jid: int, size: int, borrow: int) -> None:
        self.queue.remove(jid)
        from_hold = min(self.ledger.hold_of(jid), size - borrow)
        from_free = size - borrow - from_hold
        self.ledger.allocate(size - borrow, from_free=from_free,
                             from_hold=from_hold, hold_jid=jid if from_hold else None)
        borrowed: Dict[int, int] = {}
        left = borrow
        for od in list(self.ledger.od_reserved):
            if left == 0:
                break
            if self.od_status.get(od) != "noticed":
                continue  # never borrow from an arrived od still collecting
            k = min(self.ledger.reserved_of(od), left)
            self.ledger.allocate(k, od=od, from_reserved=k)
            borrowed[od] = borrowed.get(od, 0) + k
            left -= k
        assert left == 0
        self._begin_run(jid, size)
        self.running[jid].borrowed = borrowed

    # ---------------------------------------------------------------- results
    def finish_time(self) -> float:
        if not self.records:  # every record retired through the sink
            return self._last_completion
        return max((r.completion or 0.0) for r in self.records.values())
