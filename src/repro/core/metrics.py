"""Evaluation metrics (paper §IV-D) and streaming record summaries."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import numpy as np

from .job import JobType
from .simulator import JobRecord, Simulator


@dataclass
class Metrics:
    avg_turnaround_h: float
    avg_turnaround_rigid_h: float
    avg_turnaround_malleable_h: float
    avg_turnaround_od_h: float
    system_utilization: float
    od_instant_start_rate: float
    preemption_ratio_rigid: float
    preemption_ratio_malleable: float
    shrink_ratio_malleable: float
    n_completed: int
    n_jobs: int
    decision_p99_ms: Optional[float] = None

    def as_dict(self) -> Dict[str, float]:
        return {k: v for k, v in self.__dict__.items() if v is not None}


def _avg_turnaround(recs: List[JobRecord]) -> float:
    ts = [r.turnaround for r in recs if r.turnaround is not None]
    return float(np.mean(ts)) / 3600.0 if ts else float("nan")


def summarize_records(records: Mapping[int, JobRecord],
                      max_records: int = 256) -> dict:
    """Down-sampled per-run record summary for streaming sweeps.

    Month-scale runs produce tens of thousands of JobRecords; shipping
    them through the process-pool pipe (and holding them per finished
    run) defeats streaming aggregation.  This keeps the distribution —
    turnaround/wait percentiles over *all* records — plus an evenly
    strided sample of at most ``max_records`` compact per-job tuples
    ``(jid, jtype, turnaround_s, n_preempted, n_shrunk)`` for record-
    level inspection.
    """
    recs = list(records.values())
    turns = np.asarray([r.turnaround for r in recs
                        if r.turnaround is not None], dtype=np.float64)
    waits = np.asarray([r.first_start - r.job.submit_time for r in recs
                        if r.first_start is not None], dtype=np.float64)

    def _pcts(a: np.ndarray) -> dict:
        if a.size == 0:
            return {"p50": float("nan"), "p90": float("nan"),
                    "p99": float("nan")}
        p50, p90, p99 = np.percentile(a, (50, 90, 99))
        return {"p50": float(p50), "p90": float(p90), "p99": float(p99)}

    stride = max(1, -(-len(recs) // max_records)) if max_records > 0 else 1
    sample = [(r.job.jid, r.job.jtype.value,
               None if r.turnaround is None else round(r.turnaround, 3),
               r.n_preempted, r.n_shrunk)
              for r in recs[::stride]] if max_records > 0 else []
    return {"n_records": len(recs),
            "sample_stride": stride,
            "turnaround_s": _pcts(turns),
            "wait_s": _pcts(waits),
            "sample": sample}


def collect(sim: Simulator) -> Metrics:
    recs = list(sim.records.values())
    if not recs:
        # an empty trace (e.g. an over-filtered scenario) has no horizon:
        # every averaged metric is NaN rather than a min()-over-empty crash
        nan = float("nan")
        dec = (float(np.percentile(np.array(sim.decision_times) * 1e3, 99))
               if sim.decision_times else None)
        return Metrics(nan, nan, nan, nan, nan, nan, nan, nan, nan,
                       n_completed=0, n_jobs=0, decision_p99_ms=dec)
    by_type = {t: [r for r in recs if r.job.jtype is t] for t in JobType}
    od = by_type[JobType.ONDEMAND]
    rigid = by_type[JobType.RIGID]
    mall = by_type[JobType.MALLEABLE]

    horizon = sim.finish_time() - min(r.job.submit_time for r in recs)
    useful = sim.occupied_integral - sim.waste_node_seconds
    util = useful / (sim.cfg.n_nodes * horizon) if horizon > 0 else float("nan")

    def _instant(r: JobRecord) -> bool:
        if r.first_start is None:
            return False
        return (r.first_start - r.job.submit_time) <= sim.cfg.instant_eps

    dec = None
    if sim.decision_times:
        dec = float(np.percentile(np.array(sim.decision_times) * 1e3, 99))
    return Metrics(
        avg_turnaround_h=_avg_turnaround(recs),
        avg_turnaround_rigid_h=_avg_turnaround(rigid),
        avg_turnaround_malleable_h=_avg_turnaround(mall),
        avg_turnaround_od_h=_avg_turnaround(od),
        system_utilization=util,
        od_instant_start_rate=(float(np.mean([_instant(r) for r in od]))
                               if od else float("nan")),
        preemption_ratio_rigid=(float(np.mean([r.n_preempted > 0 for r in rigid]))
                                if rigid else float("nan")),
        preemption_ratio_malleable=(float(np.mean([r.n_preempted > 0 for r in mall]))
                                    if mall else float("nan")),
        shrink_ratio_malleable=(float(np.mean([r.n_shrunk > 0 for r in mall]))
                                if mall else float("nan")),
        n_completed=sum(r.completion is not None for r in recs),
        n_jobs=len(recs),
        decision_p99_ms=dec,
    )
