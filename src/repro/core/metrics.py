"""Evaluation metrics (paper §IV-D), streaming record summaries, and the
incremental (bounded-memory) aggregation used by year-scale replays.

Two aggregation paths produce the same :class:`Metrics` schema:

* :func:`collect` — post-hoc over ``sim.records`` (the legacy path;
  requires every JobRecord retained);
* :class:`StreamingMetrics` — a record *sink* (see
  ``Simulator(record_sink=...)``): means via Welford accumulators and
  quantiles via P² sketches, O(1) state per metric regardless of trace
  length.  Means are float-accurate to accumulation order; the
  P² quantiles are approximate (see docs/performance.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import numpy as np

from .job import JobType
from .simulator import JobRecord, Simulator


@dataclass
class Metrics:
    avg_turnaround_h: float
    avg_turnaround_rigid_h: float
    avg_turnaround_malleable_h: float
    avg_turnaround_od_h: float
    system_utilization: float
    od_instant_start_rate: float
    preemption_ratio_rigid: float
    preemption_ratio_malleable: float
    shrink_ratio_malleable: float
    n_completed: int
    n_jobs: int
    decision_p99_ms: Optional[float] = None
    # Mean bounded slowdown (BSLD, Feitelson): max(1, turnaround /
    # max(t_actual, 10s)).  Keyword-defaulted so checkpoints and golden
    # rows written before the field existed still round-trip.
    avg_bounded_slowdown: Optional[float] = None
    # Fault-axis columns (repro.faults): populated only when a fault
    # model is active, None (and dropped from as_dict) on a perfect
    # machine, so golden rows written before the axis existed — and
    # every faults="none" run — keep an unchanged schema.
    n_node_failures: Optional[int] = None        # node_down events applied
    n_interruptions: Optional[int] = None        # running jobs hit
    lost_work_node_h: Optional[float] = None     # work+setup lost to faults
    goodput: Optional[float] = None              # useful / up-capacity integral

    def as_dict(self) -> Dict[str, float]:
        return {k: v for k, v in self.__dict__.items() if v is not None}


def _avg_turnaround(recs: List[JobRecord]) -> float:
    ts = [r.turnaround for r in recs if r.turnaround is not None]
    return float(np.mean(ts)) / 3600.0 if ts else float("nan")


def bounded_slowdown(turnaround: float, t_actual: float,
                     tau: float = 10.0) -> float:
    """BSLD for one job: max(1, turnaround / max(t_actual, tau))."""
    return max(1.0, turnaround / max(t_actual, tau))


def _fault_metrics(sim: Simulator, completed_work: float) -> Dict[str, float]:
    """Fault-axis Metrics kwargs; empty (fields stay None) on a perfect
    machine.  Goodput is the node-seconds of *work that completed* over
    the up-capacity integral ∫(total - down - draining)dt — the fraction
    of the machine that actually existed which produced finished results.
    (The legacy ``occupied - waste`` utilization proxy is kept unchanged
    but can go negative under heavy restart thrash, because every
    preemption pre-charges a restart setup that a later fault may kill
    mid-setup; see docs/faults.md.)  The denominator is snapshotted at
    the last job completion, so trailing fault events beyond the
    workload's span do not dilute it."""
    if getattr(sim, "fault_model_name", "none") == "none":
        return {}
    denom = sim.avail_at_completion or sim.avail_integral
    return {
        "n_node_failures": sim.fault_downs,
        "n_interruptions": sim.n_interruptions,
        "lost_work_node_h": sim.fault_lost_node_s / 3600.0,
        "goodput": (completed_work / denom if denom > 0 else float("nan")),
    }


def records_sha256(records: Mapping[int, JobRecord]) -> str:
    """Job-for-job digest over the deterministic per-record outcome
    fields — the repeatability gate for fault-enabled cells (same
    mechanism, scenario, seed, and fault spec must reproduce it)."""
    import hashlib
    import json
    h = hashlib.sha256()
    for jid in sorted(records):
        r = records[jid]
        h.update(json.dumps(
            [jid, r.job.jtype.value, r.first_start, r.completion,
             r.killed, r.n_preempted, r.n_shrunk, r.instant]).encode())
    return h.hexdigest()


def summarize_records(records: Mapping[int, JobRecord],
                      max_records: int = 256) -> dict:
    """Down-sampled per-run record summary for streaming sweeps.

    Month-scale runs produce tens of thousands of JobRecords; shipping
    them through the process-pool pipe (and holding them per finished
    run) defeats streaming aggregation.  This keeps the distribution —
    turnaround/wait percentiles over *all* records — plus an evenly
    strided sample of at most ``max_records`` compact per-job tuples
    ``(jid, jtype, turnaround_s, n_preempted, n_shrunk)`` for record-
    level inspection.
    """
    recs = list(records.values())
    turns = np.asarray([r.turnaround for r in recs
                        if r.turnaround is not None], dtype=np.float64)
    waits = np.asarray([r.first_start - r.job.submit_time for r in recs
                        if r.first_start is not None], dtype=np.float64)

    def _pcts(a: np.ndarray) -> dict:
        if a.size == 0:
            return {"p50": float("nan"), "p90": float("nan"),
                    "p99": float("nan")}
        p50, p90, p99 = np.percentile(a, (50, 90, 99))
        return {"p50": float(p50), "p90": float(p90), "p99": float(p99)}

    stride = max(1, -(-len(recs) // max_records)) if max_records > 0 else 1
    sample = [(r.job.jid, r.job.jtype.value,
               None if r.turnaround is None else round(r.turnaround, 3),
               r.n_preempted, r.n_shrunk)
              for r in recs[::stride]] if max_records > 0 else []
    return {"n_records": len(recs),
            "sample_stride": stride,
            "turnaround_s": _pcts(turns),
            "wait_s": _pcts(waits),
            "sample": sample}


# ---------------------------------------------------- incremental primitives
# Welford and P2Quantile live in repro.core.sketches (the simulator holds
# a sketch for its streaming decision-latency p99, and metrics imports the
# simulator — the sketches must sit below both); re-exported here so
# existing ``from repro.core.metrics import P2Quantile`` imports keep
# working.
from .sketches import P2Quantile, Welford  # noqa: E402,F401


def decision_p99_ms(sim: Simulator) -> Optional[float]:
    """p99 of the tracked decision latencies, in ms, or None when none
    were recorded.  Reads whichever representation the simulator kept:
    the exact materialized list (np.percentile, the legacy output), or —
    on streaming/``record_sink`` runs, where the list would grow without
    bound — the O(1) P² sketch (approximate; the p99 is the only
    quantile ever consumed from it)."""
    sketch = getattr(sim, "_decision_sketch", None)
    if sketch is not None:
        return float(sketch.result() * 1e3) if sketch.count else None
    if sim.decision_times:
        return float(np.percentile(np.array(sim.decision_times) * 1e3, 99))
    return None


class StreamingMetrics:
    """Incremental :class:`Metrics` aggregation — the record sink for
    ``Simulator(record_sink=...)``.

    Call it with each retired :class:`JobRecord`; after ``sim.run()``,
    :meth:`result` returns the same Metrics schema :func:`collect`
    produces (means bit-comparable up to accumulation order, quantile
    summaries approximate), and :meth:`summary` the percentile summary
    ``summarize_records`` would have built — all in O(1) memory.

    ``instant_eps`` mirrors ``SimConfig.instant_eps`` (the sink cannot
    re-derive it from retired records).
    """

    def __init__(self, instant_eps: float = 1.0):
        self.instant_eps = instant_eps
        self.turn = {t: Welford() for t in JobType}
        self.turn_all = Welford()
        self.bsld = Welford()
        self.seen = {t: 0 for t in JobType}
        self.completed = 0
        self.completed_work = 0.0   # node-seconds of finished (unkilled) work
        self.od_instant = 0
        self.preempted = {t: 0 for t in JobType}
        self.shrunk_malleable = 0
        self.first_submit = float("inf")
        self.turn_q = {p: P2Quantile(p) for p in (0.50, 0.90, 0.99)}
        self.wait_q = {p: P2Quantile(p) for p in (0.50, 0.90, 0.99)}

    @property
    def n_records(self) -> int:
        return sum(self.seen.values())

    def __call__(self, rec: JobRecord) -> None:
        job = rec.job
        self.seen[job.jtype] += 1
        self.first_submit = min(self.first_submit, job.submit_time)
        if rec.completion is not None:
            self.completed += 1
            if not rec.killed:
                self.completed_work += job.work
        t = rec.turnaround
        if t is not None:
            self.turn[job.jtype].add(t)
            self.turn_all.add(t)
            self.bsld.add(bounded_slowdown(t, job.t_actual))
            for q in self.turn_q.values():
                q.add(t)
        if rec.first_start is not None:
            wait = rec.first_start - job.submit_time
            for q in self.wait_q.values():
                q.add(wait)
            if job.jtype is JobType.ONDEMAND and wait <= self.instant_eps:
                self.od_instant += 1
        if rec.n_preempted > 0:
            self.preempted[job.jtype] += 1
        if job.jtype is JobType.MALLEABLE and rec.n_shrunk > 0:
            self.shrunk_malleable += 1

    @staticmethod
    def _ratio(num: int, den: int) -> float:
        return num / den if den else float("nan")

    def result(self, sim: Simulator) -> Metrics:
        """Finalize against the finished simulator (utilization needs its
        node-seconds integrals; decision times live there too)."""
        dec = decision_p99_ms(sim)
        n = self.n_records
        if n == 0:
            nan = float("nan")
            return Metrics(nan, nan, nan, nan, nan, nan, nan, nan, nan,
                           n_completed=0, n_jobs=0, decision_p99_ms=dec)
        horizon = sim.finish_time() - self.first_submit
        useful = sim.occupied_integral - sim.waste_node_seconds
        util = useful / (sim.cfg.n_nodes * horizon) if horizon > 0 \
            else float("nan")
        return Metrics(
            avg_turnaround_h=self.turn_all.result() / 3600.0,
            avg_turnaround_rigid_h=self.turn[JobType.RIGID].result() / 3600.0,
            avg_turnaround_malleable_h=(
                self.turn[JobType.MALLEABLE].result() / 3600.0),
            avg_turnaround_od_h=self.turn[JobType.ONDEMAND].result() / 3600.0,
            system_utilization=util,
            od_instant_start_rate=self._ratio(self.od_instant,
                                              self.seen[JobType.ONDEMAND]),
            preemption_ratio_rigid=self._ratio(
                self.preempted[JobType.RIGID], self.seen[JobType.RIGID]),
            preemption_ratio_malleable=self._ratio(
                self.preempted[JobType.MALLEABLE],
                self.seen[JobType.MALLEABLE]),
            shrink_ratio_malleable=self._ratio(
                self.shrunk_malleable, self.seen[JobType.MALLEABLE]),
            n_completed=self.completed,
            n_jobs=n,
            decision_p99_ms=dec,
            avg_bounded_slowdown=self.bsld.result(),
            **_fault_metrics(sim, self.completed_work),
        )

    def summary(self) -> dict:
        """The shape of :func:`summarize_records` with sketch-backed
        percentiles and no per-job sample (those records are gone)."""
        def _pcts(qs: Dict[float, P2Quantile]) -> dict:
            return {f"p{round(p * 100)}": qs[p].result() for p in qs}
        return {"n_records": self.n_records, "sample_stride": 0,
                "turnaround_s": _pcts(self.turn_q),
                "wait_s": _pcts(self.wait_q),
                "sample": [], "approximate_quantiles": True}


def collect(sim: Simulator) -> Metrics:
    recs = list(sim.records.values())
    if not recs:
        # an empty trace (e.g. an over-filtered scenario) has no horizon:
        # every averaged metric is NaN rather than a min()-over-empty crash
        nan = float("nan")
        return Metrics(nan, nan, nan, nan, nan, nan, nan, nan, nan,
                       n_completed=0, n_jobs=0,
                       decision_p99_ms=decision_p99_ms(sim))
    by_type = {t: [r for r in recs if r.job.jtype is t] for t in JobType}
    od = by_type[JobType.ONDEMAND]
    rigid = by_type[JobType.RIGID]
    mall = by_type[JobType.MALLEABLE]

    horizon = sim.finish_time() - min(r.job.submit_time for r in recs)
    useful = sim.occupied_integral - sim.waste_node_seconds
    util = useful / (sim.cfg.n_nodes * horizon) if horizon > 0 else float("nan")

    def _instant(r: JobRecord) -> bool:
        if r.first_start is None:
            return False
        return (r.first_start - r.job.submit_time) <= sim.cfg.instant_eps

    dec = decision_p99_ms(sim)
    return Metrics(
        avg_turnaround_h=_avg_turnaround(recs),
        avg_turnaround_rigid_h=_avg_turnaround(rigid),
        avg_turnaround_malleable_h=_avg_turnaround(mall),
        avg_turnaround_od_h=_avg_turnaround(od),
        system_utilization=util,
        od_instant_start_rate=(float(np.mean([_instant(r) for r in od]))
                               if od else float("nan")),
        preemption_ratio_rigid=(float(np.mean([r.n_preempted > 0 for r in rigid]))
                                if rigid else float("nan")),
        preemption_ratio_malleable=(float(np.mean([r.n_preempted > 0 for r in mall]))
                                    if mall else float("nan")),
        shrink_ratio_malleable=(float(np.mean([r.n_shrunk > 0 for r in mall]))
                                if mall else float("nan")),
        n_completed=sum(r.completion is not None for r in recs),
        n_jobs=len(recs),
        decision_p99_ms=dec,
        avg_bounded_slowdown=(
            float(np.mean([bounded_slowdown(r.turnaround, r.job.t_actual)
                           for r in recs if r.turnaround is not None]))
            if any(r.turnaround is not None for r in recs) else float("nan")),
        **_fault_metrics(sim, sum(
            r.job.work for r in recs
            if r.completion is not None and not r.killed)),
    )
